"""Shim: paper artifact Fig 7 — implementation in repro/bench/sweeps/unit_size.py."""
import benchmarks  # noqa: F401  (src-tree fallback for bare checkouts)
from benchmarks.common import run_shim


def main():
    run_shim("unit_size")


if __name__ == "__main__":
    main()

"""Paper Fig. 7: throughput vs unit size (transaction width).

TPU analogue: random row gather with growing row bytes — the paper's claim
(throughput ~ linear in unit size until the bandwidth roof) reproduces on
both the measured CPU engine and the analytic v5e model.
"""
import jax.numpy as jnp

from benchmarks.common import FAST, emit, header
from repro.core import engines


def main():
    header("unit size sweep (paper Fig. 7)")
    units = (4, 16, 64, 256, 1024) if FAST else (4, 16, 64, 256, 1024, 4096)
    for u in units:
        r = engines.bw_random(n_rows=1 << 12, cols=max(1, u // 4),
                              n_idx=1 << 12)
        emit(f"unit_{u}B", r.wall_s * 1e6,
             gbps_measured=f"{r.gbps_measured:.3f}",
             gbps_tpu_model=f"{r.gbps_tpu_model:.3f}")
    # dtype variant of unit size (int8 vs bf16 vs f32 rows)
    for dt, tag in ((jnp.int8, "s8"), (jnp.bfloat16, "bf16"),
                    (jnp.float32, "f32")):
        r = engines.bw_sequential(rows=2048, cols=1024, dtype=dt)
        emit(f"unit_dtype_{tag}", r.wall_s * 1e6,
             gbps_measured=f"{r.gbps_measured:.3f}",
             gbps_tpu_model=f"{r.gbps_tpu_model:.3f}")


if __name__ == "__main__":
    main()

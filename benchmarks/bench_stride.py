"""Paper Figs. 8/9: throughput vs stride (Loop + Dataflow engines).

Loop analogue = XLA-fused strided traversal; Dataflow analogue = explicit
index-vector gather (address generation decoupled from access, like the
paper's FIFO-linked dataflow kernel).
"""
import jax
import jax.numpy as jnp

from benchmarks.common import FAST, emit, header, timeit
from repro.core.memmodel import predict_bw
from repro.core.patterns import Knobs, Pattern
from repro.kernels import ref


def main():
    header("stride sweep (paper Figs. 8/9)")
    rows, cols = (2048, 256) if FAST else (8192, 512)
    x = jnp.ones((rows, cols), jnp.float32)
    nbytes = x.size * 4 * 2
    for stride in (1, 2, 4, 8, 16, 32):
        # Loop engine (fused traversal)
        fn = jax.jit(lambda a, s=stride: ref.strided_copy(a, block_rows=8,
                                                          stride=s))
        wall = timeit(fn, x)
        # Dataflow engine (explicit address vector -> gather)
        idx = (jnp.arange(rows // 8) * stride) % (rows // 8)
        xf = x.reshape(rows // 8, 8 * cols)
        fn2 = jax.jit(lambda a, i: a[i])
        wall2 = timeit(fn2, xf, idx)
        model = predict_bw(Pattern.STRIDED,
                           Knobs(unit_bytes=8 * cols * 4, stride=stride))
        emit(f"stride_{stride}_loop", wall * 1e6,
             gbps_measured=f"{nbytes/wall/1e9:.3f}",
             gbps_tpu_model=f"{model/1e9:.3f}")
        emit(f"stride_{stride}_dataflow", wall2 * 1e6,
             gbps_measured=f"{nbytes/wall2/1e9:.3f}",
             gbps_tpu_model=f"{model/1e9:.3f}")


if __name__ == "__main__":
    main()

"""Shim: paper artifact Figs 8-9 — implementation in repro/bench/sweeps/stride.py."""
import benchmarks  # noqa: F401  (src-tree fallback for bare checkouts)
from benchmarks.common import run_shim


def main():
    run_shim("stride")


if __name__ == "__main__":
    main()

"""Shared benchmark plumbing: ``name,us_per_call,derived`` CSV rows."""
import os
import sys
import time

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

FAST = bool(int(os.environ.get("BENCH_FAST", "0")))


def emit(name: str, us: float, **derived):
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us:.2f},{d}", flush=True)


def timeit(fn, *args, trials: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def header(title: str):
    print(f"# --- {title} ---", flush=True)

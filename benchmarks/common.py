"""Shared benchmark plumbing for ad-hoc scripts.

The structured path is :mod:`repro.bench` (registry + JSON persistence);
what remains here is the minimal stdout-CSV toolkit for one-off probes plus
the shim used by the ``benchmarks.bench_*`` entry points.
"""
import os
import time
from typing import NamedTuple

import jax

FAST = bool(int(os.environ.get("BENCH_FAST", "0")))


class Timing(NamedTuple):
    """(best, mean, trials) — keep the spread visible, not just the best."""

    best: float
    mean: float
    trials: int


def emit(name: str, us: float, **derived):
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us:.2f},{d}", flush=True)


def timeit(fn, *args, trials: int = 3, warmup: int = 1) -> Timing:
    """Wall-clock ``fn(*args)``: returns (best, mean, trials) seconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    walls = []
    for _ in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        walls.append(time.perf_counter() - t0)
    return Timing(best=min(walls), mean=sum(walls) / len(walls),
                  trials=trials)


def header(title: str):
    print(f"# --- {title} ---", flush=True)


def run_shim(sweep: str) -> None:
    """Run one registered sweep, echoing the legacy CSV (no persistence)."""
    from repro.bench import run_sweeps

    run = run_sweeps(names=[sweep], out_dir=None)
    if run.failures:
        raise SystemExit(1)

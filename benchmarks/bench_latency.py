"""Shim: paper artifact Table 2 / Fig 6 — implementation in repro/bench/sweeps/latency.py."""
import benchmarks  # noqa: F401  (src-tree fallback for bare checkouts)
from benchmarks.common import run_shim


def main():
    run_shim("latency")


if __name__ == "__main__":
    main()

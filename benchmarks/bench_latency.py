"""Paper Table 2 (latency per channel) + Fig. 6 (latency vs stride).

TPU analogue: pointer-chase ns/hop per HBM address region (channel analogue)
and vs chain stride.  Measured = XLA:CPU chase; model = T_l (memmodel).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, emit, header, timeit
from repro.core.memmodel import V5E
from repro.kernels import ops, ref


def _strided_chain(n, stride):
    """next = (cur + stride) mod n; full cycle when gcd(stride, n) == 1."""
    idx = (np.arange(n) + stride) % n
    return jnp.asarray(idx, jnp.int32)[:, None]


def main():
    header("latency: per-region chase (paper Table 2)")
    steps = 1 << (10 if FAST else 13)
    n = 1 << (12 if FAST else 15)
    for region in range(4 if FAST else 8):
        table = ops.make_chain(n, seed=region)
        fn = jax.jit(lambda t: ref.pointer_chase(t, steps))
        wall = timeit(fn, table)
        emit(f"latency_region_{region}", wall * 1e6,
             ns_per_hop=f"{wall/steps*1e9:.1f}",
             t_l_model_ns=f"{V5E.dma_latency_s*1e9:.0f}")

    header("latency vs stride (paper Fig. 6)")
    for stride in (1, 2, 3, 4, 8, 9, 10, 18):
        table = _strided_chain(n, stride) if np.gcd(stride, n) == 1 else \
            _strided_chain(n + 1, stride)
        fn = jax.jit(lambda t: ref.pointer_chase(t, steps))
        wall = timeit(fn, table)
        emit(f"latency_stride_{stride}", wall * 1e6,
             ns_per_hop=f"{wall/steps*1e9:.1f}")


if __name__ == "__main__":
    main()

"""Paper Table 6: number of kernels vs throughput.

TPU analogue: split one stream over k separately-dispatched programs.  Fewer,
wider engines win (dispatch overhead + lost fusion) — same conclusion as the
paper's 1-2 kernel sweet spot.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import FAST, emit, header, timeit
from repro.kernels import ref


def main():
    header("number of kernels (paper Table 6)")
    rows, cols = (2048, 512) if FAST else (8192, 1024)
    x = jnp.ones((rows, cols), jnp.float32)
    nbytes = x.size * 4 * 2
    for k in (1, 2, 4, 8, 16, 32):
        parts = jnp.split(x, k, axis=0)
        fns = [jax.jit(ref.stream_copy) for _ in range(k)]
        for f, p in zip(fns, parts):
            f(p).block_until_ready()  # warm

        def run():
            outs = [f(p) for f, p in zip(fns, parts)]
            return outs[-1]

        wall = timeit(run)
        emit(f"kernels_{k}", wall * 1e6,
             gbps_measured=f"{nbytes/wall/1e9:.3f}",
             note="fewer_wider_engines_win")


if __name__ == "__main__":
    main()

"""Shim: paper artifact Table 6 — implementation in repro/bench/sweeps/num_kernels.py."""
import benchmarks  # noqa: F401  (src-tree fallback for bare checkouts)
from benchmarks.common import run_shim


def main():
    run_shim("num_kernels")


if __name__ == "__main__":
    main()

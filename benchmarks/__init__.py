"""Thin shims over :mod:`repro.bench` (one module per paper table/figure).

The implementations live in ``src/repro/bench/sweeps``; these modules only
keep the historical ``python -m benchmarks.bench_*`` entry points alive.
Prefer an installed package (``pip install -e .``) or ``PYTHONPATH=src``;
as a last resort for a bare source checkout, fall back to the sibling
``src/`` tree so ``python -m benchmarks.run`` works out of the box.
"""
import os
import sys

try:  # installed package or PYTHONPATH=src
    import repro  # noqa: F401
except ImportError:  # bare checkout: use the sibling src/ tree
    _src = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
    sys.path.insert(0, os.path.abspath(_src))
    import repro  # noqa: F401

"""Shim: paper artifact Table 9 — implementation in repro/bench/sweeps/database.py."""
import benchmarks  # noqa: F401  (src-tree fallback for bare checkouts)
from benchmarks.common import run_shim


def main():
    run_shim("database")


if __name__ == "__main__":
    main()

"""Paper Table 9: database access patterns (rs_tra / rr_tra / r_acc / nest).

Framework-level instantiations:
  rs_tra — repeated sequential weight streaming (epoch re-reads)
  rr_tra — repeated random traversal (shuffled epochs over the same table)
  r_acc  — embedding-row gather
  nest   — interleaved multi-cursor sequential = chunked attention
"""
import jax
import jax.numpy as jnp

from benchmarks.common import FAST, emit, header, timeit
from repro.core.memmodel import predict_bw
from repro.core.patterns import ADVICE, Knobs, Pattern
from repro.kernels import ops, ref
from repro.models.attention import AttnParams, chunked_attention


def main():
    header("database patterns (paper Table 9)")
    n, d = (1 << 12, 256) if FAST else (1 << 14, 512)
    table = jnp.ones((n, d), jnp.float32)
    nbytes = table.size * 4

    # rs_tra: stream the table repeatedly (3 epochs)
    fn = jax.jit(lambda t: sum(jnp.sum(t * (i + 1)) for i in range(3)))
    wall = timeit(fn, table)
    emit("rs_tra", wall * 1e6,
         gbps_measured=f"{3*nbytes/wall/1e9:.2f}",
         gbps_tpu_model=f"{predict_bw(Pattern.RS_TRA, Knobs())/1e9:.1f}",
         paper_u280_gbps=13.26,
         advice=ADVICE[Pattern.RS_TRA].knob_moves[0])

    # rr_tra: shuffled traversal each epoch
    perm = jax.random.permutation(jax.random.PRNGKey(0), n)
    fn = jax.jit(lambda t, p: jnp.sum(t[p]))
    wall = timeit(fn, table, perm)
    emit("rr_tra", wall * 1e6,
         gbps_measured=f"{nbytes/wall/1e9:.2f}",
         gbps_tpu_model=f"{predict_bw(Pattern.RR_TRA, Knobs(unit_bytes=d*4))/1e9:.2f}",
         paper_u280_gbps=3.51,
         advice=ADVICE[Pattern.RR_TRA].knob_moves[0])

    # r_acc: sparse random row access (small working fraction)
    idx = ops.lfsr_indices(n // 8, bits=24) % n
    fn = jax.jit(lambda t, i: t[i])
    wall = timeit(fn, table, idx)
    moved = idx.shape[0] * d * 4 * 2
    emit("r_acc", wall * 1e6,
         gbps_measured=f"{moved/wall/1e9:.2f}",
         gbps_tpu_model=f"{predict_bw(Pattern.R_ACC, Knobs(unit_bytes=d*4))/1e9:.2f}",
         paper_u280_gbps=0.68,
         advice=ADVICE[Pattern.R_ACC].knob_moves[0])

    # nest: blocked multi-cursor (chunked attention)
    b, s, h, hd = (1, 512, 4, 64) if FAST else (2, 1024, 8, 64)
    q = jnp.ones((b, s, h, hd), jnp.float32)
    k = jnp.ones((b, s, h, hd), jnp.float32)
    v = jnp.ones((b, s, h, hd), jnp.float32)
    p = AttnParams(bq=256, bkv=256)
    fn = jax.jit(lambda *a: chunked_attention(*a, p))
    wall = timeit(fn, q, k, v)
    moved = (q.size + 2 * (s // 256) * k.size + q.size) * 4
    emit("nest", wall * 1e6,
         gbps_measured=f"{moved/wall/1e9:.2f}",
         gbps_tpu_model=f"{predict_bw(Pattern.NEST, Knobs())/1e9:.1f}",
         paper_u280_gbps=421.89,
         advice=ADVICE[Pattern.NEST].knob_moves[0])


if __name__ == "__main__":
    main()

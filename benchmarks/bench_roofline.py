"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads runs/dryrun.json (written by repro.launch.dryrun --all --roofline) and
prints one CSV row per (arch x shape) cell with the three terms, dominant
bottleneck, and MODEL_FLOPS/HLO_FLOPs ratio.  Does not compile anything.
"""
import json
import os

from benchmarks.common import emit, header

_RUNS_DIR = os.path.join(os.path.dirname(__file__), "..", "runs")
_DEFAULT = (os.path.join(_RUNS_DIR, "dryrun_opt.json")
            if os.path.exists(os.path.join(_RUNS_DIR, "dryrun_opt.json"))
            else os.path.join(_RUNS_DIR, "dryrun.json"))
RUNS = os.environ.get("DRYRUN_JSON", _DEFAULT)


def main():
    header(f"roofline terms per (arch x shape) — from {os.path.basename(RUNS)}")
    if not os.path.exists(RUNS):
        emit("roofline_missing", 0.0,
             note=f"run 'python -m repro.launch.dryrun --all --roofline --out {RUNS}' first")
        return
    with open(RUNS) as f:
        records = json.load(f)
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        name = f"roofline_{r['arch']}_{r['shape']}"
        if r.get("status") == "skip":
            emit(name, 0.0, status="skip", reason=r.get("reason", ""))
            continue
        if r.get("status") != "ok" or "roofline" not in r:
            emit(name, 0.0, status=r.get("status", "missing"))
            continue
        rf = r["roofline"]
        sp = r.get("meshes", {}).get("single_pod", {})
        mp = r.get("meshes", {}).get("multi_pod", {})
        c, m, co = rf["compute_s"], rf["memory_s"], rf["collective_s"]
        ideal = c * rf["useful_ratio"]
        m_k = m - rf.get("bytes_flash_inner", 0.0) / 819e9
        emit(name, rf["compute_s"] * 1e6,
             compute_ms=f"{c*1e3:.2f}",
             memory_ms=f"{m*1e3:.2f}",
             collective_ms=f"{co*1e3:.2f}",
             dominant=rf["dominant"],
             useful_flops_ratio=f"{rf['useful_ratio']:.3f}",
             frac=f"{ideal/max(c,m,co):.3f}" if max(c, m, co) else "0",
             frac_serial=f"{ideal/(c+m+co):.3f}" if (c + m + co) else "0",
             frac_kernel=f"{ideal/max(c,m_k,co):.3f}" if max(c, m_k, co) else "0",
             peak_gib_per_dev=sp.get("peak_gib", ""),
             fits_16g_1pod=sp.get("peak_gib", 99) < 16.0,
             fits_16g_2pod=mp.get("peak_gib", 99) < 16.0)


if __name__ == "__main__":
    main()

"""Shim: paper artifact EXPERIMENTS §Roofline — implementation in repro/bench/sweeps/roofline.py."""
import benchmarks  # noqa: F401  (src-tree fallback for bare checkouts)
from benchmarks.common import run_shim


def main():
    run_shim("roofline")


if __name__ == "__main__":
    main()

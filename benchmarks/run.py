"""Benchmark harness: one registered sweep per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``               (full)
``BENCH_FAST=1 PYTHONPATH=src python -m benchmarks.run``  (CI-scale)

Thin shim over :mod:`repro.bench`: runs every registered sweep, echoes the
legacy ``name,us_per_call,derived`` CSV, and persists the structured run as
``runs/BENCH_<timestamp>.json`` (compare two runs with
``python -m repro.bench.compare``).
"""
import sys

import benchmarks  # noqa: F401  (src-tree fallback for bare checkouts)


def main() -> None:
    from repro.bench import run_sweeps

    print("name,us_per_call,derived")
    run = run_sweeps(out_dir="runs")
    if "path" in run.env:
        print(f"# wrote {run.env['path']}", flush=True)
    if run.failures:
        print(f"# {len(run.failures)} sweep(s) FAILED: "
              f"{sorted(run.failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

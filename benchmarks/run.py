"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``          (full)
``BENCH_FAST=1 PYTHONPATH=src python -m benchmarks.run``  (CI-scale)

Every row prints ``name,us_per_call,derived`` CSV.
"""
import sys
import traceback

from benchmarks import (bench_burst, bench_conv, bench_database,
                        bench_latency, bench_num_kernels, bench_outstanding,
                        bench_random, bench_roofline, bench_stride,
                        bench_unit_size)

MODULES = [
    ("latency (Table 2 / Fig 6)", bench_latency),
    ("outstanding (Fig 5 / Table 5)", bench_outstanding),
    ("unit size (Fig 7)", bench_unit_size),
    ("stride (Figs 8-9)", bench_stride),
    ("burst (Fig 10 / Tables 3-4)", bench_burst),
    ("num kernels (Table 6)", bench_num_kernels),
    ("random (Tables 7-8)", bench_random),
    ("database (Table 9)", bench_database),
    ("convolution (Table 10)", bench_conv),
    ("roofline (EXPERIMENTS §Roofline)", bench_roofline),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for title, mod in MODULES:
        try:
            mod.main()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"# FAILED {title}", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Shim: paper artifact Fig 10 / Tables 3-4 — implementation in repro/bench/sweeps/burst.py."""
import benchmarks  # noqa: F401  (src-tree fallback for bare checkouts)
from benchmarks.common import run_shim


def main():
    run_shim("burst")


if __name__ == "__main__":
    main()

"""Paper Table 10 + §6.1: 11x11 convolution over a 1920x1080 matrix.

Rows mirror the paper's three implementations:
  cpu       — naive numpy sliding-window (the paper's CPU row)
  fused     — XLA conv (single wide engine; the paper's 2-channel FPGA row)
  split     — 16-way row-partitioned conv (the paper's 32-channel row;
              per-shard dispatch overhead vs parallelism)
"""
import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import FAST, emit, header, timeit


def main():
    header("convolution 11x11 on 1920x1080 (paper Table 10)")
    H, W = (480, 270) if FAST else (1080, 1920)
    K = 11
    img = np.random.default_rng(0).standard_normal((H, W)).astype(np.float32)
    ker = np.ones((K, K), np.float32) / (K * K)

    # cpu: naive strided windows (small tile to keep runtime sane)
    th, tw = (64, 64)
    tile = img[:th + K - 1, :tw + K - 1]
    import time
    t0 = time.perf_counter()
    out = np.zeros((th, tw), np.float32)
    for i in range(K):
        for j in range(K):
            out += tile[i:i + th, j:j + tw] * ker[i, j]
    cpu_wall = (time.perf_counter() - t0) * (H * W) / (th * tw)
    emit("conv_cpu_naive", cpu_wall * 1e6,
         gflops=f"{2*H*W*K*K/cpu_wall/1e9:.2f}", paper_cpu_s=0.06)

    x = jnp.asarray(img)[None, :, :, None]
    kk = jnp.asarray(ker)[:, :, None, None]
    conv = jax.jit(lambda a, b: jax.lax.conv_general_dilated(
        a, b, (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")))
    wall = timeit(conv, x, kk)
    emit("conv_xla_fused", wall * 1e6,
         gflops=f"{2*H*W*K*K/wall/1e9:.2f}", paper_fpga2ch_s=2.04,
         speedup_vs_cpu=f"{cpu_wall/wall:.1f}")

    # split: 16 row-shards, separate dispatches (multi-kernel analogue)
    shards = jnp.split(jnp.asarray(img), 8, axis=0)
    pads = [jnp.pad(s, ((0, K - 1), (0, 0)))[None, :, :, None] for s in shards]
    def run_split():
        outs = [conv(p, kk) for p in pads]
        return outs[-1]
    run_split()
    wall_s = timeit(run_split)
    emit("conv_split_16", wall_s * 1e6,
         gflops=f"{2*H*W*K*K/wall_s/1e9:.2f}", paper_fpga32ch_s=21.0,
         note="per_shard_dispatch_overhead")


if __name__ == "__main__":
    main()

"""Shim: paper artifact Table 10 — implementation in repro/bench/sweeps/conv.py."""
import benchmarks  # noqa: F401  (src-tree fallback for bare checkouts)
from benchmarks.common import run_shim


def main():
    run_shim("conv")


if __name__ == "__main__":
    main()

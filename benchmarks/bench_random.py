"""Paper Tables 7/8: random access (LFSR + pointer-chase) vs sequential.

The paper's headline ordering — sequential 421 GB/s >> LFSR-random 5.8 GB/s
>> pointer-chase 0.99 GB/s — is the ratio structure we reproduce (measured on
this host + modeled on v5e).
"""
from benchmarks.common import FAST, emit, header
from repro.core import engines


def main():
    header("random vs sequential (paper Tables 7/8)")
    # working sets must exceed the host LLC or 'random' hits cache and the
    # paper's ordering inverts (an instance of its own page-hit effect!)
    seq = engines.bw_sequential(rows=4096 if FAST else 16384, cols=1024)
    emit("seq", seq.wall_s * 1e6,
         gbps_measured=f"{seq.gbps_measured:.2f}",
         gbps_tpu_model=f"{seq.gbps_tpu_model:.1f}",
         paper_u280_gbps=421.68)
    for gen in ("lfsr", "prng"):
        # one-cache-line rows (64B ~ the paper's 256-bit units) from a
        # table larger than LLC: each touch pays the latency, not the burst
        r = engines.bw_random(n_rows=1 << (17 if FAST else 20), cols=16,
                              n_idx=1 << (13 if FAST else 16), generator=gen)
        emit(f"random_{gen}", r.wall_s * 1e6,
             gbps_measured=f"{r.gbps_measured:.3f}",
             gbps_tpu_model=f"{r.gbps_tpu_model:.2f}",
             paper_u280_gbps=5.82)
    chase = engines.latency_chase(n_entries=1 << (20 if FAST else 22),
                                  steps=1 << 13)
    emit("random_pointer_chase", chase.wall_s * 1e6,
         gbps_measured=f"{chase.gbps_measured:.4f}",
         gbps_tpu_model=f"{chase.gbps_tpu_model:.4f}",
         paper_u280_gbps=0.994)
    # paper's ratio claim: seq >> random >> chase.  The chase relations are
    # host-independent (serialized loads cannot be hidden anywhere); the
    # seq-vs-random gap needs real DRAM behaviour — virtualized hosts with a
    # low streaming ceiling can flatten it, so it is reported, not asserted.
    hard = (seq.gbps_measured > chase.gbps_measured
            and r.gbps_measured > chase.gbps_measured)
    emit("ordering_check", 0.0, chase_slowest=hard,
         seq_over_random=f"{seq.gbps_measured/r.gbps_measured:.2f}x",
         v5e_model_seq_over_random=f"{seq.gbps_tpu_model/r.gbps_tpu_model:.0f}x")
    assert hard, "pointer chase must be slowest everywhere"


if __name__ == "__main__":
    main()

"""Shim: paper artifact Tables 7-8 — implementation in repro/bench/sweeps/random_access.py."""
import benchmarks  # noqa: F401  (src-tree fallback for bare checkouts)
from benchmarks.common import run_shim


def main():
    run_shim("random")


if __name__ == "__main__":
    main()

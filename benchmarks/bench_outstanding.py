"""Paper Fig. 5 + Table 5: effect of outstanding transactions.

TPU analogue: requests in flight = independent chase chains serviced in
parallel (vmap) — per-chain latency is constant, so aggregate hops/s scale
with the in-flight count until the bandwidth knee.  The model column gives
the v5e knee NO* = ceil(T_l * BW / burst) (Eq. 4); the VMEM column is the
paper's BRAM-consumption column.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import FAST, emit, header, timeit
from repro.core.memmodel import V5E, min_outstanding_for_peak, predict_bw
from repro.core.patterns import Knobs, Pattern
from repro.kernels import ops


def _multi_chase(tables, steps):
    flat = tables[:, :, 0]

    def one(tbl):
        def body(addr, _):
            nxt = tbl[addr]
            return nxt, nxt
        _, tr = jax.lax.scan(body, jnp.int32(0), None, length=steps)
        return tr

    return jax.vmap(one)(flat)


def main():
    header("outstanding transactions (paper Fig. 5 / Table 5)")
    n = 1 << (10 if FAST else 13)
    steps = 1 << (9 if FAST else 12)
    base = None
    for no in (1, 2, 4, 8, 16, 32, 64):
        tables = jnp.stack([ops.make_chain(n, seed=i) for i in range(no)])
        fn = jax.jit(lambda t: _multi_chase(t, steps))
        wall = timeit(fn, tables)
        hops_s = no * steps / wall
        base = base or hops_s
        knobs = Knobs(burst_bytes=64 * 1024, outstanding=no)
        emit(f"outstanding_{no}", wall * 1e6,
             hops_per_s=f"{hops_s:.2e}",
             speedup_vs_1=f"{hops_s/base:.2f}",
             tpu_model_gbps=f"{predict_bw(Pattern.SEQUENTIAL, knobs)/1e9:.1f}",
             vmem_bytes=knobs.vmem_bytes())
    emit("outstanding_knee_model", 0.0,
         no_star_64kb=min_outstanding_for_peak(64 * 1024),
         no_star_1mb=min_outstanding_for_peak(1 << 20))


if __name__ == "__main__":
    main()

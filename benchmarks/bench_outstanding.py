"""Shim: paper artifact Fig 5 / Table 5 — implementation in repro/bench/sweeps/outstanding.py."""
import benchmarks  # noqa: F401  (src-tree fallback for bare checkouts)
from benchmarks.common import run_shim


def main():
    run_shim("outstanding")


if __name__ == "__main__":
    main()

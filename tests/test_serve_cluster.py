"""Cluster front-end tests: traffic harness, cache-aware routing,
deadline shedding, and lossless replica failover.

Three layers:

1. pure units — the open-loop traffic generator is a pure function of
   its config, ``PrefixIndex.match_len`` is a non-mutating peek, and
   the per-fault-kind chaos sub-RNGs are stable and independent;
2. engine integration — ``evacuate``/``adopt`` move mid-stream requests
   across replicas bitwise-losslessly, the router prefers the replica
   with the predicted prefix hit, blown deadlines shed low-priority
   requests (high degrade or route at risk), transient admission
   refusals retry bounded;
3. seeded cluster chaos (``-m chaos``) — replica-kill + brownout +
   admission-fault schedules over 2-replica fronts must drain bitwise
   identical to the undisturbed run across paged / int8 / sampled / TP
   backends.
"""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, override, smoke_config
from repro.models import RuntimeFlags, build
from repro.serve import (ClusterChaos, ClusterChaosConfig, ClusterFrontEnd,
                         PageAllocator, PrefixIndex, Request, SamplingParams,
                         ServeEngine, TrafficConfig, TransientAdmitError,
                         fault_rng, generate_traffic)
from repro.serve.scheduler import PRIORITY_HIGH

# ---------------------------------------------------------------------------
# pure units
# ---------------------------------------------------------------------------


def test_traffic_schedule_is_pure_and_shaped():
    cfg = TrafficConfig(seed=3, n_requests=12, rate=1.5,
                        burst_rate_mult=2.5, n_prefixes=2, prefix_len=8,
                        deadline_rounds=(3, 9), high_priority_frac=0.5)

    def flat(sched):
        return [(t, r.rid, r.max_new_tokens, r.priority, r.deadline,
                 r.prompt.tolist()) for t, r in sched]

    a = generate_traffic(cfg, vocab_size=101)
    b = generate_traffic(cfg, vocab_size=101)
    assert flat(a) == flat(b)                # same config, same schedule
    assert a[0][1] is not b[0][1]            # ...but fresh Request objects
    arrivals = [t for t, _ in a]
    assert arrivals == sorted(arrivals)
    # Zipf sharing: fewer distinct prefix heads than requests
    heads = {tuple(r.prompt[:cfg.prefix_len].tolist()) for _, r in a}
    assert len(heads) <= cfg.n_prefixes < len(a)
    for t, r in a:
        assert 3 <= r.deadline - t <= 9      # deadline window is relative
    prios = {r.priority for _, r in a}
    assert prios == {0, 1}                   # both SLO classes present
    # a different seed reshuffles the schedule
    c = generate_traffic(TrafficConfig(**{**cfg.__dict__, "seed": 4}), 101)
    assert flat(c) != flat(a)


def test_prefix_match_len_is_a_pure_peek():
    idx = PrefixIndex()
    alloc = PageAllocator(8, 4, reserved=1)
    alloc.alloc(1)
    alloc.reserve(1, 8)                      # two pages
    p0, p1 = alloc.tables[1]
    alloc.pin(p0)
    alloc.pin(p1)
    idx.register("h0", p0)
    idx.register("h1", p1)
    assert idx.match_len(["h0", "h1"], alloc) == 2
    assert idx.match_len(["h0", "hX", "h1"], alloc) == 1
    assert idx.match_len(["hX"], alloc) == 0
    assert (idx.hits, idx.misses) == (0, 0)  # counters untouched
    alloc.unpin(p1)
    # an unpinned page is a miss for routing purposes...
    assert idx.match_len(["h0", "h1"], alloc) == 1
    # ...but the stale entry is NOT reaped (that is lookup's job, on the
    # owning engine's schedule)
    assert len(idx) == 2
    assert idx.match_len(["h0", "h1"]) == 2  # no alloc: trust the index


def test_fault_rng_streams_stable_and_independent():
    seq = [fault_rng(0, "storm").random() for _ in range(1)]
    a = fault_rng(0, "storm")
    b = fault_rng(0, "storm")
    sa = [a.random() for _ in range(8)]
    assert [b.random() for _ in range(8)] == sa   # stable per (seed, kind)
    # adding/drawing other kinds can never perturb an existing kind
    assert [fault_rng(0, "crash").random() for _ in range(8)] != sa
    assert [fault_rng(0, "brownout").random() for _ in range(8)] != sa
    assert [fault_rng(1, "storm").random() for _ in range(8)] != sa
    assert sa[:1] == seq


def test_fault_rng_rejects_unknown_kind():
    with pytest.raises(KeyError):
        fault_rng(0, "gremlin")


# ---------------------------------------------------------------------------
# engine integration (smoke-scale gemma-2b, cached like the scheduler tests)
# ---------------------------------------------------------------------------

FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                     moe_impl="dense", loss_chunk=16)

_STATE = {}


def _bundle(kv_dtype="native"):
    key = ("bundle", kv_dtype)
    if key not in _STATE:
        cfg = smoke_config(ARCHS["gemma-2b"])
        flags = (FLAGS if kv_dtype == "native"
                 else RuntimeFlags(**{**FLAGS.__dict__,
                                      "kv_dtype": kv_dtype}))
        bundle = build(cfg, flags)
        _STATE[key] = (cfg, bundle, bundle.init(jax.random.PRNGKey(7)))
    return _STATE[key]


_KW = dict(batch_size=2, max_len=64, window=4, prefill_chunk=8,
           cache_backend="paged", seed=0)


def _front(key, n=2, kv_dtype="native", config=None, **kw):
    if key not in _STATE:
        _, bundle, params = _bundle(kv_dtype)
        engines = [ServeEngine(bundle, params, **{**_KW, **kw})
                   for _ in range(n)]
        _STATE[key] = ClusterFrontEnd(engines, config)
    front = _STATE[key]
    front.reset()
    return front


_TCFG = TrafficConfig(seed=23, n_requests=8, rate=1.2, burst_rate_mult=3.0,
                      phase_rounds=4.0, n_prefixes=3, prefix_len=16,
                      tail_lo=3, tail_hi=9, out_lo=6, out_hi=12)


def _drain(front, tcfg=_TCFG, chaos=None):
    front.reset()
    sched = generate_traffic(tcfg, _bundle()[0].vocab_size)
    front.run(sched, chaos=chaos)
    assert not front.backlog and not front._live
    return {r.rid: list(r.out_tokens) for _, r in sched}


def test_front_end_rejects_bad_pools():
    with pytest.raises(ValueError, match="at least one"):
        ClusterFrontEnd([])
    _, bundle, params = _bundle()
    with pytest.raises(ValueError, match="share the sampling seed"):
        ClusterFrontEnd([ServeEngine(bundle, params, **{**_KW, "seed": 0}),
                         ServeEngine(bundle, params, **{**_KW, "seed": 1})])


def test_evacuate_adopt_midstream_is_bitwise():
    """The failover mechanism in isolation: march one engine mid-drain,
    evacuate everything, adopt on a second engine sharing params+seed —
    the finished streams must be bitwise the single-engine ones."""
    front = _front("pair")
    e1, e2 = front.engines
    cfg = _bundle()[0]
    rng = np.random.default_rng(13)
    mk = lambda: [Request(rid=i,
                          prompt=rng.integers(1, cfg.vocab_size, size=20)
                          .astype(np.int32),
                          max_new_tokens=8) for i in range(4)]
    ref_reqs = mk()
    for r in ref_reqs:
        e1.add_request(r)
    e1.run_to_completion()
    ref = {r.rid: list(r.out_tokens) for r in ref_reqs}

    front.reset()
    rng = np.random.default_rng(13)          # regenerate identical prompts
    reqs = mk()
    for r in reqs:
        e1.add_request(r)
    for _ in range(3):                       # mid-stream: some tokens out
        e1.step()
    assert any(r.out_tokens for r in reqs)
    moved = e1.evacuate()
    assert not e1.queue and all(s is None for s in e1.slots)
    assert {r.rid for r in moved} == {r.rid for r in reqs if not r.done}
    for r in moved:
        e2.adopt(r)
    e2.run_to_completion()
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref
    # mid-stream adoptions resumed through the PR 8 recompute path
    assert e2.stats.recompute_resumes >= 1


def test_router_prefers_predicted_prefix_hit():
    front = _front("pair")
    cfg = _bundle()[0]
    rng = np.random.default_rng(11)
    common = rng.integers(1, cfg.vocab_size, size=32).astype(np.int32)
    # warm replica 1's prefix cache off-router
    front.replicas[1].engine.add_request(
        Request(rid=100, prompt=common.copy(), max_new_tokens=4))
    front.replicas[1].engine.run_to_completion()
    tail = rng.integers(1, cfg.vocab_size, size=5).astype(np.int32)
    req = Request(rid=101, prompt=np.concatenate([common, tail]),
                  max_new_tokens=4)
    assert front.replicas[1].predicted_hit_tokens(req.prompt) > 0
    assert front.replicas[0].predicted_hit_tokens(req.prompt) == 0
    front.submit(req)
    front.run()
    # ties break to the LOWER index, so landing on 1 proves the cache term
    assert front.owner[101] == 1
    assert front.stats().prefix_hit_tokens > 0


def test_deadline_sheds_low_priority_keeps_high():
    front = _front("pair")
    cfg = _bundle()[0]
    rng = np.random.default_rng(17)
    mk_prompt = lambda: rng.integers(1, cfg.vocab_size,
                                     size=20).astype(np.int32)
    for i in range(4):                       # congest both replicas
        front.submit(Request(rid=i, prompt=mk_prompt(), max_new_tokens=24))
    low = Request(rid=50, prompt=mk_prompt(), max_new_tokens=8, deadline=1)
    high = Request(rid=51, prompt=mk_prompt(), max_new_tokens=8, deadline=1,
                   priority=PRIORITY_HIGH)
    front.submit(low)
    front.submit(high)
    front.run()
    assert low in front.shed_requests and low.out_tokens == []
    assert high.done                         # never shed, routed at risk
    c = front.cstats
    assert c.shed == 1 and c.slo_risk == 1
    assert c.completed + c.shed == c.submitted


def test_deadline_degrades_max_new_tokens_to_fit():
    front = _front("solo", n=1)
    cfg = _bundle()[0]
    prompt = np.arange(1, 21, dtype=np.int32) % cfg.vocab_size
    req = Request(rid=7, prompt=prompt, max_new_tokens=12, deadline=1)
    # slack = 1 round * (bsz*window = 8 units) - 3 prefill chunks = 5
    front.submit(req)
    front.run()
    assert front.cstats.degraded == 1 and front.cstats.shed == 0
    assert req.max_new_tokens == 5 and req.done


def test_transient_admit_faults_retry_bitwise():
    front = _front("pair")
    want = _drain(front)
    chaos = ClusterChaos(ClusterChaosConfig(seed=2, admit_prob=0.5))
    got = _drain(front, chaos=chaos)
    assert got == want
    assert chaos.admit_faults > 0 and front.cstats.retries > 0
    assert front.cstats.shed == 0            # bounded retry, not a drop


def test_replica_submit_raises_when_fault_armed():
    front = _front("pair")
    rep = front.replicas[0]
    rep.admit_faults = 1
    with pytest.raises(TransientAdmitError):
        rep.submit(Request(rid=9, prompt=np.ones(4, np.int32)))
    # the fault is consumed: the retry lands
    rep.submit(Request(rid=9, prompt=np.ones(4, np.int32)))
    assert rep.routed == 1


def test_crash_failover_drains_bitwise():
    front = _front("pair")
    want = _drain(front)
    chaos = ClusterChaos(ClusterChaosConfig(
        seed=1, crash_rounds=4, kill_at=((2, 1, "crash"),)))
    got = _drain(front, chaos=chaos)
    assert got == want
    c = front.cstats
    assert chaos.crashes == 1
    assert c.quarantines >= 1 and c.failovers >= 1
    assert c.probe_failures >= 1 and c.recoveries >= 1
    # PR 8 eviction machinery reused: the crashed replica's in-flight
    # work was preempted off (recompute-resume when tokens were already
    # out, restart when still mid-prefill)
    s = front.stats()
    assert s.preemptions >= 1
    assert s.recompute_resumes + s.preempt_restarts >= 1


def test_brownout_quarantine_drains_bitwise():
    front = _front("pair")
    want = _drain(front)
    chaos = ClusterChaos(ClusterChaosConfig(
        seed=1, brownout_rounds=5, brownout_latency_s=1.0,
        kill_at=((1, 0, "brownout"),)))
    got = _drain(front, chaos=chaos)
    assert got == want
    c = front.cstats
    assert chaos.brownouts == 1
    assert c.slow_probes >= 3 and c.quarantines >= 1


def test_percentiles_are_deterministic_and_positive():
    front = _front("pair")
    _drain(front)
    a = front.percentiles()
    _drain(front)
    assert front.percentiles() == a
    assert all(v > 0 for v in a.values())
    assert front.cstats.rounds > 0


# ---------------------------------------------------------------------------
# seeded cluster chaos across backends (-m chaos)
# ---------------------------------------------------------------------------

_RANDOM_CHAOS = ClusterChaosConfig(seed=12, crash_prob=0.05, crash_rounds=3,
                                   brownout_prob=0.05, brownout_rounds=3,
                                   brownout_latency_s=1.0, admit_prob=0.1)


@pytest.mark.chaos
@pytest.mark.parametrize("key,kv_dtype,kw", [
    ("pair", "native", {}),
    ("int8", "int8", {}),
    ("sampled", "native", dict(sampling=SamplingParams(temperature=0.9,
                                                       top_p=0.95), seed=3)),
])
def test_cluster_chaos_random_bitwise(key, kv_dtype, kw):
    front = _front(key, kv_dtype=kv_dtype, **kw)
    want = _drain(front)
    chaos = ClusterChaos(_RANDOM_CHAOS)
    got = _drain(front, chaos=chaos)
    assert got == want
    assert chaos.crashes + chaos.brownouts + chaos.admit_faults > 0


@pytest.mark.chaos
@pytest.mark.skipif(len(jax.devices()) < 4,
                    reason="2 replicas x TP=2 needs 4 devices")
def test_cluster_chaos_tp_bitwise():
    """Replica kill over TP-sharded replicas: failover re-prefills on a
    different 2-device mesh and must still replay the streams bitwise."""
    key = ("front", "tp")
    if key not in _STATE:
        from repro.launch.serve import build_pool
        cfg = override(smoke_config(ARCHS["gemma-2b"]), num_kv_heads=2)
        bundle = build(cfg, FLAGS)
        params = bundle.init(jax.random.PRNGKey(7))
        pool = build_pool(bundle, params, tp=2, dp=2, **_KW)
        _STATE[key] = (cfg, ClusterFrontEnd(pool.engines))
    cfg, front = _STATE[key]
    tcfg = TrafficConfig(**{**_TCFG.__dict__, "n_requests": 6})

    def drain(chaos=None):
        front.reset()
        sched = generate_traffic(tcfg, cfg.vocab_size)
        front.run(sched, chaos=chaos)
        return {r.rid: list(r.out_tokens) for _, r in sched}

    want = drain()
    chaos = ClusterChaos(ClusterChaosConfig(
        seed=4, crash_rounds=4, kill_at=((2, 0, "crash"),)))
    got = drain(chaos=chaos)
    assert got == want
    assert front.cstats.failovers >= 1 and front.cstats.quarantines >= 1

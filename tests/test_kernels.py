"""Per-kernel interpret-mode sweeps vs the ref.py jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32):
    x = RNG.standard_normal(shape)
    if dtype == jnp.int8:
        return jnp.asarray((x * 32).clip(-127, 127), jnp.int8)
    return jnp.asarray(x, dtype)


def _close(a, b, tol):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [(128, 128), (256, 512), (64, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
@pytest.mark.parametrize("block_rows", [8, 64])
def test_stream_copy(shape, dtype, block_rows):
    if shape[0] % block_rows:
        pytest.skip("non-divisible")
    x = _arr(shape, dtype)
    _close(ops.stream_copy(x, block_rows=block_rows), ref.stream_copy(x), 0)


@pytest.mark.parametrize("mode", ["copy", "rw"])
def test_stream_modes(mode):
    x = _arr((128, 256))
    _close(ops.stream_copy(x, block_rows=32, mode=mode),
           ref.stream_copy(x, mode), 0)


@pytest.mark.parametrize("stride", [1, 2, 3, 7, 15])
@pytest.mark.parametrize("block_rows", [4, 16])
def test_strided(stride, block_rows):
    x = _arr((256, 64))
    _close(ops.strided_copy(x, block_rows=block_rows, stride=stride),
           ref.strided_copy(x, block_rows=block_rows, stride=stride), 0)


@pytest.mark.parametrize("n_idx", [16, 100])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather(n_idx, dtype):
    x = _arr((512, 128), dtype)
    idx = ops.lfsr_indices(n_idx, bits=16) % 512
    _close(ops.random_gather(x, idx), ref.random_gather(x, idx), 0)


@pytest.mark.parametrize("n", [64, 256, 1000])
def test_chase(n):
    table = ops.make_chain(n, seed=n)
    steps = min(2 * n, 300)
    got = ops.pointer_chase(table, steps=steps)
    _close(got, ref.pointer_chase(table, steps), 0)


def test_chase_is_full_cycle():
    n = 128
    table = ops.make_chain(n, seed=1)
    trace = np.asarray(ref.pointer_chase(table, n))[:, 0]
    assert sorted(trace.tolist()) == list(range(n))  # visits every entry once


@pytest.mark.parametrize("mnk", [(128, 128, 128), (256, 128, 384), (64, 256, 128)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("blocks", [(64, 64, 64), (128, 128, 128)])
def test_matmul(mnk, dtype, tol, blocks):
    m, k, n = mnk
    bm, bn, bk = blocks
    if m % min(bm, m) or n % min(bn, n) or k % min(bk, k):
        pytest.skip("non-divisible")
    x, y = _arr((m, k), dtype), _arr((k, n), dtype)
    _close(ops.matmul(x, y, bm=bm, bn=bn, bk=bk), ref.matmul(x, y), tol)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("opts", [
    dict(),
    dict(window=96),
    dict(softcap=30.0),
    dict(causal=False),
    dict(window=64, softcap=20.0),
])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 3e-2)])
def test_flash_attention(hq, hkv, opts, dtype, tol):
    b, s, d = 2, 256, 64
    q = _arr((b, hq, s, d), dtype)
    k = _arr((b, hkv, s, d), dtype)
    v = _arr((b, hkv, s, d), dtype)
    got = ops.flash_attention(q, k, v, bq=64, bkv=64, **opts)
    want = ref.attention(q, k, v, **opts)
    _close(got, want, tol)


def test_flash_attention_cross_lengths():
    q = _arr((1, 2, 128, 32))
    k = _arr((1, 2, 256, 32))
    v = _arr((1, 2, 256, 32))
    got = ops.flash_attention(q, k, v, causal=False, bq=64, bkv=64)
    want = ref.attention(q, k, v, causal=False)
    _close(got, want, 2e-4)


# ---------------------------------------------------------------------------
# PR 3 parity sweep: dtypes x non-default blocks x non-divisible shapes
# (the ragged-length wrapper pads to the grid and masks in-kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("bq,bkv", [(32, 32), (64, 128), (128, 64)])
@pytest.mark.parametrize("sq,skv", [(96, 96), (37, 53), (128, 100), (65, 129)])
def test_flash_attention_parity_sweep(dtype, tol, bq, bkv, sq, skv):
    b, h, d = 1, 2, 32
    q = _arr((b, h, sq, d), dtype)
    k = _arr((b, h, skv, d), dtype)
    v = _arr((b, h, skv, d), dtype)
    got = ops.flash_attention(q, k, v, causal=False, bq=bq, bkv=bkv)
    _close(got, ref.attention(q, k, v, causal=False), tol)


@pytest.mark.parametrize("opts", [dict(), dict(window=48),
                                  dict(softcap=12.0)])
@pytest.mark.parametrize("sq", [33, 100])
def test_flash_attention_causal_ragged(opts, sq):
    """satellite: odd sequence lengths no longer trip the block-divisibility
    assert — padded inside the wrapper, masked in-kernel."""
    b, h, d = 2, 2, 16
    q = _arr((b, h, sq, d))
    k = _arr((b, h, sq, d))
    v = _arr((b, h, sq, d))
    got = ops.flash_attention(q, k, v, bq=32, bkv=32, **opts)
    _close(got, ref.attention(q, k, v, **opts), 2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("bkv", [32, 96, 256])
@pytest.mark.parametrize("t", [100, 255, 256])
def test_decode_attention_parity_sweep(dtype, tol, bkv, t):
    b, hq, hkv, d = 2, 4, 2, 32
    q = _arr((b, hq, d), dtype)
    k = _arr((b, t, hkv, d), dtype)
    v = _arr((b, t, hkv, d), dtype)
    vlen = jnp.asarray([min(7, t), t], jnp.int32)
    got = ops.decode_attention(q, k, v, vlen, bkv=bkv)
    _close(got, ref.decode_attention(q, k, v, vlen), tol)


def test_kernels_accept_tuned_plan_defaults():
    """tentpole: with no blocks given, kernels resolve the cached KernelPlan
    and still match their oracle."""
    from repro.tune import PlanCache, set_default_cache
    set_default_cache(PlanCache(None))
    try:
        q, k, v = _arr((1, 2, 60, 16)), _arr((1, 2, 60, 16)), _arr((1, 2, 60, 16))
        _close(ops.flash_attention(q, k, v),
               ref.attention(q, k, v), 2e-4)
        qd, kd, vd = _arr((2, 4, 16)), _arr((2, 90, 2, 16)), _arr((2, 90, 2, 16))
        vlen = jnp.asarray([13, 90], jnp.int32)
        _close(ops.decode_attention(qd, kd, vd, vlen),
               ref.decode_attention(qd, kd, vd, vlen), 1e-4)
        x, y = _arr((96, 100)), _arr((100, 64))
        _close(ops.matmul(x, y), ref.matmul(x, y), 1e-4)
    finally:
        set_default_cache(None)


def test_lfsr_properties():
    idx = np.asarray(ops.lfsr_indices(4096, bits=16))
    assert idx.min() >= 0 and idx.max() < (1 << 16)
    # maximal-length LFSR: no repeats within the period
    assert len(np.unique(idx)) == len(idx)


@pytest.mark.parametrize("vlens", [[7, 130, 256], [1, 64, 255]])
@pytest.mark.parametrize("hq,hkv", [(8, 2), (4, 4)])
def test_decode_attention(vlens, hq, hkv):
    b, t, d = 3, 256, 32
    q = _arr((b, hq, d))
    k = _arr((b, t, hkv, d))
    v = _arr((b, t, hkv, d))
    vlen = jnp.asarray(vlens, jnp.int32)
    got = ops.decode_attention(q, k, v, vlen, bkv=64)
    want = ref.decode_attention(q, k, v, vlen)
    _close(got, want, 1e-4)


def test_decode_attention_softcap():
    b, t, hq, hkv, d = 2, 128, 4, 2, 16
    q, k, v = _arr((b, hq, d)), _arr((b, t, hkv, d)), _arr((b, t, hkv, d))
    vlen = jnp.asarray([50, 128], jnp.int32)
    got = ops.decode_attention(q, k, v, vlen, bkv=32, softcap=10.0)
    want = ref.decode_attention(q, k, v, vlen, softcap=10.0)
    _close(got, want, 1e-4)


def test_paged_attention_matches_contiguous():
    from repro.serve.kvcache import PagedKVCache
    b, t, hq, hkv, d = 3, 256, 8, 2, 32
    q, k, v = _arr((b, hq, d)), _arr((b, t, hkv, d)), _arr((b, t, hkv, d))
    vlen = jnp.asarray([7, 130, 256], jnp.int32)
    pool = PagedKVCache(num_pages=32, page_size=32, num_kv_heads=hkv,
                        head_dim=d)
    for i in range(b):
        pool.alloc(i)
        pool.append(i, k[i, :int(vlen[i])], v[i, :int(vlen[i])])
    table, vl = pool.batch_view([0, 1, 2])
    got = ops.paged_attention(q, pool.k_pages, pool.v_pages, table, vl)
    want = ref.decode_attention(q, k, v, vlen)
    _close(got, want, 1e-4)
    # oracle for the paged layout itself
    _close(ref.paged_attention(q, pool.k_pages, pool.v_pages, table, vl),
           want, 1e-4)


# ---------------------------------------------------------------------------
# paged_attention serving paths: softcap, ring windows, int8 pages
# (satellite parity sweep — fp32/bf16 x non-divisible lengths vs ref.py)
# ---------------------------------------------------------------------------

def _fill_pool(k, v, vlen, page, window=None, dtype=None):
    """Append per-sequence k/v (B, T, Hkv, D) into a fresh page pool."""
    from repro.serve.kvcache import PagedKVCache
    b, t, hkv, d = k.shape
    pool = PagedKVCache(num_pages=4 + b * (t // page + 1), page_size=page,
                        num_kv_heads=hkv, head_dim=d,
                        dtype=dtype or str(k.dtype), window=window)
    for i in range(b):
        pool.alloc(i)
        pool.append(i, k[i, :int(vlen[i])], v[i, :int(vlen[i])])
    table, vl = pool.batch_view(list(range(b)))
    return pool, table, vl


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("vlens", [[7, 100, 256], [1, 53, 255]])
def test_paged_attention_softcap(dtype, tol, vlens):
    """satellite: the paged kernel's softcap path (gemma2) vs the dense
    oracle, across dtypes and non-divisible lengths."""
    b, t, hq, hkv, d = 3, 256, 8, 2, 32
    q, k, v = _arr((b, hq, d), dtype), _arr((b, t, hkv, d), dtype), \
        _arr((b, t, hkv, d), dtype)
    vlen = jnp.asarray(vlens, jnp.int32)
    pool, table, vl = _fill_pool(k, v, vlen, page=32)
    got = ops.paged_attention(q, pool.k_pages, pool.v_pages, table, vl,
                              softcap=20.0)
    want = ref.decode_attention(q, k, v, vlen, softcap=20.0)
    _close(got, want, tol)
    _close(ref.paged_attention(q, pool.k_pages, pool.v_pages, table, vl,
                               softcap=20.0), want, tol)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("window,vlens", [(32, [7, 100, 250]),
                                          (24, [1, 33, 256])])
def test_paged_attention_ring_window(dtype, tol, window, vlens):
    """Ring tables: the pool holds only ceil(window/page)+1 pages per
    sequence, yet attention over the live window is exact."""
    b, t, hq, hkv, d = 3, 256, 4, 2, 32
    q, k, v = _arr((b, hq, d), dtype), _arr((b, t, hkv, d), dtype), \
        _arr((b, t, hkv, d), dtype)
    vlen = jnp.asarray(vlens, jnp.int32)
    pool, table, vl = _fill_pool(k, v, vlen, page=16, window=window)
    for i in range(b):
        assert len(pool.tables[i]) <= pool.ring_slots
    got = ops.paged_attention(q, pool.k_pages, pool.v_pages, table, vl,
                              window=window)
    # dense windowed oracle: naive attention with explicit kv positions
    # (the ring layout never materializes the full sequence)
    from repro.models.attention import AttnParams, naive_attention
    kpos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    kpos = jnp.where(kpos < vl[:, None], kpos, -10**9)
    dense = naive_attention(q[:, None], k, v,
                            AttnParams(window=window),
                            q_offset=vl - 1, k_positions=kpos)[:, 0]
    _close(got, dense, tol)
    _close(ref.paged_attention(q, pool.k_pages, pool.v_pages, table, vl,
                               window=window), dense, tol)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-4),
                                       (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("vlens", [[7, 100, 250], [1, 64, 255]])
def test_paged_attention_int8_pages_match_dense_int8(dtype, tol, vlens):
    """satellite: int8 pages + per-token scale lanes dequantized in-kernel
    == dense int8-KV attention (quantize once, dequantize outside)."""
    from repro.models.transformer import _kv_quant
    b, t, hq, hkv, d = 3, 256, 8, 2, 32
    q = _arr((b, hq, d), dtype)
    k, v = _arr((b, t, hkv, d), dtype), _arr((b, t, hkv, d), dtype)
    vlen = jnp.asarray(vlens, jnp.int32)
    kq, ks_tok = _kv_quant(k)
    vq, vs_tok = _kv_quant(v)
    page = 32
    pool, table, vl = _fill_pool(kq, vq, vlen, page=page, dtype="int8")
    ks = jnp.zeros((pool.num_pages, page), jnp.float32)
    vs = jnp.zeros((pool.num_pages, page), jnp.float32)
    for i in range(b):
        for li, pid in enumerate(pool.tables[i]):
            n = min(page, int(vlen[i]) - li * page)
            ks = ks.at[pid, :n].set(ks_tok[i, li * page:li * page + n])
            vs = vs.at[pid, :n].set(vs_tok[i, li * page:li * page + n])
    got = ops.paged_attention(q, pool.k_pages, pool.v_pages, table, vl,
                              k_scale=ks, v_scale=vs)
    # dense int8-KV oracle: dequantize the whole cache, then attend
    kd = (kq.astype(jnp.float32) * ks_tok[..., None, None]).astype(dtype)
    vd = (vq.astype(jnp.float32) * vs_tok[..., None, None]).astype(dtype)
    want = ref.decode_attention(q, kd, vd, vlen)
    _close(got, want, tol)
    _close(ref.paged_attention(q, pool.k_pages, pool.v_pages, table, vl,
                               k_scale=ks, v_scale=vs), want, tol)


def test_paged_pool_alloc_release():
    from repro.serve.kvcache import PagedKVCache
    pool = PagedKVCache(num_pages=4, page_size=8, num_kv_heads=1, head_dim=8)
    pool.alloc(0)
    pool.append(0, jnp.ones((20, 1, 8)), jnp.ones((20, 1, 8)))
    assert pool.pages_in_use == 3 and pool.lengths[0] == 20
    pool.alloc(1)
    pool.append(1, jnp.ones((8, 1, 8)), jnp.ones((8, 1, 8)))
    assert pool.pages_in_use == 4
    with pytest.raises(MemoryError):
        pool.append(1, jnp.ones((8, 1, 8)), jnp.ones((8, 1, 8)))
    pool.release(0)
    assert pool.pages_in_use == 1

"""Multi-device integration tests: each scenario runs in a subprocess with
8 fake CPU devices (XLA_FLAGS is process-wide, so it must not leak into the
single-device tests)."""
import os
import subprocess
import sys

import pytest

SCRIPT = os.path.join(os.path.dirname(__file__), "_md_scenarios.py")


def _run(name, timeout=420):
    r = subprocess.run([sys.executable, SCRIPT, name], capture_output=True,
                       text=True, timeout=timeout)
    assert r.returncode == 0, f"{name} failed:\n{r.stdout}\n{r.stderr}"
    assert f"PASS {name}" in r.stdout


@pytest.mark.parametrize("scenario", [
    "sharded_train", "elastic_reshard", "dp_compression", "decode_sharded",
    "serve_tp", "serve_tp_spec", "serve_dp_pool"])
def test_multidevice(scenario):
    _run(scenario)

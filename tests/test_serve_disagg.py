"""Disaggregated prefill/decode tests: the finished-prefill hand-off
(:meth:`ServeEngine.export_finished_prefill` /
:meth:`ServeEngine.import_prefill`) and the :class:`DisaggPool` router.

Three layers:

1. pool drains — a prefill-pool -> decode-pool drain must be **bitwise
   identical** to a colocated drain of the same requests, across greedy /
   sampled / int8-KV backends (and TP=2 meshes when devices allow),
   because every piece of carried state is either shipped exactly
   (pages, by checksum) or re-derived from ``(seed, rid)`` (PRNG);
2. hand-off mechanics — export/import precondition errors, pool
   construction validation, routing through the (fixed) SwapCostModel,
   and the transfer-byte ledger;
3. failure paths — chaos-corrupted transfers degrade to decode-side
   recompute without token divergence, ``evacuate`` survives a
   swap-kind resume whose host tier is gone, and ``adopt`` of an
   already-finished request is a no-op.
"""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, override, smoke_config
from repro.models import RuntimeFlags, build
from repro.serve import (DisaggChaos, DisaggChaosConfig, DisaggConfig,
                         DisaggPool, Request, SamplingParams, Scheduler,
                         SchedulerConfig, ServeEngine, make_transfer_entry)
from repro.serve.engine import _Resume

FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                     moe_impl="dense", loss_chunk=16)

_STATE = {}


def _bundle(kv_dtype="native"):
    key = ("bundle", kv_dtype)
    if key not in _STATE:
        cfg = smoke_config(ARCHS["gemma-2b"])
        flags = (FLAGS if kv_dtype == "native"
                 else RuntimeFlags(**{**FLAGS.__dict__,
                                      "kv_dtype": kv_dtype}))
        bundle = build(cfg, flags)
        _STATE[key] = (cfg, bundle, bundle.init(jax.random.PRNGKey(7)))
    return _STATE[key]


_KW = dict(batch_size=2, max_len=64, window=4, prefill_chunk=8,
           cache_backend="paged", seed=0)


def _engine(kv_dtype="native", **kw):
    _, bundle, params = _bundle(kv_dtype)
    return ServeEngine(bundle, params, **{**_KW, **kw})


def _pool(key, kv_dtype="native", config=None, n_decode=1, **kw):
    """One prefill + ``n_decode`` decode engines, cached per key the way
    the cluster tests cache fronts (jit caches survive reset)."""
    if key not in _STATE:
        _STATE[key] = DisaggPool(
            [_engine(kv_dtype, **kw)],
            [_engine(kv_dtype, **kw) for _ in range(n_decode)],
            config or DisaggConfig(force="disagg"))
    pool = _STATE[key]
    pool.reset()
    return pool


def _mk_reqs(n=4, max_new=8, seed=13):
    cfg = _bundle()[0]
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=int(rng.integers(12, 28)))
                    .astype(np.int32),
                    max_new_tokens=max_new) for i in range(n)]


def _drain(target, reqs, chaos=None):
    submit = getattr(target, "submit", None) or target.add_request
    for r in reqs:
        submit(r)
    if isinstance(target, DisaggPool):
        target.run(chaos=chaos)
    else:
        target.run_to_completion()
    return {r.rid: list(r.out_tokens) for r in reqs}


def _reference(key, kv_dtype="native", **kw):
    """Colocated single-engine streams for the standard mix, cached."""
    if key not in _STATE:
        eng = _engine(kv_dtype, **kw)
        _STATE[key] = (_drain(eng, _mk_reqs()), eng)
    return _STATE[key][0]


# ---------------------------------------------------------------------------
# pool drains: disaggregated == colocated, bitwise
# ---------------------------------------------------------------------------


def test_disagg_drain_bitwise_greedy():
    want = _reference("ref")
    pool = _pool("pool")
    got = _drain(pool, _mk_reqs())
    assert got == want
    s = pool.stats()
    assert s.prefill_exports == s.prefill_imports == len(want)
    assert s.transfer_bytes > 0 and s.transfer_fallbacks == 0
    # the prefill pool never decoded; the decode pool never exported
    assert pool.prefill_engines[0].stats.tokens_out == len(want)  # seed toks
    assert pool.decode_engines[0].stats.prefill_exports == 0
    d = pool.dstats
    assert d.transfers == len(want) and d.completed == d.submitted


def test_disagg_drain_bitwise_sampled():
    samp = SamplingParams(temperature=0.9, top_k=11)
    want = _reference("ref_samp", sampling=samp)
    pool = _pool("pool_samp", sampling=samp)
    got = _drain(pool, _mk_reqs())
    assert got == want                       # (seed, rid) chain replayed
    assert pool.stats().prefill_imports == len(want)


def test_disagg_drain_bitwise_int8():
    want = _reference("ref8", kv_dtype="int8")
    pool = _pool("pool8", kv_dtype="int8")
    got = _drain(pool, _mk_reqs())
    assert got == want                       # scale lanes rode the buffer
    assert pool.stats().prefill_imports == len(want)


def test_disagg_two_decode_replicas_bitwise():
    want = _reference("ref")
    pool = _pool("pool2", n_decode=2)
    got = _drain(pool, _mk_reqs())
    assert got == want
    loads = [e.stats.prefill_imports for e in pool.decode_engines]
    assert sum(loads) == len(want)
    assert all(n > 0 for n in loads)         # least-loaded spread the lands


def test_force_colocated_never_ships():
    want = _reference("ref")
    pool = _pool("pool_colo", config=DisaggConfig(force="colocated"))
    got = _drain(pool, _mk_reqs())
    assert got == want                       # decode pool runs its own prefill
    s = pool.stats()
    assert s.prefill_exports == 0 and s.transfer_bytes == 0
    assert pool.dstats.colocated_routed == len(want)
    assert pool.prefill_engines[0].stats.tokens_out == 0


def test_percentiles_deterministic_and_positive():
    pool = _pool("pool")
    _drain(pool, _mk_reqs())
    a = pool.percentiles()
    pool.reset()
    _drain(pool, _mk_reqs())
    assert pool.percentiles() == a
    assert all(v > 0 for v in a.values())
    assert pool.dstats.rounds > 0


# ---------------------------------------------------------------------------
# hand-off mechanics
# ---------------------------------------------------------------------------


def test_pool_construction_validation():
    eng = _engine()
    with pytest.raises(ValueError, match=">= 1 prefill"):
        DisaggPool([], [eng])
    with pytest.raises(ValueError, match="unknown force"):
        DisaggPool([eng], [eng], DisaggConfig(force="sideways"))
    with pytest.raises(ValueError, match="share the sampling seed"):
        DisaggPool([eng], [_engine(seed=1)])
    with pytest.raises(ValueError, match="share max_len"):
        DisaggPool([eng], [_engine(max_len=32)])
    with pytest.raises(ValueError, match="share the page size"):
        DisaggPool([eng], [_engine(page_size=16)])
    _, bundle, params = _bundle()
    dense = ServeEngine(bundle, params, **{**_KW, "cache_backend": "dense"})
    with pytest.raises(ValueError, match="requires paged engines"):
        DisaggPool([eng], [dense])
    noswap = _engine(scheduler=Scheduler(SchedulerConfig(swap=False)))
    with pytest.raises(ValueError, match="host swap tier"):
        DisaggPool([noswap], [eng])


def test_route_follows_link_bandwidth():
    # auto routing (force=None) is the fixed cost model's break-even: a
    # glacial link prices the shipment above a decode-side re-prefill
    fast = DisaggPool([_engine()], [_engine()],
                      DisaggConfig(link_bw=1e15, force=None))
    slow = DisaggPool([_engine()], [_engine()],
                      DisaggConfig(link_bw=1.0, force=None))
    req = _mk_reqs(n=1)[0]
    assert fast.route(req) == "disagg"
    assert slow.route(req) == "colocated"
    # the configured link is adopted verbatim — never rescaled
    assert fast.cost_model.host_link_bw == 1e15
    slow.submit(req)
    assert slow.dstats.colocated_routed == 1 and slow.dstats.disagg_routed == 0


def test_transfer_byte_ledger_matches_geometry():
    from repro.core.memmodel import next_pow2

    pool = _pool("pool")
    reqs = _mk_reqs()
    _drain(pool, reqs)
    eng = pool.decode_engines[0]
    predicted = 2 * sum(
        next_pow2(max(1, -(-len(r.prompt) // eng.page))) * eng.bytes_per_page
        for r in reqs)
    assert pool.stats().transfer_bytes == predicted


def test_export_preconditions():
    eng = _engine()
    with pytest.raises(ValueError, match="empty slot"):
        eng.export_finished_prefill(0)
    req = _mk_reqs(n=1)[0]                   # prompt > prefill_chunk
    eng.add_request(req)
    eng._admit()                             # first chunk only
    assert 0 in eng._pending
    with pytest.raises(ValueError, match="mid-prefill"):
        eng.export_finished_prefill(0)
    while 0 in eng._pending:                 # finish the chunked prefill
        eng._admit()
    assert len(req.out_tokens) == 1          # seed token: exportable now
    eng.decode_many(1)
    with pytest.raises(ValueError, match="decode must not have begun"):
        eng.export_finished_prefill(0)

    noswap = _engine(scheduler=Scheduler(SchedulerConfig(swap=False)))
    noswap.add_request(_mk_reqs(n=1)[0])
    while 0 in noswap._pending or noswap.slots[0] is None:
        noswap._admit()
    with pytest.raises(ValueError, match="host swap tier"):
        noswap.export_finished_prefill(0)


def test_import_preconditions():
    src = _engine()
    req = _mk_reqs(n=1)[0]
    src.add_request(req)
    while 0 in src._pending or src.slots[0] is None:
        src._admit()
    shipped, entry = src.export_finished_prefill(0)
    assert shipped is req and int(entry.length) == len(req.prompt)

    noswap = _engine(scheduler=Scheduler(SchedulerConfig(swap=False)))
    with pytest.raises(ValueError, match="host swap tier"):
        noswap.import_prefill(req, entry)
    dst = _engine()
    short = Request(rid=req.rid, prompt=req.prompt[:4].copy(),
                    max_new_tokens=4)
    short.out_tokens.append(req.out_tokens[0])
    with pytest.raises(ValueError, match="prompt holds"):
        dst.import_prefill(short, entry)
    decoded = Request(rid=req.rid, prompt=req.prompt.copy(),
                      max_new_tokens=8)
    decoded.out_tokens.extend([1, 2])
    with pytest.raises(ValueError, match="exactly the seed token"):
        dst.import_prefill(decoded, entry)
    # the happy path drains to the colocated stream
    dst.import_prefill(req, entry)
    dst.run_to_completion()
    colo = _engine()
    ref = _mk_reqs(n=1)[0]
    colo.add_request(ref)
    colo.run_to_completion()
    assert list(req.out_tokens) == list(ref.out_tokens)
    assert dst.stats.prefill_imports == 1 and dst.stats.swap_ins == 0


# ---------------------------------------------------------------------------
# failure paths
# ---------------------------------------------------------------------------


def test_transfer_corruption_recovers_by_recompute():
    want = _reference("ref")
    pool = _pool("pool")
    chaos = DisaggChaos(DisaggChaosConfig(seed=5, corrupt_prob=1.0))
    got = _drain(pool, _mk_reqs(), chaos=chaos)
    assert got == want                       # recompute is the same stream
    s = pool.stats()
    assert chaos.corruptions == len(want)
    assert s.transfer_fallbacks == len(want) and s.recompute_resumes >= 1
    assert s.prefill_imports == 0            # no corrupted buffer landed


def test_transfer_corruption_partial_seeded():
    want = _reference("ref")
    pool = _pool("pool")
    chaos = DisaggChaos(DisaggChaosConfig(seed=9, corrupt_prob=0.5))
    got = _drain(pool, _mk_reqs(), chaos=chaos)
    assert got == want
    s = pool.stats()
    assert s.prefill_imports + s.transfer_fallbacks == len(want)


def test_evacuate_survives_lost_host_tier():
    """A swap-kind resume whose host tier vanished (engine built with
    swap disabled, or the tier dropped with the replica) must not crash
    ``evacuate`` — the record is discarded and ``adopt`` re-derives a
    recompute resume from the request alone."""
    e1 = _engine(scheduler=Scheduler(SchedulerConfig(swap=False)))
    assert e1.host_tier is None
    e2 = _engine()
    want = _drain(_engine(), _mk_reqs(seed=13))

    reqs = _mk_reqs(seed=13)
    for r in reqs:
        e1.add_request(r)
    for _ in range(3):
        e1.step()
    mid = [i for i, r in enumerate(e1.slots)
           if r is not None and r.out_tokens and not r.done]
    assert mid
    e1.preempt(mid[0], mode="recompute")
    rid = e1.queue[-1].rid
    res = e1._resume[rid]
    # simulate the lost tier: the resume claims swapped pages that no
    # host tier holds anymore
    e1._resume[rid] = _Resume("swap", res.ctx, res.pending)
    moved = e1.evacuate()
    assert not e1.queue and all(s is None for s in e1.slots)
    for r in moved:
        e2.adopt(r)
    e2.run_to_completion()
    assert {r.rid: list(r.out_tokens) for r in reqs} == want
    assert e2.stats.recompute_resumes >= 1


def test_adopt_finished_request_is_noop():
    eng = _engine()
    req = _mk_reqs(n=1, max_new=4)[0]
    eng.add_request(req)
    eng.run_to_completion()
    assert req.done and len(req.out_tokens) == 4
    tokens = list(req.out_tokens)
    other = _engine()
    other.adopt(req)
    assert not other.queue                   # nothing admitted
    other.run_to_completion()
    assert list(req.out_tokens) == tokens    # stream untouched
    assert other.stats.tokens_out == 0


# ---------------------------------------------------------------------------
# launch path + TP
# ---------------------------------------------------------------------------


def test_build_disagg_pool_smoke():
    from repro.launch.serve import build_disagg_pool

    _, bundle, params = _bundle()
    pool = build_disagg_pool(bundle, params, prefill_replicas=1,
                             decode_replicas=2,
                             disagg_config=DisaggConfig(force="disagg"),
                             **_KW)
    assert isinstance(pool, DisaggPool) and len(pool.engines) == 3
    want = _reference("ref")
    got = _drain(pool, _mk_reqs())
    assert got == want
    with pytest.raises(ValueError, match=">= 1 prefill"):
        build_disagg_pool(bundle, params, prefill_replicas=0, **_KW)


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="TP=2 hand-off needs 2 devices")
def test_disagg_tp2_bitwise():
    """Prefill TP=2 mesh -> decode TP=2 mesh: per-shard gathers must
    assemble full pages, and the drain must match the TP=2 colocated
    engine bitwise."""
    from repro.dist import ServeMesh

    key = ("tp2",)
    if key not in _STATE:
        cfg2 = override(smoke_config(ARCHS["gemma-2b"]), num_kv_heads=2)
        bundle2 = build(cfg2, FLAGS)
        params2 = bundle2.init(jax.random.PRNGKey(7))
        _STATE[key] = (
            ServeEngine(bundle2, params2, **_KW, dist=ServeMesh.tp(2)),
            DisaggPool(
                [ServeEngine(bundle2, params2, **_KW, dist=ServeMesh.tp(2))],
                [ServeEngine(bundle2, params2, **_KW, dist=ServeMesh.tp(2))],
                DisaggConfig(force="disagg")))
    single, pool = _STATE[key]
    single.reset()
    pool.reset()
    reqs = _mk_reqs(n=3, max_new=6)
    want = _drain(single, reqs)
    got = _drain(pool, _mk_reqs(n=3, max_new=6))
    assert got == want
    assert pool.stats().prefill_imports >= 1

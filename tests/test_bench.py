"""repro.bench subsystem: schema round-trip, comparator verdicts, registry
smoke (BENCH_FAST scale), and measured-mode calibration."""
import dataclasses
import json

import pytest

from repro.bench import (BenchResult, BenchRun, Timing, compare_runs,
                         calibrate, fit_spec, run_sweeps, samples_from_run,
                         synthetic_samples)
from repro.bench.compare import (ADDED, IMPROVEMENT, REGRESSION, REMOVED,
                                 UNCHANGED, main as compare_main)
from repro.bench.registry import ORDER, REGISTRY
from repro.core.memmodel import V5E
from repro.core.patterns import Knobs, Pattern


def _result(name, sweep="unit_size", gbps=10.0, pattern=Pattern.RANDOM,
            timing=None, **extras):
    return BenchResult(
        name=name, sweep=sweep, pattern=pattern.value,
        knobs=dataclasses.asdict(Knobs(unit_bytes=1024, outstanding=8)),
        us_per_call=123.4, gbps_measured=gbps, gbps_predicted=8.0,
        timing=timing, extras=extras)


def _run(results):
    return BenchRun(results=results, spec={"name": "test"})


# ---------------------------------------------------------------------------
# schema round-trip
# ---------------------------------------------------------------------------

def test_schema_round_trip(tmp_path):
    run = _run([
        _result("a", timing=Timing(best_s=1e-3, mean_s=1.5e-3, trials=3),
                note="x"),
        _result("b", sweep="stride", pattern=Pattern.STRIDED, gbps=2.5),
    ])
    run.calibration = {"latency_scale": 1.5}
    path = run.dump(str(tmp_path / "BENCH_test.json"))
    loaded = BenchRun.load(path)
    assert loaded.to_dict() == run.to_dict()
    assert loaded.results[0].timing.noise == pytest.approx(0.5)
    assert loaded.results[0].measured_vs_predicted == pytest.approx(10.0 / 8.0)
    assert loaded.sweeps() == ["stride", "unit_size"]
    # the file itself is valid JSON with both bandwidth columns on every row
    raw = json.loads(open(path).read())
    for row in raw["results"]:
        assert "gbps_measured" in row and "gbps_predicted" in row


def test_save_names_file_with_timestamp(tmp_path):
    p1 = _run([_result("a")]).save(str(tmp_path))
    p2 = _run([_result("a")]).save(str(tmp_path))
    assert "BENCH_" in p1 and p1.endswith(".json")
    assert p1 != p2  # same-second runs must not clobber each other


# ---------------------------------------------------------------------------
# comparator
# ---------------------------------------------------------------------------

def test_compare_verdicts_on_synthetic_pair():
    old = _run([
        _result("reg", gbps=10.0),
        _result("imp", gbps=10.0),
        _result("same", gbps=10.0),
        _result("gone", gbps=10.0),
    ])
    new = _run([
        _result("reg", gbps=5.0),      # -50% -> regression
        _result("imp", gbps=20.0),     # +100% -> improvement
        _result("same", gbps=10.5),    # +5% -> inside 15% noise floor
        _result("new", gbps=1.0),
    ])
    rep = compare_runs(old, new)
    v = rep.verdicts()
    assert v["reg"] == REGRESSION
    assert v["imp"] == IMPROVEMENT
    assert v["same"] == UNCHANGED
    assert v["gone"] == REMOVED
    assert v["new"] == ADDED
    assert [r.name for r in rep.regressions] == ["reg"]
    assert "regression" in rep.render()


def test_compare_noise_widens_threshold():
    """A jittery row (30% trial spread) must not flag a 20% drop."""
    noisy = Timing(best_s=1e-3, mean_s=1.3e-3, trials=3)
    old = _run([_result("r", gbps=10.0, timing=noisy)])
    new = _run([_result("r", gbps=8.0, timing=noisy)])
    assert compare_runs(old, new).verdicts()["r"] == UNCHANGED
    # the same drop on a steady row IS a regression at a 5% floor
    steady = Timing(best_s=1e-3, mean_s=1.0e-3, trials=3)
    old = _run([_result("r", gbps=10.0, timing=steady)])
    new = _run([_result("r", gbps=8.0, timing=steady)])
    assert compare_runs(old, new, noise_threshold=0.05).verdicts()["r"] == \
        REGRESSION


def test_compare_flags_vanished_bandwidth():
    """A row whose measured bandwidth drops to zero must not slip through
    the wall-clock fallback as 'unchanged'."""
    old = _run([_result("r", gbps=10.0)])
    new = _run([_result("r", gbps=0.0)])
    rep = compare_runs(old, new)
    row = rep.rows[0]
    assert row.verdict == REGRESSION
    assert row.metric == "gbps_measured" and row.rel_change == -1.0
    # and the mirror case reads as an improvement, not a regression
    assert compare_runs(new, old).verdicts()["r"] == IMPROVEMENT


def test_compare_us_fallback_for_bandwidthless_rows():
    old = _run([_result("r", gbps=0.0)])
    new = _run([dataclasses.replace(_result("r", gbps=0.0), us_per_call=300.0)])
    rep = compare_runs(old, new, noise_threshold=0.15)
    row = rep.rows[0]
    assert row.metric == "us_per_call"
    assert row.verdict == REGRESSION  # 123us -> 300us is slower


def test_compare_cli(tmp_path, capsys):
    a = _run([_result("r", gbps=10.0)]).dump(str(tmp_path / "a.json"))
    b = _run([_result("r", gbps=1.0)]).dump(str(tmp_path / "b.json"))
    assert compare_main([a, a]) == 0
    assert compare_main([a, b]) == 1
    assert "regression" in capsys.readouterr().out


def test_structural_gate_ignores_wallclock_noise(tmp_path, capsys):
    """--gate structural: a wall-clock row's drop is advisory (different
    host), but a deterministic-flagged row's drop and a vanished metric
    still fail the gate — the CI baseline-compare contract."""
    timed = Timing(best_s=1e-3, mean_s=1.1e-3, trials=3)
    old = _run([
        _result("wallclock", gbps=10.0, timing=timed),
        _result("counter", gbps=8.0, deterministic=True),  # ticks/dispatch
    ])
    noisy_new = _run([
        _result("wallclock", gbps=1.0, timing=timed),   # -90%: noise-class
        _result("counter", gbps=8.0, deterministic=True),
    ])
    a = old.dump(str(tmp_path / "a.json"))
    b = noisy_new.dump(str(tmp_path / "b.json"))
    assert compare_main([a, b]) == 1                    # default gate: fails
    assert compare_main([a, b, "--gate", "structural"]) == 0
    assert "1 regression" in capsys.readouterr().out

    broken = _run([
        _result("wallclock", gbps=10.0, timing=timed),
        _result("counter", gbps=1.0, deterministic=True),  # real drop
    ])
    c = broken.dump(str(tmp_path / "c.json"))
    assert compare_main([a, c, "--gate", "structural"]) == 1

    vanished = _run([
        _result("wallclock", gbps=0.0, timing=timed),   # metric vanished
        _result("counter", gbps=8.0, deterministic=True),
    ])
    d = vanished.dump(str(tmp_path / "d.json"))
    assert compare_main([a, d, "--gate", "structural"]) == 1

    rep = compare_runs(old, broken)
    assert [r.name for r in rep.structural_regressions] == ["counter"]

    # dropping a deterministic row entirely must gate too — removing the
    # invariant is not a pass — under BOTH gate modes; dropping a
    # wall-clock-only row stays advisory
    missing_counter = _run([_result("wallclock", gbps=9.0, timing=timed)])
    e = missing_counter.dump(str(tmp_path / "e.json"))
    assert compare_main([a, e, "--gate", "structural"]) == 1
    assert compare_main([a, e]) == 1

    # a >=2x us_per_call slowdown on an UNFLAGGED row is noise, not
    # structural: rel <= -1.0 only counts for the bandwidth metric
    slow_old = _run([dataclasses.replace(
        _result("uscall", gbps=0.0, timing=timed), us_per_call=100.0)])
    slow_new = _run([dataclasses.replace(
        _result("uscall", gbps=0.0, timing=timed), us_per_call=250.0)])
    f = slow_old.dump(str(tmp_path / "f.json"))
    g = slow_new.dump(str(tmp_path / "g.json"))
    assert compare_main([f, g]) == 1                    # default gate: fails
    assert compare_main([f, g, "--gate", "structural"]) == 0


# ---------------------------------------------------------------------------
# registry smoke (the BENCH_FAST=1 campaign)
# ---------------------------------------------------------------------------

def test_registry_lists_every_sweep_in_paper_order():
    assert len(REGISTRY) == len(ORDER)
    assert ORDER == ["latency", "outstanding", "unit_size", "stride", "burst",
                     "num_kernels", "random", "database", "conv", "roofline",
                     "serve", "kernel_plan", "paged_serve", "spec_serve",
                     "dist_serve", "preempt_serve", "cluster_serve",
                     "disagg_serve"]


def test_registry_rejects_unknown_sweep():
    with pytest.raises(KeyError):
        run_sweeps(names=["nope"], fast=True, echo=False)


@pytest.mark.slow
def test_fast_campaign_every_sweep_emits(tmp_path):
    """BENCH_FAST-scale smoke: every registered sweep runs, each emits
    >= 1 result (dist_serve needs >= 2 devices and is exempt on fewer),
    every row carries both bandwidth columns, and the run persists."""
    import jax
    run = run_sweeps(fast=True, echo=False, out_dir=str(tmp_path))
    assert run.failures == {}
    for name in REGISTRY:
        if name == "dist_serve" and len(jax.devices()) < 2:
            continue
        rows = run.by_sweep(name)
        assert rows, f"sweep {name} emitted no results"
    for r in run.results:
        assert r.gbps_measured >= 0.0
        assert r.gbps_predicted >= 0.0
    assert "path" in run.env
    reloaded = BenchRun.load(run.env["path"])
    assert len(reloaded.results) == len(run.results)
    # a fresh campaign compared against itself has no regressions
    assert compare_runs(reloaded, run).regressions == []


# ---------------------------------------------------------------------------
# measured-mode calibration
# ---------------------------------------------------------------------------

def test_calibration_recovers_spec_constants():
    """Acceptance: fitting samples generated FROM the model recovers the
    latency/bandwidth constants within 5%."""
    true = dataclasses.replace(V5E, dma_latency_s=420e-9, hbm_bw=512e9)
    res = fit_spec(synthetic_samples(true))
    assert abs(res.spec.dma_latency_s / true.dma_latency_s - 1) < 0.05
    assert abs(res.spec.hbm_bw / true.hbm_bw - 1) < 0.05
    assert res.rms_log_error < 0.05
    assert res.n_samples == len(synthetic_samples(true))


def test_calibration_tolerates_noise():
    true = dataclasses.replace(V5E, dma_latency_s=1200e-9, hbm_bw=96e9)
    res = fit_spec(synthetic_samples(true, noise=0.03, seed=7))
    assert abs(res.spec.dma_latency_s / true.dma_latency_s - 1) < 0.15
    assert abs(res.spec.hbm_bw / true.hbm_bw - 1) < 0.15


def test_samples_from_run_filters_and_parses():
    run = _run([
        _result("ok", sweep="unit_size", gbps=3.0),
        _result("wrong_sweep", sweep="num_kernels", gbps=3.0),
        _result("no_bw", sweep="latency", gbps=0.0),
    ])
    samples = samples_from_run(run)
    assert [s.gbps for s in samples] == [3.0]
    assert samples[0].pattern == Pattern.RANDOM
    assert samples[0].knobs.unit_bytes == 1024


def test_calibrate_measured_mode_threads_into_core():
    """calibrate() on this host: fitted spec + ratios flow through
    tune_pattern and advise_model (the measured_vs_predicted column)."""
    from repro.configs import ARCHS, SHAPES_BY_NAME
    from repro.core.advisor import advise_model, render_report
    from repro.core.autotune import tune_pattern

    cal = calibrate(fast=True)
    assert cal.spec.dma_latency_s > 0 and cal.spec.hbm_bw > 0
    assert cal.to_dict()["fitted"]["hbm_bw"] == cal.spec.hbm_bw

    tuned = tune_pattern(Pattern.SEQUENTIAL, calibration=cal)
    assert tuned.measured_vs_predicted is not None
    assert tuned.predicted_gbps <= tuned.best_gbps + 1e-9

    reps = advise_model(ARCHS["gemma-2b"], SHAPES_BY_NAME["train_4k"],
                        calibration=cal)
    assert all(r.measured_vs_predicted is not None for r in reps)
    assert all(r.predicted_gbps > 0 for r in reps)
    assert "meas/pred" in render_report(reps)

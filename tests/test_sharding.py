"""repro.dist.sharding unit tests: spec_for edge cases, policy registry,
param_shardings trees.  Pure logic — no multi-device backend needed (uses a
fake mesh object exposing only ``.shape``, like the property test)."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist import (ACT_RULES_SP, PARAM_RULES_FSDP, POLICIES,
                        param_shardings, spec_for)


class FakeMesh:
    shape = {"data": 4, "model": 2}


class FakeMultiPodMesh:
    shape = {"pod": 2, "data": 4, "model": 2}


MESH = FakeMesh()


def test_spec_for_scalar_is_empty():
    assert spec_for((), (), PARAM_RULES_FSDP, MESH) == P()


def test_spec_for_unsharded_vector():
    # a 1-D norm weight on the embed axis: divisible -> sharded over data
    assert spec_for((64,), ("embed",), PARAM_RULES_FSDP, MESH) == P("data")


def test_spec_for_axis_name_mismatch_replicates():
    # logical names absent from the rules stay replicated
    spec = spec_for((8, 8), ("layers", "state"), PARAM_RULES_FSDP, MESH)
    assert spec == P(None, None)


def test_spec_for_none_logical_axis_replicates():
    spec = spec_for((16, 32), (None, "ff"), PARAM_RULES_FSDP, MESH)
    assert spec == P(None, "model")


def test_spec_for_no_matching_mesh_axis():
    # rules naming mesh axes that don't exist on this mesh -> replicated
    rules = (("embed", "zz_missing"),)
    assert spec_for((64,), ("embed",), rules, MESH) == P(None)


def test_spec_for_divisibility_fallback():
    # 6 % 4 != 0 -> embed falls back to replicated; 6 % 2 == 0 -> ff shards
    spec = spec_for((6, 6), ("embed", "ff"), PARAM_RULES_FSDP, MESH)
    assert spec == P(None, "model")


def test_spec_for_mesh_axis_used_once_per_tensor():
    # both dims want "model"; the first (left-to-right) wins
    spec = spec_for((8, 8), ("heads", "ff"), PARAM_RULES_FSDP, MESH)
    assert spec == P("model", None)


def test_spec_for_tuple_rule_spans_multiple_axes():
    spec = spec_for((16, 32), ("batch", None),
                    (("batch", ("pod", "data")),), FakeMultiPodMesh())
    assert spec == P(("pod", "data"), None)


def test_spec_for_tuple_rule_partial_divisibility():
    # batch=4 divides pod(2) but then 4 % (2*4) != 0 -> only pod assigned
    spec = spec_for((4, 8), ("batch", None),
                    (("batch", ("pod", "data")),), FakeMultiPodMesh())
    assert spec == P("pod", None)


def test_sequence_parallel_rules_prefer_seq_over_heads():
    # residual stream: seq takes the model axis...
    assert spec_for((8, 32, 64), ("batch", "seq", "embed"),
                    ACT_RULES_SP, MESH) == P("data", "model", None)
    # ...so per-head tensors scanned later can't re-use it on heads
    assert spec_for((8, 32, 4, 16), ("batch", "seq", "heads", None),
                    ACT_RULES_SP, MESH) == P("data", "model", None, None)


def test_policies_registry_complete():
    assert {"dp", "tp", "fsdp_tp", "fsdp_tp_sp"} <= set(POLICIES)
    for p in POLICIES.values():
        assert p.name in POLICIES
        assert isinstance(p.param_rules, tuple)
    assert POLICIES["fsdp_tp"].param_rules == PARAM_RULES_FSDP


def test_policy_engines_from_mesh_shape():
    assert POLICIES["fsdp_tp"].engines(MESH) == 8
    assert POLICIES["fsdp_tp"].engines(FakeMultiPodMesh()) == 16
    # pure DP never uses the model axis -> it contributes no engines
    assert POLICIES["dp"].engines(MESH) == 4


def test_policy_param_and_data_engines():
    # params replicate under dp -> weight streaming is not divided
    assert POLICIES["dp"].param_engines(MESH) == 1
    # tp shards params only over the model axis
    assert POLICIES["tp"].param_engines(MESH) == 2
    # fsdp_tp shards params over both axes
    assert POLICIES["fsdp_tp"].param_engines(MESH) == 8
    for name in ("dp", "tp", "fsdp_tp", "fsdp_tp_sp"):
        assert POLICIES[name].data_engines(MESH) == 4
        assert POLICIES[name].data_engines(FakeMultiPodMesh()) == 8


def test_advise_model_per_site_engine_split():
    from repro.configs import ARCHS, SHAPES_BY_NAME
    from repro.core import advisor

    cfg, cell = ARCHS["gemma-2b"], SHAPES_BY_NAME["train_4k"]
    base = {r.op_name: r.bytes_moved
            for r in advisor.advise_model(cfg, cell)}
    split = {r.op_name: r.bytes_moved
             for r in advisor.advise_model(cfg, cell, engines=8,
                                           param_engines=1)}
    # batch-scaled sites split 8 ways; the replicated weight stream doesn't
    assert split["embedding.lookup"] == max(1, base["embedding.lookup"] // 8)
    assert split["params.stream"] == base["params.stream"]


def test_aggregate_bw_scales_with_policy_engines():
    from repro.core.memmodel import V5E, aggregate_bw, predict_bw
    from repro.core.patterns import Knobs, Pattern

    base = Knobs(burst_bytes=1 << 20, outstanding=4)
    per_engine = predict_bw(Pattern.SEQUENTIAL, base)
    for mesh, want in ((FakeMesh(), 8), (FakeMultiPodMesh(), 16)):
        n = POLICIES["fsdp_tp"].engines(mesh)
        assert n == want
        knobs = Knobs(burst_bytes=1 << 20, outstanding=4, engines=n)
        # Tables 3-5: aggregate bandwidth is linear in the engine count
        assert aggregate_bw(Pattern.SEQUENTIAL, knobs) == per_engine * n
        assert aggregate_bw(Pattern.SEQUENTIAL, knobs) > V5E.hbm_bw


def test_dp_shardmap_validates_mesh_and_err_shape():
    import pytest
    from repro.dist.dp_shardmap import (init_error_feedback,
                                        make_dp_train_step)
    from repro.optim import AdamWConfig, adamw

    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    loss = lambda p, b: jnp.sum(p["w"] * b["x"])
    with pytest.raises(ValueError, match="data axis"):
        make_dp_train_step(
            loss, jax.make_mesh((1,), ("batch",),
                                axis_types=(jax.sharding.AxisType.Auto,)),
            AdamWConfig())
    params = dict(w=jnp.ones((4,)))
    err = init_error_feedback(params, num_devices=2)  # wrong: mesh has 1
    step = make_dp_train_step(loss, mesh, AdamWConfig(), compress_grads=True)
    with pytest.raises(ValueError, match="residual"):
        step(params, adamw.init(params), err, dict(x=jnp.ones((2, 4))))


def test_param_shardings_tree_structure():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    abs_params = dict(
        emb=jax.ShapeDtypeStruct((256, 64), jnp.float32),
        blk=dict(w=jax.ShapeDtypeStruct((2, 64, 128), jnp.float32)))
    specs = dict(emb=("vocab", "embed"), blk=dict(w=("layers", "embed", "ff")))
    sh = param_shardings(mesh, abs_params, specs, PARAM_RULES_FSDP)
    assert set(sh) == {"emb", "blk"}
    assert sh["emb"].spec == P("model", "data")
    assert sh["blk"]["w"].spec == P(None, "data", "model")

"""Scheduler, host KV tier, and preemption tests.

Three layers:

1. pure policy units — :class:`SwapCostModel` break-even behavior,
   priority/FIFO queue ordering, victim selection, structured
   :class:`PoolExhausted` context, :class:`HostKVTier` checksum round
   trips;
2. engine integration — preempt/resume (both modes) must be bitwise
   lossless, corrupted swaps must degrade to recompute, high-priority
   traffic must displace low under pool pressure, ``reset()`` must wipe
   every scheduler/speculative trace (warm-benchmark regression);
3. seeded chaos twins (``-m chaos``) — hypothesis-free fault-injection
   drains that run even where the dev dependency is absent; the
   hypothesis differential property lives in ``test_serve_fuzz``.
"""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.core.memmodel import TPUSpec
from repro.models import RuntimeFlags, build
from repro.serve import (ChaosConfig, ChaosEngine, HostKVTier, PageAllocator,
                         PoolExhausted, Request, SamplingParams, Scheduler,
                         SchedulerConfig, ServeEngine, SwapCostModel)
from repro.serve.hosttier import checksum_pages, page_axis
from repro.serve.scheduler import VictimInfo

# ---------------------------------------------------------------------------
# SwapCostModel
# ---------------------------------------------------------------------------

# production-ish numbers: 2.5B bf16 params, gemma-2b KV row, v5e HBM,
# PCIe-class staging link
PROD = dict(weight_bytes=5e9, kv_bytes_per_token=18_432, prefill_chunk=256)


def test_cost_model_swap_beats_recompute_on_long_ctx():
    cm = SwapCostModel(**PROD)
    long_ctx = 8192
    assert cm.swap_s(long_ctx) < cm.recompute_s(long_ctx)
    assert cm.choose(long_ctx, swappable=True) == "swap"
    # and the advantage grows with context: recompute re-streams the
    # weights once per chunk, swap only moves the KV bytes
    r1 = cm.recompute_s(1024) / cm.swap_s(1024)
    r8 = cm.recompute_s(8192) / cm.swap_s(8192)
    assert r8 >= r1 > 1.0


def test_cost_model_slow_link_prefers_recompute():
    # a glacial staging link flips the decision back to recompute
    cm = SwapCostModel(**PROD, host_link_bw=1e6)
    assert cm.choose(4096, swappable=True) == "recompute"
    assert cm.resume_s(4096, swappable=True) == cm.recompute_s(4096)


def test_cost_model_unswappable_always_recomputes():
    cm = SwapCostModel(**PROD)
    assert cm.choose(8192, swappable=False) == "recompute"
    assert cm.resume_s(8192, swappable=False) == cm.recompute_s(8192)


def test_cost_model_monotonic_and_chunked():
    cm = SwapCostModel(weight_bytes=1e9, kv_bytes_per_token=1e4,
                       prefill_chunk=64)
    xs = [1, 63, 64, 65, 512, 4096]
    rec = [cm.recompute_s(x) for x in xs]
    swp = [cm.swap_s(x) for x in xs]
    assert rec == sorted(rec) and swp == sorted(swp)
    # crossing a chunk boundary costs one extra weight stream
    bump = cm.recompute_s(65) - cm.recompute_s(64)
    assert bump > 0.9 * 1e9 / cm.spec.hbm_bw


def test_cost_model_adopts_spec():
    fast = SwapCostModel(**PROD, spec=TPUSpec(hbm_bw=2 * 819e9))
    slow = SwapCostModel(**PROD, spec=TPUSpec(hbm_bw=819e9))
    assert fast.recompute_s(4096) < slow.recompute_s(4096)
    assert fast.swap_s(4096) == slow.swap_s(4096)  # link, not HBM


def _calibration(base, hbm_scale):
    """A bench CalibrationResult whose fitted spec scales base HBM bw."""
    import dataclasses

    from repro.bench.calibrate import CalibrationResult

    fitted = dataclasses.replace(base, hbm_bw=base.hbm_bw * hbm_scale)
    return CalibrationResult(spec=fitted, base_spec=base,
                             rms_log_error=0.0, n_samples=8)


def test_calibration_does_not_rescale_host_link():
    # regression: an HBM-fitted bandwidth_scale used to leak into the
    # PCIe staging link, silently doubling swap bandwidth under a 2x fit
    base = TPUSpec()
    cal = _calibration(base, 2.0)
    assert cal.bandwidth_scale == 2.0
    plain = SwapCostModel(**PROD, spec=base)
    cald = SwapCostModel(**PROD, spec=base, calibration=cal)
    # HBM side adopts the fit...
    assert cald.spec.hbm_bw == 2 * base.hbm_bw
    assert cald.recompute_s(4096) == pytest.approx(plain.recompute_s(4096) / 2)
    # ...but the staging link stays at its configured value
    assert cald.host_link_bw == plain.host_link_bw
    assert cald.swap_s(4096) == plain.swap_s(4096)


def test_calibrated_break_even_pinned_under_nonunity_scale():
    # parameters sitting between the fixed and buggy break-evens: with the
    # fitted (2x) HBM, recompute costs 1.5e-7 s/token; shipping costs
    # 2e-7 s/token on the TRUE link but 1e-7 on the wrongly-rescaled one —
    # the old code flipped this decision to "swap"
    base = TPUSpec(hbm_bw=100e9)
    cm = SwapCostModel(weight_bytes=1.28e6, kv_bytes_per_token=1e4,
                       prefill_chunk=64, spec=base, host_link_bw=1e11,
                       calibration=_calibration(base, 2.0))
    assert cm.host_link_bw == 1e11
    assert cm.choose(4096, swappable=True) == "recompute"


def test_cost_model_explicit_link_scale():
    # a separately-measured link ratio IS honored — only the implicit
    # HBM-fit leak is gone
    base = TPUSpec()
    cm = SwapCostModel(**PROD, spec=base,
                       calibration=_calibration(base, 2.0), link_scale=0.5)
    assert cm.host_link_bw == pytest.approx(0.5 * 32e9)
    plain = SwapCostModel(**PROD, spec=base)
    assert cm.swap_s(1024) == pytest.approx(2 * plain.swap_s(1024))


# ---------------------------------------------------------------------------
# Scheduler policy
# ---------------------------------------------------------------------------

def _req(rid, priority=0):
    return Request(rid=rid, prompt=np.zeros((4,), np.int32), priority=priority)


def test_order_queue_priority_then_fifo():
    sched = Scheduler()
    q = [_req(0, 0), _req(1, 1), _req(2, 0), _req(3, 1)]
    arrival = {r.rid: i for i, r in enumerate(q)}
    sched.order_queue(q, arrival)
    assert [r.rid for r in q] == [1, 3, 0, 2]


def test_order_queue_preempted_keeps_arrival_seat():
    # a preempted rid keeps its original sequence number: it resumes
    # ahead of later arrivals of its own class
    sched = Scheduler()
    q = [_req(7, 0), _req(2, 0)]          # rid 2 was admitted first, evicted
    arrival = {2: 0, 7: 5}
    sched.order_queue(q, arrival)
    assert [r.rid for r in q] == [2, 7]


def test_prefill_order_priority_first_and_capped():
    sched = Scheduler(SchedulerConfig(prefill_chunks_per_tick=2))
    prio = {0: 0, 1: 1, 2: 0, 3: 1}
    order = sched.prefill_order([0, 1, 2, 3], lambda i: prio[i])
    assert order == [1, 3]                # high-priority slots, capped at 2
    uncapped = Scheduler().prefill_order([0, 1, 2, 3], lambda i: prio[i])
    assert uncapped == [1, 3, 0, 2]


def test_prefill_chunks_per_tick_zero_rejected():
    # regression: prefill_order silently clamped a 0 cap to 1 — now the
    # config refuses values that could never advance a pending prefill
    with pytest.raises(ValueError, match="prefill_chunks_per_tick=0"):
        SchedulerConfig(prefill_chunks_per_tick=0)
    with pytest.raises(ValueError, match="must be >= 1"):
        SchedulerConfig(prefill_chunks_per_tick=-3)
    assert SchedulerConfig(prefill_chunks_per_tick=1).prefill_chunks_per_tick \
        == 1
    assert SchedulerConfig().prefill_chunks_per_tick is None


def test_pick_victim_ordering():
    sched = Scheduler()
    # no cost model: resume cost falls back to ctx tokens
    a = VictimInfo(slot=0, rid=0, priority=1, ctx_tokens=4, pages=1)
    b = VictimInfo(slot=1, rid=1, priority=0, ctx_tokens=90, pages=9)
    c = VictimInfo(slot=2, rid=2, priority=0, ctx_tokens=10, pages=2)
    d = VictimInfo(slot=3, rid=3, priority=0, ctx_tokens=10, pages=5)
    # lowest priority class first, then cheapest resume, then most pages
    assert sched.pick_victim([a, b, c, d]) == d
    # below= restricts to strictly lower priorities
    assert sched.pick_victim([a], below=1) is None
    assert sched.pick_victim([a, b], below=1) == b
    assert sched.pick_victim([a, b], below=2) == b


def test_pick_victim_disabled():
    sched = Scheduler(SchedulerConfig(preempt=False))
    v = VictimInfo(slot=0, rid=0, priority=0, ctx_tokens=4, pages=1)
    assert sched.pick_victim([v]) is None


def test_pick_victim_uses_cost_model():
    cm = SwapCostModel(**PROD)
    sched = Scheduler(cost_model=cm)
    # with the model, a short-ctx victim resumes cheaper than a long one
    short = VictimInfo(slot=0, rid=0, priority=0, ctx_tokens=8, pages=1,
                       swappable=True)
    long_ = VictimInfo(slot=1, rid=1, priority=0, ctx_tokens=4096, pages=99,
                       swappable=True)
    assert sched.pick_victim([short, long_]) == short


def test_pick_victim_mixed_swappable_prices_ring_as_recompute():
    # regression: one global swappable flag priced an unswappable
    # (ring/hybrid or mid-prefill) victim's resume at min(recompute, swap)
    # and evicted the wrong slot in a mixed pool.  Under PROD numbers swap
    # is far cheaper than recompute, so the old code saw the 1000-token
    # ring victim as the cheapest resume — but its TRUE resume is a
    # recompute costing more than shipping the 4096-token full victim.
    cm = SwapCostModel(**PROD)
    sched = Scheduler(cost_model=cm)
    ring = VictimInfo(slot=0, rid=0, priority=0, ctx_tokens=1000, pages=4,
                      swappable=False)
    full = VictimInfo(slot=1, rid=1, priority=0, ctx_tokens=4096, pages=4,
                      swappable=True)
    assert cm.swap_s(full.ctx_tokens) < cm.recompute_s(ring.ctx_tokens)
    assert sched.pick_victim([ring, full]) == full


# ---------------------------------------------------------------------------
# structured PoolExhausted (satellite)
# ---------------------------------------------------------------------------

def test_pool_exhausted_carries_structured_context():
    exc = PoolExhausted("no room", pool="ring", num_pages=8, free_pages=0,
                        live_pages=7, rid=3, need_pages=2)
    assert (exc.pool, exc.num_pages, exc.free_pages) == ("ring", 8, 0)
    assert (exc.live_pages, exc.rid, exc.need_pages) == (7, 3, 2)
    msg = str(exc)
    for frag in ("no room", "pool=ring", "pages=8", "live=7", "free=0",
                 "rid=3", "need=2"):
        assert frag in msg


def test_pool_exhausted_census_from_full_allocator():
    alloc = PageAllocator(4, 4, reserved=1)     # 3 usable pages
    alloc.alloc(0)
    alloc.reserve(0, 12)                        # takes all 3
    alloc.alloc(1)
    with pytest.raises(PoolExhausted) as ei:
        alloc.reserve(1, 8)                     # needs 2, none free
    exc = ei.value
    assert exc.pool == "full" and exc.rid == 1 and exc.need_pages == 2
    assert exc.num_pages == 4 and exc.free_pages == 0 and exc.live_pages == 3


def test_pool_exhausted_census_from_ring_allocator():
    alloc = PageAllocator(3, 4, reserved=1, window=8)   # ring_slots=3, 2 free
    alloc.alloc(0)
    with pytest.raises(PoolExhausted) as ei:
        alloc.reserve(0, 12)                    # wants 3 ring slots, 2 exist
    exc = ei.value
    assert exc.pool == "ring" and exc.rid == 0
    assert exc.need_pages == 3 and exc.free_pages == 2


# ---------------------------------------------------------------------------
# HostKVTier
# ---------------------------------------------------------------------------

def _fake_pages(n_pages=3, pad_to=4):
    """A miniature paged-cache pytree: one pool + one scale lane, padded
    along the page axis the way the engine's gather pads."""
    rng = np.random.default_rng(0)
    return {
        "k_pages": rng.standard_normal((pad_to, 8, 2, 4)).astype(np.float32),
        "k_scale": rng.standard_normal((pad_to, 8)).astype(np.float32),
    }


def test_host_tier_roundtrip():
    tier = HostKVTier()
    data = _fake_pages()
    entry = tier.put(7, data, n_pages=3, length=20)
    assert 7 in tier and len(tier) == 1
    assert tier.bytes_out == entry.nbytes and tier.bytes_held == entry.nbytes
    got, ok = tier.get(7)
    assert ok and got is entry and got.length == 20
    assert tier.bytes_in == entry.nbytes
    tier.pop(7)
    assert 7 not in tier and tier.bytes_held == 0


def test_host_tier_padding_pages_not_checksummed():
    tier = HostKVTier()
    entry = tier.put(1, _fake_pages(), n_pages=3, length=20)
    # mutate a padding page (index 3 >= n_pages): checksum must not care —
    # the engine's null-page padding legitimately changes between put/get
    entry.data["k_pages"][3] += 1.0
    _, ok = tier.get(1)
    assert ok


def test_host_tier_detects_corruption():
    tier = HostKVTier()
    entry = tier.put(1, _fake_pages(), n_pages=3, length=20)
    assert tier.corrupt(1)
    got, ok = tier.get(1)
    assert not ok and got is entry          # entry retained until popped
    assert tier.bytes_in == 0               # failed gets move no bytes
    assert not tier.corrupt(99)             # unknown rid: no-op


def test_host_tier_put_entry_installs_verbatim():
    from repro.serve import corrupt_entry, make_transfer_entry

    # a transfer buffer built off-tier installs as-is: no re-checksum, so
    # in-transit corruption surfaces at get() on the receiving side
    entry = make_transfer_entry(3, _fake_pages(), n_pages=3, length=20)
    tier = HostKVTier()
    tier.put_entry(entry)
    assert 3 in tier and tier.bytes_out == entry.nbytes
    got, ok = tier.get(3)
    assert ok and got is entry

    damaged = make_transfer_entry(4, _fake_pages(), n_pages=3, length=20)
    corrupt_entry(damaged)
    tier.put_entry(damaged)
    _, ok = tier.get(4)
    assert not ok


def test_host_tier_bytes_in_skips_failed_entries():
    # byte accounting across a mixed good/corrupt sequence: bytes_in must
    # advance only by entries whose checksum verified
    tier = HostKVTier()
    good = tier.put(1, _fake_pages(), n_pages=3, length=20)
    bad = tier.put(2, _fake_pages(), n_pages=3, length=20)
    assert tier.corrupt(2)
    _, ok = tier.get(2)
    assert not ok and tier.bytes_in == 0
    _, ok = tier.get(1)
    assert ok and tier.bytes_in == good.nbytes
    _, ok = tier.get(2)                     # retrying the bad entry: still 0
    assert not ok and tier.bytes_in == good.nbytes
    assert tier.bytes_out == good.nbytes + bad.nbytes


def test_checksum_covers_exactly_real_pages():
    data = _fake_pages()
    c3 = checksum_pages(data, 3)
    data["k_pages"][2, 0, 0, 0] += 1.0      # inside the span
    assert checksum_pages(data, 3) != c3
    c2 = checksum_pages(data, 2)
    data["k_pages"][2, 0, 0, 0] += 1.0      # outside a 2-page span
    assert checksum_pages(data, 2) == c2


def test_page_axis_rejects_non_pool_leaves():
    tree = {"kpos": np.zeros((4, 8))}
    with pytest.raises(ValueError, match="not a page-pool leaf"):
        jax.tree_util.tree_map_with_path(
            lambda p, x: page_axis(p, x), tree)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                     moe_impl="dense", loss_chunk=16)

_STATE = {}


def _bundle():
    if "bundle" not in _STATE:
        cfg = smoke_config(ARCHS["gemma-2b"])
        bundle = build(cfg, FLAGS)
        _STATE["bundle"] = (cfg, bundle, bundle.init(jax.random.PRNGKey(7)),
                            bundle.init(jax.random.PRNGKey(11)))
    return _STATE["bundle"]


def _engine(key, **kw):
    if key not in _STATE:
        cfg, bundle, params, _ = _bundle()
        _STATE[key] = ServeEngine(bundle, params, batch_size=2, max_len=64,
                                  window=4, prefill_chunk=8, **kw)
    eng = _STATE[key]
    eng.reset()
    return eng


def _mk_requests(seed=1, n=4, plen=20, new=8, priority=None):
    cfg = _bundle()[0]
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=plen).astype(np.int32),
                    max_new_tokens=new,
                    priority=0 if priority is None else priority(i))
            for i in range(n)]


def _drain(eng, reqs):
    for r in reqs:
        eng.add_request(r)
    eng.run_to_completion(max_ticks=5_000)
    assert all(r.done for r in reqs)
    return {r.rid: list(r.out_tokens) for r in reqs}


def _reference():
    if "ref" not in _STATE:
        _STATE["ref"] = _drain(_engine("eng"), _mk_requests())
    return _STATE["ref"]


def test_recompute_resume_is_lossless():
    ref = _reference()
    eng = _engine("eng")
    reqs = _mk_requests()
    for r in reqs:
        eng.add_request(r)
    for _ in range(3):
        eng.step()
    victim = next(i for i, r in enumerate(eng.slots) if r is not None)
    assert eng.preempt(victim, mode="recompute") == "recompute"
    eng.run_to_completion(max_ticks=5_000)
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref
    assert eng.stats.preemptions >= 1
    assert eng.stats.recompute_resumes + eng.stats.preempt_restarts >= 1


def test_swap_resume_is_lossless_and_counts_bytes():
    ref = _reference()
    eng = _engine("eng")
    reqs = _mk_requests()
    for r in reqs:
        eng.add_request(r)
    # past all prefills so the victim is mid-decode (swap-eligible state)
    while not any(r is not None and r.out_tokens for r in eng.slots):
        eng.step()
    victim = next(i for i, r in enumerate(eng.slots)
                  if r is not None and r.out_tokens)
    assert eng.preempt(victim, mode="swap") == "swap"
    assert eng.stats.swap_outs == 1 and len(eng.host_tier) == 1
    eng.run_to_completion(max_ticks=5_000)
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref
    assert eng.stats.swap_ins == 1 and eng.stats.swap_fallbacks == 0
    assert eng.stats.swap_bytes > 0
    assert len(eng.host_tier) == 0          # entry consumed by the resume


def test_corrupted_swap_falls_back_to_recompute():
    ref = _reference()
    eng = _engine("eng")
    reqs = _mk_requests()
    for r in reqs:
        eng.add_request(r)
    while not any(r is not None and r.out_tokens for r in eng.slots):
        eng.step()
    victim = next(i for i, r in enumerate(eng.slots)
                  if r is not None and r.out_tokens)
    rid = eng.slots[victim].rid
    eng.preempt(victim, mode="swap")
    assert eng.host_tier.corrupt(rid)
    eng.run_to_completion(max_ticks=5_000)
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref
    assert eng.stats.swap_fallbacks == 1    # checksum caught the rot
    assert eng.stats.swap_ins == 0
    assert eng.stats.recompute_resumes >= 1


def test_dense_backend_preempts_and_resumes():
    cfg, bundle, params, _ = _bundle()
    if "dense" not in _STATE:
        _STATE["dense"] = ServeEngine(bundle, params, batch_size=2,
                                      max_len=64, window=4,
                                      cache_backend="dense")
    eng = _STATE["dense"]
    eng.reset()
    reqs = _mk_requests()
    for r in reqs:
        eng.add_request(r)
    eng.run_to_completion(max_ticks=5_000)
    ref = {r.rid: list(r.out_tokens) for r in reqs}
    eng.reset()
    reqs = _mk_requests()
    for r in reqs:
        eng.add_request(r)
    for _ in range(2):
        eng.step()
    victim = next(i for i, r in enumerate(eng.slots) if r is not None)
    # dense engines have no page pools: swap silently degrades
    assert eng.preempt(victim, mode="swap") == "recompute"
    eng.run_to_completion(max_ticks=5_000)
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref


def test_high_priority_preempts_low_under_pool_pressure():
    cfg, bundle, params, _ = _bundle()
    # pool sized so two 20-token prompts fit but a third does not
    eng = ServeEngine(bundle, params, batch_size=2, max_len=64, window=4,
                      prefill_chunk=8, num_pages=2 * 3 + 3)
    rng = np.random.default_rng(2)

    def prompt():
        return rng.integers(1, cfg.vocab_size, size=20).astype(np.int32)

    low = [Request(rid=i, prompt=prompt(), max_new_tokens=24, priority=0)
           for i in range(2)]
    hi = Request(rid=99, prompt=prompt(), max_new_tokens=4, priority=1)
    for r in low:
        eng.add_request(r)
    for _ in range(4):
        eng.step()
    eng.add_request(hi)
    eng.run_to_completion(max_ticks=5_000)
    assert hi.done and all(r.done for r in low)   # nobody starves
    assert eng.stats.preemptions >= 1


def test_uniform_priorities_never_preempt():
    eng = _engine("eng")
    _drain(eng, _mk_requests())
    assert eng.stats.preemptions == 0       # legacy behavior preserved


def test_admission_orders_by_priority():
    eng = _engine("eng")
    reqs = _mk_requests(n=4, priority=lambda i: i % 2)
    for r in reqs:
        eng.add_request(r)
    eng._admit()
    admitted = {r.rid for r in eng.slots if r is not None}
    assert admitted == {1, 3}               # both high-priority rids first


def test_prefill_chunk_cap_bounds_decode_gap():
    cfg, bundle, params, _ = _bundle()

    def burst(scheduler):
        eng = ServeEngine(bundle, params, batch_size=3, max_len=64, window=4,
                          prefill_chunk=8, scheduler=scheduler)
        rng = np.random.default_rng(5)
        # the decode request must outlive both prefills: while any slot is
        # actively decoding, every round ends in a decode dispatch and the
        # chunks-between-windows counter is a true per-window burst
        decode = Request(rid=0, prompt=rng.integers(
            1, cfg.vocab_size, size=8).astype(np.int32), max_new_tokens=40)
        eng.add_request(decode)
        while eng._pending:                 # rid 0 fully prefilled, decoding
            eng.step()
        for rid in (1, 2):
            eng.add_request(Request(rid=rid, prompt=rng.integers(
                1, cfg.vocab_size, size=32).astype(np.int32),
                max_new_tokens=2))
        eng.run_to_completion(max_ticks=5_000)
        return eng.stats.prefill_burst_max

    free = burst(None)
    capped = burst(Scheduler(SchedulerConfig(prefill_chunks_per_tick=1)))
    assert free >= 2                        # two pending slots advance/round
    assert capped == 1                      # SLO bound honored


def test_reset_clears_scheduler_and_spec_state():
    """Satellite: a warm benchmark drain after a preempted speculative
    drain must start with zeroed accept-rate stats, virgin PRNG keys, no
    resume records, and an empty host tier."""
    cfg, bundle, params, draft_params = _bundle()
    if "spec" not in _STATE:
        _STATE["spec"] = ServeEngine(
            bundle, params, batch_size=2, max_len=64, window=4,
            prefill_chunk=8, sampling=SamplingParams(temperature=0.9),
            seed=3, draft_bundle=bundle, draft_params=draft_params, spec_k=3)
    eng = _STATE["spec"]
    eng.reset()
    reqs = _mk_requests()
    for r in reqs:
        eng.add_request(r)
    while not any(r is not None and r.out_tokens for r in eng.slots):
        eng.step()
    victim = next(i for i, r in enumerate(eng.slots)
                  if r is not None and r.out_tokens)
    eng.preempt(victim, mode="swap")
    eng.run_to_completion(max_ticks=5_000)
    s = eng.stats
    assert s.spec_steps > 0 and s.draft_tokens > 0
    assert s.preemptions == 1 and s.swap_outs == 1

    eng.reset()
    s = eng.stats
    assert (s.spec_steps, s.draft_tokens, s.draft_accepted) == (0, 0, 0)
    assert (s.preemptions, s.swap_outs, s.swap_ins, s.swap_bytes) == (0,) * 4
    assert s.accept_rate == 0.0
    assert not eng._resume and len(eng.host_tier) == 0
    assert not np.asarray(eng.keys).any()   # per-slot key state wiped
    # and the warm drain still matches a cold one token-for-token
    got = _drain(eng, _mk_requests())
    eng.reset()
    again = _drain(eng, _mk_requests())
    assert got == again


# ---------------------------------------------------------------------------
# seeded chaos twins (hypothesis-free; also exercised by `-m chaos` in CI)
# ---------------------------------------------------------------------------

@pytest.mark.chaos
@pytest.mark.parametrize("mode", [None, "swap", "recompute"])
def test_chaos_drain_token_identical(mode):
    ref = _reference()
    eng = _engine("eng")
    reqs = _mk_requests()
    ch = ChaosEngine(eng, ChaosConfig(seed=5, preempt_prob=0.5,
                                      exhaust_prob=0.3, corrupt_prob=0.4,
                                      mode=mode))
    for r in reqs:
        ch.add_request(r)
    ch.run_to_completion()
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref
    assert eng.stats.preemptions > 0        # the storm actually hit


@pytest.mark.chaos
def test_chaos_sampled_drain_token_identical():
    eng = _engine("sampled", sampling=SamplingParams(temperature=0.9,
                                                     top_p=0.95), seed=3)
    ref = _drain(eng, _mk_requests())
    eng.reset()
    reqs = _mk_requests()
    ch = ChaosEngine(eng, ChaosConfig(seed=9, preempt_prob=0.5,
                                      exhaust_prob=0.3, corrupt_prob=0.3))
    for r in reqs:
        ch.add_request(r)
    ch.run_to_completion()
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref
    assert eng.stats.preemptions > 0


@pytest.mark.chaos
def test_chaos_swap_latency_injection():
    ref = _reference()
    eng = _engine("eng")
    reqs = _mk_requests()
    ch = ChaosEngine(eng, ChaosConfig(seed=11, preempt_prob=0.5,
                                      mode="swap", swap_latency_s=0.002))
    for r in reqs:
        ch.add_request(r)
    ch.run_to_completion()
    assert {r.rid: list(r.out_tokens) for r in reqs} == ref
    eng.host_tier.latency_s = 0.0           # don't slow later tests

"""Serving engine: continuous batching correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import RuntimeFlags, build
from repro.serve import Request, ServeEngine

FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                     moe_impl="dense", loss_chunk=16)


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(ARCHS["phi4-mini-3.8b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


def _greedy_reference(bundle, params, prompt, n_new, max_len=64):
    """slot-free single-request reference decode."""
    cache, last = bundle.prefill(params, dict(tokens=prompt[None, :]))

    def pad(path, a):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        ax = 1 if any(n in ("blocks", "dec") for n in names) else 0
        sax = ax + 1
        if a.ndim > sax and a.shape[sax] == prompt.shape[0]:
            padw = [(0, 0)] * a.ndim
            padw[sax] = (0, max_len - a.shape[sax])
            cv = -10**9 if a.dtype == jnp.int32 else 0
            return jnp.pad(a, padw, constant_values=cv)
        return a

    cache = jax.tree_util.tree_map_with_path(pad, cache)
    toks = [int(np.argmax(np.asarray(last)[0]))]
    pos = prompt.shape[0]
    for _ in range(n_new - 1):
        logits, cache = bundle.decode_step(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        toks.append(int(np.argmax(np.asarray(logits)[0])))
        pos += 1
    return toks


def test_engine_completes_all_requests(setup):
    cfg, bundle, params = setup
    eng = ServeEngine(bundle, params, batch_size=3, max_len=64)
    for i in range(7):
        eng.add_request(Request(rid=i, prompt=np.arange(4 + i, dtype=np.int32),
                                max_new_tokens=5))
    stats = eng.run_to_completion()
    assert stats.prefills == 7
    assert stats.tokens_out == 7 * 5


def test_engine_matches_single_request_decode(setup):
    cfg, bundle, params = setup
    prompt = np.asarray([5, 9, 2, 7, 1], np.int32)
    want = _greedy_reference(bundle, params, prompt, 6)

    eng = ServeEngine(bundle, params, batch_size=2, max_len=64)
    req = Request(rid=0, prompt=prompt, max_new_tokens=6)
    eng.add_request(req)
    # distractor request sharing the batch
    eng.add_request(Request(rid=1, prompt=np.arange(9, dtype=np.int32),
                            max_new_tokens=6))
    eng.run_to_completion()
    assert req.out_tokens == want


def test_engine_slot_reuse(setup):
    cfg, bundle, params = setup
    eng = ServeEngine(bundle, params, batch_size=1, max_len=64)
    for i in range(3):
        eng.add_request(Request(rid=i, prompt=np.arange(3 + i, dtype=np.int32),
                                max_new_tokens=3))
    stats = eng.run_to_completion()
    assert stats.prefills == 3 and stats.tokens_out == 9

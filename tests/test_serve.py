"""Serving engine: continuous batching correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import RuntimeFlags, build
from repro.serve import Request, ServeEngine

FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                     moe_impl="dense", loss_chunk=16)


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(ARCHS["phi4-mini-3.8b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


def _greedy_reference(bundle, params, prompt, n_new, max_len=64):
    """slot-free single-request reference decode."""
    cache, last = bundle.prefill(params, dict(tokens=prompt[None, :]))

    def pad(path, a):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        ax = 1 if any(n in ("blocks", "dec") for n in names) else 0
        sax = ax + 1
        if a.ndim > sax and a.shape[sax] == prompt.shape[0]:
            padw = [(0, 0)] * a.ndim
            padw[sax] = (0, max_len - a.shape[sax])
            cv = -10**9 if a.dtype == jnp.int32 else 0
            return jnp.pad(a, padw, constant_values=cv)
        return a

    cache = jax.tree_util.tree_map_with_path(pad, cache)
    toks = [int(np.argmax(np.asarray(last)[0]))]
    pos = prompt.shape[0]
    for _ in range(n_new - 1):
        logits, cache = bundle.decode_step(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        toks.append(int(np.argmax(np.asarray(logits)[0])))
        pos += 1
    return toks


def test_engine_completes_all_requests(setup):
    cfg, bundle, params = setup
    eng = ServeEngine(bundle, params, batch_size=3, max_len=64)
    for i in range(7):
        eng.add_request(Request(rid=i, prompt=np.arange(4 + i, dtype=np.int32),
                                max_new_tokens=5))
    stats = eng.run_to_completion()
    assert stats.prefills == 7
    assert stats.tokens_out == 7 * 5


def test_engine_matches_single_request_decode(setup):
    cfg, bundle, params = setup
    prompt = np.asarray([5, 9, 2, 7, 1], np.int32)
    want = _greedy_reference(bundle, params, prompt, 6)

    eng = ServeEngine(bundle, params, batch_size=2, max_len=64)
    req = Request(rid=0, prompt=prompt, max_new_tokens=6)
    eng.add_request(req)
    # distractor request sharing the batch
    eng.add_request(Request(rid=1, prompt=np.arange(9, dtype=np.int32),
                            max_new_tokens=6))
    eng.run_to_completion()
    assert req.out_tokens == want


def test_engine_slot_reuse(setup):
    cfg, bundle, params = setup
    eng = ServeEngine(bundle, params, batch_size=1, max_len=64)
    for i in range(3):
        eng.add_request(Request(rid=i, prompt=np.arange(3 + i, dtype=np.int32),
                                max_new_tokens=3))
    stats = eng.run_to_completion()
    assert stats.prefills == 3 and stats.tokens_out == 9


# ---------------------------------------------------------------------------
# device-resident fast path (PR 3 acceptance: O(1) host syncs per window)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gemma_setup():
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(1))
    return cfg, bundle, params


def test_decode_many_is_one_dispatch_per_window(gemma_setup):
    """run_to_completion on the gemma_2b config: a whole decode window is ONE
    fused dispatch (ticks-per-dispatch == window), not one dispatch per
    token — the §5 pointer-chase fix."""
    cfg, bundle, params = gemma_setup
    eng = ServeEngine(bundle, params, batch_size=2, max_len=64, window=8)
    assert eng.bucket_prompts  # gemma-2b is pure full attention
    for i in range(2):
        eng.add_request(Request(rid=i, prompt=np.arange(5 + i, dtype=np.int32),
                                max_new_tokens=9))
    stats = eng.run_to_completion()
    assert stats.tokens_out == 2 * 9
    # 1 prefill token + 8 decode tokens per request, both slots admitted
    # together: exactly one fused 8-tick dispatch serves all decode tokens
    assert stats.decode_dispatches == 1
    assert stats.decode_steps / stats.decode_dispatches == 8
    # O(1) syncs per window, NOT per token: 16 tokens from 1 decode dispatch
    assert stats.decode_dispatches < stats.tokens_out - stats.prefills


def test_fast_path_matches_reference_greedy(gemma_setup):
    """Fused windows + bucketed (padded) prefill reproduce the slot-free
    per-token reference decode exactly."""
    cfg, bundle, params = gemma_setup
    prompt = np.asarray([5, 9, 2, 7, 1], np.int32)       # pads 5 -> bucket 8
    want = _greedy_reference(bundle, params, prompt, 7)

    eng = ServeEngine(bundle, params, batch_size=2, max_len=64, window=4)
    req = Request(rid=0, prompt=prompt, max_new_tokens=7)
    eng.add_request(req)
    eng.add_request(Request(rid=1, prompt=np.arange(11, dtype=np.int32),
                            max_new_tokens=7))           # pads 11 -> 16
    eng.run_to_completion()
    assert req.out_tokens == want


def test_prompt_bucketing_dedups_prefill_traces(gemma_setup):
    """Prompts of different lengths inside one pow2 bucket share a compile.
    (First tokens differ so the paged prefix cache can't shorten any prompt
    into a different chunk bucket — that behavior has its own test.)"""
    cfg, bundle, params = gemma_setup
    eng = ServeEngine(bundle, params, batch_size=2, max_len=64)
    for i, n in enumerate((9, 11, 13, 16)):              # all bucket to 16
        eng.add_request(Request(rid=i,
                                prompt=np.arange(n, dtype=np.int32) + i,
                                max_new_tokens=2))
    stats = eng.run_to_completion()
    assert stats.prefills == 4
    assert stats.prefill_retraces == 1


def test_decode_many_respects_budgets(gemma_setup):
    """A request wanting fewer tokens than the window stops exactly on
    budget despite the fused loop running masked ticks."""
    cfg, bundle, params = gemma_setup
    eng = ServeEngine(bundle, params, batch_size=2, max_len=64, window=8)
    short = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=3)
    long = Request(rid=1, prompt=np.arange(6, dtype=np.int32),
                   max_new_tokens=12)
    eng.add_request(short)
    eng.add_request(long)
    eng.run_to_completion()
    assert len(short.out_tokens) == 3
    assert len(long.out_tokens) == 12


def test_prefill_satisfied_and_maxlen_pinned_slots_retire(gemma_setup):
    """max_new_tokens=1 is satisfied by prefill alone, and a request pinned
    at the cache-length guard stops — neither may wedge its slot."""
    cfg, bundle, params = gemma_setup
    eng = ServeEngine(bundle, params, batch_size=1, max_len=16, window=4)
    eng.add_request(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                            max_new_tokens=1))
    # wants 40 tokens but max_len=16 caps it: 1 prefill + (16-1-6) decode
    eng.add_request(Request(rid=1, prompt=np.arange(6, dtype=np.int32),
                            max_new_tokens=40))
    stats = eng.run_to_completion(max_ticks=200)
    assert stats.prefills == 2
    assert all(s is None for s in eng.slots)
    assert stats.tokens_out == 1 + (1 + 16 - 1 - 6)


def test_bucketing_auto_disabled_for_recurrent_families(setup):
    """Right-padding is not mask-safe for ssd/rglru/windowed stacks — the
    engine must auto-detect and keep exact-length prefill."""
    cfg_r = smoke_config(ARCHS["mamba2-130m"])
    bundle_r = build(cfg_r, FLAGS)
    assert ServeEngine._bucketable(cfg_r) is False
    cfg_w = smoke_config(ARCHS["gemma2-27b"])           # sliding windows
    assert ServeEngine._bucketable(cfg_w) is False
    cfg_full = smoke_config(ARCHS["phi4-mini-3.8b"])    # pure full attention
    assert ServeEngine._bucketable(cfg_full) is True

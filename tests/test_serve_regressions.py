"""Regression tests for the single-device engine seams (ISSUE 7 satellites).

Three seams, each with the failure it pins down:

1. ``ServeEngine.reset()`` must zero every ``ServeStats`` field — a warm
   benchmark rerun must not report the previous drain's ``pages_peak`` /
   ``ring_pages_peak`` (and through them ``live_kv_bytes_peak``).
2. Speculative rollback over a shared (pinned) prefix: ``truncate`` +
   ``_release_finished`` in one tick must never decref the pinned prefix
   below its pin floor.  The allocator now *refuses* to free a pinned page
   (refcount-underflow guard) instead of silently re-issuing it.
3. ``PrefixIndex`` staleness: an entry whose page was freed while indexed
   and re-issued to a new request must (a) MISS on lookup rather than
   attach the foreign page, and (b) be self-healed by ``evict_unused``
   rather than decref the new owner's only reference.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import RuntimeFlags, build
from repro.serve import (PageAllocator, PrefixIndex, Request, SamplingParams,
                         ServeEngine, ServeStats, page_hashes)

FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                     moe_impl="dense", loss_chunk=16)


@pytest.fixture(scope="module")
def env():
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(0))
    draft_params = bundle.init(jax.random.PRNGKey(3))
    return cfg, bundle, params, draft_params


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
            for n in sizes]


# ---------------------------------------------------------------------------
# satellite 1: reset() zeroes peak stats
# ---------------------------------------------------------------------------

def test_reset_then_drain_reports_only_the_new_drain(env):
    """Warm-benchmark shape: drain, reset, drain a *smaller* load — the
    second drain's peaks (and live_kv_bytes_peak) must reflect only the
    second drain, not the bigger first one."""
    cfg, bundle, params, _ = env
    eng = ServeEngine(bundle, params, batch_size=2, max_len=64,
                      cache_backend="paged", prefill_chunk=8)
    for i, p in enumerate(_prompts(cfg, (20, 24, 17, 22))):
        eng.add_request(Request(rid=i, prompt=p, max_new_tokens=8))
    eng.run_to_completion()
    big_peak = eng.stats.pages_peak
    big_bytes = eng.live_kv_bytes_peak()
    assert big_peak > 0 and big_bytes > 0

    eng.reset()
    # EVERY stats field resets — compare against a fresh ServeStats, field
    # by field, so new counters can't silently opt out of reset()
    for f in dataclasses.fields(ServeStats):
        assert getattr(eng.stats, f.name) == getattr(ServeStats(), f.name), \
            f"ServeStats.{f.name} survived reset()"
    assert eng.stats.pages_peak == 0 and eng.stats.ring_pages_peak == 0
    # with no pages ever allocated, peak live bytes is the always-resident
    # recurrent state only (zero for this pure-attention stack)
    assert eng.live_kv_bytes_peak() == eng._recurrent_state_bytes()

    eng.add_request(Request(rid=100, prompt=_prompts(cfg, (4,), seed=1)[0],
                            max_new_tokens=2))
    eng.run_to_completion()
    assert 0 < eng.stats.pages_peak < big_peak
    assert 0 < eng.live_kv_bytes_peak() < big_bytes


# ---------------------------------------------------------------------------
# satellite 2: spec rollback over pinned shared prefixes
# ---------------------------------------------------------------------------

def _alloc_invariants(eng):
    a = eng.alloc
    assert len(a.free) + len(a.ref) == a.num_pages - a.reserved, \
        "page conservation broken"
    assert a.free == sorted(set(a.free)), "free list dup/unsorted"
    assert all(r >= 1 for r in a.ref.values())
    for pid in a.pinned:
        assert pid in a.ref and pid not in a.free, \
            f"pinned page {pid} freed while pinned"
    if eng.prefix is not None:
        for h, pid in eng.prefix._by_hash.items():
            assert pid in a.pinned, f"indexed page {pid} lost its pin"


@pytest.mark.parametrize("variant", ["greedy", "sampled"])
def test_spec_rollback_shared_prefix_rejected_suffix(env, variant):
    """Shared-prefix + rejected-suffix drain: every spec tick runs
    ``truncate`` (suffix rollback) and finished slots run
    ``_release_finished`` in the same tick, over prefix pages the index
    pins.  Streams must equal vanilla and no pinned page may underflow
    (the allocator raises if one does)."""
    cfg, bundle, params, draft_params = env
    sampling = (None if variant == "greedy"
                else SamplingParams(temperature=0.9, top_k=11))
    rng = np.random.default_rng(7)
    common = rng.integers(0, cfg.vocab_size, size=18).astype(np.int32)
    reqs = []
    for i in range(6):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 9))).astype(np.int32)
        prompt = (np.concatenate([common, tail]) if i % 2 == 0
                  else np.concatenate([tail, tail, tail]))
        reqs.append((prompt, 10))

    def drain(**extra):
        eng = ServeEngine(bundle, params, batch_size=2, max_len=64,
                          cache_backend="paged", prefill_chunk=8,
                          sampling=sampling, seed=0, **extra)
        rs = [Request(rid=i, prompt=p, max_new_tokens=m)
              for i, (p, m) in enumerate(reqs)]
        for r in rs:
            eng.add_request(r)
        eng.run_to_completion()
        return [r.out_tokens for r in rs], eng

    want, _ = drain()
    # tiny pool: rollback/release churn under pool pressure + eviction
    got, spec = drain(draft_bundle=bundle, draft_params=draft_params,
                      spec_k=3, num_pages=12)
    assert got == want, "speculative drain diverged from vanilla"
    assert spec.stats.spec_steps > 0
    _alloc_invariants(spec)
    # after the drain only the pinned prefix pages remain live
    assert spec.alloc.pages_in_use == len(spec.alloc.pinned)
    assert len(spec.prefix._by_hash) == len(spec.alloc.pinned)


# ---------------------------------------------------------------------------
# satellite 3: stale prefix-index entries after evict/reuse
# ---------------------------------------------------------------------------

def _stale_entry(n_pages=6, page=4):
    """Build the stale-entry state: a page registered in the index, freed
    (pin discipline slipped: registered without pin), then re-issued to a
    NEW request by the lowest-id-first free list."""
    a = PageAllocator(n_pages, page, reserved=1)
    idx = PrefixIndex()
    prompt = np.arange(2 * page, dtype=np.int64)
    hashes = page_hashes(prompt, page)
    a.alloc(1)
    a.reserve(1, 2 * page)
    pid = a.tables[1][0]
    idx.register(hashes[0], pid)    # indexed but NOT pinned
    a.release(1)                    # page freed while still indexed
    a.alloc(2)
    a.reserve(2, page)              # lowest-first reuse: same id, new owner
    assert a.tables[2][0] == pid
    return a, idx, hashes, pid


def test_lookup_after_evict_reuse_misses_not_foreign_page():
    a, idx, hashes, pid = _stale_entry()
    # the re-issued page holds request 2's KV rows — attaching it to a new
    # request via the stale hash would serve foreign context
    assert idx.lookup(hashes[:1], alloc=a) == []
    assert hashes[0] not in idx._by_hash  # stale entry self-healed
    assert a.ref[pid] == 1                # new owner's ref untouched


def test_evict_unused_self_heals_stale_entries():
    a, idx, hashes, pid = _stale_entry()
    freed = idx.evict_unused(a)
    # the stale entry is dropped WITHOUT decrefing the new owner (ref==1
    # here is request 2's only reference, not the index's)
    assert freed == 0
    assert len(idx) == 0
    assert a.ref[pid] == 1 and pid not in a.free
    a.release(2)                          # still releasable exactly once


def test_evict_unused_drops_entries_for_freed_pages():
    a = PageAllocator(6, 4, reserved=1)
    idx = PrefixIndex()
    a.alloc(1)
    a.reserve(1, 4)
    pid = a.tables[1][0]
    idx.register("h", pid)
    a.release(1)                          # freed, never re-issued
    assert idx.evict_unused(a) == 0       # heals: no unpin of a free page
    assert len(idx) == 0
    assert idx.lookup(["h"], alloc=a) == []


def test_unpin_refuses_without_a_pin():
    a, idx, hashes, pid = _stale_entry()
    with pytest.raises(KeyError):
        a.unpin(pid)                      # would decref the new owner
    a.alloc(3)
    a.reserve(3, 4)
    a.pin(a.tables[3][0])
    a.unpin(a.tables[3][0])               # matched pin/unpin is fine
    with pytest.raises(KeyError):
        a.unpin(a.tables[3][0])           # double unpin is not


def test_pinned_page_refcount_underflow_is_refused():
    """The sat-2 guard at its root: a buggy rollback/release path that
    drives a pinned page's refcount to zero must raise, not return the
    page (still indexed!) to the free list."""
    a = PageAllocator(6, 4, reserved=1)
    a.alloc(1)
    a.reserve(1, 4)
    pid = a.tables[1][0]
    a.pin(pid)
    a.release(1)                          # ref: pin only (floor)
    a.ref[pid] -= 1                       # simulate the underflow bug
    with pytest.raises(RuntimeError):
        a._free_page(pid)
    with pytest.raises(ValueError):
        a.pin(pid)                        # double pin is API misuse too

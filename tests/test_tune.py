"""repro.tune: plan derivation, cache round-trip, invalidation, threading."""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro.core.memmodel import V5E, vmem_ok
from repro.tune import (KERNELS, KernelPlan, PlanCache, default_cache,
                        derive_plan, plan_for, plan_key, set_default_cache,
                        spec_fingerprint)

SIGS = {
    "flash_attention": (512, 768, 64),
    "decode_attention": (4096, 128),
    "matmul": (512, 512, 256),
    "paged_attention": (4096, 128),
    "paged_verify": (5, 4096, 128),
}


def test_top_level_namespace_export():
    """satellite: ``import repro`` exposes the tune subsystem."""
    assert repro.tune.KernelPlan is KernelPlan
    assert callable(repro.tune.plan_for)


@pytest.mark.parametrize("kernel", KERNELS)
def test_derive_plan_every_kernel(kernel):
    plan = derive_plan(kernel, shape_sig=SIGS[kernel], dtype="bfloat16")
    assert plan.kernel == kernel
    assert plan.bq >= 1 and plan.bkv >= 1
    assert plan.pipeline_depth >= 1
    assert plan.predicted_gbps > 0
    assert plan.source == "analytic"
    assert vmem_ok(plan.knobs(), V5E)
    # interpret auto-detect: None until resolved; CPU CI resolves to True
    assert plan.interpret is None
    assert plan.resolve_interpret() is True  # tests run on CPU


def test_unknown_kernel_rejected():
    with pytest.raises(ValueError):
        derive_plan("warp_attention", shape_sig=(4096, 128), dtype="bfloat16")


def test_paged_plan_page_size_is_transaction_optimum():
    """satellite: the paged plan's bkv IS the page size — the smallest pow2
    token count whose contiguous row block crosses the advisor's >= 512B
    transaction optimum (r_acc), clamped so max_len spans >= 2 pages."""
    plan = derive_plan("paged_attention", shape_sig=(4096, 128),
                       dtype="bfloat16")
    assert plan.page_size == plan.bkv
    assert plan.page_size & (plan.page_size - 1) == 0      # pow2
    assert plan.page_size * plan.head_dim * plan.dtype_bytes >= 512
    # halving the page would drop below the optimum (or below the 8 floor)
    half = plan.page_size // 2
    assert half < 8 or half * plan.head_dim * plan.dtype_bytes < 512
    # wider rows need fewer tokens per page; narrower rows need more
    wide = derive_plan("paged_attention", shape_sig=(4096, 256),
                       dtype="bfloat16")
    narrow = derive_plan("paged_attention", shape_sig=(4096, 16),
                         dtype="float32")
    assert wide.page_size <= plan.page_size <= narrow.page_size
    # a tiny max_len clamps: never a single page per sequence
    tiny = derive_plan("paged_attention", shape_sig=(16, 16), dtype="float32")
    assert tiny.page_size == 8


def test_verify_plan_rides_the_paged_page():
    """The speculative verify step reads the same pool paged decode laid
    out, so its transaction unit (bkv = the page) must match the paged
    plan for the same (max_len, head_dim, dtype); what it adds is burst
    length — bq becomes the verify width (pending + k drafts) and the
    predicted bandwidth scales with the per-transaction reuse."""
    base = derive_plan("paged_attention", shape_sig=(4096, 128),
                       dtype="bfloat16")
    for vt in (2, 5, 9):
        vplan = derive_plan("paged_verify", shape_sig=(vt, 4096, 128),
                            dtype="bfloat16")
        assert vplan.kernel == "paged_verify"
        assert vplan.bkv == base.page_size       # same pool layout
        assert vplan.bq == vt                    # burst = verify width
        assert vplan.predicted_gbps == pytest.approx(
            base.predicted_gbps * vt)
    # plan_for caches verify plans under the 3-tuple signature
    cached = plan_for("paged_verify", shape_sig=(5, 4096, 128),
                      dtype="bfloat16")
    assert cached == plan_for("paged_verify", shape_sig=(5, 4096, 128),
                              dtype="bfloat16")


def test_paged_plan_int8_widens_page_by_dtype_ratio():
    """int8 KV pages halve the unit width, so the derived page holds
    proportionally more tokens — the serving engine lays its pool out from
    the kv *storage* dtype, not the compute dtype."""
    bf16 = derive_plan("paged_attention", shape_sig=(4096, 16),
                       dtype="bfloat16")
    f32 = derive_plan("paged_attention", shape_sig=(4096, 16),
                      dtype="float32")
    int8 = derive_plan("paged_attention", shape_sig=(4096, 16), dtype="int8")
    assert int8.page_size == 2 * bf16.page_size == 4 * f32.page_size
    # same transaction bytes either way: the optimum is dtype-invariant
    assert int8.page_size * 16 * 1 >= 512
    assert bf16.page_size * 16 * 2 >= 512


def test_plan_blocks_clamped_to_shape():
    plan = derive_plan("flash_attention", shape_sig=(16, 24, 16),
                       dtype="float32")
    assert plan.bq <= 16 and plan.bkv <= 24


def test_plan_round_trips_through_json():
    plan = derive_plan("flash_attention", shape_sig=SIGS["flash_attention"],
                       dtype="bfloat16")
    again = KernelPlan.from_dict(json.loads(json.dumps(plan.to_dict())))
    assert again == plan


def test_plan_cache_persistence_round_trip(tmp_path):
    path = str(tmp_path / "tuneplans.json")
    cache = PlanCache(path)
    plan = cache.get_or_derive("flash_attention",
                               shape_sig=SIGS["flash_attention"],
                               dtype="bfloat16")
    assert len(cache) == 1
    # a fresh cache instance over the same file serves the persisted plan
    reloaded = PlanCache(path)
    key = plan_key("flash_attention", SIGS["flash_attention"], "bfloat16", V5E)
    assert reloaded.get(key) == plan
    # and get_or_derive is a pure cache hit (identical plan, count stable)
    assert reloaded.get_or_derive(
        "flash_attention", shape_sig=SIGS["flash_attention"],
        dtype="bfloat16") == plan
    assert len(reloaded) == 1


def test_plan_cache_memory_only_and_corrupt_file(tmp_path):
    mem = PlanCache(None)
    mem.get_or_derive("matmul", shape_sig=SIGS["matmul"], dtype="float32")
    assert len(mem) == 1
    bad = tmp_path / "tuneplans.json"
    bad.write_text("{not json")
    assert len(PlanCache(str(bad))) == 0  # corrupt file degrades gracefully


def test_key_invalidates_on_spec_and_calibration_change():
    """satellite/tentpole: new constants => new fingerprint => new key."""
    base_key = plan_key("flash_attention", (512, 512, 128), "bfloat16", V5E)
    other = dataclasses.replace(V5E, hbm_bw=V5E.hbm_bw * 2)
    assert spec_fingerprint(other) != spec_fingerprint(V5E)
    assert plan_key("flash_attention", (512, 512, 128), "bfloat16",
                    other) != base_key
    # dtype and shape are part of the key too
    assert plan_key("flash_attention", (512, 512, 128), "float32",
                    V5E) != base_key
    assert plan_key("flash_attention", (512, 256, 128), "bfloat16",
                    V5E) != base_key


def test_calibration_threads_into_plans():
    """A calibrated spec drives the derivation and marks the plan."""
    from repro.bench.calibrate import fit_spec, synthetic_samples
    slow = dataclasses.replace(V5E, dma_latency_s=2000e-9, hbm_bw=64e9)
    cal = fit_spec(synthetic_samples(slow))
    cache = PlanCache(None)
    plan = cache.get_or_derive("decode_attention",
                               shape_sig=SIGS["decode_attention"],
                               dtype="bfloat16", calibration=cal)
    assert plan.source == "calibrated"
    assert vmem_ok(plan.knobs(), cal.spec)
    # cached under the calibrated fingerprint, not the analytic one
    assert cache.get(plan_key("decode_attention", SIGS["decode_attention"],
                              "bfloat16", V5E)) is None


def test_default_cache_swap_and_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNEPLANS", str(tmp_path / "plans.json"))
    set_default_cache(None)  # force re-read of the env var
    try:
        cache = default_cache()
        assert cache.path == str(tmp_path / "plans.json")
        plan = plan_for("matmul", shape_sig=(256, 256, 256), dtype="float32")
        assert (tmp_path / "plans.json").exists()
        assert plan.kernel == "matmul"
    finally:
        set_default_cache(None)


def test_plan_defaults_reach_the_kernels(tmp_path, monkeypatch):
    """tentpole: kernels called with no blocks use the cached plan and still
    match the oracle (the applied-knobs path is correct end to end)."""
    from repro.kernels import ops, ref
    mem = PlanCache(None)
    set_default_cache(mem)
    try:
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.standard_normal((1, 4, 37, 16)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 2, 53, 16)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 2, 53, 16)), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.attention(q, k, v,
                                                            causal=False)),
                                   rtol=2e-4, atol=2e-4)
        keys = list(mem.plans())
        assert any(key.startswith("flash_attention|37x53x16|") for key in keys)
    finally:
        set_default_cache(None)

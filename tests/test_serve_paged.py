"""Paged continuous-batching backend: pool mechanics, parity, prefix cache.

The fast ones run in tier-1; the cross-backend serve-parity drains are
``@pytest.mark.slow`` and run in the CI bench-smoke job instead (they drain
two engines per config).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, override, smoke_config
from repro.models import RuntimeFlags, build
from repro.serve import (PageAllocator, PagedKVCache, PoolExhausted,
                         PrefixIndex, Request, ServeEngine, page_hashes)

FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                     moe_impl="dense", loss_chunk=16)


# ---------------------------------------------------------------------------
# PageAllocator / PagedKVCache mechanics (satellite 1)
# ---------------------------------------------------------------------------

def test_release_raises_on_unknown_and_double_release():
    a = PageAllocator(8, 4)
    a.alloc(0)
    a.reserve(0, 6)
    a.release(0)
    with pytest.raises(KeyError):
        a.release(0)            # double release
    with pytest.raises(KeyError):
        a.release(99)           # never allocated


def test_free_list_reuse_is_deterministic_sorted():
    """Released pages are reused lowest-id-first, so page-table contents are
    reproducible run to run (the old stack-order pop was allocation-history
    dependent)."""
    a = PageAllocator(10, 4, reserved=1)
    a.alloc(0); a.reserve(0, 12)          # pages 1,2,3
    a.alloc(1); a.reserve(1, 8)           # pages 4,5
    assert a.tables[0] == [1, 2, 3] and a.tables[1] == [4, 5]
    a.release(0)
    a.alloc(2); a.reserve(2, 16)          # refills from the *sorted* holes
    assert a.tables[2] == [1, 2, 3, 6]
    a.release(1)
    a.release(2)
    assert a.free == list(range(1, 10))


def test_reserve_is_all_or_nothing_and_raises_typed():
    a = PageAllocator(4, 4)
    a.alloc(0)
    a.reserve(0, 8)                       # 2 of 4 pages
    with pytest.raises(PoolExhausted):
        a.reserve(0, 24)                  # needs 4 more, only 2 free
    assert len(a.tables[0]) == 2          # nothing partially allocated
    assert a.can_grow(0, 24) == 16        # the engine's backpressure cap
    a.reserve(0, 16)                      # the feasible target still works
    assert a.pages_in_use == 4


def test_append_spans_page_boundaries():
    pool = PagedKVCache(num_pages=5, page_size=4, num_kv_heads=1, head_dim=2)
    pool.alloc(0)
    k = jnp.arange(10 * 2, dtype=jnp.float32).reshape(10, 1, 2)
    pool.append(0, k[:3], k[:3])          # partial first page
    pool.append(0, k[3:10], k[3:10])      # spans pages 0->1->2
    assert pool.lengths[0] == 10 and len(pool.tables[0]) == 3
    table, vlen = pool.batch_view([0])
    gathered = pool.k_pages[table[0]].reshape(-1, 1, 2)[:10]
    np.testing.assert_array_equal(np.asarray(gathered), np.asarray(k))


def test_fork_copy_on_write_never_mutates_shared_pages():
    """satellite: after a fork, the first divergent append copies the shared
    page; the original bytes are bit-identical before and after."""
    pool = PagedKVCache(num_pages=8, page_size=4, num_kv_heads=1, head_dim=2)
    pool.alloc(0)
    pool.append(0, jnp.ones((6, 1, 2)), jnp.ones((6, 1, 2)))
    shared_before = np.asarray(pool.k_pages[np.asarray(pool.tables[0])])
    pool.fork(0, 1)
    assert pool.tables[1] == pool.tables[0]
    assert all(pool.is_shared(p) for p in pool.tables[0])
    pool.append(1, jnp.full((3, 1, 2), 7.0), jnp.full((3, 1, 2), 7.0))
    # the partially-filled page diverged: rid 1 got a private copy
    assert pool.tables[1][0] == pool.tables[0][0]      # full page still shared
    assert pool.tables[1][1] != pool.tables[0][1]      # COW split
    shared_after = np.asarray(pool.k_pages[np.asarray(pool.tables[0])])
    np.testing.assert_array_equal(shared_before, shared_after)
    # rid 1 sees its own timeline: old rows + the divergent append
    priv = np.asarray(pool.k_pages[pool.tables[1][1]])
    np.testing.assert_array_equal(priv[:2], shared_before[1][:2])
    assert (priv[2:] == 7.0).all()


def test_append_cow_budget_is_all_or_nothing():
    """An append that cannot afford its copy-on-write pages raises BEFORE
    mutating lengths/table — no phantom tokens claimed as valid."""
    pool = PagedKVCache(num_pages=3, page_size=4, num_kv_heads=1, head_dim=2)
    pool.alloc(0)
    pool.append(0, jnp.ones((6, 1, 2)), jnp.ones((6, 1, 2)))
    pool.fork(0, 1)
    with pytest.raises(PoolExhausted):
        # needs 1 fresh page + 1 COW copy of the shared partial page,
        # but only 1 page is free
        pool.append(1, jnp.ones((6, 1, 2)), jnp.ones((6, 1, 2)))
    assert pool.lengths[1] == 6 and len(pool.tables[1]) == 2


def test_prefix_index_longest_match_and_eviction():
    a = PageAllocator(8, 4)
    a.alloc(0); a.reserve(0, 12)
    idx = PrefixIndex()
    h = page_hashes(np.arange(12), 4)
    for hh, pid in zip(h, a.tables[0]):
        idx.register(hh, pid)
        a.pin(pid)
    # a longer prompt sharing 2 pages matches exactly its leading run
    h2 = page_hashes(np.concatenate([np.arange(8), [99, 99, 99, 99]]), 4)
    assert idx.lookup(h2) == a.tables[0][:2]
    a.release(0)
    assert a.pages_in_use == 3            # pinned pages survive release
    freed = idx.evict_unused(a)
    assert freed == 3 and a.pages_in_use == 0 and len(idx) == 0


# ---------------------------------------------------------------------------
# engine: paged vs dense parity + churn (satellite 3; acceptance)
# ---------------------------------------------------------------------------

def _drain_tokens(bundle, params, *, backend, prompts, max_new, bsz=2,
                  max_len=64, **kw):
    eng = ServeEngine(bundle, params, batch_size=bsz, max_len=max_len,
                      cache_backend=backend, **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
    stats = eng.run_to_completion()
    return [r.out_tokens for r in reqs], stats, eng


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma-2b", "phi4-mini-3.8b"])
def test_paged_matches_dense_token_for_token(arch):
    """Acceptance: greedy decode over the page pool reproduces the dense
    engine exactly — non-divisible prompt lengths, slot churn (6 requests
    through 2 slots with release/realloc reuse), chunked prefill."""
    cfg = smoke_config(ARCHS[arch])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
               for n in (5, 13, 9, 27, 7, 18)]   # none divisible by page=8
    dense, sd, _ = _drain_tokens(bundle, params, backend="dense",
                                 prompts=prompts, max_new=6)
    paged, sp, eng = _drain_tokens(bundle, params, backend="paged",
                                   prompts=prompts, max_new=6,
                                   prefill_chunk=8)
    assert paged == dense
    assert sp.tokens_out == sd.tokens_out == 6 * 6
    # slot churn really released: after the drain only prefix-pinned pages
    # may persist in the pool
    assert eng.alloc.pages_in_use * eng.page <= sum(len(p) for p in prompts)


@pytest.mark.slow
def test_paged_matches_dense_bfloat16():
    cfg = override(smoke_config(ARCHS["gemma-2b"]), compute_dtype="bfloat16")
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (11, 6)]
    dense, _, _ = _drain_tokens(bundle, params, backend="dense",
                                prompts=prompts, max_new=5)
    paged, _, _ = _drain_tokens(bundle, params, backend="paged",
                                prompts=prompts, max_new=5)
    assert paged == dense


def test_paged_is_default_for_pure_attention_and_dense_for_the_rest():
    gemma = build(smoke_config(ARCHS["gemma-2b"]), FLAGS)
    assert gemma.paged_supported()
    mamba = build(smoke_config(ARCHS["mamba2-130m"]), FLAGS)
    assert not mamba.paged_supported()
    windowed = build(smoke_config(ARCHS["gemma2-27b"]), FLAGS)
    assert not windowed.paged_supported()
    int8 = build(smoke_config(ARCHS["gemma-2b"]),
                 RuntimeFlags(attn_impl="chunked", kv_dtype="int8"))
    assert not int8.paged_supported()
    params = mamba.init(jax.random.PRNGKey(0))
    eng = ServeEngine(mamba, params, batch_size=1, max_len=32)
    assert eng.backend == "dense"       # auto fallback
    with pytest.raises(ValueError):
        ServeEngine(mamba, params, batch_size=1, max_len=32,
                    cache_backend="paged")


def test_pool_exhaustion_becomes_backpressure(gemma_env=None):
    """A pool too small for the whole batch keeps requests queued (typed
    backpressure, not a crash) and still completes them as pages free."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(4))
    # page=8, 3 usable pages: one 20-token request needs 3 -> solo admission
    eng = ServeEngine(bundle, params, batch_size=2, max_len=32,
                      num_pages=4, prefix_cache=False)
    for i in range(3):
        eng.add_request(Request(rid=i,
                                prompt=np.arange(17, dtype=np.int32) + i,
                                max_new_tokens=4))
    stats = eng.run_to_completion()
    assert stats.tokens_out == 3 * 4
    assert stats.pool_stalls > 0        # admission actually backed off
    assert eng.alloc.pages_in_use == 0


def test_impossible_prompt_raises_instead_of_silent_drop():
    """A prompt no amount of backpressure can ever admit (needs more pages
    than the pool holds) must raise loudly, not sit queued forever while
    run_to_completion returns 'drained'."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(4))
    eng = ServeEngine(bundle, params, batch_size=1, max_len=32,
                      num_pages=3)          # 2 usable pages of 8 = 16 tokens
    eng.add_request(Request(rid=0, prompt=np.arange(17, dtype=np.int32),
                            max_new_tokens=2))
    with pytest.raises(ValueError, match="pages"):
        eng.run_to_completion()


def test_explicit_page_size_reshapes_pool_and_plan():
    """page_size overrides the derived plan; the plan handed to the kernel
    must describe the pool actually laid out (the kernel asserts it)."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(4))
    eng = ServeEngine(bundle, params, batch_size=1, max_len=32, page_size=4)
    assert eng.page == 4 and eng.plan.page_size == 4
    req = Request(rid=0, prompt=np.arange(6, dtype=np.int32),
                  max_new_tokens=4)
    eng.add_request(req)
    eng.run_to_completion()
    assert len(req.out_tokens) == 4


def test_long_prompt_prefills_in_chunks_between_decode_ticks():
    """Chunked prefill: a long prompt admits in prefill_chunk pieces and
    in-flight decode keeps ticking between chunks."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(5))
    eng = ServeEngine(bundle, params, batch_size=2, max_len=64, window=2,
                      prefill_chunk=8)
    short = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=12)
    long = Request(rid=1, prompt=np.arange(40, dtype=np.int32) + 100,
                   max_new_tokens=4)
    eng.add_request(short)
    eng.add_request(long)
    stats = eng.run_to_completion()
    assert len(short.out_tokens) == 12 and len(long.out_tokens) == 4
    assert stats.prefill_chunks >= 1 + 5   # 40 tokens / 8-token chunks
    # decode went on while the long prompt was still prefilling: more
    # dispatches than a single post-prefill drain would need
    assert stats.decode_dispatches > 2


# ---------------------------------------------------------------------------
# prefix caching (tentpole; satellite 3's fork test is above)
# ---------------------------------------------------------------------------

def test_prefix_cache_hits_and_outputs_unchanged():
    """Requests sharing a >= 1-page prompt prefix reuse its pages read-only:
    hit accounting moves, outputs stay bit-identical to an uncached run."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(6))
    rng = np.random.default_rng(9)
    common = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    prompts = [np.concatenate([common, rng.integers(
        0, cfg.vocab_size, size=5).astype(np.int32)]) for _ in range(4)]
    # batch_size=1 serializes requests => later ones see registered pages
    cached, sc, eng = _drain_tokens(bundle, params, backend="paged",
                                    prompts=prompts, max_new=4, bsz=1)
    uncached, su, _ = _drain_tokens(bundle, params, backend="paged",
                                    prompts=prompts, max_new=4, bsz=1,
                                    prefix_cache=False)
    assert cached == uncached
    assert su.prefix_hit_tokens == 0
    assert sc.prefix_hit_tokens == 3 * 16   # requests 2..4 reuse both pages
    # shared pages survive in the pool for future hits (pinned by the index)
    assert eng.alloc.pages_in_use >= 2


def test_shared_prefix_pages_never_written_by_later_requests():
    """The engine-level never-write guarantee: page bytes registered by the
    first request are bit-identical after later requests decode over them."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(7))
    common = (np.arange(16, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    eng = ServeEngine(bundle, params, batch_size=1, max_len=64)
    eng.add_request(Request(rid=0, prompt=common, max_new_tokens=3))
    eng.run_to_completion()
    shared = sorted(eng.prefix._by_hash.values())
    assert len(shared) == 2
    def snapshot():
        leaf = jax.tree_util.tree_leaves(eng.cache)[0]
        # stacked pools carry LAYERS first: (nb, P, page, Hkv, D)
        return np.asarray(leaf[:, shared] if leaf.ndim == 5 else leaf[shared])
    before = snapshot()
    tail = np.asarray([7, 7, 7, 7, 7], np.int32)
    eng.add_request(Request(rid=1,
                            prompt=np.concatenate([common, tail]),
                            max_new_tokens=6))
    stats = eng.run_to_completion()
    assert stats.prefix_hit_tokens == 16
    np.testing.assert_array_equal(before, snapshot())


# ---------------------------------------------------------------------------
# memory figure of merit (acceptance)
# ---------------------------------------------------------------------------

def test_live_bytes_below_dense_footprint():
    """The whole point: live-token HBM bytes strictly below the dense
    ``batch x max_len`` commitment for a short-request mix."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(8))
    prompts = [np.arange(6, dtype=np.int32) + 10 * i for i in range(4)]
    _, _, dense_eng = _drain_tokens(bundle, params, backend="dense",
                                    prompts=prompts, max_new=4, bsz=4)
    _, _, paged_eng = _drain_tokens(bundle, params, backend="paged",
                                    prompts=prompts, max_new=4, bsz=4)
    assert paged_eng.live_kv_bytes_peak() < dense_eng.live_kv_bytes_peak()
    assert paged_eng.stats.pages_peak <= paged_eng.num_pages - 1

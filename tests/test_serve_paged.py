"""Paged continuous-batching backend: pool mechanics, parity, prefix cache.

The fast ones run in tier-1; the cross-backend serve-parity drains are
``@pytest.mark.slow`` and run in the CI bench-smoke job instead (they drain
two engines per config).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, override, smoke_config
from repro.models import RuntimeFlags, build
from repro.serve import (PageAllocator, PagedKVCache, PoolExhausted,
                         PrefixIndex, Request, ServeEngine, page_hashes)

FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                     moe_impl="dense", loss_chunk=16)


# ---------------------------------------------------------------------------
# PageAllocator / PagedKVCache mechanics (satellite 1)
# ---------------------------------------------------------------------------

def test_release_raises_on_unknown_and_double_release():
    a = PageAllocator(8, 4)
    a.alloc(0)
    a.reserve(0, 6)
    a.release(0)
    with pytest.raises(KeyError):
        a.release(0)            # double release
    with pytest.raises(KeyError):
        a.release(99)           # never allocated


def test_free_list_reuse_is_deterministic_sorted():
    """Released pages are reused lowest-id-first, so page-table contents are
    reproducible run to run (the old stack-order pop was allocation-history
    dependent)."""
    a = PageAllocator(10, 4, reserved=1)
    a.alloc(0); a.reserve(0, 12)          # pages 1,2,3
    a.alloc(1); a.reserve(1, 8)           # pages 4,5
    assert a.tables[0] == [1, 2, 3] and a.tables[1] == [4, 5]
    a.release(0)
    a.alloc(2); a.reserve(2, 16)          # refills from the *sorted* holes
    assert a.tables[2] == [1, 2, 3, 6]
    a.release(1)
    a.release(2)
    assert a.free == list(range(1, 10))


def test_reserve_is_all_or_nothing_and_raises_typed():
    a = PageAllocator(4, 4)
    a.alloc(0)
    a.reserve(0, 8)                       # 2 of 4 pages
    with pytest.raises(PoolExhausted):
        a.reserve(0, 24)                  # needs 4 more, only 2 free
    assert len(a.tables[0]) == 2          # nothing partially allocated
    assert a.can_grow(0, 24) == 16        # the engine's backpressure cap
    a.reserve(0, 16)                      # the feasible target still works
    assert a.pages_in_use == 4


def test_append_spans_page_boundaries():
    pool = PagedKVCache(num_pages=5, page_size=4, num_kv_heads=1, head_dim=2)
    pool.alloc(0)
    k = jnp.arange(10 * 2, dtype=jnp.float32).reshape(10, 1, 2)
    pool.append(0, k[:3], k[:3])          # partial first page
    pool.append(0, k[3:10], k[3:10])      # spans pages 0->1->2
    assert pool.lengths[0] == 10 and len(pool.tables[0]) == 3
    table, vlen = pool.batch_view([0])
    gathered = pool.k_pages[table[0]].reshape(-1, 1, 2)[:10]
    np.testing.assert_array_equal(np.asarray(gathered), np.asarray(k))


def test_fork_copy_on_write_never_mutates_shared_pages():
    """satellite: after a fork, the first divergent append copies the shared
    page; the original bytes are bit-identical before and after."""
    pool = PagedKVCache(num_pages=8, page_size=4, num_kv_heads=1, head_dim=2)
    pool.alloc(0)
    pool.append(0, jnp.ones((6, 1, 2)), jnp.ones((6, 1, 2)))
    shared_before = np.asarray(pool.k_pages[np.asarray(pool.tables[0])])
    pool.fork(0, 1)
    assert pool.tables[1] == pool.tables[0]
    assert all(pool.is_shared(p) for p in pool.tables[0])
    pool.append(1, jnp.full((3, 1, 2), 7.0), jnp.full((3, 1, 2), 7.0))
    # the partially-filled page diverged: rid 1 got a private copy
    assert pool.tables[1][0] == pool.tables[0][0]      # full page still shared
    assert pool.tables[1][1] != pool.tables[0][1]      # COW split
    shared_after = np.asarray(pool.k_pages[np.asarray(pool.tables[0])])
    np.testing.assert_array_equal(shared_before, shared_after)
    # rid 1 sees its own timeline: old rows + the divergent append
    priv = np.asarray(pool.k_pages[pool.tables[1][1]])
    np.testing.assert_array_equal(priv[:2], shared_before[1][:2])
    assert (priv[2:] == 7.0).all()


def test_append_cow_budget_is_all_or_nothing():
    """An append that cannot afford its copy-on-write pages raises BEFORE
    mutating lengths/table — no phantom tokens claimed as valid."""
    pool = PagedKVCache(num_pages=3, page_size=4, num_kv_heads=1, head_dim=2)
    pool.alloc(0)
    pool.append(0, jnp.ones((6, 1, 2)), jnp.ones((6, 1, 2)))
    pool.fork(0, 1)
    with pytest.raises(PoolExhausted):
        # needs 1 fresh page + 1 COW copy of the shared partial page,
        # but only 1 page is free
        pool.append(1, jnp.ones((6, 1, 2)), jnp.ones((6, 1, 2)))
    assert pool.lengths[1] == 6 and len(pool.tables[1]) == 2


def test_allocator_random_ops_conserve_pages_without_hypothesis():
    """Hypothesis-free twin of the test_serve_fuzz conservation property
    (that module skips entirely when hypothesis is absent): 120 seeded
    random alloc/reserve/fork/release/truncate/evict sequences over full
    and ring allocators must conserve pages, keep refcounts >= 1, and
    respect the ring bound.  The evict op is the scheduler's preemption
    release path: truncate to the victim's live length, then release."""
    rng = np.random.default_rng(3)
    for trial in range(120):
        num_pages = int(rng.integers(4, 25))
        window = [None, 8, 13, 24][trial % 4]
        a = PageAllocator(num_pages, 4, reserved=1, window=window)
        live, next_rid = [], 0
        for _ in range(int(rng.integers(1, 40))):
            op = int(rng.integers(0, 6))
            try:
                if op == 0:
                    a.alloc(next_rid)
                    live.append(next_rid)
                    next_rid += 1
                elif op == 1 and live:
                    rid = live[int(rng.integers(0, len(live)))]
                    a.reserve(rid, a.lengths[rid] + int(rng.integers(1, 49)))
                elif op == 2 and live:
                    src = live[int(rng.integers(0, len(live)))]
                    a.fork(src, next_rid)
                    live.append(next_rid)
                    next_rid += 1
                elif op == 3 and live:
                    a.release(live.pop(int(rng.integers(0, len(live)))))
                elif op == 4 and live:
                    # speculative rollback: rewind to a random shorter length
                    rid = live[int(rng.integers(0, len(live)))]
                    a.truncate(rid, int(rng.integers(0, a.lengths[rid] + 1)))
                elif op == 5 and live:
                    # preemption eviction: truncate-then-release the victim
                    rid = live.pop(int(rng.integers(0, len(live))))
                    a.truncate(rid, a.lengths[rid] // 2)
                    a.release(rid)
            except PoolExhausted:
                pass     # backpressure is legal; corruption is not
            assert a.pages_in_use + len(a.free) == num_pages - 1
            assert all(r >= 1 for r in a.ref.values())
            if a.ring_slots is not None:
                assert all(len(t) <= a.ring_slots for t in a.tables.values())
        for rid in live:
            a.release(rid)
        assert a.pages_in_use == 0


def test_prefix_index_longest_match_and_eviction():
    a = PageAllocator(8, 4)
    a.alloc(0); a.reserve(0, 12)
    idx = PrefixIndex()
    h = page_hashes(np.arange(12), 4)
    for hh, pid in zip(h, a.tables[0]):
        idx.register(hh, pid)
        a.pin(pid)
    # a longer prompt sharing 2 pages matches exactly its leading run
    h2 = page_hashes(np.concatenate([np.arange(8), [99, 99, 99, 99]]), 4)
    assert idx.lookup(h2) == a.tables[0][:2]
    a.release(0)
    assert a.pages_in_use == 3            # pinned pages survive release
    freed = idx.evict_unused(a)
    assert freed == 3 and a.pages_in_use == 0 and len(idx) == 0


# ---------------------------------------------------------------------------
# engine: paged vs dense parity + churn (satellite 3; acceptance)
# ---------------------------------------------------------------------------

def _drain_tokens(bundle, params, *, backend, prompts, max_new, bsz=2,
                  max_len=64, **kw):
    eng = ServeEngine(bundle, params, batch_size=bsz, max_len=max_len,
                      cache_backend=backend, **kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.add_request(r)
    stats = eng.run_to_completion()
    return [r.out_tokens for r in reqs], stats, eng


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma-2b", "phi4-mini-3.8b"])
def test_paged_matches_dense_token_for_token(arch):
    """Acceptance: greedy decode over the page pool reproduces the dense
    engine exactly — non-divisible prompt lengths, slot churn (6 requests
    through 2 slots with release/realloc reuse), chunked prefill."""
    cfg = smoke_config(ARCHS[arch])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
               for n in (5, 13, 9, 27, 7, 18)]   # none divisible by page=8
    dense, sd, _ = _drain_tokens(bundle, params, backend="dense",
                                 prompts=prompts, max_new=6)
    paged, sp, eng = _drain_tokens(bundle, params, backend="paged",
                                   prompts=prompts, max_new=6,
                                   prefill_chunk=8)
    assert paged == dense
    assert sp.tokens_out == sd.tokens_out == 6 * 6
    # slot churn really released: after the drain only prefix-pinned pages
    # may persist in the pool
    assert eng.alloc.pages_in_use * eng.page <= sum(len(p) for p in prompts)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma2-27b", "recurrentgemma-9b",
                                  "mamba2-130m"])
def test_paged_matches_dense_newly_supported_stacks(arch):
    """Tentpole acceptance: ring-paged windows (gemma2), hybrid recurrent
    stacks (recurrentgemma, mamba2) reproduce the dense engine exactly
    under slot churn and chunked prefill."""
    cfg = smoke_config(ARCHS[arch])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(12))
    rng = np.random.default_rng(21)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
               for n in (5, 13, 9, 27, 7, 18)]
    dense, sd, _ = _drain_tokens(bundle, params, backend="dense",
                                 prompts=prompts, max_new=6)
    paged, sp, eng = _drain_tokens(bundle, params, backend="paged",
                                   prompts=prompts, max_new=6,
                                   prefill_chunk=8)
    assert paged == dense
    assert sp.tokens_out == sd.tokens_out == 6 * 6
    if eng.ralloc is not None:
        assert eng.ralloc.pages_in_use == 0   # churn really released


@pytest.mark.slow
def test_paged_matches_dense_int8_kv():
    """int8 KV pages (quantized k/v + per-page scale lanes, dequant fused
    into the kernel) reproduce the dense int8 engine token for token."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16, kv_dtype="int8")
    bundle = build(cfg, flags)
    params = bundle.init(jax.random.PRNGKey(13))
    rng = np.random.default_rng(22)
    prompts = [rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
               for n in (5, 13, 9, 27)]
    dense, _, de = _drain_tokens(bundle, params, backend="dense",
                                 prompts=prompts, max_new=6)
    paged, _, pe = _drain_tokens(bundle, params, backend="paged",
                                 prompts=prompts, max_new=6, prefill_chunk=8)
    assert paged == dense
    # int8 halves the unit size, so the derived page doubles in tokens
    assert pe.page >= 2 * ServeEngine(
        build(cfg, FLAGS), params, batch_size=1, max_len=64).page
    assert pe.live_kv_bytes_peak() < de.kv_bytes()


def test_ring_pages_bounded_and_eagerly_released():
    """The ring headline: a windowed layer's live pages never exceed
    ceil(window/page)+1 per slot however long the sequence runs — the
    trailing page is reused in place the moment the window slides past."""
    cfg = smoke_config(ARCHS["gemma2-27b"])     # (local 16, global) pattern
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(14))
    eng = ServeEngine(bundle, params, batch_size=1, max_len=64,
                      cache_backend="paged", prefill_chunk=8)
    req = Request(rid=0, prompt=np.arange(10, dtype=np.int32),
                  max_new_tokens=40)           # runs to position 50: 7 pages
    eng.add_request(req)
    eng.run_to_completion()
    assert len(req.out_tokens) == 40
    assert eng.ring_slots == 3                  # ceil(16/8) + 1
    assert eng.stats.ring_pages_peak <= eng.ring_slots
    # the full-attention layer kept every page; the ring did not
    assert eng.stats.pages_peak >= 7
    assert eng.ralloc.pages_in_use == 0 and eng.alloc.pages_in_use == 0


def test_ring_prefill_chunk_wider_than_ring_capacity():
    """A prefill chunk spanning more logical pages than the ring has slots
    must not scatter two pages through one slot (duplicate indices have
    unspecified order): writes older than the trailing (R-1) pages steer
    to the null page instead, and outputs still match dense exactly."""
    cfg = smoke_config(ARCHS["gemma2-27b"])   # window 16, page 8, R = 3
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(16))
    rng = np.random.default_rng(24)
    prompts = [rng.integers(0, cfg.vocab_size, size=40).astype(np.int32)]
    dense, _, _ = _drain_tokens(bundle, params, backend="dense",
                                prompts=prompts, max_new=6)
    # prefill_chunk=32 > ring capacity 24 tokens: one chunk wraps the ring
    paged, _, eng = _drain_tokens(bundle, params, backend="paged",
                                  prompts=prompts, max_new=6,
                                  prefill_chunk=32)
    assert eng.prefill_chunk > eng.ring_slots * eng.page - eng.page
    assert paged == dense


def test_hybrid_pending_prefill_state_survives_decode_windows():
    """Hybrid regression guard: a long prompt prefilling in chunks while
    another slot decodes must not have its recurrent state trampled by the
    masked decode ticks between its chunks."""
    cfg = smoke_config(ARCHS["recurrentgemma-9b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(15))
    rng = np.random.default_rng(23)
    prompts = [rng.integers(0, cfg.vocab_size, size=4).astype(np.int32),
               rng.integers(0, cfg.vocab_size, size=40).astype(np.int32)]
    dense, _, _ = _drain_tokens(bundle, params, backend="dense",
                                prompts=prompts, max_new=8)
    paged, sp, _ = _drain_tokens(bundle, params, backend="paged",
                                 prompts=prompts, max_new=8,
                                 prefill_chunk=8)
    assert paged == dense
    assert sp.prefill_chunks >= 6   # the long prompt really chunked


@pytest.mark.slow
def test_paged_matches_dense_bfloat16():
    cfg = override(smoke_config(ARCHS["gemma-2b"]), compute_dtype="bfloat16")
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (11, 6)]
    dense, _, _ = _drain_tokens(bundle, params, backend="dense",
                                prompts=prompts, max_new=5)
    paged, _, _ = _drain_tokens(bundle, params, backend="paged",
                                prompts=prompts, max_new=5)
    assert paged == dense


def test_paged_is_default_for_every_decoder_only_stack():
    """Tentpole: the page pool is the default backend for (nearly) every
    decoder in the registry — windowed (ring pages), recurrent hybrids
    (dense state beside the pools), pure-ssm, and int8-KV stacks included.
    Only enc-dec and frontend stacks keep the dense per-slot cache."""
    for arch in ("gemma-2b", "mamba2-130m", "gemma2-27b",
                 "recurrentgemma-9b", "phi4-mini-3.8b"):
        assert build(smoke_config(ARCHS[arch]), FLAGS).paged_supported(), arch
    int8 = build(smoke_config(ARCHS["gemma-2b"]),
                 RuntimeFlags(attn_impl="chunked", kv_dtype="int8"))
    assert int8.paged_supported()
    encdec = build(smoke_config(ARCHS["seamless-m4t-medium"]), FLAGS)
    assert not encdec.paged_supported()
    vlm = build(smoke_config(ARCHS["pixtral-12b"]), FLAGS)
    assert not vlm.paged_supported()
    params = encdec.init(jax.random.PRNGKey(0))
    eng = ServeEngine(encdec, params, batch_size=1, max_len=32)
    assert eng.backend == "dense"       # auto fallback
    with pytest.raises(ValueError):
        ServeEngine(encdec, params, batch_size=1, max_len=32,
                    cache_backend="paged")


def test_pool_exhaustion_becomes_backpressure(gemma_env=None):
    """A pool too small for the whole batch keeps requests queued (typed
    backpressure, not a crash) and still completes them as pages free."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(4))
    # page=8, 3 usable pages: one 20-token request needs 3 -> solo admission
    eng = ServeEngine(bundle, params, batch_size=2, max_len=32,
                      num_pages=4, prefix_cache=False)
    for i in range(3):
        eng.add_request(Request(rid=i,
                                prompt=np.arange(17, dtype=np.int32) + i,
                                max_new_tokens=4))
    stats = eng.run_to_completion()
    assert stats.tokens_out == 3 * 4
    assert stats.pool_stalls > 0        # admission actually backed off
    assert eng.alloc.pages_in_use == 0


def test_impossible_prompt_raises_instead_of_silent_drop():
    """A prompt no amount of backpressure can ever admit (needs more pages
    than the pool holds) must raise loudly, not sit queued forever while
    run_to_completion returns 'drained'."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(4))
    eng = ServeEngine(bundle, params, batch_size=1, max_len=32,
                      num_pages=3)          # 2 usable pages of 8 = 16 tokens
    eng.add_request(Request(rid=0, prompt=np.arange(17, dtype=np.int32),
                            max_new_tokens=2))
    with pytest.raises(ValueError, match="pages"):
        eng.run_to_completion()


def test_explicit_page_size_reshapes_pool_and_plan():
    """page_size overrides the derived plan; the plan handed to the kernel
    must describe the pool actually laid out (the kernel asserts it)."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(4))
    eng = ServeEngine(bundle, params, batch_size=1, max_len=32, page_size=4)
    assert eng.page == 4 and eng.plan.page_size == 4
    req = Request(rid=0, prompt=np.arange(6, dtype=np.int32),
                  max_new_tokens=4)
    eng.add_request(req)
    eng.run_to_completion()
    assert len(req.out_tokens) == 4


def test_long_prompt_prefills_in_chunks_between_decode_ticks():
    """Chunked prefill: a long prompt admits in prefill_chunk pieces and
    in-flight decode keeps ticking between chunks."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(5))
    eng = ServeEngine(bundle, params, batch_size=2, max_len=64, window=2,
                      prefill_chunk=8)
    short = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=12)
    long = Request(rid=1, prompt=np.arange(40, dtype=np.int32) + 100,
                   max_new_tokens=4)
    eng.add_request(short)
    eng.add_request(long)
    stats = eng.run_to_completion()
    assert len(short.out_tokens) == 12 and len(long.out_tokens) == 4
    assert stats.prefill_chunks >= 1 + 5   # 40 tokens / 8-token chunks
    # decode went on while the long prompt was still prefilling: more
    # dispatches than a single post-prefill drain would need
    assert stats.decode_dispatches > 2


# ---------------------------------------------------------------------------
# prefix caching (tentpole; satellite 3's fork test is above)
# ---------------------------------------------------------------------------

def test_prefix_cache_hits_and_outputs_unchanged():
    """Requests sharing a >= 1-page prompt prefix reuse its pages read-only:
    hit accounting moves, outputs stay bit-identical to an uncached run."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(6))
    rng = np.random.default_rng(9)
    common = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    prompts = [np.concatenate([common, rng.integers(
        0, cfg.vocab_size, size=5).astype(np.int32)]) for _ in range(4)]
    # batch_size=1 serializes requests => later ones see registered pages
    cached, sc, eng = _drain_tokens(bundle, params, backend="paged",
                                    prompts=prompts, max_new=4, bsz=1)
    uncached, su, _ = _drain_tokens(bundle, params, backend="paged",
                                    prompts=prompts, max_new=4, bsz=1,
                                    prefix_cache=False)
    assert cached == uncached
    assert su.prefix_hit_tokens == 0
    assert sc.prefix_hit_tokens == 3 * 16   # requests 2..4 reuse both pages
    # shared pages survive in the pool for future hits (pinned by the index)
    assert eng.alloc.pages_in_use >= 2


def test_shared_prefix_pages_never_written_by_later_requests():
    """The engine-level never-write guarantee: page bytes registered by the
    first request are bit-identical after later requests decode over them."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(7))
    common = (np.arange(16, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    eng = ServeEngine(bundle, params, batch_size=1, max_len=64)
    eng.add_request(Request(rid=0, prompt=common, max_new_tokens=3))
    eng.run_to_completion()
    shared = sorted(eng.prefix._by_hash.values())
    assert len(shared) == 2
    def snapshot():
        leaf = jax.tree_util.tree_leaves(eng.cache)[0]
        # stacked pools carry LAYERS first: (nb, P, page, Hkv, D)
        return np.asarray(leaf[:, shared] if leaf.ndim == 5 else leaf[shared])
    before = snapshot()
    tail = np.asarray([7, 7, 7, 7, 7], np.int32)
    eng.add_request(Request(rid=1,
                            prompt=np.concatenate([common, tail]),
                            max_new_tokens=6))
    stats = eng.run_to_completion()
    assert stats.prefix_hit_tokens == 16
    np.testing.assert_array_equal(before, snapshot())


# ---------------------------------------------------------------------------
# memory figure of merit (acceptance)
# ---------------------------------------------------------------------------

def test_live_bytes_below_dense_footprint():
    """The whole point: live-token HBM bytes strictly below the dense
    ``batch x max_len`` commitment for a short-request mix."""
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(8))
    prompts = [np.arange(6, dtype=np.int32) + 10 * i for i in range(4)]
    _, _, dense_eng = _drain_tokens(bundle, params, backend="dense",
                                    prompts=prompts, max_new=4, bsz=4)
    _, _, paged_eng = _drain_tokens(bundle, params, backend="paged",
                                    prompts=prompts, max_new=4, bsz=4)
    assert paged_eng.live_kv_bytes_peak() < dense_eng.live_kv_bytes_peak()
    assert paged_eng.stats.pages_peak <= paged_eng.num_pages - 1


# ---------------------------------------------------------------------------
# speculative decoding: seeded twins of the fuzz equivalence layer
# (test_serve_fuzz skips wholesale without hypothesis; these always run)
# ---------------------------------------------------------------------------

from repro.serve import SamplingParams  # noqa: E402


@pytest.fixture(scope="module")
def spec_env():
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(7))
    # different params: proposals genuinely get rejected, so every drain
    # exercises suffix rollback, not just the accept-everything fast lane
    draft_params = bundle.init(jax.random.PRNGKey(11))
    return cfg, bundle, params, draft_params


def _seeded_mixes(cfg, n_mixes=3):
    """Deterministic request mixes with shared prefixes and varied budgets."""
    rng = np.random.default_rng(17)
    common = rng.integers(0, cfg.vocab_size, size=9).astype(np.int32)
    mixes = []
    for _ in range(n_mixes):
        reqs = []
        for r in range(int(rng.integers(2, 4))):
            plen = int(rng.integers(1, 13))
            tail = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
            prompt = (np.concatenate([common, tail])
                      if rng.integers(0, 2) else tail)
            reqs.append((prompt, int(rng.integers(1, 9))))
        mixes.append(reqs)
    return mixes


def _drive_mix(eng, mix):
    eng.reset()
    reqs = []
    first, rest = mix[:1], mix[1:]
    for prompt, max_new in first:
        r = Request(rid=len(reqs), prompt=prompt, max_new_tokens=max_new)
        reqs.append(r)
        eng.add_request(r)
    eng.step()                      # later admissions land mid-drain
    for prompt, max_new in rest:
        r = Request(rid=len(reqs), prompt=prompt, max_new_tokens=max_new)
        reqs.append(r)
        eng.add_request(r)
    eng.run_to_completion(max_ticks=5_000)
    assert all(s is None for s in eng.slots)
    return [r.out_tokens for r in reqs]


@pytest.mark.parametrize("variant", ["greedy", "sampled"])
def test_spec_matches_vanilla_seeded_mixes(spec_env, variant):
    """T=0 speculative drains are token-identical to vanilla paged drains;
    T>0 drains sharing per-slot keys are key-exact identical — and every
    drain leaves the page pool conserved after rollback churn."""
    cfg, bundle, params, draft_params = spec_env
    sampling = (None if variant == "greedy"
                else SamplingParams(temperature=0.9, top_p=0.95))
    vanilla = ServeEngine(bundle, params, batch_size=2, max_len=64,
                          cache_backend="paged", prefill_chunk=8,
                          sampling=sampling, seed=3)
    spec = ServeEngine(bundle, params, batch_size=2, max_len=64,
                       cache_backend="paged", prefill_chunk=8,
                       sampling=sampling, seed=3, draft_bundle=bundle,
                       draft_params=draft_params, spec_k=3)
    for mix in _seeded_mixes(cfg):
        want = _drive_mix(vanilla, mix)
        got = _drive_mix(spec, mix)
        assert got == want
        assert spec.stats.spec_steps > 0
        a = spec.alloc
        assert a.pages_in_use + len(a.free) == a.num_pages - a.reserved
        assert all(r >= 1 for r in a.ref.values())
    # the draft path must have seen real rejections, or this proved nothing
    assert spec.stats.draft_accepted < spec.stats.draft_tokens


def test_spec_stats_track_acceptance(spec_env):
    """Self-draft greedy: every proposal matches the coupled sample, so the
    accept rate is exactly 1 and each dispatch advances spec_k+1 tokens
    per unblocked slot (modulo end-of-budget truncation)."""
    cfg, bundle, params, _ = spec_env
    eng = ServeEngine(bundle, params, batch_size=1, max_len=64,
                      cache_backend="paged", prefill_chunk=8,
                      draft_bundle=bundle, draft_params=params, spec_k=3)
    req = Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                  max_new_tokens=12)
    eng.add_request(req)
    stats = eng.run_to_completion()
    assert len(req.out_tokens) == 12
    assert stats.accept_rate == 1.0
    assert stats.spec_steps == stats.decode_dispatches
    # 12 tokens = 1 prefill seed + 11 decoded; at k+1=4/dispatch that is
    # ceil(11/4) = 3 verify dispatches
    assert stats.spec_steps == 3
    assert stats.accepted_per_step > 1.0


def test_spec_validation_errors(spec_env):
    cfg, bundle, params, draft_params = spec_env
    with pytest.raises(ValueError, match="draft_params"):
        ServeEngine(bundle, params, batch_size=1, max_len=64,
                    draft_bundle=bundle)
    ring_cfg = smoke_config(ARCHS["gemma2-27b"])     # sliding-window stack
    ring_bundle = build(ring_cfg, FLAGS)
    ring_params = ring_bundle.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="rollback"):
        ServeEngine(ring_bundle, ring_params, batch_size=1, max_len=64,
                    cache_backend="paged", draft_bundle=ring_bundle,
                    draft_params=ring_params)
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(bundle, params, batch_size=1, max_len=64,
                    cache_backend="dense", draft_bundle=bundle,
                    draft_params=draft_params)


# ---------------------------------------------------------------------------
# rollback mechanics: PageAllocator.truncate (tentpole support)
# ---------------------------------------------------------------------------

def test_truncate_frees_only_private_trailing_pages():
    a = PageAllocator(10, 4, reserved=1)
    a.alloc(0)
    a.reserve(0, 14)                       # pages for 14 tokens: 4 pages
    assert len(a.tables[0]) == 4
    freed = a.truncate(0, 9)               # keep ceil(9/4) = 3 pages
    assert len(freed) == 1 and len(a.tables[0]) == 3
    assert a.pages_in_use + len(a.free) == 9
    freed = a.truncate(0, 9)               # idempotent at the same length
    assert freed == []
    with pytest.raises(ValueError):
        a.truncate(0, 10)                  # growth is reserve's job
    a.release(0)
    assert a.pages_in_use == 0


def test_truncate_never_frees_or_mutates_shared_pages():
    """Speculative rollback on a forked table: shared pages are decref'd,
    never freed early — the sibling still owns them, byte-identical."""
    a = PageAllocator(12, 4, reserved=1)
    a.alloc(0)
    a.reserve(0, 16)                       # 4 pages
    a.fork(0, 1)                           # rid 1 shares all 4
    src_table = list(a.tables[0])
    freed = a.truncate(1, 5)               # drop rid 1 back to 2 pages
    assert freed == []                     # shared: nothing returns to pool
    assert a.tables[0] == src_table        # sibling table untouched
    assert all(a.ref[p] == 2 for p in a.tables[1])
    assert all(a.ref[p] == 1 for p in src_table[2:])
    a.release(0)
    # now rid 1's remaining pages are the last references
    freed = a.truncate(1, 0)
    assert sorted(freed) == sorted(src_table[:2])
    a.release(1)
    assert a.pages_in_use == 0


def test_ring_truncate_only_rewinds_length():
    a = PageAllocator(8, 4, reserved=1, window=8)
    a.alloc(0)
    a.reserve(0, 20)                       # rotates within ring_slots pages
    held = list(a.tables[0])
    a.truncate(0, 17)
    assert a.tables[0] == held             # rotation handles regrowth
    assert a.lengths[0] == 17
    a.release(0)
    assert a.pages_in_use == 0


def test_ring_evict_never_frees_rotated_shared_page_early():
    """Satellite: the scheduler's eviction path (truncate to the live
    length, then release) on a windowed victim whose ring has rotated and
    whose pages a sibling still shares.  The sibling must keep every one
    of its pages referenced and byte-consistent through the eviction —
    rotation makes trailing slot indices ambiguous, so only refcounts
    (never position arithmetic) may decide what returns to the pool."""
    a = PageAllocator(10, 4, reserved=1, window=8)   # ring_slots = 3
    a.alloc(0)
    a.reserve(0, 20)                       # grown past the window: rotated
    victim_pages = list(a.tables[0])
    assert len(victim_pages) == a.ring_slots
    # a sibling attaches the victim's rotated table (the engine's ring
    # fork: attach a copy of the slot-indexed table at the same length)
    a.alloc(1)
    a.attach(1, list(a.tables[0]), a.lengths[0])
    assert all(a.ref[p] == 2 for p in set(victim_pages))
    before = {p: a.ref[p] for p in set(victim_pages)}

    # evict the victim mid-flight: rewind (possibly into rotated history),
    # then release its references
    a.truncate(0, 9)
    assert a.tables[0] == victim_pages     # ring truncate rewinds length only
    a.release(0)

    # the sibling's pages all survive with exactly one reference left;
    # nothing the sibling can still read was freed early
    for p in set(victim_pages):
        assert a.ref[p] == before[p] - 1 == 1
    assert not set(a.tables[1]) & set(a.free)
    assert a.pages_in_use + len(a.free) == a.num_pages - a.reserved

    # sibling continues growing through its (rotating) ring unharmed
    a.reserve(1, 24)
    assert len(a.tables[1]) <= a.ring_slots
    assert all(a.ref[p] >= 1 for p in a.tables[1])
    a.release(1)
    assert a.pages_in_use == 0


# ---------------------------------------------------------------------------
# per-slot PRNG isolation under churn (satellite)
# ---------------------------------------------------------------------------

def test_prng_stream_is_churn_invariant(spec_env):
    """A request's sampled stream depends only on (seed, rid) — masked
    ticks, pending-prefill neighbours, budget-exhausted slots, and
    mid-drain admissions must not consume its PRNG state."""
    cfg, bundle, params, _ = spec_env
    sp = SamplingParams(temperature=3.0, top_p=0.98)
    prompt0 = np.asarray([5, 9, 2, 7, 1], np.int32)

    eng = ServeEngine(bundle, params, batch_size=2, max_len=64,
                      cache_backend="paged", prefill_chunk=8,
                      sampling=sp, seed=21)
    solo_req = Request(rid=0, prompt=prompt0, max_new_tokens=10)
    eng.add_request(solo_req)
    eng.run_to_completion()

    eng.reset()
    churn_req = Request(rid=0, prompt=prompt0, max_new_tokens=10)
    eng.add_request(churn_req)
    # a long-prompt neighbour: its chunked prefill interleaves masked
    # decode ticks over rid 0's live slot
    eng.add_request(Request(rid=1, prompt=np.arange(30, dtype=np.int32),
                            max_new_tokens=2))
    for _ in range(4):
        eng.step()
    # mid-drain admissions churn slot 1 through several occupants
    eng.add_request(Request(rid=2, prompt=np.arange(7, dtype=np.int32),
                            max_new_tokens=6))
    eng.add_request(Request(rid=3, prompt=np.arange(3, dtype=np.int32),
                            max_new_tokens=4))
    eng.run_to_completion()
    assert churn_req.out_tokens == solo_req.out_tokens

"""Device-resident sampling: bit-identity against a host reference.

The fused decode loops draw tokens on device (``jax.random.categorical``
over temperature/top-k/top-p-masked logits, one key split per emitted
token).  These tests pin that machinery to an independent host-side
reference: the masks are recomputed in numpy (the kept entries are a
single IEEE float32 division, so numpy and jax agree bit-for-bit) and
the draw is reproduced via the gumbel-max identity
``categorical(key, l) == argmax(l + gumbel(key))``.  A chi-square check
then ties the sampled frequencies back to the truncated softmax the
masks define — the sampler is not just deterministic, it draws from the
*right* distribution.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import RuntimeFlags, build
from repro.serve import Request, ServeEngine
from repro.serve.sampling import (NEG_INF, SamplingParams, mask_logits,
                                  sample_token)

FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                     moe_impl="dense", loss_chunk=16)

PARAM_GRID = [
    SamplingParams(temperature=1.0),
    SamplingParams(temperature=0.7, top_k=5),
    SamplingParams(temperature=1.3, top_p=0.9),
    SamplingParams(temperature=0.9, top_k=13, top_p=0.8),
    SamplingParams(temperature=2.5, top_p=0.5),
]


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(ARCHS["gemma-2b"])
    bundle = build(cfg, FLAGS)
    params = bundle.init(jax.random.PRNGKey(7))
    return cfg, bundle, params


# ---------------------------------------------------------------------------
# host reference sampler (numpy masks + gumbel-max draw)
# ---------------------------------------------------------------------------

def ref_mask(logits: np.ndarray, sp: SamplingParams) -> np.ndarray:
    """Numpy twin of :func:`repro.serve.sampling.mask_logits`."""
    l = np.asarray(logits, np.float32) / np.float32(sp.temperature)
    v = l.shape[-1]
    if 0 < sp.top_k < v:
        kth = np.sort(l)[v - sp.top_k]
        l = np.where(l < kth, np.float32(NEG_INF), l)
    if sp.top_p < 1.0:
        sl = np.sort(l)[::-1]
        e = np.exp(sl - sl.max())
        probs = e / e.sum()
        csum = np.cumsum(probs)
        keep = (csum - probs) < sp.top_p
        cutoff = np.min(np.where(keep, sl, np.inf))
        l = np.where(l < cutoff, np.float32(NEG_INF), l)
    return l


def ref_sample(key, logits: np.ndarray, sp: SamplingParams) -> int:
    """categorical(key, masked) == argmax(masked + gumbel(key)) — the
    masked logits come from numpy, only the gumbel noise from jax."""
    if sp.greedy:
        return int(np.argmax(logits))
    masked = ref_mask(logits, sp)
    g = np.asarray(jax.random.gumbel(key, masked.shape, jnp.float32))
    return int(np.argmax(masked + g))


# ---------------------------------------------------------------------------
# unit: masks and draws are bit-identical to the reference
# ---------------------------------------------------------------------------

def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy


def test_mask_logits_bit_identical_to_numpy():
    rng = np.random.default_rng(0)
    for t in range(25):
        logits = (rng.standard_normal(256) * 3).astype(np.float32)
        for sp in PARAM_GRID:
            got = np.asarray(mask_logits(jnp.asarray(logits), sp))
            want = ref_mask(logits, sp)
            assert np.array_equal(got, want), (t, sp)


def test_sample_token_bit_identical_to_host_reference():
    rng = np.random.default_rng(1)
    for t in range(25):
        logits = (rng.standard_normal(256) * 3).astype(np.float32)
        key = jax.random.fold_in(jax.random.PRNGKey(42), t)
        for sp in PARAM_GRID:
            dev = int(sample_token(key, jnp.asarray(logits), sp))
            host = ref_sample(key, logits, sp)
            assert dev == host, (t, sp)


def test_temperature_zero_is_exact_argmax():
    rng = np.random.default_rng(2)
    logits = (rng.standard_normal(128) * 2).astype(np.float32)
    for t in range(8):
        key = jax.random.fold_in(jax.random.PRNGKey(0), t)
        tok = int(sample_token(key, jnp.asarray(logits), SamplingParams()))
        assert tok == int(np.argmax(logits))  # key-independent


# ---------------------------------------------------------------------------
# distribution: sampled frequencies match the truncated softmax
# ---------------------------------------------------------------------------

def test_chi_square_matches_truncated_softmax():
    logits = np.asarray([2.0, 1.5, 1.0, 0.5, 0.0, -0.5, -1.0, -4.0],
                        np.float32)
    for sp in [SamplingParams(temperature=1.0),
               SamplingParams(temperature=0.8, top_k=5),
               SamplingParams(temperature=1.2, top_p=0.9)]:
        masked = ref_mask(logits, sp)
        e = np.exp(masked - masked.max())
        p = e / e.sum()                       # truncated softmax
        n = 4000
        keys = jax.vmap(
            lambda i: jax.random.fold_in(jax.random.PRNGKey(3), i))(
                jnp.arange(n))
        draws = np.asarray(jax.vmap(
            lambda k: sample_token(k, jnp.asarray(logits), sp))(keys))
        counts = np.bincount(draws, minlength=8)
        # masked tokens must never appear at all
        assert counts[p < 1e-12].sum() == 0, sp
        live = p > 1e-12
        stat = float((((counts[live] - n * p[live]) ** 2)
                      / (n * p[live])).sum())
        # df <= 7; the 99.9th percentile of chi2(7) is ~24.3 — give slack,
        # the draw is deterministic so this either passes forever or never
        assert stat < 30.0, (sp, stat, counts, p)


# ---------------------------------------------------------------------------
# engine: the fused loop IS the reference sampler, step for step
# ---------------------------------------------------------------------------

def _host_replay(bundle, params, prompt, n_new, sp, seed, rid, max_len=64):
    """Stepwise eager decode + reference sampler, walking the exact key
    chain the engine pins at admission: fold_in(PRNGKey(seed), rid), one
    split per emitted token."""
    cache, last = bundle.prefill(params, dict(tokens=prompt[None, :]))

    def pad(path, a):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        ax = 1 if any(n in ("blocks", "dec") for n in names) else 0
        sax = ax + 1
        if a.ndim > sax and a.shape[sax] == prompt.shape[0]:
            padw = [(0, 0)] * a.ndim
            padw[sax] = (0, max_len - a.shape[sax])
            cv = -10**9 if a.dtype == jnp.int32 else 0
            return jnp.pad(a, padw, constant_values=cv)
        return a

    cache = jax.tree_util.tree_map_with_path(pad, cache)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), rid)
    key, sub = jax.random.split(key)
    toks = [ref_sample(sub, np.asarray(last)[0], sp)]
    pos = prompt.shape[0]
    for _ in range(n_new - 1):
        logits, cache = bundle.decode_step(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        key, sub = jax.random.split(key)
        toks.append(ref_sample(sub, np.asarray(logits)[0], sp))
        pos += 1
    return toks


@pytest.mark.parametrize("sp", [SamplingParams(temperature=3.0, top_p=0.98),
                                SamplingParams(temperature=0.8, top_k=40)])
def test_fused_drain_matches_host_stepwise_replay(setup, sp):
    cfg, bundle, params = setup
    prompt = np.asarray([5, 9, 2, 7, 1], np.int32)
    want = _host_replay(bundle, params, prompt, 10, sp, seed=5, rid=0)

    eng = ServeEngine(bundle, params, batch_size=1, max_len=64,
                      cache_backend="dense", bucket_prompts=False,
                      sampling=sp, seed=5)
    req = Request(rid=0, prompt=prompt, max_new_tokens=10)
    eng.add_request(req)
    eng.run_to_completion()
    assert req.out_tokens == want


def test_paged_fused_drain_matches_host_stepwise_replay(setup):
    cfg, bundle, params = setup
    sp = SamplingParams(temperature=3.0, top_p=0.98)
    prompt = np.asarray([3, 1, 4, 1, 5, 9, 2, 6], np.int32)
    want = _host_replay(bundle, params, prompt, 9, sp, seed=11, rid=0)

    eng = ServeEngine(bundle, params, batch_size=2, max_len=64,
                      cache_backend="paged", prefill_chunk=8,
                      sampling=sp, seed=11)
    req = Request(rid=0, prompt=prompt, max_new_tokens=9)
    eng.add_request(req)
    # distractor sharing the batch: per-slot keys must not cross-talk
    eng.add_request(Request(rid=1, prompt=np.arange(6, dtype=np.int32),
                            max_new_tokens=7))
    eng.run_to_completion()
    assert req.out_tokens == want


def test_greedy_engine_consumes_no_prng_state(setup):
    """temperature=0 collapses exactly to the pre-sampling engine: the
    per-slot keys are never set nor split."""
    cfg, bundle, params = setup
    prompt = np.asarray([5, 9, 2, 7, 1], np.int32)

    outs = []
    for sampling in (None, SamplingParams()):
        eng = ServeEngine(bundle, params, batch_size=2, max_len=64,
                          sampling=sampling, seed=123)
        req = Request(rid=0, prompt=prompt, max_new_tokens=8)
        eng.add_request(req)
        eng.run_to_completion()
        outs.append(list(req.out_tokens))
        assert not np.asarray(eng.keys).any()  # untouched zeros
    assert outs[0] == outs[1]

"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="dev dependency — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import memmodel
from repro.core.patterns import Knobs, Pattern
from repro.core.roofline import (CellCost, affine_extrapolate,
                                 collective_stats, _shape_bytes)
from repro.kernels import ops, ref
from repro.optim import compress

SET = settings(max_examples=40, deadline=None)


# ---------------------------------------------------------------------------
# memory model (paper equations)
# ---------------------------------------------------------------------------

@SET
@given(no1=st.integers(1, 64), no2=st.integers(1, 64))
def test_outstanding_monotone(no1, no2):
    """More outstanding never slows the modeled stream (paper Fig. 5)."""
    lo, hi = sorted((no1, no2))
    k_lo = Knobs(outstanding=lo)
    k_hi = Knobs(outstanding=hi)
    assert (memmodel.predict_bw(Pattern.SEQUENTIAL, k_hi)
            >= memmodel.predict_bw(Pattern.SEQUENTIAL, k_lo) - 1e-6)


@SET
@given(s1=st.integers(1, 64), s2=st.integers(1, 64))
def test_stride_monotone(s1, s2):
    """Larger stride never speeds the modeled traversal (paper Figs. 8/9)."""
    lo, hi = sorted((s1, s2))
    assert (memmodel.predict_bw(Pattern.STRIDED, Knobs(stride=hi))
            <= memmodel.predict_bw(Pattern.STRIDED, Knobs(stride=lo)) + 1e-6)


@SET
@given(u1=st.integers(2, 12), u2=st.integers(2, 12))
def test_unit_size_monotone_random(u1, u2):
    """Random-access throughput grows with unit size (paper Fig. 7)."""
    lo, hi = sorted((u1, u2))
    assert (memmodel.predict_bw(Pattern.RANDOM, Knobs(unit_bytes=1 << hi))
            >= memmodel.predict_bw(Pattern.RANDOM, Knobs(unit_bytes=1 << lo)) - 1e-6)


@SET
@given(b=st.integers(10, 24))
def test_pattern_ordering(b):
    """sequential >= random >= chase at any burst (paper Table 8)."""
    k = Knobs(unit_bytes=256, burst_bytes=1 << b, outstanding=4)
    seq = memmodel.predict_bw(Pattern.SEQUENTIAL, k)
    rnd = memmodel.predict_bw(Pattern.RANDOM, k)
    chs = memmodel.predict_bw(Pattern.CHASE, k)
    assert seq >= rnd >= chs


def test_outstanding_knee():
    """Eq. 4: NO* covers the latency-bandwidth product."""
    burst = 64 * 1024
    no_star = memmodel.min_outstanding_for_peak(burst)
    near_peak = memmodel.predict_bw(
        Pattern.SEQUENTIAL, Knobs(burst_bytes=burst, outstanding=no_star))
    assert near_peak >= 0.99 * memmodel.V5E.hbm_bw


# ---------------------------------------------------------------------------
# autotune + measured-mode calibration
# ---------------------------------------------------------------------------

_TUNABLE = [Pattern.SEQUENTIAL, Pattern.STRIDED, Pattern.RANDOM,
            Pattern.CHASE, Pattern.RS_TRA, Pattern.RR_TRA, Pattern.R_ACC,
            Pattern.NEST]


@SET
@given(pattern=st.sampled_from(_TUNABLE),
       frac=st.floats(0.01, 0.5))
def test_tuned_knobs_always_fit_vmem(pattern, frac):
    """Whatever the budget, the tuner never returns knobs that bust it."""
    from repro.core import autotune
    t = autotune.tune_pattern(pattern, vmem_budget_fraction=frac)
    assert memmodel.vmem_ok(t.knobs, memmodel.V5E, budget_fraction=frac)
    assert t.vmem_bytes == t.knobs.vmem_bytes()
    assert 0 < t.predicted_gbps <= t.best_gbps + 1e-9


@SET
@given(pattern=st.sampled_from(_TUNABLE),
       f1=st.floats(0.01, 0.5), f2=st.floats(0.01, 0.5))
def test_tuned_bandwidth_monotone_in_budget(pattern, f1, f2):
    """A bigger VMEM budget can only expand the feasible set, so the best
    predicted bandwidth is monotone non-decreasing in the budget."""
    from repro.core import autotune
    lo, hi = sorted((f1, f2))
    t_lo = autotune.tune_pattern(pattern, vmem_budget_fraction=lo)
    t_hi = autotune.tune_pattern(pattern, vmem_budget_fraction=hi)
    assert t_hi.best_gbps >= t_lo.best_gbps - 1e-9


@SET
@given(kernel=st.sampled_from(["flash_attention", "decode_attention",
                               "matmul"]),
       a=st.integers(8, 8192), b=st.integers(8, 8192),
       d=st.sampled_from([16, 64, 128, 256]),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_every_cached_kernel_plan_fits_vmem(kernel, a, b, d, dtype):
    """PR 3 acceptance: any plan the cache can hand a kernel satisfies the
    paper's BRAM/VMEM constraint (vmem_ok) — whatever the shape/dtype."""
    from repro.tune import PlanCache
    cache = PlanCache(None)
    sig = {"flash_attention": (a, b, d), "decode_attention": (a, d),
           "matmul": (a, b, d)}[kernel]
    plan = cache.get_or_derive(kernel, shape_sig=sig, dtype=dtype)
    assert memmodel.vmem_ok(plan.knobs(), memmodel.V5E)
    assert plan.vmem_bytes() <= memmodel.V5E.vmem_bytes * 0.5
    assert 1 <= plan.bq and 1 <= plan.bkv
    # round-trip: the cached plan is the one handed back
    assert cache.get_or_derive(kernel, shape_sig=sig, dtype=dtype) == plan


CAL_SET = settings(max_examples=8, deadline=None)


@CAL_SET
@given(lat_exp=st.floats(-7.5, -5.5), bw_exp=st.floats(9.0, 12.5))
def test_calibration_recovers_model_constants(lat_exp, bw_exp):
    """Fitting samples generated FROM the model recovers the spec's
    latency/bandwidth constants within 5% anywhere in the plausible range
    (30ns..3us latency, 1..3000 GB/s bandwidth)."""
    import dataclasses
    from repro.bench.calibrate import fit_spec, synthetic_samples
    true = dataclasses.replace(memmodel.V5E, dma_latency_s=10.0 ** lat_exp,
                               hbm_bw=10.0 ** bw_exp)
    res = fit_spec(synthetic_samples(true))
    assert abs(res.spec.dma_latency_s / true.dma_latency_s - 1) < 0.05
    assert abs(res.spec.hbm_bw / true.hbm_bw - 1) < 0.05


# ---------------------------------------------------------------------------
# roofline extraction
# ---------------------------------------------------------------------------

@SET
@given(base=st.floats(0, 1e12), slope=st.floats(0, 1e12),
       nb=st.integers(3, 100))
def test_affine_extrapolation_exact(base, slope, nb):
    c = lambda n: CellCost(base + slope * n, 2 * base + slope * n,
                           base + 2 * slope * n, slope * n, 0.0)
    got = affine_extrapolate(c(1), c(2), 1, 2, nb)
    want = c(nb)
    for f in ("flops", "bytes_raw", "bytes_fused", "collective"):
        np.testing.assert_allclose(getattr(got, f), getattr(want, f),
                                   rtol=1e-6, atol=1e-3)


def test_shape_bytes_parsing():
    assert _shape_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
    assert _shape_bytes("(f32[8,8], s32[4])") == 8 * 8 * 4 + 4 * 4
    assert _shape_bytes("pred[]") == 1
    assert _shape_bytes("%name.1") == 0


def test_collective_stats_parsing():
    hlo = """
  %ag = bf16[32,128]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[64]{0} all-reduce(%x), replica_groups=[2,8]<=[16], to_apply=%add
  %rs = f32[4,4]{1,0} reduce-scatter(%y), replica_groups={{0,1}}, dimensions={0}
  %done = bf16[32,128]{1,0} all-gather-done(%ag2)
"""
    total, per = collective_stats(hlo)
    ag = 32 * 128 * 2 * 3 / 4
    ar = 2 * 64 * 4 * 7 / 8
    rs = 4 * 4 * 4 * 1
    assert per["all-gather"]["count"] == 1
    assert per["all-reduce"]["count"] == 1
    np.testing.assert_allclose(total, ag + ar + rs)


# ---------------------------------------------------------------------------
# LFSR / chase structures
# ---------------------------------------------------------------------------

@SET
@given(n=st.integers(2, 400), seed=st.integers(0, 2**31 - 1))
def test_chain_is_single_cycle(n, seed):
    table = np.asarray(ops.make_chain(n, seed))[:, 0]
    assert sorted(table.tolist()) == list(range(n))  # permutation
    seen = set()
    cur = 0
    for _ in range(n):
        assert cur not in seen
        seen.add(cur)
        cur = int(table[cur])
    assert cur == 0 and len(seen) == n  # one full cycle


@SET
@given(n=st.integers(1, 2000), bits=st.sampled_from([16, 24, 32]),
       seed=st.integers(1, 2**16 - 1))
def test_lfsr_range(n, bits, seed):
    idx = np.asarray(ops.lfsr_indices(n, bits=bits, seed=seed))
    assert idx.shape == (n,)
    assert idx.min() >= 0 and idx.max() < (1 << min(bits, 31))


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

@SET
@given(shape=st.sampled_from([(8,), (4, 16), (3, 5, 7)]),
       seed=st.integers(0, 1000))
def test_quantize_bounded_error(shape, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    q, s = compress.quantize(x)
    err = np.abs(np.asarray(compress.dequantize(q, s) - x))
    amax = np.max(np.abs(np.asarray(x)), axis=tuple(range(1, len(shape))),
                  keepdims=True) if len(shape) > 1 else np.max(np.abs(x))
    assert np.all(err <= amax / 127.0 + 1e-6)


def test_error_feedback_unbiased_over_steps():
    """Sum of EF-compressed grads converges to sum of true grads."""
    rng = np.random.default_rng(0)
    err = jnp.zeros((32,), jnp.float32)
    true_sum = np.zeros(32)
    deq_sum = np.zeros(32)
    for i in range(50):
        g = jnp.asarray(rng.standard_normal(32) * (1 + i % 3), jnp.float32)
        q, s, err = compress.ef_compress(g, err)
        deq_sum += np.asarray(compress.dequantize(q, s))
        true_sum += np.asarray(g)
    # residual is bounded by one quantization step -> averages match
    np.testing.assert_allclose(deq_sum + np.asarray(err), true_sum, rtol=1e-4,
                               atol=1e-3)


# ---------------------------------------------------------------------------
# sharding fallback
# ---------------------------------------------------------------------------

@SET
@given(d0=st.integers(1, 64), d1=st.integers(1, 64))
def test_spec_for_always_divides(d0, d1):
    import jax
    from jax.sharding import PartitionSpec
    from repro.dist.sharding import PARAM_RULES_FSDP, spec_for
    if jax.device_count() != 1:
        pytest.skip("single-device test")

    class FakeMesh:
        shape = {"data": 4, "model": 2}

    spec = spec_for((d0 * 8, d1 * 8), ("embed", "ff"), PARAM_RULES_FSDP,
                    FakeMesh())
    sizes = {"data": 4, "model": 2}
    dims = (d0 * 8, d1 * 8)
    for i, part in enumerate(spec):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        total = 1
        for a in axes:
            total *= sizes[a]
        assert dims[i] % total == 0

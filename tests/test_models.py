"""Model-level correctness: decode == full forward; SSD/RG-LRU state
continuation; MoE dispatch equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import RuntimeFlags, build
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import ParamBuilder

FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                     moe_impl="dense", loss_chunk=16)
B, S = 2, 32


def _pad_self_kv(cache, s_tot):
    def padf(path, a):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        if names[-1] in ("k", "v") and "ck" not in names[-1]:
            ax = 2
            if a.ndim >= 3 and a.shape[ax] == s_tot:
                pad = [(0, 0)] * a.ndim
                pad[ax] = (0, 1)
                return jnp.pad(a, pad)
        return a
    return jax.tree_util.tree_map_with_path(padf, cache)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_full_forward(arch):
    cfg = smoke_config(ARCHS[arch])
    bundle = build(cfg, FLAGS)
    key = jax.random.PRNGKey(3)
    params = bundle.init(key)
    tok = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    if cfg.enc_dec:
        frames = jax.random.normal(key, (B, S, cfg.d_model))
        cache, _ = bundle.prefill(params, dict(frames=frames,
                                               dec_tokens=tok[:, :S]))
        _, last_full = bundle.prefill(params, dict(frames=frames,
                                                   dec_tokens=tok[:, :S + 1]))
        s_tot = S
    else:
        batch = dict(tokens=tok[:, :S])
        if cfg.frontend:
            p = cfg.num_frontend_tokens
            batch["patch_embeds"] = jax.random.normal(key, (B, p, cfg.d_model))
        cache, _ = bundle.prefill(params, batch)
        bf = dict(batch)
        bf["tokens"] = tok[:, :S + 1]
        _, last_full = bundle.prefill(params, bf)
        s_tot = S + (cfg.num_frontend_tokens if cfg.frontend else 0)
    cache = _pad_self_kv(cache, s_tot)
    logits, _ = bundle.decode_step(params, cache, tok[:, S:S + 1],
                                   jnp.full((B,), s_tot, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(last_full),
                               rtol=2e-3, atol=2e-3)


def _ssd_params(cfg, key):
    b = ParamBuilder(key, jnp.float32)
    ssm_mod.init(b, "ssd", cfg)
    return b.params["ssd"]


def test_ssd_prefill_state_matches_stepwise():
    cfg = smoke_config(ARCHS["mamba2-130m"])
    key = jax.random.PRNGKey(0)
    p = _ssd_params(cfg, key)
    x = jax.random.normal(key, (B, 24, cfg.d_model)) * 0.3  # 24 % chunk != 0
    out_full, st = ssm_mod.forward(p, x, cfg, return_state=True)
    # step one more token through decode; compare with prefill of 25
    x1 = jax.random.normal(jax.random.PRNGKey(9), (B, 1, cfg.d_model)) * 0.3
    out_step, _ = ssm_mod.decode_step(p, x1, st, cfg)
    out_ref, _ = ssm_mod.forward(p, jnp.concatenate([x, x1], 1), cfg,
                                 return_state=True)
    np.testing.assert_allclose(np.asarray(out_step[:, 0]),
                               np.asarray(out_ref[:, -1]), rtol=2e-3, atol=2e-3)


def test_rglru_prefill_state_matches_stepwise():
    cfg = smoke_config(ARCHS["recurrentgemma-9b"])
    key = jax.random.PRNGKey(0)
    b = ParamBuilder(key, jnp.float32)
    rglru_mod.init(b, "r", cfg)
    p = b.params["r"]
    x = jax.random.normal(key, (B, 17, cfg.d_model)) * 0.3
    _, st = rglru_mod.forward(p, x, cfg, return_state=True)
    x1 = jax.random.normal(jax.random.PRNGKey(9), (B, 1, cfg.d_model)) * 0.3
    out_step, _ = rglru_mod.decode_step(p, x1, st, cfg)
    out_ref, _ = rglru_mod.forward(p, jnp.concatenate([x, x1], 1), cfg,
                                   return_state=True)
    np.testing.assert_allclose(np.asarray(out_step[:, 0]),
                               np.asarray(out_ref[:, -1]), rtol=2e-3, atol=2e-3)


def _moe_params(key, d, f, e, act="swiglu"):
    b = ParamBuilder(key, jnp.float32)
    moe_mod.init(b, "moe", d, f, e, act)
    return b.params["moe"]


def test_moe_sorted_matches_dense_with_ample_capacity():
    key = jax.random.PRNGKey(0)
    d, f, e, k = 32, 64, 8, 2
    p = _moe_params(key, d, f, e)
    x = jax.random.normal(key, (2, 64, d)) * 0.5
    out_d, aux_d = moe_mod.apply_dense(p, x, k, "swiglu")
    out_s, aux_s = moe_mod.apply_sorted(p, x, k, "swiglu", group_size=64,
                                        capacity_factor=float(e) / k)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_d),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(float(aux_s), float(aux_d), rtol=1e-5)


def test_moe_capacity_drops_tokens_not_correctness():
    key = jax.random.PRNGKey(1)
    d, f, e, k = 16, 32, 4, 2
    p = _moe_params(key, d, f, e)
    x = jax.random.normal(key, (1, 32, d))
    out, _ = moe_mod.apply_sorted(p, x, k, "swiglu", group_size=32,
                                  capacity_factor=0.25)
    assert bool(jnp.all(jnp.isfinite(out)))
    # with tiny capacity some tokens get zero contribution
    out_full, _ = moe_mod.apply_sorted(p, x, k, "swiglu", group_size=32,
                                       capacity_factor=float(e) / k)
    assert float(jnp.max(jnp.abs(out - out_full))) > 0


def test_moe_grads_flow_through_sorted_dispatch():
    key = jax.random.PRNGKey(2)
    d, f, e, k = 16, 32, 4, 2
    p = _moe_params(key, d, f, e)
    x = jax.random.normal(key, (1, 32, d))

    def loss(p):
        out, aux = moe_mod.apply_sorted(p, x, k, "swiglu", group_size=32)
        return jnp.mean(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert bool(jnp.all(jnp.isfinite(leaf))), path
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0  # router learns


def test_int8_kv_decode_close_to_native():
    """int8 KV cache (paper's unit-size lever) stays within ~1% rel. logits."""
    cfg = smoke_config(ARCHS["gemma2-27b"])
    f8 = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                      loss_chunk=16, kv_dtype="int8")
    b8, bref = build(cfg, f8), build(cfg, FLAGS)
    key = jax.random.PRNGKey(5)
    params = b8.init(key)
    tok = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    cache, _ = b8.prefill(params, dict(tokens=tok[:, :S]))
    _, last_full = bref.prefill(params, dict(tokens=tok[:, :S + 1]))

    def padf(path, a):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        if names[-1] in ("k", "v", "k_scale", "v_scale") and a.ndim >= 3 \
                and a.shape[2] == S:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, 1)
            return jnp.pad(a, pad)
        return a

    cache = jax.tree_util.tree_map_with_path(padf, cache)
    logits, _ = b8.decode_step(params, cache, tok[:, S:S + 1], jnp.int32(S))
    rel = (np.max(np.abs(np.asarray(logits) - np.asarray(last_full)))
           / (np.max(np.abs(np.asarray(last_full))) + 1e-9))
    assert rel < 0.05, rel

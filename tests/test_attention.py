"""Attention impl equivalence + flash custom-VJP gradient checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (AttnParams, chunked_attention,
                                    naive_attention, unrolled_attention)

RNG = np.random.default_rng(7)


def _qkv(b=2, sq=48, skv=48, hq=4, hkv=2, d=16):
    return (jnp.asarray(RNG.standard_normal((b, sq, hq, d)), jnp.float32),
            jnp.asarray(RNG.standard_normal((b, skv, hkv, d)), jnp.float32),
            jnp.asarray(RNG.standard_normal((b, skv, hkv, d)), jnp.float32))


CASES = [
    ("causal", AttnParams(bq=16, bkv=16)),
    ("window", AttnParams(bq=16, bkv=16, window=20)),
    ("softcap", AttnParams(bq=16, bkv=16, softcap=8.0)),
    ("noncausal", AttnParams(bq=16, bkv=16, causal=False)),
    ("scale", AttnParams(bq=16, bkv=16, scale=0.05)),
    ("bigblocks", AttnParams(bq=64, bkv=64)),
]


@pytest.mark.parametrize("name,p", CASES)
@pytest.mark.parametrize("impl", [chunked_attention, unrolled_attention])
def test_forward_matches_naive(name, p, impl):
    q, k, v = _qkv()
    got = impl(q, k, v, p)
    want = naive_attention(q, k, v, p)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("sq,skv", [(33, 41), (17, 64), (48, 31)])
def test_forward_odd_lengths(sq, skv):
    p = AttnParams(bq=16, bkv=16, causal=False)
    q, k, v = _qkv(sq=sq, skv=skv)
    got = chunked_attention(q, k, v, p)
    want = naive_attention(q, k, v, p)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name,p", CASES)
def test_flash_vjp_matches_naive_grads(name, p):
    q, k, v = _qkv()
    t = jnp.asarray(RNG.standard_normal(q.shape), jnp.float32)
    f_c = lambda *a: jnp.sum(chunked_attention(*a, p) * t)
    f_n = lambda *a: jnp.sum(naive_attention(*a, p) * t)
    g_c = jax.grad(f_c, argnums=(0, 1, 2))(q, k, v)
    g_n = jax.grad(f_n, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_c, g_n):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_flash_vjp_odd_lengths_grads():
    p = AttnParams(bq=16, bkv=16)
    q, k, v = _qkv(sq=33, skv=41)
    t = jnp.asarray(RNG.standard_normal(q.shape), jnp.float32)
    g_c = jax.grad(lambda *a: jnp.sum(chunked_attention(*a, p) * t),
                   argnums=(0, 1, 2))(q, k, v)
    g_n = jax.grad(lambda *a: jnp.sum(naive_attention(*a, p) * t),
                   argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_c, g_n):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


def test_decode_positions_and_ring_cache():
    """naive with k_positions == masked ring-buffer semantics."""
    p = AttnParams(window=8)
    b, w, hkv, d = 2, 8, 2, 16
    q = jnp.asarray(RNG.standard_normal((b, 1, 4, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, w, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, w, hkv, d)), jnp.float32)
    # ring buffer holding positions 10..17 rotated, query at pos 17
    k_pos = jnp.asarray(np.tile(np.array([16, 17, 10, 11, 12, 13, 14, 15]),
                                (b, 1)), jnp.int32)
    got = naive_attention(q, k, v, p, q_offset=jnp.full((b,), 17),
                          k_positions=k_pos)
    # reference: sort by position
    order = np.argsort(np.asarray(k_pos[0]))
    ks = k[:, order]
    vs = v[:, order]
    want = naive_attention(q, ks, vs, p, q_offset=jnp.full((b,), 17),
                           k_positions=k_pos[:, order])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    # empty slots (pos < 0) are masked
    k_pos_empty = k_pos.at[:, 2:].set(-10**9)
    got2 = naive_attention(q, k, v, p, q_offset=jnp.full((b,), 17),
                           k_positions=k_pos_empty)
    want2 = naive_attention(q, k[:, :2], v[:, :2], p,
                            q_offset=jnp.full((b,), 17),
                            k_positions=k_pos[:, :2])
    np.testing.assert_allclose(got2, want2, rtol=1e-5, atol=1e-5)


def test_per_batch_positions():
    p = AttnParams()
    b, t, hkv, d = 3, 32, 2, 16
    q = jnp.asarray(RNG.standard_normal((b, 1, 4, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, t, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, t, hkv, d)), jnp.float32)
    pos = jnp.asarray([5, 17, 31], jnp.int32)
    got = naive_attention(q, k, v, p, q_offset=pos, kv_valid_len=pos + 1)
    for i in range(b):
        want_i = naive_attention(q[i:i+1], k[i:i+1, :int(pos[i])+1],
                                 v[i:i+1, :int(pos[i])+1], p,
                                 q_offset=int(pos[i]))
        np.testing.assert_allclose(got[i:i+1], want_i, rtol=1e-5, atol=1e-5)

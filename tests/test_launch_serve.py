"""ReplicaPool / launcher satellites: least-loaded tie-breaking,
full-field stats aggregation, device-overcommit rejection, and the
round-counted drain budget (the old per-replica-step budget shrank as
``dp`` grew)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.launch.serve import ReplicaPool, build_pool, device_groups
from repro.serve import Request, ServeStats


class _Stub:
    """Duck-typed replica: one slot, one token per step — enough surface
    (queue / slots / stats / add_request / step) for the pool's
    scheduling logic without a model."""

    def __init__(self):
        self.queue = []
        self.slots = [None]
        self.stats = ServeStats()

    def add_request(self, req):
        self.queue.append(req)

    def step(self):
        if self.slots[0] is None and self.queue:
            self.slots[0] = self.queue.pop(0)
        req = self.slots[0]
        if req is None:
            return False
        req.out_tokens.append(0)
        self.stats.tokens_out += 1
        if req.done:
            self.slots[0] = None
        return True


def _req(rid, new=10):
    return Request(rid=rid, prompt=np.zeros(4, np.int32),
                   max_new_tokens=new)


def test_least_loaded_ties_round_robin():
    pool = ReplicaPool([_Stub() for _ in range(3)])
    owners = [pool.submit(_req(i)) for i in range(6)]
    # every submit bumps that replica's load, so an idle pool round-robins
    assert owners == [0, 1, 2, 0, 1, 2]
    assert pool.routed == [2, 2, 2]


def test_least_loaded_counts_in_flight_slots():
    pool = ReplicaPool([_Stub(), _Stub()])
    pool.engines[0].slots[0] = _req(99)      # busy slot, empty queue
    assert pool.submit(_req(0)) == 1         # queue empty on both; 0 is busier


def test_stats_aggregates_every_field():
    pool = ReplicaPool([_Stub(), _Stub()])
    for k, f in enumerate(dataclasses.fields(ServeStats)):
        setattr(pool.engines[0].stats, f.name, k + 1)
        setattr(pool.engines[1].stats, f.name, 2 * (k + 1))
    agg = pool.stats()
    for k, f in enumerate(dataclasses.fields(ServeStats)):
        assert getattr(agg, f.name) == 3 * (k + 1), f.name


def test_build_pool_rejects_device_overcommit():
    n = len(jax.devices())
    with pytest.raises(ValueError, match=f"needs {2 * n + 2} devices"):
        device_groups(n + 1, 2)
    # build_pool validates the layout before touching bundle/params
    with pytest.raises(ValueError,
                       match=f"needs {2 * n + 2} devices, have {n}"):
        build_pool(None, None, tp=n + 1, dp=2)
    with pytest.raises(ValueError, match="must be >= 1"):
        device_groups(0, 1)


def test_drain_budget_counts_rounds_not_replica_steps():
    # 4 replicas x 10-step requests: 10 rounds of work.  The old budget
    # counted per-replica steps (40), so max=12 would have spuriously
    # timed out on the wider pool.
    pool = ReplicaPool([_Stub() for _ in range(4)])
    reqs = [_req(i) for i in range(4)]
    for r in reqs:
        pool.submit(r)
    pool.drain(max_rounds=12)
    assert all(r.done for r in reqs)
    assert pool.stats().tokens_out == 40


def test_drain_timeout_reports_partial_aggregate():
    pool = ReplicaPool([_Stub(), _Stub()])
    for i in range(2):
        pool.submit(_req(i, new=50))
    with pytest.raises(RuntimeError) as ei:
        pool.drain(max_rounds=5)
    msg = str(ei.value)
    assert "5 rounds" in msg
    assert "2/2 replicas busy" in msg
    assert "tokens_out=10" in msg            # partial stats, not just a count

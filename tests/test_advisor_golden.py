"""Golden tests: the advisor's pattern taxonomy per site is stable.

The paper's mapping (§5/§6) is load-bearing for every downstream consumer
(autotune knobs, dryrun roofline, sharding advice), so pin it per config:
embedding gather -> r_acc, attention -> nest, weight streaming -> rs_tra,
MoE routing -> r_acc, recurrent/SSM state -> sequential, decode cache
re-read -> rs_tra.
"""
import pytest

from repro.configs import ARCHS, SHAPES_BY_NAME
from repro.configs.base import ATTN, MOE, RGLRU, SSD
from repro.core.advisor import advise_model, render_report
from repro.core.patterns import Pattern

ARCH_NAMES = sorted(ARCHS)


def _patterns_by_site(reports):
    return {r.op_name: r.pattern for r in reports}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_taxonomy_train(arch):
    cfg = ARCHS[arch]
    reports = advise_model(cfg, SHAPES_BY_NAME["train_4k"])
    by_site = _patterns_by_site(reports)

    # universal sites
    assert by_site["embedding.lookup"] == Pattern.R_ACC
    assert by_site["params.stream"] == Pattern.RS_TRA

    # per-layer sites follow the mixer/mlp kinds in the config
    for site, pattern in by_site.items():
        if site.startswith("attn["):
            assert pattern == Pattern.NEST, site
        if site.startswith(("ssd[", "rglru[")):
            assert pattern == Pattern.SEQUENTIAL, site
        if site.startswith("moe[") and site.endswith(".route"):
            assert pattern == Pattern.R_ACC, site


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_sites_match_layer_pattern(arch):
    """Every mixer/mlp kind in the config produces its site, and no site
    appears without its kind — the golden structure, derived not hardcoded."""
    cfg = ARCHS[arch]
    reports = advise_model(cfg, SHAPES_BY_NAME["train_4k"])
    sites = [r.op_name for r in reports]
    kinds = {spec.mixer for spec in cfg.layer_pattern}
    mlps = {spec.mlp for spec in cfg.layer_pattern}

    assert (ATTN in kinds) == any(s.startswith("attn[") for s in sites)
    assert (SSD in kinds) == any(s.startswith("ssd[") for s in sites)
    assert (RGLRU in kinds) == any(s.startswith("rglru[") for s in sites)
    assert (MOE in mlps) == any(s.startswith("moe[") for s in sites)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_adds_cache_stream(arch):
    cfg = ARCHS[arch]
    reports = advise_model(cfg, SHAPES_BY_NAME["decode_32k"])
    by_site = _patterns_by_site(reports)
    assert by_site["kv_cache.decode_stream"] == Pattern.RS_TRA
    # the cache stream aggregates exactly the nest (attention) bytes
    nest_bytes = sum(r.bytes_moved for r in reports
                     if r.pattern == Pattern.NEST)
    cache = next(r for r in reports
                 if r.op_name == "kv_cache.decode_stream")
    assert cache.bytes_moved == nest_bytes


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_every_site_has_advice_and_prediction(arch):
    cfg = ARCHS[arch]
    reports = advise_model(cfg, SHAPES_BY_NAME["train_4k"])
    for r in reports:
        assert r.advice is not None and r.advice.pattern == r.pattern
        assert r.bytes_moved > 0
        assert r.predicted_gbps > 0  # spec-grounded model prediction
        assert r.measured_vs_predicted is None  # analytic mode
    assert render_report(reports).count("\n") == len(reports)

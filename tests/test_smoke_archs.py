"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
the same family runs one forward/train step on CPU; output shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import RuntimeFlags, build

FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                     moe_impl="dense", loss_chunk=16)
B, S = 2, 32


def _batch(cfg, key):
    tok = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    if cfg.enc_dec:
        return dict(frames=jax.random.normal(key, (B, S, cfg.d_model)),
                    dec_tokens=tok, labels=tok)
    if cfg.frontend:
        p = cfg.num_frontend_tokens
        return dict(patch_embeds=jax.random.normal(key, (B, p, cfg.d_model)),
                    tokens=tok[:, :S - p], labels=tok)
    return dict(tokens=tok, labels=tok)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = smoke_config(ARCHS[arch])
    bundle = build(cfg, FLAGS)
    key = jax.random.PRNGKey(0)
    params = bundle.init(key)
    batch = _batch(cfg, key)

    loss, aux = bundle.train_loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    grads = jax.grad(lambda p: bundle.train_loss(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_smoke(arch):
    cfg = smoke_config(ARCHS[arch])
    bundle = build(cfg, FLAGS)
    key = jax.random.PRNGKey(1)
    params = bundle.init(key)
    batch = {k: v for k, v in _batch(cfg, key).items() if k != "labels"}
    cache, last_logits = bundle.prefill(params, batch)
    assert last_logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(last_logits)))

    # decode one token from a fresh full-size cache
    cache = bundle.init_cache(B, S + 8, S)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, new_cache = bundle.decode_step(
        params, cache, tok, jnp.zeros((B,), jnp.int32))
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_count_formula_matches_tree(arch):
    """Analytic param_count (used for MODEL_FLOPS) matches the real tree."""
    cfg = smoke_config(ARCHS[arch])
    bundle = build(cfg, FLAGS)
    abs_params, _ = bundle.abstract_params()
    tree_n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(abs_params))
    formula_n, _ = cfg.param_count()
    # within 5%: the formula skips conv biases / dt biases etc.
    assert abs(tree_n - formula_n) / tree_n < 0.05, (arch, tree_n, formula_n)

"""core/ library: engines, advisor, autotune, roofline on a real compile."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES_BY_NAME
from repro.core import advisor, autotune, engines, memmodel
from repro.core.patterns import ADVICE, Knobs, Pattern
from repro.core.roofline import cost_of, fused_bytes_detail, memory_summary


def test_engine_rows_have_model_columns():
    r = engines.bw_sequential(rows=256, cols=256)
    assert r.gbps_measured > 0
    assert r.gbps_tpu_model > 0
    assert "theoretical_tpu_gbps" in r.extras
    assert r.csv().count(",") >= 2


def test_engine_pattern_ordering_measured():
    """The paper's Table 8 ordering holds for the measured engines too."""
    seq = engines.bw_sequential(rows=1024, cols=512)
    rnd = engines.bw_random(n_rows=1 << 13, cols=32, n_idx=1 << 12)
    chs = engines.latency_chase(n_entries=1 << 12, steps=1 << 12)
    assert seq.gbps_measured > rnd.gbps_measured > chs.gbps_measured


def test_latency_regions_uniform():
    rows = engines.latency_by_region(n_regions=3, entries_per_region=1 << 10,
                                     steps=1 << 10)
    hops = [float(r.extras["ns_per_hop"]) for r in rows]
    assert max(hops) < 10 * min(hops)  # uniform-ish across regions


def test_advisor_covers_all_archs():
    for name, cfg in ARCHS.items():
        reps = advisor.advise_model(cfg, SHAPES_BY_NAME["train_4k"])
        pats = {r.pattern for r in reps}
        assert Pattern.RS_TRA in pats  # weight streaming always present
        assert Pattern.R_ACC in pats   # embedding gather always present
        if cfg.num_experts:
            assert any("moe" in r.op_name for r in reps)
        if cfg.family in ("ssm", "hybrid"):
            assert any("state" in r.op_name for r in reps)
        assert advisor.render_report(reps)


def test_advice_table_complete():
    for p in Pattern:
        assert p in ADVICE
        assert ADVICE[p].knob_moves


def test_autotune_respects_vmem():
    t = autotune.tune_pattern(Pattern.SEQUENTIAL, vmem_budget_fraction=0.25)
    assert t.vmem_bytes <= memmodel.V5E.vmem_bytes * 0.25
    assert t.predicted_gbps >= 0.9 * memmodel.V5E.hbm_bw / 1e9


def test_autotune_attention_blocks_mxu_aligned():
    bq, bkv = autotune.tune_attention_blocks(128)
    assert bq % 128 == 0 and bkv % 128 == 0


def test_roofline_on_real_compile():
    """Small sharded train-ish fn: fused bytes < raw bytes; flops ~ analytic;
    collectives appear on a >1-device... falls back to 1-device checks."""
    d, f = 64, 256
    w1 = jnp.ones((d, f), jnp.float32)
    x = jnp.ones((32, d), jnp.float32)

    def fn(w, x):
        h = jax.nn.gelu(x @ w)
        return jnp.sum(h @ w.T)

    comp = jax.jit(jax.grad(fn)).lower(w1, x).compile()
    c = cost_of(comp)
    # fwd 2*32*64*256*2(matmuls) + bwd 2x
    analytic = 3 * 2 * 32 * d * f * 2
    assert 0.5 * analytic < c.flops < 3 * analytic
    assert c.bytes_fused <= c.bytes_raw
    assert c.bytes_fused >= (d * f * 4) * 2  # at least weights r/w
    mem = memory_summary(comp)
    assert mem["peak_bytes_per_device"] > 0


def test_fused_bytes_scope_attribution():
    def fn(x):
        with jax.named_scope("flash_inner"):
            y = x @ x.T
        return jnp.sum(y * 2)

    comp = jax.jit(fn).lower(jnp.ones((64, 64), jnp.float32)).compile()
    total, scopes = fused_bytes_detail(comp.as_text())
    assert total > 0
    assert scopes["flash_inner"] > 0
    assert scopes["flash_inner"] <= total

"""Trainer, checkpoint/restore, fault recovery — single-device versions.
(Multi-device variants live in test_multidevice.py subprocesses.)"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ShapeCell, smoke_config
from repro.dist import POLICIES
from repro.models import RuntimeFlags, build
from repro.optim import AdamWConfig, adamw, schedule
from repro.train import (CheckpointManager, FailureInjector, TrainConfig,
                         Trainer, run_with_recovery)

FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                     moe_impl="dense", loss_chunk=16)
CELL = ShapeCell("smoke", "train", 32, 4)


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _trainer(tmp, steps=4, arch="gemma-2b", injector=None, ckpt_every=2):
    cfg = smoke_config(ARCHS[arch])
    bundle = build(cfg, FLAGS)
    return Trainer(bundle, CELL, _mesh(), POLICIES["fsdp_tp"],
                   AdamWConfig(lr=1e-3),
                   TrainConfig(steps=steps, ckpt_dir=tmp, ckpt_every=ckpt_every,
                               log_every=1),
                   injector=injector)


def test_loss_decreases_on_fixed_batch():
    tr = _trainer(None)
    params, opt, _ = tr.init_state()
    batch = tr._put(tr.data.batch_at(0))
    losses = []
    for _ in range(8):
        params, opt, m = tr.step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


def test_checkpoint_roundtrip(tmp_path):
    tr = _trainer(str(tmp_path), steps=4)
    with jax.set_mesh(tr.mesh):
        final = tr.run()
    assert final == 4
    params, opt = tr._final
    restored_p, restored_o, step = tr.restore_state()
    assert step == 4
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_recovery_matches_uninterrupted_run(tmp_path):
    """Deterministic data + exact restore => identical final params."""
    a_dir, b_dir = str(tmp_path / "a"), str(tmp_path / "b")
    tr_a = _trainer(a_dir, steps=6, ckpt_every=2)
    with jax.set_mesh(tr_a.mesh):
        tr_a.run()
    p_ref, _ = tr_a._final

    inj = FailureInjector(fail_at=(3, 5))
    tr_b = _trainer(b_dir, steps=6, ckpt_every=2, injector=inj)

    def run_fn(resume):
        with jax.set_mesh(tr_b.mesh):
            return tr_b.run(resume)

    final = run_with_recovery(run_fn)
    assert final == 6
    p_rec, _ = tr_b._final
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_rec)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_straggler_monitor_flags():
    tr = _trainer(None)
    for i in range(10):
        tr.monitor.record(i, 0.1)
    assert not tr.monitor.flagged
    assert tr.monitor.record(10, 1.0)
    assert tr.monitor.flagged == [10]


def test_checkpoint_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, dict(x=jnp.full((4,), s)))
    assert mgr.all_steps() == [3, 4]
    out = mgr.restore(None, dict(x=jnp.zeros((4,))))
    np.testing.assert_array_equal(np.asarray(out["x"]), np.full((4,), 4.0))


def test_adamw_decreases_quadratic():
    w = dict(w=jnp.asarray([2.0, -3.0, 1.0]))
    st = adamw.init(w)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=None)
    for _ in range(60):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(w)
        w, st, _ = adamw.update(g, st, w, cfg)
    assert float(jnp.max(jnp.abs(w["w"]))) < 0.2


def test_schedules_shape():
    f = schedule.warmup_cosine(10, 100)
    s = jnp.asarray
    assert float(f(s(0))) == 0.0
    assert float(f(s(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(f(s(100))) == pytest.approx(0.1, abs=1e-2)
    g = schedule.wsd(10, 100, decay_frac=0.2)
    assert float(g(s(50))) == 1.0
    assert float(g(s(100))) == pytest.approx(0.05, abs=1e-2)


def test_microbatched_step_matches_full_batch():
    """grad accumulation is numerically equivalent to the full-batch step."""
    from repro.dist.steps import make_train_step
    from repro.models import build as build_bundle
    cfg = smoke_config(ARCHS["phi4-mini-3.8b"])
    bundle = build_bundle(cfg, FLAGS)
    mesh = _mesh()
    outs = {}
    for m in (1, 4):
        step, p_sh, o_sh, bsh = make_train_step(
            bundle, mesh, POLICIES["fsdp_tp"], AdamWConfig(lr=1e-3),
            microbatches=m)
        with jax.set_mesh(mesh):
            params = bundle.init(jax.random.PRNGKey(0))
            params = Trainer._put_tree(params, p_sh)
            opt = Trainer._put_tree(adamw.init(params), o_sh)
            tok = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                     cfg.vocab_size)
            new_p, _, metrics = step(params, opt, dict(tokens=tok, labels=tok))
        outs[m] = (new_p, float(metrics["loss"]))
    assert abs(outs[1][1] - outs[4][1]) < 1e-4
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)

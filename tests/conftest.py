import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests run single-device.
# Multi-device scenarios run in subprocesses (tests/test_multidevice.py)
# that set --xla_force_host_platform_device_count themselves.

try:
    from hypothesis import settings

    # CI and local runs must explore the same example stream: the fuzz
    # layer's speculative==vanilla properties are equivalence proofs, not
    # coverage hunting, so a flaky example would mean a real bug — pin the
    # profile (derandomized, no deadline: jit warm-up skews wall time).
    settings.register_profile("repro", derandomize=True, deadline=None,
                              print_blob=True)
    settings.load_profile("repro")
except ImportError:
    pass  # hypothesis is a dev dependency; non-fuzz tests run without it

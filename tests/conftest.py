import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS here on purpose — smoke tests run single-device.
# Multi-device scenarios run in subprocesses (tests/test_multidevice.py)
# that set --xla_force_host_platform_device_count themselves.

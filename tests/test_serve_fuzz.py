"""Differential fuzz harness for the universal paged serving backend.

Hypothesis drives random request mixes — prompt lengths, generation
budgets, shared prefixes, mid-drain admissions — through the dense and
paged engines and demands *token-identical* greedy outputs for every
newly-supported stack: gemma2-27b (sliding-window ring pages + softcap
kernel path), recurrentgemma-9b (hybrid rglru + windowed attention), and
int8-KV gemma-2b (quantized pages with per-page scale lanes).  Greedy
decode is schedule-invariant (slots never mix requests), so the two
engines may interleave prefill chunks and decode windows differently and
must still agree token for token.

Also here: the allocator/prefix-index conservation property (satellite) —
any alloc/reserve/fork/release/evict sequence conserves pages, never
drives a refcount negative, and ring tables never exceed
``ceil(window/page)+1`` slots.

The short mixes run in tier-1; the long-drain mixes are ``slow`` and run
in the CI bench-smoke job.
"""
import jax
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="dev dependency — pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import ARCHS, smoke_config  # noqa: E402
from repro.models import RuntimeFlags, build  # noqa: E402
from repro.serve import (ChaosConfig, ChaosEngine, PageAllocator,  # noqa: E402
                         PoolExhausted, PrefixIndex, Request, SamplingParams,
                         ServeEngine)

FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                     moe_impl="dense", loss_chunk=16)
INT8_FLAGS = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                          moe_impl="dense", loss_chunk=16, kv_dtype="int8")

STACKS = {
    "gemma2-27b": FLAGS,              # ring pages + softcap kernel path
    "recurrentgemma-9b": FLAGS,       # hybrid: rglru + windowed attention
    "gemma-2b-int8": INT8_FLAGS,      # int8 KV pages + scale lanes
}

MAX_LEN = 64
BATCH = 2

_ENGINES = {}


def _engines(stack: str):
    """One (dense, paged) engine pair per stack, reused across hypothesis
    examples via ``reset()`` so jit traces amortize."""
    if stack not in _ENGINES:
        arch = "gemma-2b" if stack == "gemma-2b-int8" else stack
        cfg = smoke_config(ARCHS[arch])
        bundle = build(cfg, STACKS[stack])
        params = bundle.init(jax.random.PRNGKey(7))
        dense = ServeEngine(bundle, params, batch_size=BATCH,
                            max_len=MAX_LEN, cache_backend="dense")
        paged = ServeEngine(bundle, params, batch_size=BATCH,
                            max_len=MAX_LEN, cache_backend="paged",
                            prefill_chunk=8)
        _ENGINES[stack] = (cfg, dense, paged)
    return _ENGINES[stack]


# ---------------------------------------------------------------------------
# workload strategy
# ---------------------------------------------------------------------------

def _mix(max_requests: int, max_prompt: int):
    """A request mix: per request (prompt_len, shared_prefix?, max_new,
    second_wave?)."""
    req = st.tuples(st.integers(1, max_prompt), st.booleans(),
                    st.integers(1, 8), st.booleans())
    return st.lists(req, min_size=1, max_size=max_requests)


def _materialize(cfg, mix, seed):
    """Deterministic prompts from the mix spec: shared-prefix requests
    start with the same 9-token run (crosses a page boundary for page=8),
    so the paged engine's prefix machinery sees real sharing."""
    rng = np.random.default_rng(seed)
    common = rng.integers(0, cfg.vocab_size, size=9).astype(np.int32)
    waves = ([], [])
    for plen, shared, max_new, second in mix:
        tail = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        prompt = np.concatenate([common, tail]) if shared else tail
        waves[1 if second else 0].append((prompt, max_new))
    if not waves[0]:  # at least one request must open the drain
        waves = (waves[1], [])
    return waves


def _drive(eng, waves):
    """Admit wave 0, tick a few times so wave 1 lands mid-drain, then
    drain.  Returns the per-request greedy outputs in admission order."""
    eng.reset()
    reqs = []
    for prompt, max_new in waves[0]:
        r = Request(rid=len(reqs), prompt=prompt, max_new_tokens=max_new)
        reqs.append(r)
        eng.add_request(r)
    if waves[1]:
        for _ in range(3):
            eng.step()      # mid-drain: slots busy, maybe prefill pending
        for prompt, max_new in waves[1]:
            r = Request(rid=len(reqs), prompt=prompt, max_new_tokens=max_new)
            reqs.append(r)
            eng.add_request(r)
    eng.run_to_completion(max_ticks=5_000)
    assert all(s is None for s in eng.slots)
    return [r.out_tokens for r in reqs]


def _assert_token_identical(stack, mix, seed):
    cfg, dense, paged = _engines(stack)
    waves = _materialize(cfg, mix, seed)
    want = _drive(dense, waves)
    got = _drive(paged, waves)
    assert got == want, (
        f"{stack}: paged outputs diverged from dense for mix {mix}")
    for toks, (_, max_new) in zip(got, waves[0] + waves[1]):
        assert len(toks) == max_new       # budget exactness rides along


@pytest.mark.parametrize("stack", sorted(STACKS))
@settings(max_examples=4, deadline=None)
@given(mix=_mix(max_requests=3, max_prompt=12), seed=st.integers(0, 2**16))
def test_fuzz_paged_matches_dense(stack, mix, seed):
    """Tier-1 fuzz: small mixes, every newly-supported stack."""
    _assert_token_identical(stack, mix, seed)


@pytest.mark.slow
@pytest.mark.parametrize("stack", sorted(STACKS))
@settings(max_examples=6, deadline=None)
@given(mix=_mix(max_requests=6, max_prompt=40), seed=st.integers(0, 2**16))
def test_fuzz_paged_matches_dense_long_drain(stack, mix, seed):
    """Long drains: prompts overflow several pages (and the ring), slots
    churn through multiple requests, mid-drain admissions stack up."""
    _assert_token_identical(stack, mix, seed)


# ---------------------------------------------------------------------------
# speculative decoding == vanilla decoding (tentpole equivalence layer)
# ---------------------------------------------------------------------------
#
# Coupled-sample verification promises the spec engine's emitted stream is
# bit-identical to the non-speculative engine — greedy AND sampled.  The
# draft here is the same architecture with *different* params (PRNGKey(11)
# vs 7), so proposals genuinely get rejected and every drain exercises
# suffix rollback, not just the accept-everything fast lane.

SPEC_STACKS = {
    "gemma-2b": FLAGS,                # pure full attention (spec-eligible)
    "gemma-2b-int8": INT8_FLAGS,      # int8 KV pages under the verify step
}

_SPEC_ENGINES = {}


def _spec_engines(stack: str, variant: str):
    """One (vanilla paged, speculative paged) pair per stack x variant,
    sharing params, sampling, and seed — key-exact comparability."""
    if (stack, variant) not in _SPEC_ENGINES:
        arch = "gemma-2b" if stack == "gemma-2b-int8" else stack
        cfg = smoke_config(ARCHS[arch])
        bundle = build(cfg, SPEC_STACKS[stack])
        params = bundle.init(jax.random.PRNGKey(7))
        draft_params = bundle.init(jax.random.PRNGKey(11))
        sampling = (None if variant == "greedy"
                    else SamplingParams(temperature=0.9, top_p=0.95))
        vanilla = ServeEngine(bundle, params, batch_size=BATCH,
                              max_len=MAX_LEN, cache_backend="paged",
                              prefill_chunk=8, sampling=sampling, seed=3)
        spec = ServeEngine(bundle, params, batch_size=BATCH,
                           max_len=MAX_LEN, cache_backend="paged",
                           prefill_chunk=8, sampling=sampling, seed=3,
                           draft_bundle=bundle, draft_params=draft_params,
                           spec_k=3)
        _SPEC_ENGINES[(stack, variant)] = (cfg, vanilla, spec)
    return _SPEC_ENGINES[(stack, variant)]


def _assert_spec_identical(stack, variant, mix, seed):
    cfg, vanilla, spec = _spec_engines(stack, variant)
    waves = _materialize(cfg, mix, seed)
    want = _drive(vanilla, waves)
    got = _drive(spec, waves)
    assert got == want, (
        f"{stack}/{variant}: speculative outputs diverged from vanilla "
        f"for mix {mix}")
    assert spec.stats.spec_steps > 0       # the draft path actually ran
    # zero allocator-conservation violations after rollback churn
    a = spec.alloc
    assert a.pages_in_use + len(a.free) == a.num_pages - a.reserved
    for pid, r in a.ref.items():
        assert r >= 1


@pytest.mark.parametrize("variant", ["greedy", "sampled"])
@pytest.mark.parametrize("stack", sorted(SPEC_STACKS))
@settings(max_examples=3, deadline=None)
@given(mix=_mix(max_requests=3, max_prompt=12), seed=st.integers(0, 2**16))
def test_fuzz_spec_matches_vanilla(stack, variant, mix, seed):
    """Tier-1: T=0 speculative drains are token-identical to vanilla
    paged drains; T>0 drains with shared per-slot keys are key-exact."""
    _assert_spec_identical(stack, variant, mix, seed)


@pytest.mark.slow
@pytest.mark.parametrize("variant", ["greedy", "sampled"])
@pytest.mark.parametrize("stack", sorted(SPEC_STACKS))
@settings(max_examples=5, deadline=None)
@given(mix=_mix(max_requests=6, max_prompt=40), seed=st.integers(0, 2**16))
def test_fuzz_spec_matches_vanilla_long_drain(stack, variant, mix, seed):
    """Long speculative drains: slots churn through multiple requests,
    rollbacks interleave with mid-drain admissions and prefix sharing."""
    _assert_spec_identical(stack, variant, mix, seed)


# ---------------------------------------------------------------------------
# sharded (TP) engine == single-device engine (PR 7 tentpole)
# ---------------------------------------------------------------------------
#
# The dist backend shards params and KV page pools over a 2-device TP mesh
# (shard_map islands around the paged dispatches, logits all-gathered
# before token selection).  The contract is the same as paged-vs-dense
# above: whatever the mix, the sharded drain is token-identical to the
# single-device paged drain — greedy AND sampled, because the per-slot
# PRNG chains never see the mesh.  Skipped on single-device hosts; the CI
# tier-1 matrix forces a multi-device host platform.

_DIST_ENGINES = {}


def _dist_engines(variant: str):
    """One (single-device paged, TP=2 paged) pair per sampling variant,
    sharing params and seed."""
    from repro.configs import override
    from repro.dist import ServeMesh

    if variant not in _DIST_ENGINES:
        # smoke gemma-2b is MQA; TP=2 needs kv-heads divisible by 2
        cfg = override(smoke_config(ARCHS["gemma-2b"]), num_kv_heads=2)
        bundle = build(cfg, FLAGS)
        params = bundle.init(jax.random.PRNGKey(7))
        sampling = (None if variant == "greedy"
                    else SamplingParams(temperature=0.9, top_k=11))
        single = ServeEngine(bundle, params, batch_size=BATCH,
                             max_len=MAX_LEN, cache_backend="paged",
                             prefill_chunk=8, sampling=sampling, seed=5)
        tp = ServeEngine(bundle, params, batch_size=BATCH,
                         max_len=MAX_LEN, cache_backend="paged",
                         prefill_chunk=8, sampling=sampling, seed=5,
                         dist=ServeMesh.tp(2))
        _DIST_ENGINES[variant] = (cfg, single, tp)
    return _DIST_ENGINES[variant]


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="TP fuzz needs >=2 devices (CI forces a "
                           "multi-device host platform)")
@pytest.mark.parametrize("variant", ["greedy", "sampled"])
@settings(max_examples=3, deadline=None)
@given(mix=_mix(max_requests=3, max_prompt=12), seed=st.integers(0, 2**16))
def test_fuzz_sharded_matches_single_device(variant, mix, seed):
    """Tier-1: TP=2 drains are token-identical to single-device paged
    drains for arbitrary request mixes, greedy and sampled."""
    cfg, single, tp = _dist_engines(variant)
    waves = _materialize(cfg, mix, seed)
    want = _drive(single, waves)
    got = _drive(tp, waves)
    assert got == want, (
        f"{variant}: TP=2 outputs diverged from single-device for "
        f"mix {mix}")


# ---------------------------------------------------------------------------
# preemption/swap/resume == unpreempted (scheduler tentpole)
# ---------------------------------------------------------------------------
#
# The robustness claim: ANY schedule of mid-flight preemptions (page
# eviction + recompute-resume or host-tier swap-resume), forced pool
# exhaustion, and swap corruption drains token-identically — bitwise,
# including the per-slot PRNG key chains and the speculative paths — to
# the run nothing ever interrupted.  ChaosEngine additionally asserts
# allocator conservation (live + free == pool, refcounts >= 1, every
# table page live) after every fault round.  Priorities ride along
# (rid % 2) so admission-pressure preemption and queue reordering are
# exercised, not just the forced storms.

CHAOS_ENGINES = {           # backend -> (cfg, engine), lazily built
    "paged-int8": lambda: _engines("gemma-2b-int8")[::2],
    "ring": lambda: _engines("gemma2-27b")[::2],
    "dense": lambda: _engines("gemma-2b-int8")[:2],
    "sampled": lambda: _spec_engines("gemma-2b", "sampled")[:2],
    "spec": lambda: _spec_engines("gemma-2b", "greedy")[::2],
}


def _drive_chaos(eng, waves, ccfg):
    """The chaos twin of :func:`_drive`: same waves, same priorities, but
    the drain runs under fault injection."""
    eng.reset()
    chaos = ChaosEngine(eng, ccfg)
    reqs = []
    for prompt, max_new in waves[0]:
        r = Request(rid=len(reqs), prompt=prompt, max_new_tokens=max_new,
                    priority=len(reqs) % 2)
        reqs.append(r)
        chaos.add_request(r)
    if waves[1]:
        for _ in range(3):
            chaos.step()
        for prompt, max_new in waves[1]:
            r = Request(rid=len(reqs), prompt=prompt, max_new_tokens=max_new,
                        priority=len(reqs) % 2)
            reqs.append(r)
            chaos.add_request(r)
    chaos.run_to_completion()
    if eng.host_tier is not None:
        eng.host_tier.latency_s = 0.0    # engines are cached across examples
    return [r.out_tokens for r in reqs]


def _drive_prio(eng, waves):
    """Unpreempted reference with the same rid%2 priorities the chaos
    drive assigns (priority reorders scheduling, never tokens)."""
    eng.reset()
    reqs = []
    for prompt, max_new in waves[0]:
        r = Request(rid=len(reqs), prompt=prompt, max_new_tokens=max_new,
                    priority=len(reqs) % 2)
        reqs.append(r)
        eng.add_request(r)
    if waves[1]:
        for _ in range(3):
            eng.step()
        for prompt, max_new in waves[1]:
            r = Request(rid=len(reqs), prompt=prompt, max_new_tokens=max_new,
                        priority=len(reqs) % 2)
            reqs.append(r)
            eng.add_request(r)
    eng.run_to_completion(max_ticks=5_000)
    assert all(s is None for s in eng.slots)
    return [r.out_tokens for r in reqs]


@pytest.mark.chaos
@pytest.mark.parametrize("backend", sorted(CHAOS_ENGINES))
@settings(max_examples=2, deadline=None)
@given(mix=_mix(max_requests=3, max_prompt=12),
       seed=st.integers(0, 2**16), chaos_seed=st.integers(0, 2**16),
       mode=st.sampled_from([None, "swap", "recompute"]))
def test_fuzz_chaos_drain_matches_unpreempted(backend, mix, seed,
                                              chaos_seed, mode):
    """Tier-1 + chaos-smoke: random preemption/swap/resume schedules are
    lossless on every backend the acceptance criteria name."""
    cfg, eng = CHAOS_ENGINES[backend]()
    waves = _materialize(cfg, mix, seed)
    want = _drive_prio(eng, waves)
    ccfg = ChaosConfig(seed=chaos_seed, preempt_prob=0.35, exhaust_prob=0.3,
                       corrupt_prob=0.3, mode=mode)
    got = _drive_chaos(eng, waves, ccfg)
    assert got == want, (
        f"{backend}: chaos drain diverged from unpreempted reference for "
        f"mix {mix} (chaos_seed={chaos_seed}, mode={mode})")


@pytest.mark.chaos
@pytest.mark.slow
@pytest.mark.parametrize("backend", sorted(CHAOS_ENGINES))
@settings(max_examples=4, deadline=None)
@given(mix=_mix(max_requests=6, max_prompt=40),
       seed=st.integers(0, 2**16), chaos_seed=st.integers(0, 2**16),
       mode=st.sampled_from([None, "swap", "recompute"]))
def test_fuzz_chaos_drain_matches_unpreempted_long(backend, mix, seed,
                                                   chaos_seed, mode):
    """Long chaos drains: storms hit requests holding many pages, swaps
    move multi-page contexts, slots churn through preempted requeues."""
    cfg, eng = CHAOS_ENGINES[backend]()
    waves = _materialize(cfg, mix, seed)
    want = _drive_prio(eng, waves)
    ccfg = ChaosConfig(seed=chaos_seed, preempt_prob=0.35, exhaust_prob=0.3,
                       corrupt_prob=0.3, swap_latency_s=1e-4, mode=mode)
    got = _drive_chaos(eng, waves, ccfg)
    assert got == want, (
        f"{backend}: long chaos drain diverged for mix {mix} "
        f"(chaos_seed={chaos_seed}, mode={mode})")


@pytest.mark.chaos
@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="TP chaos needs >=2 devices (CI forces a "
                           "multi-device host platform)")
@settings(max_examples=2, deadline=None)
@given(mix=_mix(max_requests=3, max_prompt=12),
       seed=st.integers(0, 2**16), chaos_seed=st.integers(0, 2**16))
def test_fuzz_chaos_sharded_matches_single_device(mix, seed, chaos_seed):
    """Preemption under TP: per-shard page swap (gather/scatter of each
    shard's kv-head stripe) drains token-identically to the untouched
    single-device engine."""
    cfg, single, tp = _dist_engines("greedy")
    waves = _materialize(cfg, mix, seed)
    want = _drive_prio(single, waves)
    ccfg = ChaosConfig(seed=chaos_seed, preempt_prob=0.35, exhaust_prob=0.3,
                       corrupt_prob=0.3)
    got = _drive_chaos(tp, waves, ccfg)
    assert got == want, (
        f"TP chaos drain diverged for mix {mix} (chaos_seed={chaos_seed})")


# ---------------------------------------------------------------------------
# allocator + prefix-index conservation property (satellite)
# ---------------------------------------------------------------------------

def _check_invariants(alloc: PageAllocator):
    assert alloc.pages_in_use + len(alloc.free) == (
        alloc.num_pages - alloc.reserved), "pages leaked or double-freed"
    for pid, r in alloc.ref.items():
        assert r >= 1, f"refcount underflow on page {pid}"
    for rid, table in alloc.tables.items():
        if alloc.ring_slots is not None:
            assert len(table) <= alloc.ring_slots, (
                f"ring rid {rid} holds {len(table)} > "
                f"{alloc.ring_slots} pages")


OPS = st.lists(
    st.tuples(st.sampled_from(["alloc", "reserve", "fork", "release",
                               "pin_evict", "truncate", "evict"]),
              st.integers(0, 5), st.integers(1, 48)),
    min_size=1, max_size=40)


def _exercise_allocator(ops, num_pages, window):
    alloc = PageAllocator(num_pages, 4, reserved=1, window=window)
    idx = PrefixIndex()
    next_rid = 0
    live = []
    for op, pick, length in ops:
        try:
            if op == "alloc":
                alloc.alloc(next_rid)
                live.append(next_rid)
                next_rid += 1
            elif op == "reserve" and live:
                rid = live[pick % len(live)]
                alloc.reserve(rid, alloc.lengths[rid] + length)
            elif op == "fork" and live and window is None:
                src = live[pick % len(live)]
                alloc.fork(src, next_rid)
                live.append(next_rid)
                next_rid += 1
            elif op == "fork" and live:
                # ring fork: attach a copy of the (<= ring_slots) table
                src = live[pick % len(live)]
                alloc.alloc(next_rid)
                alloc.attach(next_rid, list(alloc.tables[src]),
                             alloc.lengths[src])
                live.append(next_rid)
                next_rid += 1
            elif op == "release" and live:
                rid = live.pop(pick % len(live))
                alloc.release(rid)
            elif op == "truncate" and live:
                # speculative rollback: rewind to a shorter length — pages
                # covering only the rejected suffix return to the pool,
                # shared (forked) pages are decref'd, never freed early
                rid = live[pick % len(live)]
                alloc.truncate(rid, alloc.lengths[rid] % (length + 1))
            elif op == "evict" and live:
                # scheduler preemption: rewind to the victim's live length
                # then release everything — shared pages must survive via
                # their refcounts, ring pools must only rewind length
                rid = live.pop(pick % len(live))
                alloc.truncate(rid, alloc.lengths[rid] // 2)
                alloc.release(rid)
            elif op == "pin_evict" and live and window is None:
                rid = live[pick % len(live)]
                for pid in alloc.tables[rid]:
                    # content-hash surrogate: one index entry per page
                    if idx.register(f"h{pid}", pid):
                        alloc.pin(pid)
                idx.evict_unused(alloc)
        except PoolExhausted:
            pass  # backpressure is a legal outcome, never a corrupt state
        _check_invariants(alloc)
    for rid in list(live):
        alloc.release(rid)
    _check_invariants(alloc)
    assert alloc.pages_in_use == len(idx), (
        "after releasing every request, only index-pinned pages may live")
    idx.evict_unused(alloc)
    assert alloc.pages_in_use == 0 and len(idx) == 0


@settings(max_examples=120, deadline=None)
@given(ops=OPS, num_pages=st.integers(4, 24),
       window=st.sampled_from([None, 8, 13, 24]))
def test_allocator_conserves_pages_and_ring_bound(ops, num_pages, window):
    """Any alloc/reserve/fork/release/evict sequence conserves pages
    (live + free == pool - reserved), never drives a refcount negative,
    and ring tables never exceed ceil(window/page)+1 slots."""
    _exercise_allocator(ops, num_pages, window)

"""Multi-device scenarios, run as a subprocess with 8 fake devices.

Usage: python tests/_md_scenarios.py <scenario>
Prints "PASS <scenario>" on success; raises otherwise.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def mesh42():
    return jax.make_mesh((4, 2), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def put(tree, shardings):
    flat, treedef = jax.tree.flatten(tree)
    flat_s = treedef.flatten_up_to(shardings)
    return jax.tree.unflatten(
        treedef, [jax.device_put(x, s) for x, s in zip(flat, flat_s)])


def scenario_sharded_train():
    """FSDP x TP trainer step on 8 devices, loss decreases, params sharded."""
    from repro.configs import ARCHS, ShapeCell, smoke_config
    from repro.dist import POLICIES
    from repro.models import RuntimeFlags, build
    from repro.optim import AdamWConfig
    from repro.train import TrainConfig, Trainer

    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16)
    cfg = smoke_config(ARCHS["gemma2-27b"])
    bundle = build(cfg, flags)
    mesh = mesh42()
    tr = Trainer(bundle, ShapeCell("s", "train", 32, 8), mesh,
                 POLICIES["fsdp_tp"], AdamWConfig(lr=1e-3),
                 TrainConfig(steps=2, log_every=1))
    with jax.set_mesh(mesh):
        params, opt, _ = tr.init_state()
        batch = tr._put(tr.data.batch_at(0))
        losses = []
        for _ in range(6):
            params, opt, m = tr.step_fn(params, opt, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    # at least one param is actually sharded across devices
    sharded = any(
        len(p.sharding.device_set) > 1 and not p.sharding.is_fully_replicated
        for p in jax.tree.leaves(params))
    assert sharded


def scenario_elastic_reshard():
    """checkpoint on (4,2) mesh restores onto (2,2) subset mesh (elastic)."""
    from repro.configs import ARCHS, ShapeCell, smoke_config
    from repro.dist import POLICIES, param_shardings
    from repro.models import RuntimeFlags, build
    from repro.optim import AdamWConfig
    from repro.train import CheckpointManager, TrainConfig, Trainer

    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16)
    cfg = smoke_config(ARCHS["phi4-mini-3.8b"])
    bundle = build(cfg, flags)
    mesh_a = mesh42()
    tmp = "/tmp/elastic_ckpt_test"
    import shutil
    shutil.rmtree(tmp, ignore_errors=True)
    tr = Trainer(bundle, ShapeCell("s", "train", 32, 8), mesh_a,
                 POLICIES["fsdp_tp"], AdamWConfig(lr=1e-3),
                 TrainConfig(steps=2, ckpt_dir=tmp, ckpt_every=2, log_every=1))
    with jax.set_mesh(mesh_a):
        tr.run()
    p_a, _ = tr._final

    # new, smaller mesh (simulating node loss -> elastic re-shard)
    mesh_b = jax.make_mesh((2, 2), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    abs_params, specs = bundle.abstract_params()
    shard_b = param_shardings(mesh_b, abs_params, specs,
                              POLICIES["fsdp_tp"].param_rules)
    mgr = CheckpointManager(tmp)
    restored = mgr.restore(None, dict(params=abs_params),
                           dict(params=shard_b))["params"]
    for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=0)
    # and the restored params still run a step on the new mesh
    with jax.set_mesh(mesh_b):
        loss, _ = bundle.train_loss(
            restored, dict(tokens=jnp.zeros((4, 32), jnp.int32),
                           labels=jnp.zeros((4, 32), jnp.int32)))
    assert bool(jnp.isfinite(loss))


def scenario_dp_compression():
    """shard_map DP trainer with int8+EF grads tracks uncompressed training."""
    from jax.sharding import Mesh
    from repro.dist.dp_shardmap import init_error_feedback, make_dp_train_step
    from repro.optim import AdamWConfig, adamw

    mesh = jax.make_mesh((8,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    k = jax.random.PRNGKey(0)
    w_true = jax.random.normal(k, (16, 4))

    def loss_fn(params, batch):
        x, y = batch["x"], batch["y"]
        pred = x @ params["w"]
        return jnp.mean((pred - y) ** 2)

    def make_batch(i):
        kk = jax.random.PRNGKey(i)
        x = jax.random.normal(kk, (64, 16))
        return dict(x=x, y=x @ w_true)

    results = {}
    for comp in (False, True):
        params = dict(w=jnp.zeros((16, 4)))
        opt = adamw.init(params)
        err = init_error_feedback(params)
        step = make_dp_train_step(
            loss_fn, mesh,
            AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=None),
            compress_grads=comp)
        with jax.set_mesh(mesh):
            first = None
            for i in range(150):
                params, opt, err, m = step(params, opt, err, make_batch(i))
                first = first if first is not None else float(m["loss"])
        results[comp] = (first, float(m["loss"]))
    # both converge by >100x; compressed tracks uncompressed within 5x
    assert results[False][1] < results[False][0] / 100, results
    assert results[True][1] < results[True][0] / 100, results
    assert results[True][1] < 5 * results[False][1] + 1e-3, results


def scenario_decode_sharded():
    """sharded decode step with per-slot positions on 8 devices."""
    from repro.configs import ARCHS, ShapeCell, smoke_config
    from repro.dist import POLICIES
    from repro.dist.steps import make_decode_step
    from repro.models import RuntimeFlags, build

    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16)
    cfg = smoke_config(ARCHS["gemma2-27b"])
    bundle = build(cfg, flags)
    mesh = mesh42()
    cell = ShapeCell("d", "decode", 64, 8)
    from jax.sharding import NamedSharding, PartitionSpec as P
    with jax.set_mesh(mesh):
        step, p_sh, c_sh = make_decode_step(bundle, mesh, POLICIES["fsdp_tp"],
                                            cell)
        params = put(bundle.init(jax.random.PRNGKey(0)), p_sh)
        cache = put(bundle.init_cache(8, 64), c_sh)
        toks = jax.device_put(jnp.zeros((8, 1), jnp.int32),
                              NamedSharding(mesh, P("data", None)))
        pos = jax.device_put(jnp.int32(5), NamedSharding(mesh, P()))
        logits, cache = step(params, cache, toks, pos)
        logits.block_until_ready()
    assert logits.shape == (8, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def _serve_fixture():
    """Shared smoke fixture for the serve scenarios: gemma-2b with
    kv-heads widened to 2 (smoke is MQA; TP=2 must divide both head
    counts), a deterministic request mix, and a drain helper."""
    from repro.configs import ARCHS, override, smoke_config
    from repro.models import RuntimeFlags, build
    from repro.serve import Request

    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16)
    cfg = override(smoke_config(ARCHS["gemma-2b"]), num_kv_heads=2)
    bundle = build(cfg, flags)
    params = bundle.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(3)
    common = rng.integers(0, cfg.vocab_size, size=18).astype(np.int32)
    prompts = []
    for i in range(5):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 11))).astype(np.int32)
        prompts.append(np.concatenate([common, tail]) if i % 2 == 0
                       else tail)

    def reqs():
        return [Request(rid=i, prompt=p, max_new_tokens=8)
                for i, p in enumerate(prompts)]

    return bundle, params, reqs


def scenario_serve_tp():
    """TP=2 ServeEngine drains token-identical to single-device, greedy
    and sampled; one shard holds exactly half the live KV bytes."""
    from repro.dist import ServeMesh
    from repro.serve import SamplingParams, ServeEngine

    bundle, params, reqs = _serve_fixture()
    sm = ServeMesh.tp(2)

    def drain(dist=None, sampling=None):
        eng = ServeEngine(bundle, params, batch_size=2, max_len=64,
                          cache_backend="paged", prefill_chunk=8,
                          sampling=sampling, seed=0, dist=dist)
        rs = reqs()
        for r in rs:
            eng.add_request(r)
        eng.run_to_completion()
        return [r.out_tokens for r in rs], eng

    for samp in (None, SamplingParams(temperature=0.9, top_k=11)):
        want, e1 = drain(sampling=samp)
        got, e2 = drain(dist=sm, sampling=samp)
        assert want == got, (samp, want, got)
        assert e2.live_kv_bytes_peak() == e1.live_kv_bytes_peak()
        assert e2.live_kv_bytes_peak() == (
            2 * e2.live_kv_bytes_peak(per_shard=True))
    # the pools are genuinely partitioned across devices
    leaves = jax.tree_util.tree_leaves_with_path(e2.cache)
    pool = [x for p, x in leaves
            if "k_pages" in jax.tree_util.keystr(p)][0]
    assert len(pool.sharding.device_set) == 2
    assert not pool.sharding.is_fully_replicated


def scenario_serve_tp_spec():
    """Speculative decoding under TP=2: draft + verify stay
    token-identical to the single-device non-speculative drain."""
    from repro.dist import ServeMesh
    from repro.serve import SamplingParams, ServeEngine

    bundle, params, reqs = _serve_fixture()
    draft_params = bundle.init(jax.random.PRNGKey(5))
    sm = ServeMesh.tp(2)

    def drain(dist=None, spec=False,
              sampling=SamplingParams(temperature=0.9, top_k=11)):
        kw = (dict(draft_bundle=bundle, draft_params=draft_params,
                   spec_k=3) if spec else {})
        eng = ServeEngine(bundle, params, batch_size=2, max_len=64,
                          cache_backend="paged", prefill_chunk=8,
                          sampling=sampling, seed=0, dist=dist, **kw)
        rs = reqs()
        for r in rs:
            eng.add_request(r)
        eng.run_to_completion()
        return [r.out_tokens for r in rs], eng

    want, _ = drain()
    got, eng = drain(dist=sm, spec=True)
    assert want == got, (want, got)
    assert eng.stats.spec_steps > 0


def scenario_serve_dp_pool():
    """DP=2 replica pool behind the shared admission queue reproduces the
    single-engine greedy streams; both replicas take work."""
    from repro.launch.serve import build_pool
    from repro.serve import ServeEngine

    bundle, params, reqs = _serve_fixture()
    single = ServeEngine(bundle, params, batch_size=2, max_len=64,
                         cache_backend="paged", prefill_chunk=8, seed=0)
    rs = reqs()
    for r in rs:
        single.add_request(r)
    single.run_to_completion()
    want = [r.out_tokens for r in rs]

    pool = build_pool(bundle, params, tp=1, dp=2,
                      devices=jax.devices()[:2], batch_size=2, max_len=64,
                      prefill_chunk=8, seed=0)
    rs = reqs()
    for r in rs:
        pool.submit(r)
    stats = pool.drain()
    assert [r.out_tokens for r in rs] == want
    assert stats.tokens_out == sum(len(t) for t in want)
    # the least-loaded queue actually spread the mix over both replicas
    assert all(e.stats.tokens_out > 0 for e in pool.engines)


SCENARIOS = {k[len("scenario_"):]: v for k, v in list(globals().items())
             if k.startswith("scenario_")}

if __name__ == "__main__":
    name = sys.argv[1]
    SCENARIOS[name]()
    print(f"PASS {name}")

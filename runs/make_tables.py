"""Emit EXPERIMENTS.md tables from runs/dryrun*.json."""
import json
import os
import sys

HERE = os.path.dirname(__file__)


def load(name):
    path = os.path.join(HERE, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def dryrun_table(recs):
    out = ["| arch × shape | mesh | chips | peak GiB/dev | fits 16G | collectives |",
           "|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        cell = f"{r['arch']} × {r['shape']}"
        if r.get("status") == "skip":
            out.append(f"| {cell} | — | — | — | SKIP | {r['reason']} |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {cell} | — | — | — | FAIL | {r.get('error','')[:60]} |")
            continue
        for key, m in sorted(r["meshes"].items()):
            colls = ",".join(sorted(m.get("collectives", {})))
            fits = "✓" if m["peak_gib"] < 16 else f"✗ ({m['peak_gib']:.1f})"
            out.append(
                f"| {cell} | {key} | {m['chips']} | {m['peak_gib']:.2f} "
                f"| {fits} | {colls} |")
    return "\n".join(out)


HBM_BW = 819e9


def next_lever(r):
    """One sentence: what moves the dominant term down (assignment req)."""
    rf = r.get("roofline", {})
    dom = rf.get("dominant")
    kind = r.get("kind", "")
    fi = rf.get("bytes_flash_inner", 0) / max(rf.get("hlo_bytes", 1), 1)
    if dom == "memory":
        if kind == "prefill" and fi > 0.2:
            return (f"fuse attention blocks into the Pallas kernel "
                    f"(flash_inner = {fi:.0%} of bytes, see frac kernel)")
        if kind == "train":
            return ("kernel-fuse attention + relax remat to 'dots' "
                    "(recompute is the other big byte source)")
        if kind == "decode":
            return "int8 KV stream (opt preset) + larger decode batch to amortize"
        return "larger contiguous tiles (burst) on the dominant stream"
    if dom == "collective":
        if kind == "train":
            return ("overlap per-layer FSDP gathers behind compute "
                    "(latency-hiding) + int8 grad reduction (dist.dp_shardmap)")
        if kind == "decode":
            return ("replicate small-model params across 'model' (TP off) "
                    "to drop per-layer gathers")
        return "reshard so the hot einsum contracts an unsharded dim"
    return "increase arithmetic intensity (bigger microbatch per chip)"


def roofline_table(recs):
    """frac = useful-ideal / max(terms) (overlapped TPU model);
    serial = / sum(terms); kernel = overlapped with the flash_inner bytes
    (VMEM-resident in the Pallas deployment) removed from the memory term."""
    out = ["| arch × shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO flops | frac | frac serial | frac kernel | peak GiB "
           "| next lever for the dominant term |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        cell = f"{r['arch']} × {r['shape']}"
        if r.get("status") == "skip":
            out.append(f"| {cell} | — | — | — | skip: {r['reason'][:40]} "
                       "| — | — | — | — | — | — |")
            continue
        rf = r.get("roofline")
        if not rf:
            continue
        sp = r.get("meshes", {}).get("single_pod", {})
        c, m, co = rf["compute_s"], rf["memory_s"], rf["collective_s"]
        ideal = c * rf["useful_ratio"]
        m_k = m - rf.get("bytes_flash_inner", 0.0) / HBM_BW
        frac = ideal / max(c, m, co) if max(c, m, co) else 0.0
        serial = ideal / (c + m + co) if (c + m + co) else 0.0
        kern = ideal / max(c, m_k, co) if max(c, m_k, co) else 0.0
        out.append(
            f"| {cell} | {c:.3f} | {m:.3f} | {co:.3f} | **{rf['dominant']}** "
            f"| {rf['useful_ratio']:.3f} | {frac:.3f} | {serial:.3f} "
            f"| {kern:.3f} | {sp.get('peak_gib','—')} | {next_lever(r)} |")
    return "\n".join(out)


def compare_table(base, opt):
    bmap = {(r["arch"], r["shape"]): r for r in base if r.get("status") == "ok"}
    out = ["| cell | metric | baseline | optimized | Δ |", "|---|---|---|---|---|"]
    for r in sorted(opt, key=lambda r: (r["arch"], r["shape"])):
        if r.get("status") != "ok":
            continue
        b = bmap.get((r["arch"], r["shape"]))
        if not b:
            continue
        cell = f"{r['arch']} × {r['shape']}"
        for metric, get in [
            ("peak GiB (1 pod)", lambda x: x["meshes"].get("single_pod", {}).get("peak_gib")),
            ("peak GiB (2 pod)", lambda x: x["meshes"].get("multi_pod", {}).get("peak_gib")),
            ("memory term s", lambda x: x.get("roofline", {}).get("memory_s")),
            ("collective term s", lambda x: x.get("roofline", {}).get("collective_s")),
            ("roofline frac", lambda x: x.get("roofline", {}).get("roofline_fraction")),
        ]:
            vb, vo = get(b), get(r)
            if vb is None or vo is None or vb == 0:
                continue
            delta = (vo - vb) / abs(vb) * 100
            if abs(delta) < 3 and "peak" not in metric:
                continue
            out.append(f"| {cell} | {metric} | {vb:.3f} | {vo:.3f} | {delta:+.0f}% |")
    return "\n".join(out)


if __name__ == "__main__":
    base = load("dryrun.json")
    opt = load("dryrun_opt.json")
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### baseline dry-run\n")
        print(dryrun_table(base))
    if which in ("all", "roofline"):
        print("\n### baseline roofline\n")
        print(roofline_table(base))
        print("\n### optimized roofline\n")
        print(roofline_table(opt))
    if which in ("all", "compare"):
        print("\n### baseline vs optimized\n")
        print(compare_table(base, opt))

"""memroof: a memory-access-pattern-aware JAX training/serving framework.

Reproduction of "Optimizing Memory Performance of Xilinx FPGAs under Vitis"
(CS.DC 2020), adapted to the TPU memory hierarchy.  See DESIGN.md.
"""
__version__ = "1.0.0"

from repro import compat as _compat

_compat.install()
del _compat

# the closed tune->execute loop is part of the public surface:
# ``import repro; repro.tune.plan_for(...)``
from repro import tune  # noqa: E402,F401

"""SLO-aware scheduling policy for the serving engine: priority classes,
prefill/decode interleave bounds, and mid-flight preemption.

The paper's memory-hierarchy argument — bandwidth is only achievable if
you manage which tier data lives in and when it moves — applied one level
up: under pool pressure the engine no longer just backpressures the
admission queue.  It picks a *victim* by (priority, resume cost, page
footprint), evicts the victim's pages through the refcounted
:class:`~repro.serve.kvcache.PageAllocator` release path, and brings the
request back later by whichever move the memory hierarchy prices cheaper:

- **recompute** — re-prefill ``prompt ++ emitted[:-1]`` in chunks (the
  prefix cache serves the original prompt pages when they survived), at
  the cost of re-streaming the weights once per chunk; or
- **swap** — gather the victim's whole pages (+ int8 scale lanes) to a
  host-memory :class:`~repro.serve.hosttier.HostKVTier` and stream them
  back through the page table on resume, at the cost of two traversals
  of the device<->host staging link.

:class:`SwapCostModel` prices both against the same
:class:`~repro.core.memmodel.TPUSpec` the bench subsystem calibrates, so
``run_sweeps(calibration=...)`` reshapes this decision exactly the way it
reshapes kernel block geometry.  Everything here is pure policy — the
mechanism (page gather/scatter, PRNG replay, table republication) lives
in :class:`~repro.serve.engine.ServeEngine`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.core.memmodel import TPUSpec, V5E

# priority classes: higher admits (and holds its slot) first under
# pressure.  Plain ints so callers can invent finer gradations.
PRIORITY_LOW = 0
PRIORITY_HIGH = 1


@dataclass(frozen=True)
class SchedulerConfig:
    """Policy knobs.  The defaults reproduce the pre-scheduler engine for
    uniform-priority workloads: FIFO admission (priority ties break by
    arrival), no preemption ever triggers (admission preempts only
    strictly-lower-priority victims), and every pending prefill advances
    one chunk per admit round."""

    preempt: bool = True
    swap: bool = True                 # allow host-tier swap resumes
    # SLO bound: at most this many chunked-prefill dispatches between
    # consecutive decode windows (None = unbounded, the legacy behavior).
    # Under a prefill-heavy mix this caps the decode-tick gap — the TPOT
    # tail — at a known number of chunk dispatches.
    prefill_chunks_per_tick: Optional[int] = None
    # device<->host staging-link bandwidth for the swap path (PCIe-class;
    # the HBM spec comes from the — possibly calibrated — TPUSpec).
    host_link_bw: float = 32e9

    def __post_init__(self) -> None:
        cap = self.prefill_chunks_per_tick
        if cap is not None and cap < 1:
            raise ValueError(
                f"prefill_chunks_per_tick={cap}: the cap must be >= 1 (every "
                "admit round must be able to advance at least one pending "
                "prefill chunk, or pending prompts would stall forever) — "
                "use None for the unbounded legacy behavior")


@dataclass(frozen=True)
class VictimInfo:
    """One active slot's preemption candidacy, as the engine sees it."""

    slot: int
    rid: int
    priority: int
    ctx_tokens: int        # live KV rows a resume must restore
    pages: int             # page footprint across pools (freed on evict)
    # Whether THIS victim can take the swap-resume path.  Per victim, not
    # per pool: a mixed pool holds full-attention slots that can swap next
    # to mid-prefill (and, engine-wide, ring/hybrid) slots that can only
    # recompute, and pricing the latter at min(recompute, swap) evicts the
    # wrong slot.
    swappable: bool = False


class SwapCostModel:
    """Price recompute-resume vs swap-resume for a victim with ``ctx``
    live tokens.

    Recompute re-runs chunked prefill over the context: each chunk
    re-streams the weights from HBM once (the dominant term for short
    chunks) and rewrites the context's KV rows.  Swap moves the victim's
    KV bytes across the host staging link twice (out + back).  Both sides
    use the same ``spec`` the calibrated bench model fits, so a
    measured-mode calibration moves this break-even point too.
    """

    def __init__(self, *, weight_bytes: float, kv_bytes_per_token: float,
                 prefill_chunk: int, spec: TPUSpec = V5E,
                 host_link_bw: float = 32e9, calibration=None,
                 link_scale: Optional[float] = None):
        if calibration is not None:
            # a bench CalibrationResult: adopt its fitted spec for the HBM
            # side only.  bandwidth_scale is a ratio fitted against HBM
            # curves; the PCIe-class staging link is a different interface
            # with its own controller geometry, and rescaling it by an HBM
            # fit moves the swap/recompute break-even for the wrong reason.
            spec = calibration.spec
        if link_scale is not None:
            # a separately-measured staging-link ratio, when the caller
            # actually calibrated the host link
            host_link_bw *= link_scale
        self.weight_bytes = float(weight_bytes)
        self.kv_bytes_per_token = float(kv_bytes_per_token)
        self.prefill_chunk = max(1, int(prefill_chunk))
        self.spec = spec
        self.host_link_bw = float(host_link_bw)

    def recompute_s(self, ctx_tokens: int) -> float:
        """Modeled chunked-prefill time for ``ctx_tokens``: one weight
        stream per chunk + one KV-row write per token."""
        chunks = -(-max(1, ctx_tokens) // self.prefill_chunk)
        return (chunks * self.weight_bytes
                + ctx_tokens * self.kv_bytes_per_token) / self.spec.hbm_bw

    def swap_s(self, ctx_tokens: int) -> float:
        """Modeled page-swap time: the victim's KV bytes cross the host
        staging link twice (gather out, stream back)."""
        return 2.0 * ctx_tokens * self.kv_bytes_per_token / self.host_link_bw

    def resume_s(self, ctx_tokens: int, swappable: bool) -> float:
        """Cheapest resume the hierarchy offers this victim."""
        r = self.recompute_s(ctx_tokens)
        return min(r, self.swap_s(ctx_tokens)) if swappable else r

    def choose(self, ctx_tokens: int, swappable: bool) -> str:
        """``"swap"`` or ``"recompute"`` for a victim with ``ctx`` live
        tokens.  Ring/hybrid victims are never swappable: rotation and
        recurrent state are not captured by full-pool pages."""
        if swappable and self.swap_s(ctx_tokens) < self.recompute_s(ctx_tokens):
            return "swap"
        return "recompute"


@dataclass
class Scheduler:
    """Priority ordering + victim selection.  Mutable so the engine can
    lazily attach a cost model derived from its own geometry (weight
    bytes, page bytes) when the caller didn't supply a calibrated one."""

    config: SchedulerConfig = field(default_factory=SchedulerConfig)
    cost_model: Optional[SwapCostModel] = None

    # ------------------------------------------------------------------
    def order_queue(self, queue: List, arrival) -> None:
        """Stable priority order: higher priority first, FIFO within a
        class (``arrival`` maps rid -> admission sequence number, which a
        preempted request keeps — it resumes ahead of later arrivals of
        its own class, behind whatever displaced it)."""
        queue.sort(key=lambda r: (-r.priority, arrival.get(r.rid, 0)))

    def prefill_order(self, slots: Sequence[int], priorities) -> List[int]:
        """Which pending-prefill slots advance a chunk this admit round:
        high-priority prompts first, capped at ``prefill_chunks_per_tick``
        so decode windows keep their cadence under prefill load."""
        order = sorted(slots, key=lambda i: (-priorities(i), i))
        cap = self.config.prefill_chunks_per_tick
        return order if cap is None else order[:cap]

    def pick_victim(self, cands: Sequence[VictimInfo], *,
                    below: Optional[int] = None) -> Optional[VictimInfo]:
        """The ISSUE's ordering: lowest priority class first, then the
        cheapest modeled resume, then the largest page footprint (free the
        most pool per eviction).  ``below`` restricts to victims strictly
        below a priority (admission-pressure preemption never cannibalizes
        peers); window-pressure shedding passes ``below=None``."""
        if not self.config.preempt:
            return None
        pool = [v for v in cands
                if below is None or v.priority < below]
        if not pool:
            return None
        cm = self.cost_model

        def key(v: VictimInfo):
            cost = (cm.resume_s(v.ctx_tokens, v.swappable)
                    if cm is not None else v.ctx_tokens)
            return (v.priority, cost, -v.pages, v.slot)

        return min(pool, key=key)

"""Open-loop traffic for the cluster front end: what "heavy traffic from
millions of users" looks like to the arbiter, shrunk onto a virtual
clock so every draw is reproducible.

``generate_traffic`` emits an arrival schedule — ``(round, Request)``
pairs — with the three properties that stress a router:

- **Poisson + bursty arrivals**: exponential inter-arrival gaps whose
  rate is modulated by a two-state (calm/burst) Markov phase, so the
  schedule has both steady load and the bursts that blow queue-delay
  predictions;
- **Zipf-shared prefixes**: each prompt opens with one of ``n_prefixes``
  common prefixes drawn Zipf(``zipf_a``) — a few prefixes dominate,
  which is exactly the skew that makes cache-aware routing beat
  least-loaded;
- **mixed lengths + SLOs**: uniform prompt-tail and output lengths, an
  optional deadline window (rounds after arrival), and a high-priority
  fraction.

Everything comes from one seeded ``numpy`` generator: the same config
always yields the same schedule, with fresh :class:`Request` objects per
call (requests are mutated by serving — regenerate, never reuse).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.serve.engine import Request
from repro.serve.scheduler import PRIORITY_HIGH, PRIORITY_LOW


@dataclass(frozen=True)
class TrafficConfig:
    seed: int = 0
    n_requests: int = 32
    rate: float = 1.0              # mean arrivals per round (calm phase)
    burst_rate_mult: float = 1.0   # rate multiplier inside a burst (1 = off)
    phase_rounds: float = 8.0      # mean rounds per calm/burst phase
    # -- prompt shape ----------------------------------------------------
    n_prefixes: int = 4            # shared-prefix vocabulary
    zipf_a: float = 1.2            # Zipf exponent over the prefixes
    prefix_len: int = 16           # tokens per shared prefix
    tail_lo: int = 3               # unique prompt tail, uniform [lo, hi]
    tail_hi: int = 9
    # -- output / SLO ----------------------------------------------------
    out_lo: int = 4                # max_new_tokens, uniform [lo, hi]
    out_hi: int = 12
    deadline_rounds: Optional[Tuple[int, int]] = None  # uniform window
    high_priority_frac: float = 0.0


def generate_traffic(cfg: TrafficConfig,
                     vocab_size: int) -> List[Tuple[int, Request]]:
    """The arrival schedule, sorted by round (rids follow arrival
    order).  Pure function of ``(cfg, vocab_size)``."""
    rng = np.random.default_rng(cfg.seed)
    prefixes = [rng.integers(0, vocab_size, size=cfg.prefix_len)
                .astype(np.int32) for _ in range(cfg.n_prefixes)]
    weights = 1.0 / np.arange(1, cfg.n_prefixes + 1) ** cfg.zipf_a
    weights /= weights.sum()

    schedule: List[Tuple[int, Request]] = []
    t = 0.0
    burst = False
    phase_left = rng.exponential(cfg.phase_rounds)
    for rid in range(cfg.n_requests):
        rate = cfg.rate * (cfg.burst_rate_mult if burst else 1.0)
        gap = rng.exponential(1.0 / max(rate, 1e-9))
        t += gap
        phase_left -= gap
        while phase_left <= 0:
            burst = not burst
            phase_left += rng.exponential(cfg.phase_rounds)
        arrival = int(t)
        pidx = int(rng.choice(cfg.n_prefixes, p=weights))
        tail = rng.integers(0, vocab_size,
                            size=int(rng.integers(cfg.tail_lo,
                                                  cfg.tail_hi + 1))
                            ).astype(np.int32)
        prompt = np.concatenate([prefixes[pidx], tail])
        deadline = None
        if cfg.deadline_rounds is not None:
            lo, hi = cfg.deadline_rounds
            deadline = arrival + int(rng.integers(lo, hi + 1))
        prio = (PRIORITY_HIGH if rng.random() < cfg.high_priority_frac
                else PRIORITY_LOW)
        schedule.append((arrival, Request(
            rid=rid, prompt=prompt,
            max_new_tokens=int(rng.integers(cfg.out_lo, cfg.out_hi + 1)),
            priority=prio, deadline=deadline)))
    return schedule

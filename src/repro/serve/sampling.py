"""Device-resident token sampling for the serving fast path.

The fused decode loops (:mod:`repro.serve.engine`) carry per-slot PRNG
keys as device arrays and draw each token inside the ``lax.fori_loop``
body, so sampled serving keeps the PR 3 dispatch regime: one launch and
one host sync per window, never per token.

Semantics (all knobs compose, applied in this order):

- ``temperature`` scales logits; ``0.0`` is *exact* greedy argmax — the
  sampler never touches the key, so the greedy path stays bit-identical
  to the pre-sampling engine and consumes no PRNG state.
- ``top_k`` keeps the k highest logits (ties at the k-th value are all
  kept — the threshold rule is deterministic and mirrored by the host
  reference sampler in the tests).
- ``top_p`` keeps the smallest prefix of the descending-sorted
  distribution whose mass reaches p (the top-1 token is always kept).

The final draw is ``jax.random.categorical`` (gumbel-max) over the
masked logits.  Key discipline: one ``jax.random.split`` per *emitted*
token — the carried key advances exactly with the output stream, which
is what makes speculative decoding key-exact with vanilla sampling (the
verify step derives the same per-position subkeys by iterating the same
split chain).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

# large-negative instead of -inf: masked logits must stay NaN-free under
# the gumbel add inside jax.random.categorical
NEG_INF = jnp.float32(-1e30)


@dataclass(frozen=True)
class SamplingParams:
    """Per-engine sampling configuration (hashable: it is baked into the
    fused decode jits as a static closure argument).

    ``temperature=0`` is greedy argmax; ``top_k=0`` and ``top_p=1.0``
    disable their filters.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingParams()


def mask_logits(logits: jax.Array, params: SamplingParams) -> jax.Array:
    """Temperature-scaled, top-k/top-p-masked logits for one (V,) row.

    Kept entries are exactly ``logits / temperature`` (a single IEEE
    division, so the host reference sampler reproduces them bit-for-bit);
    dropped entries become :data:`NEG_INF`.
    """
    l = logits.astype(jnp.float32) / jnp.float32(params.temperature)
    v = l.shape[-1]
    if 0 < params.top_k < v:
        kth = jnp.sort(l)[v - params.top_k]
        l = jnp.where(l < kth, NEG_INF, l)
    if params.top_p < 1.0:
        sl = jnp.sort(l)[::-1]
        probs = jax.nn.softmax(sl)
        csum = jnp.cumsum(probs)
        # keep while the *exclusive* prefix mass is below p: the smallest
        # covering set, and the top-1 token is always in it
        keep = (csum - probs) < params.top_p
        cutoff = jnp.min(jnp.where(keep, sl, jnp.inf))
        l = jnp.where(l < cutoff, NEG_INF, l)
    return l


def sample_token(key: jax.Array, logits: jax.Array,
                 params: SamplingParams) -> jax.Array:
    """Draw one token id from a (V,) logits row with a (2,) uint32 key."""
    if params.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, mask_logits(logits, params)).astype(
        jnp.int32)


def sample_tokens(keys: jax.Array, logits: jax.Array,
                  params: SamplingParams) -> jax.Array:
    """Batched draw: keys (B, 2) uint32, logits (B, V) -> (B,) int32.

    Greedy ignores the keys entirely (no PRNG state is consumed)."""
    if params.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.vmap(lambda k, l: sample_token(k, l, params))(keys, logits)


def split_keys(keys: jax.Array):
    """Advance a (B, 2) key batch one step: returns (carried, subkeys)."""
    s = jax.vmap(lambda k: jax.random.split(k, 2))(keys)
    return s[:, 0], s[:, 1]


def subkey_chain(keys: jax.Array, n: int):
    """Iterate the per-slot split chain ``n`` steps without consuming it.

    Returns ``(subs, carried)`` with subs (B, n, 2) — the subkey that
    samples the i-th emitted token — and carried (B, n+1, 2) — the key
    the slot holds *after* emitting i tokens (``carried[:, 0]`` is the
    input key).  This is exactly the chain the fused vanilla loop walks
    one split per token, which is what lets the speculative verify step
    emit m tokens and land on ``carried[:, m]`` — key-exact with a
    vanilla engine that emitted the same m tokens one tick at a time.
    """

    def chain(key):
        def step(c, _):
            nk, sub = jax.random.split(c)
            return nk, (sub, nk)

        _, (subs, carrs) = jax.lax.scan(step, key, None, length=n)
        return subs, jnp.concatenate([key[None], carrs], axis=0)

    return jax.vmap(chain)(keys)

"""Deterministic fault injection for the serving engine.

The scheduler's correctness claim — any preemption/swap/resume schedule
drains token-identically to the unpreempted run — is only worth stating
if something adversarial tries to break it.  :class:`ChaosEngine` wraps a
live :class:`~repro.serve.engine.ServeEngine` and, from a seeded
``numpy`` generator (reproducible failures, shrinkable under
hypothesis), injects per round:

- **preemption storms** — every active slot is independently evicted
  with ``preempt_prob``, mode forced or left to the cost model;
- **forced pool exhaustion** — a *phantom* request (negative rid, so it
  can never collide with real traffic) grabs a random slice of the free
  list for one round, driving admission into its backpressure/victim
  paths and decode into its shedding path;
- **swap-tier faults** — extra staging latency (``swap_latency_s``) and
  in-place corruption of swapped entries (``corrupt_prob``), which the
  tier's checksum must catch and the engine must survive by falling
  back to recompute-resume.

After every round the wrapper asserts allocator conservation (live +
free == pool, every refcount >= 1, every table page live) — faults may
slow the drain, never leak a page.

:class:`ClusterChaos` is the replica-scale sibling: whole-replica
crashes, brownouts (stalled rounds + slow health probes), and transient
admission refusals, injected into a
:class:`~repro.serve.cluster.ClusterFrontEnd` per virtual-clock round.
Every fault kind — engine-level and cluster-level — draws from its own
seed-derived sub-stream (:func:`fault_rng`), so kinds compose without
perturbing each other's schedules.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.serve.hosttier import corrupt_entry
from repro.serve.kvcache import PoolExhausted

# Stable fault-kind ids: each kind draws from its own seed-derived
# sub-stream keyed (seed, kind-id) through a SeedSequence, so adding a new
# fault kind (the cluster faults below) can NEVER perturb an existing
# kind's schedule — the PR 8 chaos expectations survive unchanged.  Only
# append here; renumbering an existing kind reshuffles its schedule.
_FAULT_KIND_IDS = {
    "storm": 0,       # per-slot preemption storms   (ChaosEngine)
    "exhaust": 1,     # phantom free-list grabs      (ChaosEngine)
    "corrupt": 2,     # host-tier byte flips         (ChaosEngine)
    "crash": 3,       # whole-replica crash          (ClusterChaos)
    "brownout": 4,    # replica stall / slow probes  (ClusterChaos)
    "admit": 5,       # transient admission refusals (ClusterChaos)
    "transfer": 6,    # in-transit buffer corruption (DisaggChaos)
}


def fault_rng(seed: int, kind: str) -> np.random.Generator:
    """The sub-generator for one fault kind under one chaos seed."""
    return np.random.default_rng(
        np.random.SeedSequence((seed, _FAULT_KIND_IDS[kind])))


@dataclass(frozen=True)
class ChaosConfig:
    seed: int = 0
    preempt_prob: float = 0.25    # per active slot, per round
    exhaust_prob: float = 0.2     # phantom free-list grab, per round
    corrupt_prob: float = 0.0     # per swapped host entry, per round
    swap_latency_s: float = 0.0   # injected staging-link stall per put/get
    mode: Optional[str] = None    # force "swap"/"recompute"; None = cost model


class ChaosEngine:
    """Drives ``eng`` to completion while injecting faults.  Use exactly
    like ``run_to_completion``: enqueue requests on the engine (or via
    :meth:`add_request`), then :meth:`run_to_completion`."""

    def __init__(self, eng, cfg: ChaosConfig = ChaosConfig()):
        self.eng = eng
        self.cfg = cfg
        self.rngs = {k: fault_rng(cfg.seed, k)
                     for k in ("storm", "exhaust", "corrupt")}
        self.faults = 0               # injected preemptions
        self.exhausts = 0             # phantom grabs
        self.corruptions = 0          # host-tier bytes flipped
        self._phantoms: List = []     # [(allocator, rid)] held this round
        self._next_phantom = -1
        if eng.host_tier is not None and cfg.swap_latency_s > 0:
            eng.host_tier.latency_s = cfg.swap_latency_s

    # ------------------------------------------------------------------
    def add_request(self, req) -> None:
        self.eng.add_request(req)

    @property
    def stats(self):
        return self.eng.stats

    # ------------------------------------------------------------------
    def _pools(self):
        if self.eng.backend != "paged":
            return []
        return [a for a in (self.eng.alloc, self.eng.ralloc) if a is not None]

    def _release_phantoms(self) -> None:
        for alloc, rid in self._phantoms:
            alloc.release(rid)
        self._phantoms = []

    def _grab_phantom(self) -> None:
        """Steal a random slice of each pool's free list for one round —
        the outside world's version of 'someone else is using the HBM'."""
        for alloc in self._pools():
            free = len(alloc.free)
            cap = (free if alloc.ring_slots is None
                   else min(free, alloc.ring_slots))
            if cap < 1:
                continue
            k = int(self.rngs["exhaust"].integers(1, cap + 1))
            rid = self._next_phantom
            self._next_phantom -= 1
            alloc.alloc(rid)
            alloc.reserve(rid, k * alloc.page_size)
            self._phantoms.append((alloc, rid))
            self.exhausts += 1

    def _storm(self) -> None:
        eng = self.eng
        for i, req in enumerate(eng.slots):
            if req is None or req.done:
                continue
            if self.rngs["storm"].random() < self.cfg.preempt_prob:
                eng.preempt(i, mode=self.cfg.mode)
                self.faults += 1

    def _corrupt(self) -> None:
        tier = self.eng.host_tier
        if tier is None or self.cfg.corrupt_prob <= 0:
            return
        for rid in tier.rids():
            if self.rngs["corrupt"].random() < self.cfg.corrupt_prob:
                tier.corrupt(rid)
                self.corruptions += 1

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Allocator conservation after a fault round: live + free == pool
        (minus the reserved null page), every live page holds >= 1
        reference, and every table entry points at a live page."""
        for a in self._pools():
            live = a.num_pages - a.reserved - len(a.free)
            assert live == len(a.ref), (
                f"{a.kind} pool leak: {live} unaccounted vs {len(a.ref)} "
                "refcounted")
            assert all(c >= 1 for c in a.ref.values()), (
                f"{a.kind} pool holds a zero refcount")
            assert not set(a.free) & set(a.ref), (
                f"{a.kind} pool has pages both free and referenced")
            for rid, table in a.tables.items():
                for pid in table:
                    assert pid in a.ref, (
                        f"{a.kind} pool: rid {rid} maps freed page {pid}")

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One fault-injection round: release last round's phantom pages,
        inject (storm, corruption, exhaustion), then advance the engine
        one admit + decode-window round.  False once fully drained."""
        eng = self.eng
        self._release_phantoms()
        self._storm()
        self._corrupt()
        if self.rngs["exhaust"].random() < self.cfg.exhaust_prob:
            self._grab_phantom()
        eng._admit()
        if not any(s is not None for s in eng.slots):
            # drained, or everything stalled behind phantom pages — free
            # them either way so the next round can admit
            self._release_phantoms()
            self.check_invariants()
            return bool(eng.queue)
        try:
            eng.decode_many(eng.window)
        except PoolExhausted:
            if not self._phantoms:
                raise  # genuinely undersized pool: surface it
            self._release_phantoms()  # chaos-induced: recover next round
        self.check_invariants()
        return True

    def run_to_completion(self, max_rounds: int = 10_000):
        """Drain under fire.  Raises if the drain does not converge —
        fault injection may slow completion, never prevent it."""
        for _ in range(max_rounds):
            if not self.step():
                self._release_phantoms()
                return self.eng.stats
        raise AssertionError(
            f"chaos drain did not converge in {max_rounds} rounds "
            f"(faults={self.faults}, exhausts={self.exhausts}, "
            f"queue={len(self.eng.queue)})")


# ----------------------------------------------------------------------
# cluster-scale faults
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ClusterChaosConfig:
    """Cluster fault mix for :class:`ClusterChaos`.  Probabilities are
    per replica, per round; ``kill_at`` pins explicit faults to rounds —
    ``(round, replica_index, kind)`` with kind one of ``"crash"`` /
    ``"brownout"`` / ``"admit"`` — for reproducible kill schedules the
    bench gates replay."""
    seed: int = 0
    crash_prob: float = 0.0        # replica goes dark (device state lost)
    crash_rounds: int = 6          # rounds a crashed replica stays dark
    brownout_prob: float = 0.0     # replica stalls, probes turn slow
    brownout_rounds: int = 4
    brownout_latency_s: float = 1.0   # what the health probe observes
    admit_prob: float = 0.0        # transient admission refusal queued
    kill_at: Tuple[Tuple[int, int, str], ...] = ()
    max_down: Optional[int] = None    # fault budget; default n_replicas - 1


class ClusterChaos:
    """Seeded replica-scale fault injector for a cluster front end.

    Pass as ``chaos=`` to :meth:`ClusterFrontEnd.run` — :meth:`inject`
    fires at the top of every virtual-clock round and arms faults on the
    :class:`~repro.serve.cluster.Replica` wrappers (crash/stall timers,
    queued admission refusals).  Each fault kind draws from its own
    ``(seed, kind)`` sub-stream (see :func:`fault_rng`), and every
    per-replica draw happens whether or not the fault fires, so a fault
    schedule is a pure function of the config — independent of cluster
    state.  ``max_down`` keeps at least one replica standing (liveness:
    chaos may slow the drain, never wedge it)."""

    def __init__(self, cfg: ClusterChaosConfig = ClusterChaosConfig()):
        self.cfg = cfg
        self.rngs = {k: fault_rng(cfg.seed, k)
                     for k in ("crash", "brownout", "admit")}
        self.crashes = 0
        self.brownouts = 0
        self.admit_faults = 0

    def _down(self, front) -> int:
        return sum(1 for r in front.replicas
                   if r.crash_rounds > 0 or r.stall_rounds > 0
                   or r.state == "quarantined")

    def _budget(self, front) -> int:
        cap = self.cfg.max_down
        if cap is None:
            cap = len(front.replicas) - 1
        return cap - self._down(front)

    def fire(self, rep, kind: str) -> None:
        if kind == "crash":
            rep.crash_rounds = self.cfg.crash_rounds
            self.crashes += 1
        elif kind == "brownout":
            rep.stall_rounds = self.cfg.brownout_rounds
            rep.probe_latency_s = self.cfg.brownout_latency_s
            self.brownouts += 1
        elif kind == "admit":
            rep.admit_faults += 1
            self.admit_faults += 1
        else:
            raise ValueError(f"unknown cluster fault kind {kind!r}")

    def inject(self, front) -> None:
        now = front.round
        for rnd, idx, kind in self.cfg.kill_at:
            if rnd == now:
                self.fire(front.replicas[idx], kind)
        for rep in front.replicas:
            # draw-before-gate: streams advance identically whatever fires
            if (self.rngs["crash"].random() < self.cfg.crash_prob
                    and rep.crash_rounds == 0 and self._budget(front) > 0):
                self.fire(rep, "crash")
            if (self.rngs["brownout"].random() < self.cfg.brownout_prob
                    and rep.stall_rounds == 0 and rep.crash_rounds == 0
                    and self._budget(front) > 0):
                self.fire(rep, "brownout")
            if self.rngs["admit"].random() < self.cfg.admit_prob:
                self.fire(rep, "admit")


# ----------------------------------------------------------------------
# disaggregated-transfer faults
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class DisaggChaosConfig:
    """Fault mix for :class:`DisaggChaos`: per in-transit transfer
    buffer, per round, flip a byte inside the checksummed span.  The
    decode pool's import path must catch every hit at swap-in (checksum
    verify) and recover by recompute-resume — the drained tokens may
    never diverge from the clean run."""
    seed: int = 0
    corrupt_prob: float = 0.0


class DisaggChaos:
    """Seeded fault injector for a :class:`~repro.serve.cluster.DisaggPool`.

    Pass as ``chaos=`` to :meth:`DisaggPool.run` — :meth:`inject` fires
    at the top of every virtual-clock round, while shipped prefill pages
    are still in flight between the pools.  Draws come from the
    ``(seed, "transfer")`` sub-stream (one draw per in-transit buffer per
    round, fired or not), so the schedule composes with every other
    chaos kind without perturbing it."""

    def __init__(self, cfg: DisaggChaosConfig = DisaggChaosConfig()):
        self.cfg = cfg
        self.rng = fault_rng(cfg.seed, "transfer")
        self.corruptions = 0

    def inject(self, pool) -> None:
        if self.cfg.corrupt_prob <= 0:
            return
        for t in pool._transit:
            if self.rng.random() < self.cfg.corrupt_prob:
                corrupt_entry(t.entry)
                self.corruptions += 1

"""Paged KV-cache pool: vLLM-style page allocation for the serving engine.

Memory-system rationale (the paper's lens): fixed-size pages sized to the
transaction optimum (advisor: r_acc wants unit_bytes >= 512B -> page tokens =
unit / row bytes) turn per-request cache growth from fragmentation-prone
contiguous buffers into constant-time page appends; the paged_attention
kernel dereferences the table inside its BlockSpec index_map.

Three layers, mechanism only (the engine owns policy):

- :class:`PageAllocator` — host-side bookkeeping: per-request page tables,
  refcounted shared pages, a *sorted* free list (lowest page id reused
  first, so table contents are reproducible run to run), and a typed
  :class:`PoolExhausted` the engine turns into admission backpressure.
- :class:`PagedKVCache` — allocator + the device-resident page arrays, with
  copy-on-write ``append`` (a shared page is copied before its first
  divergent write, so forked/prefix-shared pages are never mutated).
- :class:`PrefixIndex` — chain-hash -> page-id map for prefix caching:
  requests with a common prompt prefix attach the same *full* pages
  read-only (the paper's access-coalescing move applied to prompts).
"""
from __future__ import annotations

import bisect
import hashlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class PoolExhausted(MemoryError):
    """No free pages left.  The engine catches this and keeps the request
    queued (backpressure) instead of crashing the serving loop.

    The exception carries structured context — which pool, how full it
    was, who asked for how much — so operators (and the chaos harness's
    assertions) see *why* admission stalled instead of a bare raise:

    - ``pool``: ``"full"`` / ``"ring"`` (allocator kind) or ``"engine"``
      for the decode-time all-slots-blocked raise
    - ``num_pages`` / ``free_pages`` / ``live_pages``: pool census at the
      moment of the raise (live excludes the reserved null page)
    - ``rid`` / ``need_pages``: the requester and its unmet page demand
      (``None`` when the raise is not tied to one request)
    """

    def __init__(self, msg: str = "", *, pool: str = "full",
                 num_pages: Optional[int] = None,
                 free_pages: Optional[int] = None,
                 live_pages: Optional[int] = None,
                 rid: Optional[int] = None,
                 need_pages: Optional[int] = None):
        self.pool = pool
        self.num_pages = num_pages
        self.free_pages = free_pages
        self.live_pages = live_pages
        self.rid = rid
        self.need_pages = need_pages
        bits = [f"pool={pool}"]
        if num_pages is not None:
            bits.append(f"pages={num_pages}")
        if live_pages is not None:
            bits.append(f"live={live_pages}")
        if free_pages is not None:
            bits.append(f"free={free_pages}")
        if rid is not None:
            bits.append(f"rid={rid}")
        if need_pages is not None:
            bits.append(f"need={need_pages}")
        super().__init__(f"{msg} [{', '.join(bits)}]" if msg
                         else f"[{', '.join(bits)}]")


def page_hashes(tokens: np.ndarray, page_size: int) -> List[str]:
    """Chain hashes of the *full* pages of a prompt.

    ``h_i = sha1(h_{i-1} | tokens[i*page:(i+1)*page])`` — the chain makes a
    page hash identify the whole prefix up to and including that page, so a
    flat dict lookup implements longest-prefix matching.
    """
    toks = np.asarray(tokens, np.int64)
    out: List[str] = []
    h = b""
    for i in range(len(toks) // page_size):
        chunk = toks[i * page_size:(i + 1) * page_size]
        h = hashlib.sha1(h + chunk.tobytes()).digest()
        out.append(h.hex())
    return out


class PageAllocator:
    """Host-side page bookkeeping shared by every layer's page array.

    Page ids index the same slot in each layer's pool, so one table serves
    the whole stack.  ``reserved`` ids (0..reserved-1) are never allocated —
    the engine reserves page 0 as the *null page* that padded table entries
    point at, so masked/inactive writes can never corrupt live data.

    ``window`` turns the allocator into a *ring*: a request's table holds at
    most ``ceil(window/page_size) + 1`` pages, indexed by ring slot
    (``logical_page % ring_slots``), and growth past the ring *rotates* —
    the trailing page (fully outside the sliding window by the capacity
    argument: ``ring_slots*page >= window + page``) is reused in place, so
    a windowed sequence's footprint is constant however long it runs.  A
    rotated-onto page that is shared (fork) is copy-split instead of reused,
    so sharers never see the overwrite.
    """

    def __init__(self, num_pages: int, page_size: int, reserved: int = 0,
                 window: Optional[int] = None):
        if reserved >= num_pages:
            raise ValueError("reserved pages exhaust the pool")
        self.num_pages = num_pages
        self.page_size = page_size
        self.reserved = reserved
        self.window = window
        self.kind = "full" if window is None else "ring"
        self.ring_slots = (None if window is None
                           else -(-window // page_size) + 1)
        self.free: List[int] = list(range(reserved, num_pages))  # kept sorted
        self.tables: Dict[int, List[int]] = {}
        self.lengths: Dict[int, int] = {}
        self.ref: Dict[int, int] = {}
        # pages holding a prefix-index reference: the index's claim on a
        # page is explicit, so eviction can tell "my pin keeps this alive"
        # from "the pool re-issued this id to someone else"
        self.pinned: set = set()

    # ------------------------------------------------------------------
    def alloc(self, rid: int) -> None:
        if rid in self.tables:
            raise ValueError(f"rid {rid} already allocated")
        self.tables[rid] = []
        self.lengths[rid] = 0

    def exhausted(self, msg: str, rid: Optional[int] = None,
                  need: Optional[int] = None) -> PoolExhausted:
        """A :class:`PoolExhausted` pre-filled with this pool's census."""
        return PoolExhausted(msg, pool=self.kind, num_pages=self.num_pages,
                             free_pages=len(self.free),
                             live_pages=self.pages_in_use,
                             rid=rid, need_pages=need)

    def _take_page(self) -> int:
        if not self.free:
            raise self.exhausted(
                f"KV page pool exhausted ({self.num_pages} pages of "
                f"{self.page_size} tokens)", need=1)
        pid = self.free.pop(0)  # lowest id first: deterministic reuse order
        self.ref[pid] = 1
        return pid

    def _free_page(self, pid: int) -> None:
        if pid in self.pinned:
            # a pinned page's refcount includes the index's +1, so hitting
            # zero means something decref'd the pinned prefix below its
            # floor (double release / rollback past an attached prefix) —
            # freeing it here would hand a still-indexed page to the next
            # reserve and silently serve foreign KV rows
            raise RuntimeError(
                f"page {pid} freed while pinned by the prefix index "
                "(refcount underflow on a shared prefix page)")
        bisect.insort(self.free, pid)
        del self.ref[pid]

    def _ring_growth(self, rid: int, new_len: int) -> List[Tuple[int, int]]:
        """Ring bookkeeping for growing ``rid`` to ``new_len`` tokens:
        ``(logical_page, kind)`` steps, kind 0 = append a fresh page,
        kind 1 = rotate in place (free), kind 2 = rotate a *shared* page
        (costs one copy-split page)."""
        page, r = self.page_size, self.ring_slots
        hi = (new_len - 1) // page if new_len > 0 else -1
        old = self.lengths[rid]
        old_hi = (old - 1) // page if old > 0 else -1
        table = self.tables[rid]
        nslots = len(table)
        private = set()  # slots whose page is known private this round
        steps: List[Tuple[int, int]] = []
        for logical in range(old_hi + 1, hi + 1):
            slot = logical % r
            if slot >= nslots:
                steps.append((logical, 0))
                nslots += 1
                private.add(slot)  # fresh page: private by construction
            elif slot not in private and self.is_shared(table[slot]):
                steps.append((logical, 2))
                private.add(slot)
            else:
                steps.append((logical, 1))
                private.add(slot)
        return steps

    def can_grow(self, rid: int, new_len: int) -> int:
        """Largest length <= ``new_len`` coverable without exhausting the
        pool (the engine's budget cap under pool pressure)."""
        if self.window is not None:
            old = self.lengths[rid]
            old_hi = (old - 1) // self.page_size if old > 0 else -1
            ok = (old_hi + 1) * self.page_size  # covered by existing pages
            free = len(self.free)
            for logical, kind in self._ring_growth(rid, new_len):
                if kind != 1:
                    if free == 0:
                        break
                    free -= 1
                ok = (logical + 1) * self.page_size
            return min(new_len, ok)
        have = len(self.tables[rid])
        cap = (have + len(self.free)) * self.page_size
        return min(new_len, cap)

    def reserve(self, rid: int, new_len: int) -> List[int]:
        """Ensure the table covers ``new_len`` tokens; returns the newly
        allocated page ids.  All-or-nothing: raises :class:`PoolExhausted`
        without partial allocation.  Ring allocators rotate in place past
        ``ring_slots`` pages, releasing/reusing the trailing page the moment
        the window slides past it."""
        table = self.tables[rid]
        if self.window is not None:
            steps = self._ring_growth(rid, new_len)
            cost = sum(1 for _, kind in steps if kind != 1)
            if cost > len(self.free):
                raise self.exhausted(
                    f"need {cost} ring pages for rid {rid}, only "
                    f"{len(self.free)} free", rid=rid, need=cost)
            fresh: List[int] = []
            for logical, kind in steps:
                slot = logical % self.ring_slots
                if kind == 0:
                    pid = self._take_page()
                    table.append(pid)
                    fresh.append(pid)
                elif kind == 2:  # shared: split off a private page
                    old = table[slot]
                    self.ref[old] -= 1  # shared => never drops to 0 here
                    pid = self._take_page()
                    table[slot] = pid
                    fresh.append(pid)
                # kind 1: in-place reuse — no pool traffic at all
            self.lengths[rid] = max(self.lengths[rid], new_len)
            return fresh
        need = -(-new_len // self.page_size)
        grow = need - len(table)
        if grow > len(self.free):
            raise self.exhausted(
                f"need {grow} pages for rid {rid}, only {len(self.free)} "
                "free", rid=rid, need=grow)
        fresh = [self._take_page() for _ in range(max(0, grow))]
        table.extend(fresh)
        self.lengths[rid] = max(self.lengths[rid], new_len)
        return fresh

    def attach(self, rid: int, pages: Sequence[int], length: int) -> None:
        """Share existing pages into ``rid``'s table (prefix-cache hit or
        fork): refcount++ on each, no data copied."""
        table = self.tables[rid]
        if table:
            raise ValueError("attach only onto an empty table")
        if self.ring_slots is not None and len(pages) > self.ring_slots:
            raise ValueError(
                f"attach of {len(pages)} pages exceeds the ring "
                f"({self.ring_slots} slots)")
        for pid in pages:
            self.ref[pid] += 1
            table.append(pid)
        self.lengths[rid] = length

    def fork(self, src: int, dst: int) -> None:
        """Clone ``src``'s table into a new request ``dst`` (parallel
        sampling / beam fork): every page becomes shared; the first
        divergent append copies-on-write."""
        self.alloc(dst)
        self.attach(dst, list(self.tables[src]), self.lengths[src])

    def truncate(self, rid: int, new_len: int) -> List[int]:
        """Roll ``rid`` back to ``new_len`` tokens (speculative rejection):
        trailing pages wholly past the new length are dereferenced —
        freed when this was the last reference, merely detached when the
        page is shared (prefix-pinned / forked pages are never mutated,
        only their tail rows go stale and are masked by ``valid_len``).
        Ring tables rotate in place, so only the length rewinds.  Returns
        the page ids actually returned to the free list."""
        old = self.lengths[rid]
        if new_len > old:
            raise ValueError(
                f"truncate of rid {rid} to {new_len} exceeds its current "
                f"length {old}")
        freed: List[int] = []
        if self.ring_slots is None:
            table = self.tables[rid]
            keep = -(-new_len // self.page_size)
            while len(table) > keep:
                pid = table.pop()
                self.ref[pid] -= 1
                if self.ref[pid] == 0:
                    self._free_page(pid)
                    freed.append(pid)
        self.lengths[rid] = new_len
        return freed

    def release(self, rid: int) -> None:
        """Drop the request's pages; a page returns to the (sorted) free
        list when its last reference goes.  Unknown/double release raises —
        silent tolerance hid engine accounting bugs."""
        if rid not in self.tables:
            raise KeyError(f"release of unknown rid {rid} (double release?)")
        for pid in self.tables.pop(rid):
            self.ref[pid] -= 1
            if self.ref[pid] == 0:
                self._free_page(pid)
        del self.lengths[rid]

    # -- prefix-index pinning ------------------------------------------
    def pin(self, pid: int) -> None:
        """Extra reference held by the prefix index: the page outlives its
        owning request so later prompts can share it.  Membership is
        tracked so :meth:`unpin` and eviction act only on pages this
        allocator actually pinned — never on a re-issued page id."""
        if pid not in self.ref:
            raise KeyError(f"pin of unallocated page {pid}")
        if pid in self.pinned:
            raise ValueError(f"page {pid} already pinned")
        self.ref[pid] += 1
        self.pinned.add(pid)

    def unpin(self, pid: int) -> None:
        if pid not in self.pinned:
            # refusing here is the whole point: a stale index entry whose
            # page id was freed and re-issued must not decref the NEW
            # owner's only reference
            raise KeyError(f"unpin of page {pid} that holds no pin")
        self.pinned.discard(pid)
        self.ref[pid] -= 1
        if self.ref[pid] == 0:
            self._free_page(pid)

    def is_shared(self, pid: int) -> bool:
        return self.ref.get(pid, 0) > 1

    # ------------------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return self.num_pages - self.reserved - len(self.free)

    @property
    def live_tokens(self) -> int:
        return sum(self.lengths.values())


class PrefixIndex:
    """Chain-hash -> page id.  Policy lives in the engine: it pins pages on
    register and evicts unused entries under pool pressure."""

    def __init__(self):
        self._by_hash: Dict[str, int] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._by_hash)

    def lookup(self, hashes: Sequence[str],
               alloc: Optional[PageAllocator] = None) -> List[int]:
        """Longest run of leading hashes present; returns their page ids.

        With ``alloc`` the run is additionally validated against the
        allocator's pin registry: an entry whose page the pool has freed
        (and possibly re-issued to a new request) is a *miss*, not a hit —
        attaching it would share a foreign request's KV rows.  Stale
        entries found this way are dropped on the spot."""
        pages: List[int] = []
        for h in hashes:
            pid = self._by_hash.get(h)
            if pid is not None and alloc is not None \
                    and pid not in alloc.pinned:
                del self._by_hash[h]  # stale: freed/re-issued since indexed
                pid = None
            if pid is None:
                self.misses += 1
                break
            self.hits += 1
            pages.append(pid)
        return pages

    def match_len(self, hashes: Sequence[str],
                  alloc: Optional[PageAllocator] = None) -> int:
        """Longest run of leading hashes this index would serve — a pure
        *peek* for routing decisions: unlike :meth:`lookup` it never bumps
        the hit/miss counters and never drops stale entries, so scoring a
        request against many replicas' indexes perturbs none of them.
        With ``alloc`` an entry whose page lost its pin counts as a miss
        (it could not be attached), but is left in place for ``lookup`` /
        ``evict_unused`` to reap on the owning engine's own schedule."""
        n = 0
        for h in hashes:
            pid = self._by_hash.get(h)
            if pid is None or (alloc is not None and pid not in alloc.pinned):
                break
            n += 1
        return n

    def register(self, h: str, pid: int) -> bool:
        """Idempotent: the first page registered for a hash wins (identical
        content by construction)."""
        if h in self._by_hash:
            return False
        self._by_hash[h] = pid
        return True

    def evict_unused(self, alloc: PageAllocator) -> int:
        """Drop every entry whose page is only kept alive by the index's
        pin (pinned and ref == 1): the deterministic response to pool
        pressure.  Entries whose page lost its pin (freed while indexed,
        possibly already re-issued to a new request) are *self-healed* —
        dropped without touching refcounts, because ``ref == 1`` on such a
        page means the NEW owner's only reference, not ours.  Returns the
        number of pages freed back to the pool."""
        freed = 0
        for h, pid in list(self._by_hash.items()):
            if pid not in alloc.pinned:
                del self._by_hash[h]  # stale: not our reference to drop
                continue
            if alloc.ref.get(pid) == 1:
                del self._by_hash[h]
                alloc.unpin(pid)
                freed += 1
        return freed


@dataclass
class PagedKVCache(PageAllocator):
    """Single-layer page pool with device-resident k/v arrays.

    The serving engine keeps one :class:`PageAllocator` for the whole stack
    (the model pytree holds per-layer page arrays); this class is the
    self-contained one-layer variant the kernels and tests drive directly.
    """
    num_pages: int
    page_size: int
    num_kv_heads: int
    head_dim: int
    dtype: str = "float32"
    reserved: int = 0
    window: Optional[int] = None

    def __post_init__(self):
        PageAllocator.__init__(self, self.num_pages, self.page_size,
                               self.reserved, window=self.window)
        shape = (self.num_pages, self.page_size, self.num_kv_heads,
                 self.head_dim)
        self.k_pages = jnp.zeros(shape, jnp.dtype(self.dtype))
        self.v_pages = jnp.zeros(shape, jnp.dtype(self.dtype))

    # ------------------------------------------------------------------
    def _slot(self, logical: int) -> int:
        """Table index of a logical page (identity, or the ring slot)."""
        return logical if self.ring_slots is None else logical % self.ring_slots

    def _cow(self, rid: int, logical: int) -> int:
        """Copy-on-write: give ``rid`` a private copy of a shared page
        before writing into it.  The shared original is never mutated."""
        old = self.tables[rid][self._slot(logical)]
        if not self.is_shared(old):
            return old
        new = self._take_page()
        self.k_pages = self.k_pages.at[new].set(self.k_pages[old])
        self.v_pages = self.v_pages.at[new].set(self.v_pages[old])
        self.ref[old] -= 1  # shared => never drops to 0 here
        self.tables[rid][self._slot(logical)] = new
        return new

    def append(self, rid: int, k: jax.Array, v: jax.Array):
        """Append (S, Hkv, D) keys/values for one request.  All-or-nothing:
        the page budget (fresh pages + copy-on-write copies of shared pages
        in the write range) is checked before any table/length mutation, so
        :class:`PoolExhausted` never leaves lengths claiming unwritten
        tokens."""
        s = k.shape[0]
        start = self.lengths[rid]
        table = self.tables[rid]
        end_li = (start + s - 1) // self.page_size
        if self.ring_slots is None:
            need_fresh = max(0, end_li + 1 - len(table))
            in_table = range(start // self.page_size,
                             min(len(table), end_li + 1))
            need_cow = sum(1 for li in in_table if self.is_shared(table[li]))
        else:
            steps = self._ring_growth(rid, start + s)
            need_fresh = sum(1 for _, kind in steps if kind != 1)
            touched = {lg % self.ring_slots for lg, _ in steps}
            old_hi = (start - 1) // self.page_size if start > 0 else -1
            need_cow = sum(
                1 for li in range(start // self.page_size, old_hi + 1)
                if (li % self.ring_slots) not in touched
                and self.is_shared(table[li % self.ring_slots]))
        if need_fresh + need_cow > len(self.free):
            raise self.exhausted(
                f"append of {s} tokens needs {need_fresh} fresh + "
                f"{need_cow} copy-on-write pages, only {len(self.free)} "
                "free", rid=rid, need=need_fresh + need_cow)
        self.reserve(rid, start + s)
        off = 0
        while off < s:
            logical = (start + off) // self.page_size
            slot = (start + off) % self.page_size
            n = min(self.page_size - slot, s - off)
            pid = self._cow(rid, logical)
            self.k_pages = self.k_pages.at[pid, slot:slot + n].set(
                k[off:off + n])
            self.v_pages = self.v_pages.at[pid, slot:slot + n].set(
                v[off:off + n])
            off += n
        self.lengths[rid] = start + s

    def batch_view(self, rids: List[int],
                   width: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
        """(page_table (B, N), valid_len (B,)) padded to ``width`` logical
        pages (default: the max across ``rids``).  Unused table entries
        point at page 0 — reserve it as a null page (``reserved=1``) when
        padded entries may be written through (masked decode ticks)."""
        if self.ring_slots is not None:
            # ring tables must be exactly ring_slots wide: the kernel maps
            # logical pages to slots with ``logical % width``
            width = self.ring_slots
        n = width or max(1, max(len(self.tables[r]) for r in rids))
        table = np.zeros((len(rids), n), np.int32)
        for i, r in enumerate(rids):
            pages = self.tables[r]
            table[i, :len(pages)] = pages
        vlen = np.asarray([self.lengths[r] for r in rids], np.int32)
        return jnp.asarray(table), jnp.asarray(vlen)

    @property
    def page_bytes(self) -> int:
        """HBM bytes of one page (k + v)."""
        return (2 * self.page_size * self.num_kv_heads * self.head_dim
                * jnp.dtype(self.dtype).itemsize)

"""Paged KV-cache pool: vLLM-style page allocation for the serving engine.

Memory-system rationale (the paper's lens): fixed-size pages sized to the
transaction optimum (advisor: r_acc wants unit_bytes >= 512B -> page >= 16
tokens x Hkv x D x 2B) turn per-request cache growth from fragmentation-prone
contiguous buffers into constant-time page appends; the paged_attention
kernel dereferences the table inside its BlockSpec index_map.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class PagedKVCache:
    num_pages: int
    page_size: int
    num_kv_heads: int
    head_dim: int
    dtype: str = "float32"

    def __post_init__(self):
        shape = (self.num_pages, self.page_size, self.num_kv_heads,
                 self.head_dim)
        self.k_pages = jnp.zeros(shape, jnp.dtype(self.dtype))
        self.v_pages = jnp.zeros(shape, jnp.dtype(self.dtype))
        self.free: List[int] = list(range(self.num_pages))
        self.tables: Dict[int, List[int]] = {}
        self.lengths: Dict[int, int] = {}

    # ------------------------------------------------------------------
    def alloc(self, rid: int):
        assert rid not in self.tables
        self.tables[rid] = []
        self.lengths[rid] = 0

    def release(self, rid: int):
        self.free.extend(self.tables.pop(rid, []))
        self.lengths.pop(rid, None)

    def _ensure_capacity(self, rid: int, new_len: int):
        need = -(-new_len // self.page_size)
        while len(self.tables[rid]) < need:
            if not self.free:
                raise MemoryError("KV page pool exhausted")
            self.tables[rid].append(self.free.pop())

    # ------------------------------------------------------------------
    def append(self, rid: int, k: jax.Array, v: jax.Array):
        """Append (S, Hkv, D) keys/values for one request."""
        s = k.shape[0]
        start = self.lengths[rid]
        self._ensure_capacity(rid, start + s)
        off = 0
        while off < s:
            logical = (start + off) // self.page_size
            slot = (start + off) % self.page_size
            n = min(self.page_size - slot, s - off)
            pid = self.tables[rid][logical]
            self.k_pages = self.k_pages.at[pid, slot:slot + n].set(
                k[off:off + n])
            self.v_pages = self.v_pages.at[pid, slot:slot + n].set(
                v[off:off + n])
            off += n
        self.lengths[rid] = start + s

    def batch_view(self, rids: List[int]) -> Tuple[jax.Array, jax.Array]:
        """(page_table (B, N), valid_len (B,)) padded to the max page count.
        Unused table entries point at page 0 (masked by valid_len)."""
        n = max(1, max(len(self.tables[r]) for r in rids))
        table = np.zeros((len(rids), n), np.int32)
        for i, r in enumerate(rids):
            pages = self.tables[r]
            table[i, :len(pages)] = pages
        vlen = np.asarray([self.lengths[r] for r in rids], np.int32)
        return jnp.asarray(table), jnp.asarray(vlen)

    @property
    def pages_in_use(self) -> int:
        return self.num_pages - len(self.free)

"""Host-memory KV tier: the swap target for preempted requests.

One level further down the paper's memory hierarchy than the page pools:
when the scheduler evicts a victim whose context is expensive to
recompute, the engine gathers the victim's whole pages (k/v plus the int8
scale lanes) device->host and parks them here; resume reserves fresh
pages and streams the bytes back through the page table.  The tier is
pure host state — numpy pytrees keyed by rid — so it survives device
cache donation and TP resharding untouched.

Every entry carries a CRC32 over its *real* pages (the gather pads the
page list to a power of two with null-page ids; those padding lanes are
excluded — the null page legitimately changes under masked decode
writes).  ``get`` re-verifies the checksum, so a corrupted swap (the
chaos harness injects exactly this) is detected before a single stale
row reaches the device and the engine falls back to recompute-resume.
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def page_axis(path, leaf) -> int:
    """Which axis of a paged-cache leaf indexes pages.

    Pool leaves are ``*_pages`` ``(..., P, page, Hkv, D)`` and int8 scale
    lanes are ``*_scale`` ``(..., P, page)``; stacked pattern-block leaves
    carry a leading layer axis.  Swappable stacks are pure full attention
    (validated by the engine), so every leaf is one of the two.
    """
    name = ""
    for p in path:
        name = str(getattr(p, "key", getattr(p, "name", name)))
    if name.endswith("_pages"):
        ax = leaf.ndim - 4
    elif name.endswith("_scale"):
        ax = leaf.ndim - 2
    else:
        raise ValueError(
            f"leaf {name!r} is not a page-pool leaf: host swap serves pure "
            "full-attention stacks whose cache is pages + scale lanes only")
    if ax not in (0, 1):
        raise ValueError(f"leaf {name!r}: unexpected rank {leaf.ndim}")
    return ax


def _real_page_bytes(data, n_pages: int):
    """Iterate the checksummed byte ranges: each leaf's first ``n_pages``
    along its page axis, in deterministic flatten order."""
    leaves = jax.tree_util.tree_flatten_with_path(data)[0]
    for path, leaf in leaves:
        ax = page_axis(path, leaf)
        sl = (slice(None),) * ax + (slice(0, n_pages),)
        yield np.ascontiguousarray(leaf[sl]).tobytes()


def checksum_pages(data, n_pages: int) -> int:
    crc = 0
    for chunk in _real_page_bytes(data, n_pages):
        crc = zlib.crc32(chunk, crc)
    return crc


@dataclass
class HostKVEntry:
    rid: int
    n_pages: int          # real pages (data is padded to pow2 beyond this)
    length: int           # live KV rows the pages cover
    data: Any             # pytree of host numpy arrays
    checksum: int
    nbytes: int


def make_transfer_entry(rid: int, data, n_pages: int,
                        length: int) -> HostKVEntry:
    """Package gathered pages into a self-validating transfer buffer.

    The same wire format a swap-out parks in the local tier, but built
    free-standing: the disaggregated hand-off ships these entries from a
    prefill mesh to a decode mesh, and the checksum travels with the
    bytes — whoever installs the entry (see :meth:`HostKVTier.put_entry`)
    verifies on readback, so corruption anywhere in transit surfaces as a
    failed ``get`` on the receiving side.
    """
    host = jax.tree_util.tree_map(lambda x: np.array(x), data)
    nbytes = int(sum(x.nbytes for x in jax.tree_util.tree_leaves(host)))
    return HostKVEntry(rid=rid, n_pages=n_pages, length=length,
                       data=host, checksum=checksum_pages(host, n_pages),
                       nbytes=nbytes)


def corrupt_entry(entry: HostKVEntry) -> None:
    """Flip one byte inside the checksummed span (bit-rot model).  Byte 0
    is element [0, ..., 0] — page index 0 of the gathered data, i.e. the
    first real page: always checksummed."""
    leaf = jax.tree_util.tree_leaves(entry.data)[0]
    leaf.view(np.uint8).flat[0] ^= 0xFF


@dataclass
class HostKVTier:
    """rid -> swapped page data, with checksum-verified readback.

    ``latency_s`` sleeps on every put/get — the chaos harness uses it to
    model a slow staging link and prove the schedule (not just the data)
    tolerates a laggy tier.
    """

    latency_s: float = 0.0
    _entries: Dict[int, HostKVEntry] = field(default_factory=dict)
    bytes_out: int = 0     # cumulative device->host
    bytes_in: int = 0      # cumulative host->device (verified gets)

    def _stall(self) -> None:
        if self.latency_s > 0:
            time.sleep(self.latency_s)

    def put(self, rid: int, data, n_pages: int, length: int) -> HostKVEntry:
        """Own a host copy of the gathered pages (writable — the chaos
        harness corrupts entries in place) and checksum the real-page
        span."""
        self._stall()
        entry = make_transfer_entry(rid, data, n_pages, length)
        self._entries[rid] = entry
        self.bytes_out += entry.nbytes
        return entry

    def put_entry(self, entry: HostKVEntry) -> None:
        """Install a pre-built transfer entry verbatim — checksum and all.
        The disaggregated import path lands prefill pages shipped from
        another mesh here; deliberately NO re-checksum, so damage the
        buffer took in transit is caught by the next :meth:`get` exactly
        like local tier bit-rot."""
        self._stall()
        self._entries[entry.rid] = entry
        self.bytes_out += entry.nbytes

    def get(self, rid: int) -> Tuple[Optional[HostKVEntry], bool]:
        """(entry, ok).  ``ok`` is False when the stored checksum no longer
        matches — the caller must fall back to recompute and :meth:`pop`
        the entry.  The entry stays resident until popped so a failed
        swap-in never loses the (only remaining) eviction record."""
        self._stall()
        entry = self._entries.get(rid)
        if entry is None:
            return None, False
        ok = checksum_pages(entry.data, entry.n_pages) == entry.checksum
        if ok:
            self.bytes_in += entry.nbytes
        return entry, ok

    def pop(self, rid: int) -> None:
        self._entries.pop(rid, None)

    def rids(self) -> list:
        return list(self._entries)

    def clear(self) -> None:
        self._entries.clear()
        self.bytes_out = 0
        self.bytes_in = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, rid: int) -> bool:
        return rid in self._entries

    @property
    def bytes_held(self) -> int:
        return sum(e.nbytes for e in self._entries.values())

    # -- fault injection -------------------------------------------------
    def corrupt(self, rid: int) -> bool:
        """Flip one byte inside the checksummed span of ``rid``'s entry
        (the chaos harness's bit-rot model).  Returns False when the rid
        holds no entry."""
        entry = self._entries.get(rid)
        if entry is None:
            return False
        corrupt_entry(entry)
        return True

"""Continuous-batching serving engine.

Slot-based scheduler over a fixed decode batch: each slot holds one request
at its own position (the per-slot ``pos`` vector the decode step supports).
Prefill runs per-request into the slot's cache region; decode steps run the
whole batch every tick.  The memory system is the product here — KV caches
are the dominant HBM consumer and the advisor classifies their access as the
paper's `nest` (prefill) and `rs_tra` (decode streaming) patterns.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.registry import ModelBundle


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


@dataclass
class ServeStats:
    prefills: int = 0
    decode_steps: int = 0
    tokens_out: int = 0


class ServeEngine:
    """greedy-decodes; batch-uniform architecture state handled per family."""

    def __init__(self, bundle: ModelBundle, params, batch_size: int,
                 max_len: int):
        self.bundle = bundle
        self.params = params
        self.bsz = batch_size
        self.max_len = max_len
        self.cache = bundle.init_cache(batch_size, max_len)
        self.pos = np.zeros((batch_size,), np.int32)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self.stats = ServeStats()
        self._decode = jax.jit(bundle.decode_step, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def add_request(self, req: Request):
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _prefill_into_slot(self, slot: int, req: Request):
        """Prefill a single request, then scatter its cache into the batch
        cache at ``slot``.  Stacked leaves (under blocks/dec) carry batch at
        axis 1; remainder leaves at axis 0.  Shorter prompt caches are padded
        (zeros for k/v — masked by kv_valid_len; -1e9 for kpos = empty)."""
        cache1, last_logits = self.bundle.prefill(
            self.params, dict(tokens=req.prompt[None, :]))
        s = req.prompt.shape[0]

        def place(path, tgt, upd):
            names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
            batch_ax = 1 if any(n in ("blocks", "dec") for n in names) else 0
            for ax in range(upd.ndim):
                if ax != batch_ax and upd.shape[ax] != tgt.shape[ax]:
                    pad = [(0, 0)] * upd.ndim
                    pad[ax] = (0, tgt.shape[ax] - upd.shape[ax])
                    cv = -10**9 if upd.dtype == jnp.int32 else 0
                    upd = jnp.pad(upd, pad, constant_values=cv)
            return jax.lax.dynamic_update_slice_in_dim(
                tgt, upd.astype(tgt.dtype), slot, batch_ax)

        self.cache = jax.tree_util.tree_map_with_path(place, self.cache, cache1)
        self.slots[slot] = req
        self.pos[slot] = s
        req.out_tokens.append(int(np.argmax(np.asarray(last_logits)[0])))
        self.stats.prefills += 1
        self.stats.tokens_out += 1

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Admit queued requests, run one decode tick.  False when idle."""
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                break
            self._prefill_into_slot(slot, self.queue.pop(0))

        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return False

        tokens = np.zeros((self.bsz, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].out_tokens[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.pos))
        self.stats.decode_steps += 1
        nxt = np.argmax(np.asarray(logits), axis=-1)
        for i in active:
            req = self.slots[i]
            req.out_tokens.append(int(nxt[i]))
            self.pos[i] += 1
            self.stats.tokens_out += 1
            if req.done or self.pos[i] >= self.max_len - 1:
                self.slots[i] = None
                self.pos[i] = 0
        return True

    def run_to_completion(self, max_ticks: int = 10_000) -> ServeStats:
        for _ in range(max_ticks):
            if not self.step() and not self.queue:
                break
        return self.stats

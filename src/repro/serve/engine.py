"""Continuous-batching serving engine with a device-resident decode path.

Slot-based scheduler over a fixed decode batch: each slot holds one request
at its own position (the per-slot ``pos`` vector the decode step supports).
Prefill runs per-request into the slot's cache region; decode runs the whole
batch in fused multi-tick *windows*.

The fast path is the paper's §5 pointer-chase fix applied to our own
scheduler: the old engine paid one host round-trip per generated token
(dispatch decode, pull logits to host, argmax, push the token back — a
dependent-load chain over PCIe, the `chase` pattern).  Now greedy sampling
is fused into the decode dispatch, tokens/positions stay device arrays, and
``decode_many(n)`` runs n ticks under one ``lax.fori_loop`` jit — one
dispatch and one device->host transfer (the token block) per *window*, not
per token.  Prompt lengths are bucketed to powers of two before prefill so
continuous batching stops retracing per distinct prompt length.

The memory system is the product here — KV caches are the dominant HBM
consumer and the advisor classifies their access as the paper's `nest`
(prefill) and `rs_tra` (decode streaming) patterns.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN
from repro.models.registry import ModelBundle


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


@dataclass
class ServeStats:
    prefills: int = 0
    decode_steps: int = 0            # device decode ticks executed
    tokens_out: int = 0
    decode_dispatches: int = 0       # fused decode_many launches (host syncs)
    prefill_retraces: int = 0        # distinct prefill shapes compiled


class ServeEngine:
    """greedy-decodes; batch-uniform architecture state handled per family.

    ``window`` is the fused decode chunk: ``run_to_completion`` advances all
    active slots up to ``window`` tokens per dispatch.  ``bucket_prompts``
    pads prompts to the next power of two before prefill (defaults to on for
    pure full-attention decoders, where right-padding is provably masked;
    recurrent/windowed/enc-dec families keep exact lengths).
    """

    def __init__(self, bundle: ModelBundle, params, batch_size: int,
                 max_len: int, *, window: int = 8,
                 bucket_prompts: Optional[bool] = None):
        self.bundle = bundle
        self.params = params
        self.bsz = batch_size
        self.max_len = max_len
        self.window = max(1, window)
        self.cache = bundle.init_cache(batch_size, max_len)
        self.pos = jnp.zeros((batch_size,), jnp.int32)       # device
        self.tokens = jnp.zeros((batch_size, 1), jnp.int32)  # device
        self._hpos = np.zeros((batch_size,), np.int64)       # host mirror
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.queue: List[Request] = []
        self.stats = ServeStats()
        self.bucket_prompts = (self._bucketable(bundle.cfg)
                               if bucket_prompts is None else bucket_prompts)
        self._seen_prefill_shapes = set()
        self._prefill = jax.jit(
            lambda p, toks, vl: bundle.prefill(
                p, dict(tokens=toks, valid_len=vl)))
        self._decode_many = jax.jit(
            functools.partial(_decode_many_impl, bundle),
            static_argnums=(0,), donate_argnums=(2,))

    def reset(self) -> None:
        """Clear all serving state (cache, slots, queue, stats) but KEEP the
        compiled prefill/decode callables and their trace caches — benchmark
        drivers drain once to warm the jit caches, reset, then time a
        steady-state drain."""
        self.cache = self.bundle.init_cache(self.bsz, self.max_len)
        self.pos = jnp.zeros((self.bsz,), jnp.int32)
        self.tokens = jnp.zeros((self.bsz, 1), jnp.int32)
        self._hpos[:] = 0
        self.slots = [None] * self.bsz
        self.queue = []
        self.stats = ServeStats()
        # _seen_prefill_shapes survives: those shapes remain compiled, so a
        # post-reset drain reports only genuinely new compiles

    @staticmethod
    def _bucketable(cfg) -> bool:
        """Right-padding is mask-safe only when every mixer is full causal
        attention: windowed ring caches would evict real tokens for pad, and
        recurrent state (ssd/rglru) would absorb the pad tokens."""
        if cfg.enc_dec or cfg.frontend:
            return False
        specs = tuple(cfg.layer_pattern) + tuple(cfg.remainder_specs)
        return all(s.mixer == ATTN and s.sliding_window is None
                   for s in specs)

    # ------------------------------------------------------------------
    def add_request(self, req: Request):
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _prefill_into_slot(self, slot: int, req: Request):
        """Prefill a single request, then scatter its cache into the batch
        cache at ``slot``.  Stacked leaves (under blocks/dec) carry batch at
        axis 1; remainder leaves at axis 0.  Shorter prompt caches are padded
        (zeros for k/v — masked by kv_valid_len; -1e9 for kpos = empty)."""
        s = int(req.prompt.shape[0])
        if self.bucket_prompts:
            bucket = min(_next_pow2(max(8, s)), self.max_len)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :s] = req.prompt
            if bucket not in self._seen_prefill_shapes:
                self._seen_prefill_shapes.add(bucket)
                self.stats.prefill_retraces += 1
            cache1, last_logits = self._prefill(
                self.params, jnp.asarray(padded), jnp.int32(s))
        else:
            if s not in self._seen_prefill_shapes:
                self._seen_prefill_shapes.add(s)
                self.stats.prefill_retraces += 1
            cache1, last_logits = self.bundle.prefill(
                self.params, dict(tokens=req.prompt[None, :]))

        def place(path, tgt, upd):
            names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
            batch_ax = 1 if any(n in ("blocks", "dec") for n in names) else 0
            for ax in range(upd.ndim):
                if ax != batch_ax and upd.shape[ax] != tgt.shape[ax]:
                    pad = [(0, 0)] * upd.ndim
                    pad[ax] = (0, tgt.shape[ax] - upd.shape[ax])
                    cv = -10**9 if upd.dtype == jnp.int32 else 0
                    upd = jnp.pad(upd, pad, constant_values=cv)
            return jax.lax.dynamic_update_slice_in_dim(
                tgt, upd.astype(tgt.dtype), slot, batch_ax)

        self.cache = jax.tree_util.tree_map_with_path(place, self.cache, cache1)
        self.slots[slot] = req
        self.pos = self.pos.at[slot].set(s)
        self._hpos[slot] = s
        tok0 = int(np.argmax(np.asarray(last_logits)[0]))
        self.tokens = self.tokens.at[slot, 0].set(tok0)
        req.out_tokens.append(tok0)
        self.stats.prefills += 1
        self.stats.tokens_out += 1

    def _admit(self) -> None:
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                break
            self._prefill_into_slot(slot, self.queue.pop(0))

    # ------------------------------------------------------------------
    def _budgets(self, n: int) -> np.ndarray:
        """Per-slot token budget for an n-tick window: remaining request
        quota, capped by the cache length guard."""
        budgets = np.zeros((self.bsz,), np.int64)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            remaining = req.max_new_tokens - len(req.out_tokens)
            cap = self.max_len - 1 - self._hpos[i]
            budgets[i] = max(0, min(remaining, cap, n))
        return budgets

    def decode_many(self, n: int) -> int:
        """Run up to ``n`` decode ticks in ONE fused dispatch (greedy
        sampling on device, per-slot budgets masked in-loop), then harvest
        the produced token block with a single device->host transfer.
        Returns the number of real tokens produced."""
        budgets = self._budgets(n)
        for i, req in enumerate(self.slots):
            if req is not None and budgets[i] == 0:
                # done already (e.g. max_new_tokens=1 satisfied by prefill)
                # or pinned at the cache-length guard: retire the slot now,
                # otherwise it would never advance and never free
                self.slots[i] = None
        top = int(budgets.max(initial=0))
        if top == 0:
            return 0
        n_run = min(n, _next_pow2(top))  # pow2 ticks: bounded trace count
        steps = jnp.asarray(np.minimum(budgets, n_run), jnp.int32)
        self.cache, self.tokens, self.pos, out = self._decode_many(
            n_run, self.params, self.cache, self.tokens, self.pos, steps)
        self.stats.decode_steps += n_run
        self.stats.decode_dispatches += 1

        out_np = np.asarray(out)  # (n_run, B) — the one host sync
        produced = 0
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            adv = int(min(budgets[i], n_run))
            req.out_tokens.extend(int(t) for t in out_np[:adv, i])
            self._hpos[i] += adv
            produced += adv
            if req.done or self._hpos[i] >= self.max_len - 1:
                self.slots[i] = None
        self.stats.tokens_out += produced
        return produced

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Admit queued requests, run one decode tick.  False when idle.
        (Compatibility wrapper: one-tick window of the fused path.)"""
        self._admit()
        if not any(s is not None for s in self.slots):
            return False
        self.decode_many(1)
        return True

    def run_to_completion(self, max_ticks: int = 10_000) -> ServeStats:
        """Serve until queue and slots drain; ``max_ticks`` bounds the device
        decode ticks executed (``ServeStats.decode_steps``)."""
        start = self.stats.decode_steps
        while self.stats.decode_steps - start < max_ticks:
            self._admit()
            if not any(s is not None for s in self.slots):
                break
            # decode_many always makes progress: it produces tokens or
            # retires every zero-budget slot, so this loop cannot spin
            self.decode_many(self.window)
        return self.stats


def _decode_many_impl(bundle: ModelBundle, n: int, params, cache, tokens,
                      pos, steps):
    """n fused greedy-decode ticks.  ``steps`` (B,) caps each slot: past its
    budget a slot is masked — tokens/pos freeze, and its (discarded) cache
    writes re-store the same k/v at the frozen position, which is idempotent.
    Returns (cache, tokens, pos, out) with out (n, B) int32 (-1 = masked)."""
    bsz = tokens.shape[0]

    def body(i, carry):
        cache, tokens, pos, out = carry
        logits, cache = bundle.decode_step(params, cache, tokens, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B,)
        act = i < steps
        tokens = jnp.where(act[:, None], nxt[:, None], tokens)
        pos = jnp.where(act, pos + 1, pos)
        out = out.at[i].set(jnp.where(act, nxt, -1))
        return cache, tokens, pos, out

    out0 = jnp.full((n, bsz), -1, jnp.int32)
    return jax.lax.fori_loop(0, n, body, (cache, tokens, pos, out0))

"""Continuous-batching serving engine with a device-resident decode path.

Slot-based scheduler over a fixed decode batch: each slot holds one request
at its own position (the per-slot ``pos`` vector the decode step supports).
Two interchangeable KV backends:

- ``dense`` — the classic per-slot ``(batch, max_len)`` cache: prefill runs
  per-request into the slot's cache region, decode gathers dense rows.
- ``paged`` (default wherever the stack supports it) — vLLM-style
  continuous batching over a shared :class:`~repro.serve.kvcache.
  PageAllocator` pool: prefill appends k/v into fixed-size pages *in
  chunks* (a long prompt can no longer stall the decode tick), the decode
  fast path dispatches the ``paged_attention`` kernel against a
  device-resident ``(batch, max_pages)`` table, and finished requests
  release pages immediately — admission is bounded by live tokens, not
  ``batch x max_len``.  Common prompt prefixes share read-only pages
  (hash-chained prefix cache); pool exhaustion becomes backpressure
  (requests stay queued), never a crash.

The fast path is the paper's §5 pointer-chase fix applied to our own
scheduler: token selection — greedy argmax or full temperature/top-k/top-p
sampling (:class:`~repro.serve.sampling.SamplingParams`, per-slot PRNG
keys carried as device arrays) — is fused into the decode dispatch, tokens
and positions stay device arrays, and ``decode_many(n)`` runs n ticks
under one ``lax.fori_loop`` jit — one dispatch and one device->host
transfer (the token block) per *window*, not per token.  The page size
itself is a tuned knob: :func:`repro.tune.derive_paged_plan` derives it
from the advisor's ``unit_bytes >= 512B`` transaction-optimum rule, so
calibration reshapes the pool exactly the way it reshapes attention
blocks.

Speculative decoding (``draft_bundle``) rides the paged fast path: a
small draft model proposes ``spec_k`` tokens per dispatch from a dense
per-slot cache, the target verifies all of them in ONE batched
``paged_extend`` read over the page tables (``paged_verify`` — the
paper's burst-length lever: k+1 query positions amortize one table
walk), and rejected suffixes roll back page-table state
(:meth:`PageAllocator.truncate`) and per-slot keys.  Acceptance uses
*coupled* sampling: the target's sample at each position is drawn with
the same per-position subkey the vanilla fused loop would have used (one
split per emitted token), and a draft token is accepted only when it
equals that sample — so the emitted stream is bit-identical to the
non-speculative engine, greedy and sampled alike, and trivially
distribution-preserving.

The memory system is the product here — KV caches are the dominant HBM
consumer and the advisor classifies their access as the paper's `nest`
(prefill), `rs_tra` (dense decode streaming) and `r_acc` (paged table
indirection) patterns.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN
from repro.core.memmodel import next_pow2
from repro.models.registry import ModelBundle
from repro.serve.hosttier import (HostKVEntry, HostKVTier, make_transfer_entry,
                                  page_axis)
from repro.serve.kvcache import (PageAllocator, PoolExhausted, PrefixIndex,
                                 page_hashes)
from repro.serve.sampling import (GREEDY, SamplingParams, sample_token,
                                  sample_tokens, split_keys, subkey_chain)
from repro.serve.scheduler import Scheduler, SwapCostModel, VictimInfo


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (S,) int32
    max_new_tokens: int = 16
    priority: int = 0                # scheduler class: higher admits first
    deadline: Optional[int] = None   # cluster virtual-clock round to finish
                                     # by; None = no SLO (never shed)
    out_tokens: List[int] = field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens


@dataclass
class _Resume:
    """What a preempted request needs to pick up exactly where it left
    off.  ``ctx`` is the KV context (``prompt ++ out_tokens[:-1]``) whose
    rows the resume must restore — by re-prefilling it (``recompute``,
    prefix cache serving the surviving prompt pages) or by streaming the
    swapped pages back (``swap``; the page data lives in the host tier).
    ``pending`` is ``out_tokens[-1]``: the already-emitted token the next
    decode tick feeds, so resume must NOT re-seed from prefill logits."""

    kind: str                        # "swap" | "recompute"
    ctx: np.ndarray                  # (hpos,) int32
    pending: int


@dataclass
class ServeStats:
    prefills: int = 0                # requests fully prefilled
    decode_steps: int = 0            # device decode ticks executed
    tokens_out: int = 0
    decode_dispatches: int = 0       # fused decode_many launches (host syncs)
    prefill_retraces: int = 0        # distinct prefill shapes compiled
    # -- paged backend ----------------------------------------------------
    prefill_chunks: int = 0          # chunked-prefill dispatches
    prompt_tokens: int = 0           # prompt tokens admitted
    prefix_hit_tokens: int = 0       # prompt tokens served from shared pages
    pages_peak: int = 0              # peak full-pool pages_in_use over the run
    ring_pages_peak: int = 0         # peak ring-pool pages_in_use (windowed)
    pool_stalls: int = 0             # admissions deferred by PoolExhausted
    # -- speculative decoding ---------------------------------------------
    spec_steps: int = 0              # draft->verify dispatches
    draft_tokens: int = 0            # draft tokens proposed to the verifier
    draft_accepted: int = 0          # proposals matching the coupled sample
    # -- scheduler / preemption ---------------------------------------------
    preemptions: int = 0             # mid-flight evictions (all modes)
    preempt_restarts: int = 0        # mid-prefill victims requeued from scratch
    swap_outs: int = 0               # victims whose pages moved to the host tier
    swap_ins: int = 0                # resumes streamed back through the table
    swap_bytes: int = 0              # bytes moved across the host tier, both ways
    recompute_resumes: int = 0       # resumes that re-prefilled their context
    swap_fallbacks: int = 0          # checksum-failed swaps recovered by recompute
    prefill_burst_max: int = 0       # max prefill chunks between decode windows
    # -- disaggregated prefill/decode ---------------------------------------
    prefill_exports: int = 0         # finished prefills shipped off this engine
    prefill_imports: int = 0         # shipped prefills landed into decode slots
    transfer_bytes: int = 0          # bytes crossing the prefill->decode link
    transfer_fallbacks: int = 0      # corrupted transfers recovered by recompute

    @property
    def accept_rate(self) -> float:
        """Fraction of proposed draft tokens the target accepted."""
        return self.draft_accepted / max(1, self.draft_tokens)

    @property
    def accepted_per_step(self) -> float:
        """Mean accepted draft tokens per verify dispatch: the speedup
        knob — every accepted token is a serial target pass amortized
        into the batched verify read."""
        return self.draft_accepted / max(1, self.spec_steps)


class ServeEngine:
    """Continuous-batching engine; batch-uniform architecture state handled
    per family.  Token selection is fused on device: greedy argmax by
    default, or temperature/top-k/top-p sampling via ``sampling`` with
    per-slot PRNG keys derived as ``fold_in(PRNGKey(seed), rid)`` — a
    slot's stream depends only on the request, never on scheduling, and
    masked/pending/budget-exhausted slots consume no PRNG state.

    ``window`` is the fused decode chunk: ``run_to_completion`` advances all
    active slots up to ``window`` tokens per dispatch.  ``bucket_prompts``
    pads prompts (dense) / prefill chunks (paged) to the next power of two
    (defaults to on for pure full-attention decoders, where right-padding
    is provably masked; recurrent/windowed/enc-dec families keep exact
    lengths).  ``cache_backend`` is ``"dense"``, ``"paged"``, or ``None``
    (auto: paged wherever :meth:`ModelBundle.paged_supported` allows).

    Paged knobs: ``page_size=None`` derives from the tuned
    :class:`~repro.tune.KernelPlan` (int8 KV halves the unit size, so the
    derived page doubles in tokens); ``num_pages=None`` sizes the
    full-attention pool at the dense footprint plus the reserved null page
    — shrink it to admit by live tokens and exercise backpressure, grow it
    to persist more prefix cache.  ``num_ring_pages=None`` sizes the
    windowed-layer ring pool at ``batch x (ceil(window/page)+1)`` rotating
    pages — the constant-memory bound however long windowed sequences run.
    ``prefill_chunk`` caps prompt tokens per prefill dispatch so decode
    ticks interleave with long prompts.

    Speculative decoding: pass ``draft_bundle``/``draft_params`` (a small
    pure full-attention decoder sharing the target's vocab) and the paged
    engine switches ``decode_many`` to draft->verify dispatches of up to
    ``spec_k`` proposed tokens each.  The emitted stream is bit-identical
    to the non-speculative engine (coupled-sample verification), so the
    draft only changes *throughput*, never output.  Requires a pure
    full-attention target stack: ring rotation and recurrent state cannot
    roll back a rejected suffix.

    Tensor parallelism: pass ``dist`` (a :class:`repro.dist.ServeMesh`)
    and this ONE engine spans the mesh — params shard by the ``tp``
    policy, the KV page pools split on their kv-heads dim (every shard
    holds its head-stripe of every page; one global page-id space, tables
    replicated), and the paged dispatches run as ``shard_map`` islands.
    Logits are all-gathered before token selection, so a TP=N drain is
    token-identical to the single-device engine — greedy, sampled, and
    speculative.  Requires ``cache_backend="paged"`` and ``tp`` dividing
    both head counts.  DP is a scheduling concern, not an engine one:
    see ``launch/serve.py:ReplicaPool``.
    """

    def __init__(self, bundle: ModelBundle, params, batch_size: int,
                 max_len: int, *, window: int = 8,
                 bucket_prompts: Optional[bool] = None,
                 cache_backend: Optional[str] = None,
                 page_size: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 num_ring_pages: Optional[int] = None,
                 prefill_chunk: int = 32,
                 prefix_cache: bool = True,
                 sampling: Optional[SamplingParams] = None,
                 seed: int = 0,
                 draft_bundle: Optional[ModelBundle] = None,
                 draft_params=None,
                 spec_k: int = 4,
                 dist=None,
                 scheduler: Optional[Scheduler] = None,
                 host_tier: Optional[HostKVTier] = None):
        self.bundle = bundle
        self.params = params
        self.bsz = batch_size
        self.max_len = max_len
        self.window = max(1, window)
        self.sampling = sampling or GREEDY
        self.seed = seed
        self._base_key = jax.random.PRNGKey(seed)
        self.draft = draft_bundle
        self.draft_params = draft_params
        self.spec_k = max(1, spec_k)
        if cache_backend is None:
            cache_backend = "paged" if bundle.paged_supported() else "dense"
        elif cache_backend not in ("dense", "paged"):
            raise ValueError(f"unknown cache_backend {cache_backend!r}")
        elif cache_backend == "paged" and not bundle.paged_supported():
            raise ValueError(
                f"{bundle.cfg.name}: paged KV serves decoder-only stacks "
                "(enc-dec and frontend stacks keep the dense cache; see "
                "ModelBundle.paged_supported)")
        self.backend = cache_backend
        # -- tensor parallelism (dist = a repro.dist.ServeMesh) ------------
        # one engine spans the mesh: params shard by the tp policy, the KV
        # page pools split on their kv-heads dim, page tables + sampling
        # state replicate, and the paged dispatches run as shard_map
        # islands.  The host-side allocator keeps ONE global page-id space,
        # so every bit of scheduling below is mesh-oblivious.
        self.dist = dist
        self.tp = 1
        if dist is not None:
            if self.backend != "paged":
                raise ValueError(
                    "dist serving shards the KV page pools; "
                    "cache_backend='paged' is required")
            dist.validate(bundle.cfg)
            bundle = self.bundle = dist.bind(bundle)
            params = self.params = dist.shard_params(bundle, params)
            if draft_bundle is not None:
                dist.validate(draft_bundle.cfg)
                draft_bundle = self.draft = dist.bind(draft_bundle)
                if draft_params is not None:
                    draft_params = self.draft_params = dist.shard_params(
                        draft_bundle, draft_params)
            self.tp = dist.tp_degree
        self.bucket_prompts = (self._bucketable(bundle.cfg)
                               if bucket_prompts is None else bucket_prompts)

        if self.backend == "paged":
            cfg = bundle.cfg
            specs = tuple(cfg.layer_pattern) + tuple(cfg.remainder_specs)
            attn = [s for s in specs if s.mixer == ATTN]
            self.has_full = any(s.sliding_window is None for s in attn)
            windows = [s.sliding_window for s in attn
                       if s.sliding_window is not None]
            # the ring is sized by the largest window (smaller windows mask
            # more); a window past max_len degenerates to hold-everything
            self.attn_window = (min(max(windows), max_len)
                                if windows else None)
            self.has_recurrent = any(s.mixer != ATTN for s in specs)
            hd = cfg.resolved_head_dim
            from repro.tune import plan_for
            # int8 pages halve the unit size, so the transaction-optimum
            # page (the r_acc >= 512B rule) doubles in tokens — derive the
            # plan from the dtype the pool actually stores
            kv_store = ("int8" if bundle.flags.kv_dtype == "int8"
                        else str(cfg.compute_dtype))
            # under TP the plan cache keys by the PER-SHARD kv-head count:
            # each shard's kernel walks its own pool slice, so a calibrated
            # multi-device host derives its plan independently of the
            # single-device one (page geometry itself is per-head-row and
            # does not change)
            sig = ((max_len, hd) if self.tp == 1
                   else (max_len, hd, cfg.num_kv_heads // self.tp))
            base = plan_for("paged_attention", shape_sig=sig, dtype=kv_store)
            self.page = int(page_size or base.page_size)
            # an explicit page_size overrides the derived one; the plan the
            # kernel receives must describe the pool actually laid out
            self.plan = (base if base.page_size == self.page
                         else dataclasses.replace(base, bkv=self.page))
            self.pages_per_seq = (-(-max_len // self.page)
                                  if self.has_full else 0)
            self.ring_slots = (-(-self.attn_window // self.page) + 1
                               if self.attn_window is not None else 0)
            # dense-footprint default + the reserved null page (id 0) that
            # padded table entries target, so masked writes stay harmless
            self.num_pages = int(num_pages
                                 or 1 + batch_size * self.pages_per_seq)
            self.num_ring_pages = int(num_ring_pages
                                      or 1 + batch_size * self.ring_slots)
            self.prefill_chunk = max(8, prefill_chunk)
            # prefix pages are only reusable when the WHOLE stack reads
            # them: ring layers rotate prefix tokens away and recurrent
            # state is never cached, so sharing is a pure-full-attn move
            pure_full = self.has_full and not windows and not self.has_recurrent
            self.prefix: Optional[PrefixIndex] = (
                PrefixIndex() if prefix_cache and pure_full else None)
            def _prefill_impl(p, cache, toks, off, tbl, cv, slot,
                              bundle=bundle):
                cache, logits = bundle.paged_prefill_chunk(
                    p, cache, toks, off, tbl, cv, slot)
                return cache, _gather_logits(bundle, logits)

            self._paged_prefill = jax.jit(_prefill_impl, donate_argnums=(1,))
            self._paged_decode_many = jax.jit(
                functools.partial(_paged_decode_many_impl, bundle, self.plan,
                                  self.sampling),
                static_argnums=(0,), donate_argnums=(2,))
        else:
            self._prefill = jax.jit(
                lambda p, toks, vl: bundle.prefill(
                    p, dict(tokens=toks, valid_len=vl)))
            self._decode_many = jax.jit(
                functools.partial(_decode_many_impl, bundle, self.sampling),
                static_argnums=(0,), donate_argnums=(2,))
        if draft_bundle is not None:
            self._init_spec(draft_bundle)
        # -- scheduler: priority admission + mid-flight preemption ---------
        # swap-resume needs whole-page state capture, which only pure
        # full-attention stacks offer (ring rotation and recurrent state
        # are not in the full pool); everything else resumes by recompute.
        self.sched = scheduler or Scheduler()
        self._swappable = (self.backend == "paged" and self.has_full
                           and self.attn_window is None
                           and not self.has_recurrent)
        self.host_tier: Optional[HostKVTier] = None
        if self._swappable and self.sched.config.swap:
            self.host_tier = host_tier or HostKVTier()
        self._seen_prefill_shapes = set()
        self._init_state()
        if self.host_tier is not None:
            self._gather_pages = jax.jit(_gather_pages_impl)
            # pin the scatter's output sharding under TP so a swap-in
            # cannot silently replicate the pools
            if self.dist is None:
                self._scatter_pages = jax.jit(_scatter_pages_impl,
                                              donate_argnums=(0,))
            else:
                self._scatter_pages = jax.jit(
                    _scatter_pages_impl, donate_argnums=(0,),
                    out_shardings=self.dist.page_swap_shardings(self.cache))

    def _init_spec(self, draft: ModelBundle) -> None:
        """Validate + compile the speculative draft->verify dispatch."""
        cfg = self.bundle.cfg
        if self.draft_params is None:
            raise ValueError("draft_bundle needs draft_params")
        if self.backend != "paged":
            raise ValueError(
                "speculative decoding rides the paged fast path; "
                "cache_backend='paged' is required")
        if not (self.has_full and self.attn_window is None
                and not self.has_recurrent):
            raise ValueError(
                f"{cfg.name}: speculative verify needs suffix rollback, "
                "which only pure full-attention page tables support (ring "
                "rotation overwrites history and recurrent state cannot "
                "rewind)")
        if draft.cfg.vocab_size != cfg.vocab_size:
            raise ValueError(
                f"draft vocab {draft.cfg.vocab_size} != target vocab "
                f"{cfg.vocab_size}: proposals must share the token space")
        if not self._bucketable(draft.cfg):
            raise ValueError(
                f"{draft.cfg.name}: the draft must be a pure full-attention "
                "decoder — its rollback is a position rewind over a dense "
                "cache, which windows/recurrence cannot mask")
        from repro.tune import plan_for
        kv_store = ("int8" if self.bundle.flags.kv_dtype == "int8"
                    else str(cfg.compute_dtype))
        vsig = (self.spec_k + 1, self.max_len, cfg.resolved_head_dim)
        if self.tp > 1:  # keyed per shard, like the decode plan
            vsig += (cfg.num_kv_heads // self.tp,)
        vplan = plan_for("paged_verify", shape_sig=vsig, dtype=kv_store)
        # the verify step reads the pool the engine laid out: an explicit
        # page_size override must reach the verify plan too
        self.vplan = (vplan if vplan.page_size == self.page
                      else dataclasses.replace(vplan, bkv=self.page))
        self._draft_prefill = jax.jit(
            lambda p, toks, vl: self.draft.prefill(
                p, dict(tokens=toks, valid_len=vl)))
        self._spec_decode = jax.jit(
            functools.partial(_spec_decode_many_impl, self.bundle, self.draft,
                              self.vplan, self.sampling, self.spec_k),
            donate_argnums=(2, 3))

    def _init_state(self) -> None:
        self.pos = self._dev(jnp.zeros((self.bsz,), jnp.int32))
        self.tokens = self._dev(jnp.zeros((self.bsz, 1), jnp.int32))
        # per-slot PRNG keys (device): set at admission from (seed, rid),
        # advanced one split per emitted token inside the fused loops.
        # Under TP they replicate across the mesh — token selection runs on
        # all-gathered logits, so every shard walks the same chain
        self.keys = self._dev(jnp.zeros((self.bsz, 2), jnp.uint32))
        self._hpos = np.zeros((self.bsz,), np.int64)       # host mirror
        if self.draft is not None:
            self.draft_cache = self._dev(
                self.draft.init_cache(self.bsz, self.max_len))
        self.slots: List[Optional[Request]] = [None] * self.bsz
        self.queue: List[Request] = []
        self.stats = ServeStats()
        # scheduler state: resume records for preempted requests, arrival
        # sequence (priority ties admit FIFO), and the chunks-since-decode
        # counter behind stats.prefill_burst_max
        self._resume: Dict[int, _Resume] = {}
        self._arrival: Dict[int, int] = {}
        self._arrival_seq = 0
        self._chunks_since_decode = 0
        # rids whose pending swap-resume is a cross-mesh prefill import
        # (counts against the transfer stats, not the local swap stats)
        self._transfer_rids: set = set()
        if self.host_tier is not None:
            self.host_tier.clear()
        if self.backend == "paged":
            self.alloc = (PageAllocator(self.num_pages, self.page, reserved=1)
                          if self.has_full else None)
            self.ralloc = (PageAllocator(self.num_ring_pages, self.page,
                                         reserved=1, window=self.attn_window)
                           if self.attn_window is not None else None)
            if self.prefix is not None:
                self.prefix = PrefixIndex()
            self.cache = self.bundle.init_paged_cache(
                self.num_pages if self.has_full else 1, self.page,
                batch=self.bsz,
                ring_pages=self.num_ring_pages)
            if self.dist is not None:
                # the per-shard pool slice: same page ids on every shard,
                # each holding its own kv-heads stripe of every page
                self.cache = self.dist.shard_paged_cache(self.cache)
            self._htable = np.zeros((self.bsz, max(1, self.pages_per_seq)),
                                    np.int32)
            self._hrtable = np.zeros((self.bsz, max(1, self.ring_slots)),
                                     np.int32)
            self._sync_table()
            self._pending: Dict[int, int] = {}   # slot -> next prefill offset
            self._hashes: Dict[int, List[str]] = {}  # rid -> full-page hashes
        else:
            self.cache = self.bundle.init_cache(self.bsz, self.max_len)

    def reset(self) -> None:
        """Clear all serving state (cache, pool, slots, queue, stats —
        including the speculative accept-rate counters and the per-slot
        PRNG keys, both rebuilt from scratch in ``_init_state`` — plus
        resume records and the host swap tier) but KEEP the compiled
        prefill/decode callables and their trace caches — benchmark
        drivers drain once to warm the jit caches, reset, then time a
        steady-state drain.  A warm drain after a preempted one therefore
        starts with zeroed accept-rate stats and virgin key state."""
        self._init_state()
        # _seen_prefill_shapes survives: those shapes remain compiled, so a
        # post-reset drain reports only genuinely new compiles

    def _dev(self, x):
        """Place host/engine state on the mesh (replicated) under TP; a
        no-op single-device."""
        return x if self.dist is None else self.dist.replicated(x)

    def _sync_table(self) -> None:
        """Publish the host table mirrors as the device table dict (page
        tables replicate across the mesh — page ids are global)."""
        self._table = dict(full=self._dev(jnp.asarray(self._htable)),
                           ring=self._dev(jnp.asarray(self._hrtable)))
        self._table_dirty = False

    @staticmethod
    def _bucketable(cfg) -> bool:
        """Right-padding is mask-safe only when every mixer is full causal
        attention: windowed ring caches would evict real tokens for pad, and
        recurrent state (ssd/rglru) would absorb the pad tokens."""
        if cfg.enc_dec or cfg.frontend:
            return False
        specs = tuple(cfg.layer_pattern) + tuple(cfg.remainder_specs)
        return all(s.mixer == ATTN and s.sliding_window is None
                   for s in specs)

    # ------------------------------------------------------------------
    # bookkeeping views (benchmarks / examples)
    # ------------------------------------------------------------------
    def kv_bytes(self) -> int:
        """Allocated HBM bytes of the KV cache pytree (both backends)."""
        return int(sum(x.size * x.dtype.itemsize
                       for x in jax.tree_util.tree_leaves(self.cache)))

    def _page_bytes_by_kind(self, per_shard: bool = False):
        """(full, ring) HBM bytes of ONE page summed over every layer of
        that kind (k + v, plus the int8 scale lanes).  ``per_shard``
        reports one TP shard's slice: the pools split on kv-heads, so page
        bytes divide by ``tp``; the scale lanes replicate (they are
        per-token, reduced over heads) and do not."""
        cfg = self.bundle.cfg
        nb = cfg.num_pattern_blocks
        n_full = n_ring = 0
        for spec, mult in ([(s, nb) for s in cfg.layer_pattern]
                           + [(s, 1) for s in cfg.remainder_specs]):
            if spec.mixer != ATTN:
                continue
            if spec.sliding_window is None:
                n_full += mult
            else:
                n_ring += mult
        int8 = self.bundle.flags.kv_dtype == "int8"
        itm = 1 if int8 else jnp.dtype(cfg.compute_dtype).itemsize
        heads = cfg.num_kv_heads // (self.tp if per_shard else 1)
        per_layer = (2 * self.page * heads
                     * cfg.resolved_head_dim * itm
                     + (2 * self.page * 4 if int8 else 0))
        return n_full * per_layer, n_ring * per_layer

    @property
    def bytes_per_page(self) -> int:
        """One page across every layer pool of its kind (k + v)."""
        assert self.backend == "paged"
        full_pb, ring_pb = self._page_bytes_by_kind()
        return full_pb or ring_pb

    def _recurrent_state_bytes(self) -> int:
        """Dense per-slot recurrent state (hybrid stacks): always live."""
        full_pb, ring_pb = self._page_bytes_by_kind()
        pools = ((self.num_pages * full_pb if self.has_full else 0)
                 + (self.num_ring_pages * ring_pb if self.ralloc else 0))
        return self.kv_bytes() - pools

    def live_kv_bytes_peak(self, per_shard: bool = False) -> int:
        """Peak *live-token* HBM bytes: what the cache actually held, vs the
        ``batch x max_len`` footprint the dense backend commits upfront.
        Ring layers are the headline win: however long a windowed sequence
        runs, its pages stay bounded by ``ceil(window/page)+1``.
        ``per_shard`` reports one TP shard's slice (pool bytes divide by
        the mesh width; replicated recurrent state does not) — the
        per-channel footprint in the paper's multi-bank framing."""
        if self.backend == "paged":
            full_pb, ring_pb = self._page_bytes_by_kind(per_shard)
            return (self.stats.pages_peak * full_pb
                    + self.stats.ring_pages_peak * ring_pb
                    + self._recurrent_state_bytes())
        return self.kv_bytes()

    # ------------------------------------------------------------------
    def add_request(self, req: Request):
        if req.rid not in self._arrival:
            self._arrival[req.rid] = self._arrival_seq
            self._arrival_seq += 1
        self.queue.append(req)

    def adopt(self, req: Request) -> None:
        """Admit a request that may already be mid-stream — the cluster
        failover path.  A request evacuated from another replica carries
        its emitted tokens on the host-side :class:`Request`; adoption
        installs the recompute-resume record an in-engine preemption
        would have left (re-prefill ``prompt ++ emitted[:-1]``, re-feed
        — never re-sample — the pending last token, replay the
        ``(seed, rid)`` PRNG chain past the emitted prefix).  Because
        that chain depends only on the request and the engine seed, a
        drain finished *here* is bitwise the one the failed replica
        would have produced.  Fresh requests fall through to plain
        :meth:`add_request`."""
        if req.done:
            # already at its token budget: there is nothing left to run —
            # adopting it into a slot would re-prefill a finished request.
            # The caller keeps the (complete) Request object; no-op here.
            return
        if req.out_tokens:
            ctx = np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(req.out_tokens[:-1], np.int32)])
            self._resume[req.rid] = _Resume("recompute", ctx,
                                            int(req.out_tokens[-1]))
        self.add_request(req)

    def evacuate(self) -> List[Request]:
        """Pull every unfinished request off this engine — queued AND
        in-flight — for adoption by another replica (cluster failover
        after a crash or quarantine).  In-flight slots preempt in
        ``recompute`` mode; the resume records and host-tier entries this
        engine held are *dropped*, because no device or host-tier state
        can follow a request across replicas — :meth:`adopt` re-derives
        resume state from the request alone.  Finished slots retire
        normally.  The engine is left idle with every per-request page
        released (prefix-pinned pages persist until the router decides
        the HBM itself is gone)."""
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if req.done:
                self._release_finished(i)
            else:
                self.preempt(i, mode="recompute")
        moved = list(self.queue)
        self.queue.clear()
        for r in moved:
            res = self._resume.pop(r.rid, None)
            if (res is not None and res.kind == "swap"
                    and self.host_tier is not None
                    and r.rid in self.host_tier):
                self.host_tier.pop(r.rid)
            self._transfer_rids.discard(r.rid)
            self._arrival.pop(r.rid, None)
        return moved

    # ------------------------------------------------------------------
    # disaggregated prefill/decode: finished-prefill hand-off
    # ------------------------------------------------------------------
    def export_finished_prefill(self, slot: int):
        """Ship a freshly prefilled request off this engine: gather its
        pages (k/v + int8 scale lanes; per-shard stripes assembled on host
        under TP) into a checksummed transfer buffer, release every local
        resource, and return ``(request, entry)`` for a decode mesh to
        :meth:`import_prefill`.

        Mechanically this is a swap-out of a request that has emitted
        exactly its seed token — the prefill side's last act.  The pending
        token rides on ``request.out_tokens``; the PRNG chain needs no
        shipping because it is a pure function of ``(seed, rid)`` and the
        emitted count.  Requires the host swap tier (paged, pure
        full-attention stack) and a completed prefill."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"export of empty slot {slot}")
        if not self._swap_ok():
            raise ValueError(
                "export requires the host swap tier (paged backend, pure "
                "full-attention stack, scheduler swap enabled)")
        if slot in self._pending:
            raise ValueError(
                f"slot {slot} is mid-prefill: only a completed prefill "
                "(seed token emitted) can be exported")
        if len(req.out_tokens) != 1:
            raise ValueError(
                f"rid {req.rid} has emitted {len(req.out_tokens)} tokens; "
                "export is a prefill hand-off — decode must not have begun")
        hpos = int(self._hpos[slot])
        # drop any reservation past the live rows, then gather the table
        # (shared prefix pages are read-only; gathering them is safe)
        self.alloc.truncate(req.rid, hpos)
        pids = list(self.alloc.tables[req.rid])
        data = self._gather_to_host(pids)
        entry = make_transfer_entry(req.rid, data, len(pids), length=hpos)
        self.stats.prefill_exports += 1
        self.stats.transfer_bytes += entry.nbytes
        self.alloc.release(req.rid)
        if self.ralloc is not None:
            self.ralloc.release(req.rid)
        self._hashes.pop(req.rid, None)
        self._htable[slot, :] = 0
        self._hrtable[slot, :] = 0
        self._table_dirty = True
        self.slots[slot] = None
        self._arrival.pop(req.rid, None)
        return req, entry

    def import_prefill(self, req: Request, entry: HostKVEntry) -> None:
        """Land a shipped prefill on this (decode) engine: install the
        transfer buffer in the local host tier VERBATIM — original
        checksum and all — and queue the request behind a swap-kind resume
        record.  Admission then walks the ordinary swap-in path: reserve
        pages, scatter the buffer through the page table, restore
        pos/pending-token, replay the ``(seed, rid)`` PRNG chain.  A
        checksum mismatch (corruption anywhere in transit) degrades to
        recompute-resume: the prompt re-prefills *here*, chunked, which is
        bitwise the same stream — the transfer is an optimization, never a
        correctness dependency."""
        if not self._swap_ok():
            raise ValueError(
                "import requires the host swap tier on the decode engine "
                "(paged backend, pure full-attention stack, swap enabled)")
        if len(req.out_tokens) != 1:
            raise ValueError(
                f"rid {req.rid} has emitted {len(req.out_tokens)} tokens; "
                "import expects a prefill hand-off (exactly the seed token)")
        ctx = np.asarray(req.prompt, np.int32)
        if int(entry.length) != len(ctx):
            raise ValueError(
                f"transfer entry covers {entry.length} rows but rid "
                f"{req.rid}'s prompt holds {len(ctx)} tokens")
        self.host_tier.put_entry(entry)
        self._transfer_rids.add(req.rid)
        self._resume[req.rid] = _Resume("swap", ctx, int(req.out_tokens[-1]))
        self.add_request(req)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    # ------------------------------------------------------------------
    # sampling state
    # ------------------------------------------------------------------
    def _assign_key(self, slot: int, req: Request) -> None:
        """Pin the slot's PRNG stream to the request: the key depends only
        on ``(seed, rid)``, never on which slot the request landed in or
        what ran there before — replays are churn-invariant."""
        if self.sampling.greedy:
            return  # greedy never touches PRNG state
        self.keys = self.keys.at[slot].set(
            jax.random.fold_in(self._base_key, req.rid))

    def _seed_token(self, slot: int, logits_row) -> int:
        """First decode token from the prefill logits, drawn with the same
        one-split-per-token chain the fused loop continues."""
        if self.sampling.greedy:
            return int(np.argmax(np.asarray(logits_row)))
        nk, sub = jax.random.split(self.keys[slot])
        tok = int(sample_token(sub, jnp.asarray(logits_row), self.sampling))
        self.keys = self.keys.at[slot].set(nk)
        return tok

    def _replay_key(self, slot: int, req: Request) -> None:
        """Restore the slot's PRNG chain after a resume: re-derive the
        admission key from ``(seed, rid)`` and advance it one split per
        token the request has already emitted — the carried key is then
        bitwise the one an unpreempted run would hold, so the continued
        stream (sampled or speculative) cannot diverge."""
        if self.sampling.greedy:
            return  # greedy consumes zero PRNG state
        n = len(req.out_tokens)
        base = jax.random.fold_in(self._base_key, req.rid)
        if n:
            _, carried = subkey_chain(base[None], n)
            base = carried[0, n]
        self.keys = self.keys.at[slot].set(base)

    # ------------------------------------------------------------------
    # preemption: victim choice, page swap, resume
    # ------------------------------------------------------------------
    def _cost_model(self) -> SwapCostModel:
        """The scheduler's swap-vs-recompute pricer, lazily derived from
        this engine's own geometry when the caller didn't inject a
        calibrated one: weight bytes (each prefill chunk re-streams them)
        and KV bytes per token (what a swap moves per context row)."""
        if self.sched.cost_model is None:
            wb = sum(x.size * x.dtype.itemsize
                     for x in jax.tree_util.tree_leaves(self.params))
            if self.backend == "paged":
                kv_tok = self.bytes_per_page / self.page
                chunk = self.prefill_chunk
            else:
                kv_tok = self.kv_bytes() / (self.bsz * self.max_len)
                chunk = self.max_len  # dense prefill is one dispatch
            self.sched.cost_model = SwapCostModel(
                weight_bytes=wb, kv_bytes_per_token=kv_tok,
                prefill_chunk=chunk,
                host_link_bw=self.sched.config.host_link_bw)
        return self.sched.cost_model

    def _swap_ok(self) -> bool:
        return self.host_tier is not None

    def _victims(self, exclude=()) -> List[VictimInfo]:
        """Preemption candidacies of every active slot, as the scheduler's
        policy sees them.  Mid-prefill slots count the tokens already
        chunked in as their recompute cost (a restart redoes them)."""
        cands = []
        for i, req in enumerate(self.slots):
            if req is None or i in exclude or req.done:
                continue
            pages = 0
            if self.backend == "paged":
                for a in (self.alloc, self.ralloc):
                    if a is not None:
                        pages += len(a.tables.get(req.rid, ()))
                ctx = (self._pending[i] if i in self._pending
                       else int(self._hpos[i]))
            else:
                ctx = int(self._hpos[i])
            # swappability is per victim: the engine must hold a host tier
            # (paged, pure full attention — ring/hybrid stacks never do),
            # and a mid-prefill slot can only restart, never swap
            cands.append(VictimInfo(slot=i, rid=req.rid, priority=req.priority,
                                    ctx_tokens=ctx, pages=pages,
                                    swappable=(self._swap_ok()
                                               and i not in self._pending)))
        return cands

    def _pick_victim(self, below: Optional[int] = None) -> Optional[int]:
        v = self.sched.pick_victim(self._victims(), below=below)
        if v is None:
            return None
        self._cost_model()  # materialize before preempt() prices the resume
        return v.slot

    def preempt(self, slot: int, mode: Optional[str] = None) -> str:
        """Evict the request in ``slot`` mid-flight and requeue it.

        Returns the eviction mode used: ``"restart"`` (mid-prefill — the
        partial pages are dropped and the prompt re-admits from scratch,
        minus whatever the prefix cache retained), ``"recompute"`` (the
        resume re-prefills ``prompt ++ emitted[:-1]``), or ``"swap"`` (the
        pages moved to the host tier and stream back on resume).  ``mode``
        forces the choice; default defers to the scheduler's cost model.
        Either way the resumed request drains token-identically to an
        unpreempted run: KV rows are restored exactly (swap) or recomputed
        row-for-row (chunked prefill is position-wise), the pending token
        is re-fed rather than re-sampled, and the PRNG chain is replayed
        to the carried key."""
        req = self.slots[slot]
        if req is None:
            raise ValueError(f"preempt of empty slot {slot}")
        self.stats.preemptions += 1
        if self.backend == "paged" and slot in self._pending:
            # prompt still building: nothing emitted, no resume state —
            # drop the partial pages and let admission redo the prompt
            del self._pending[slot]
            self._hashes.pop(req.rid, None)
            if self.alloc is not None:
                self.alloc.release(req.rid)
            if self.ralloc is not None:
                self.ralloc.release(req.rid)
            self.slots[slot] = None
            self._htable[slot, :] = 0
            self._hrtable[slot, :] = 0
            self._table_dirty = True
            self.stats.preempt_restarts += 1
            self.queue.append(req)
            return "restart"
        hpos = int(self._hpos[slot])
        ctx = np.concatenate(
            [np.asarray(req.prompt, np.int32),
             np.asarray(req.out_tokens[:-1], np.int32)])
        assert len(ctx) == hpos, "context/KV length drift"
        if mode is None:
            mode = self._cost_model().choose(hpos, self._swap_ok())
        elif mode == "swap" and not self._swap_ok():
            mode = "recompute"
        if mode == "swap":
            # capture exactly the live rows: drop window-reservation pages
            # past hpos first, then gather the table (shared prefix pages
            # are read-only — gathering them is safe, and resume owns
            # private copies)
            self.alloc.truncate(req.rid, hpos)
            pids = list(self.alloc.tables[req.rid])
            data = self._gather_to_host(pids)
            entry = self.host_tier.put(req.rid, data, len(pids), length=hpos)
            self.stats.swap_outs += 1
            self.stats.swap_bytes += entry.nbytes
        self._resume[req.rid] = _Resume(mode, ctx, int(req.out_tokens[-1]))
        if self.backend == "paged":
            if self.alloc is not None:
                self.alloc.release(req.rid)
            if self.ralloc is not None:
                self.ralloc.release(req.rid)
            self._hashes.pop(req.rid, None)
            self._htable[slot, :] = 0
            self._hrtable[slot, :] = 0
            self._table_dirty = True
        self.slots[slot] = None
        self.queue.append(req)
        return mode

    def _gather_to_host(self, pids: List[int]):
        """Device->host page gather: one fused take over every pool leaf
        (k/v pages + int8 scale lanes), page list padded to a power of two
        with null-page ids (bounded trace count; the null page's junk is
        outside the checksummed span).  Under TP each shard gathers its
        own kv-heads stripe and ``device_get`` assembles the full pages on
        host — the per-shard half of the disaggregation primitive."""
        m = next_pow2(max(1, len(pids)))
        idx = jnp.asarray(list(pids) + [0] * (m - len(pids)), jnp.int32)
        return jax.device_get(self._gather_pages(self.cache, self._dev(idx)))

    def _swap_in_slot(self, slot: int, req: Request, res: _Resume) -> bool:
        """Stream a swapped-out request's pages back through the page
        table: reserve fresh pages (ids may differ — the table indirection
        is what makes that free), scatter the host bytes, republish the
        row, and restore pos/pending-token/PRNG state.  False when the
        checksum no longer matches: the entry is dropped and the caller
        degrades to recompute-resume (chaos-injected corruption lands
        here)."""
        entry, ok = self.host_tier.get(req.rid)
        if not ok:
            self.host_tier.pop(req.rid)
            if req.rid in self._transfer_rids:
                self._transfer_rids.discard(req.rid)
                self.stats.transfer_fallbacks += 1
            else:
                self.stats.swap_fallbacks += 1
            res.kind = "recompute"
            return False
        s = len(res.ctx)
        self.alloc.alloc(req.rid)
        try:
            try:
                self.alloc.reserve(req.rid, s)
            except PoolExhausted:
                if (self.prefix is None
                        or not self.prefix.evict_unused(self.alloc)):
                    raise
                self.alloc.reserve(req.rid, s)
        except PoolExhausted:
            self.alloc.release(req.rid)
            raise
        pids = self.alloc.tables[req.rid]
        assert len(pids) == entry.n_pages, "swap-in page count drift"
        m = next_pow2(max(1, len(pids)))
        idx = jnp.asarray(list(pids) + [0] * (m - len(pids)), jnp.int32)
        self.cache = self._scatter_pages(self.cache, self._dev(idx),
                                         entry.data)
        self.host_tier.pop(req.rid)
        self._resume.pop(req.rid)
        self.slots[slot] = req
        self._htable[slot, :] = 0
        self._htable[slot, :len(pids)] = pids
        self._table_dirty = True
        self.pos = self.pos.at[slot].set(s)
        self._hpos[slot] = s
        self._replay_key(slot, req)
        self.tokens = self.tokens.at[slot, 0].set(res.pending)
        if self.draft is not None:
            # the draft's dense cache was not swapped (it is derived state:
            # a prefill over the context rebuilds it, and coupled sampling
            # means draft differences can never change emitted tokens)
            self._draft_prefill_slot(slot, req, tokens=res.ctx)
        if req.rid in self._transfer_rids:
            self._transfer_rids.discard(req.rid)
            self.stats.prefill_imports += 1
            self.stats.transfer_bytes += entry.nbytes
        else:
            self.stats.swap_ins += 1
            self.stats.swap_bytes += entry.nbytes
        self._track_peaks()
        return True

    @staticmethod
    def _scatter_slot_cache(cache, cache1, slot: int):
        """Scatter a single-request prefill cache into the batch cache at
        ``slot``.  Stacked leaves (under blocks/dec) carry batch at axis 1;
        remainder leaves at axis 0.  Shorter prompt caches are padded
        (zeros for k/v — masked by kv_valid_len; -1e9 for kpos = empty)."""

        def place(path, tgt, upd):
            names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
            batch_ax = 1 if any(n in ("blocks", "dec") for n in names) else 0
            for ax in range(upd.ndim):
                if ax != batch_ax and upd.shape[ax] != tgt.shape[ax]:
                    pad = [(0, 0)] * upd.ndim
                    pad[ax] = (0, tgt.shape[ax] - upd.shape[ax])
                    cv = -10**9 if upd.dtype == jnp.int32 else 0
                    upd = jnp.pad(upd, pad, constant_values=cv)
            return jax.lax.dynamic_update_slice_in_dim(
                tgt, upd.astype(tgt.dtype), slot, batch_ax)

        return jax.tree_util.tree_map_with_path(place, cache, cache1)

    # ------------------------------------------------------------------
    # dense prefill (whole prompt, one dispatch)
    # ------------------------------------------------------------------
    def _prefill_into_slot(self, slot: int, req: Request):
        """Prefill a single request, then scatter its cache into the batch
        cache at ``slot``.  A preempted request resumes here by
        re-prefilling its recorded context (prompt + emitted tokens) and
        re-feeding — not re-sampling — its pending token."""
        res = self._resume.get(req.rid)
        prompt = req.prompt if res is None else res.ctx
        s = int(prompt.shape[0])
        if self.bucket_prompts:
            bucket = min(next_pow2(max(8, s)), self.max_len)
            padded = np.zeros((1, bucket), np.int32)
            padded[0, :s] = prompt
            if bucket not in self._seen_prefill_shapes:
                self._seen_prefill_shapes.add(bucket)
                self.stats.prefill_retraces += 1
            cache1, last_logits = self._prefill(
                self.params, jnp.asarray(padded), jnp.int32(s))
        else:
            if s not in self._seen_prefill_shapes:
                self._seen_prefill_shapes.add(s)
                self.stats.prefill_retraces += 1
            cache1, last_logits = self.bundle.prefill(
                self.params, dict(tokens=prompt[None, :]))

        self.cache = self._scatter_slot_cache(self.cache, cache1, slot)
        self.slots[slot] = req
        self.pos = self.pos.at[slot].set(s)
        self._hpos[slot] = s
        if res is None:
            self._assign_key(slot, req)
            tok0 = self._seed_token(slot, np.asarray(last_logits)[0])
            req.out_tokens.append(tok0)
            self.stats.prompt_tokens += s
            self.stats.tokens_out += 1
        else:
            self._resume.pop(req.rid)
            self._replay_key(slot, req)
            tok0 = int(res.pending)
            self.stats.recompute_resumes += 1
        self.tokens = self.tokens.at[slot, 0].set(tok0)
        self.stats.prefills += 1

    # ------------------------------------------------------------------
    # paged admission + chunked prefill
    # ------------------------------------------------------------------
    def _track_peaks(self) -> None:
        if self.alloc is not None:
            self.stats.pages_peak = max(self.stats.pages_peak,
                                        self.alloc.pages_in_use)
        if self.ralloc is not None:
            self.stats.ring_pages_peak = max(self.stats.ring_pages_peak,
                                             self.ralloc.pages_in_use)

    def _paged_admit_slot(self, slot: int, req: Request) -> None:
        """Attach the cached prompt prefix (shared read-only pages), then
        reserve pages for the whole prompt on every pool the stack uses
        (full table + windowed ring) — all-or-nothing, so admission either
        sticks or backs off cleanly (:class:`PoolExhausted`).

        Preempted requests re-enter here: swap-resumes stream their pages
        back (falling back to recompute if the host copy fails its
        checksum), recompute-resumes ride the normal chunked-prefill path
        over their recorded context — the original prompt pages typically
        hit the prefix cache, so only the generated tail recomputes."""
        res = self._resume.get(req.rid)
        if res is not None and res.kind == "swap" \
                and self._swap_in_slot(slot, req, res):
            return
        prompt = req.prompt if res is None else res.ctx
        s = int(prompt.shape[0])
        if s > self.max_len:
            raise ValueError(f"prompt ({s}) exceeds max_len ({self.max_len})")
        if self.alloc is not None:
            need = -(-s // self.page)
            if need > self.num_pages - 1:
                # no amount of backpressure can ever admit this one — waiting
                # would silently drop it (and head-of-line-block the queue)
                raise ValueError(
                    f"prompt needs {need} pages ({s} tokens) but the pool "
                    f"holds only {self.num_pages - 1}; raise num_pages")
        if self.ralloc is not None:
            need = min(-(-s // self.page), self.ralloc.ring_slots)
            if need > self.num_ring_pages - 1:
                raise ValueError(
                    f"prompt needs {need} ring pages but the ring pool "
                    f"holds only {self.num_ring_pages - 1}; raise "
                    "num_ring_pages")
        hit_len = 0
        hashes: List[str] = []
        if self.alloc is not None:
            self.alloc.alloc(req.rid)
            if self.prefix is not None:
                hashes = page_hashes(prompt, self.page)
                # cap at (s-1) tokens: the last token must be computed so
                # the final chunk yields the logits that seed decoding
                usable = (s - 1) // self.page
                pages = self.prefix.lookup(hashes[:usable],
                                           alloc=self.alloc)
                if pages:
                    hit_len = len(pages) * self.page
                    self.alloc.attach(req.rid, pages, hit_len)
        if self.ralloc is not None:
            self.ralloc.alloc(req.rid)
        try:
            if self.alloc is not None:
                try:
                    self.alloc.reserve(req.rid, s)
                except PoolExhausted:
                    if (self.prefix is None
                            or not self.prefix.evict_unused(self.alloc)):
                        raise
                    self.alloc.reserve(req.rid, s)
            if self.ralloc is not None:
                self.ralloc.reserve(req.rid, s)
        except PoolExhausted:
            if self.alloc is not None:
                self.alloc.release(req.rid)
            if self.ralloc is not None:
                self.ralloc.release(req.rid)
            raise
        self._hashes[req.rid] = hashes
        self.slots[slot] = req
        self._pending[slot] = hit_len
        self._hpos[slot] = 0  # no stale position while the prompt builds
        if res is None:  # a resume's context was already counted admitted
            self.stats.prompt_tokens += s
            self.stats.prefix_hit_tokens += hit_len
        self._track_peaks()
        # the batch table row stays null until prefill completes: masked
        # decode ticks must not write through a half-built row

    def _prefill_tick(self, slot: int) -> None:
        """Advance one pending slot by ONE chunk (<= prefill_chunk tokens).
        run_to_completion interleaves these with decode windows, so a long
        prompt admits without stalling in-flight decodes."""
        req = self.slots[slot]
        res = self._resume.get(req.rid)
        prompt = req.prompt if res is None else res.ctx
        s = int(prompt.shape[0])
        off = self._pending[slot]
        c = min(self.prefill_chunk, s - off)
        cb = (min(next_pow2(max(8, c)), self.prefill_chunk)
              if self.bucket_prompts else c)
        if ("chunk", cb) not in self._seen_prefill_shapes:
            self._seen_prefill_shapes.add(("chunk", cb))
            self.stats.prefill_retraces += 1
        chunk = np.zeros((1, cb), np.int32)
        chunk[0, :c] = prompt[off:off + c]
        row = self.alloc.tables[req.rid] if self.alloc is not None else []
        trow = np.zeros((1, max(1, self.pages_per_seq)), np.int32)
        trow[0, :len(row)] = row
        rrow = np.zeros((1, max(1, self.ring_slots)), np.int32)
        if self.ralloc is not None:
            rring = self.ralloc.tables[req.rid]
            rrow[0, :len(rring)] = rring
        self.cache, logits = self._paged_prefill(
            self.params, self.cache, jnp.asarray(chunk),
            jnp.asarray([off], jnp.int32),
            dict(full=jnp.asarray(trow), ring=jnp.asarray(rrow)),
            jnp.asarray([c], jnp.int32), jnp.int32(slot))
        self.stats.prefill_chunks += 1
        self._chunks_since_decode += 1
        off += c
        if off < s:
            self._pending[slot] = off
            return
        # prompt complete: seed decoding and publish the table rows
        del self._pending[slot]
        if self.prefix is not None:
            for i, h in enumerate(self._hashes.get(req.rid, [])):
                if self.prefix.register(h, row[i]):
                    self.alloc.pin(row[i])
        self._hashes.pop(req.rid, None)
        self._htable[slot, :] = 0
        self._htable[slot, :len(row)] = row
        if self.ralloc is not None:
            rring = self.ralloc.tables[req.rid]
            self._hrtable[slot, :] = 0
            self._hrtable[slot, :len(rring)] = rring
        self._table_dirty = True
        self.pos = self.pos.at[slot].set(s)
        self._hpos[slot] = s
        if res is None:
            self._assign_key(slot, req)
            tok0 = self._seed_token(slot, np.asarray(logits)[0])
            req.out_tokens.append(tok0)
            self.stats.tokens_out += 1
        else:
            # recompute-resume: the context's last logits re-derive a token
            # that was already emitted — re-feed it, never re-sample, and
            # fast-forward the PRNG chain to where the preempted run stood
            self._resume.pop(req.rid)
            self._replay_key(slot, req)
            tok0 = int(res.pending)
            self.stats.recompute_resumes += 1
        self.tokens = self.tokens.at[slot, 0].set(tok0)
        if self.draft is not None:
            self._draft_prefill_slot(slot, req,
                                     tokens=None if res is None else res.ctx)
        self.stats.prefills += 1

    def _draft_prefill_slot(self, slot: int, req: Request,
                            tokens: Optional[np.ndarray] = None) -> None:
        """Build the draft model's dense cache for a freshly admitted slot
        (or, with ``tokens``, rebuild it over a resumed request's context —
        the draft cache is derived state, and coupled-sample verification
        means a rebuilt draft can only change throughput, never output).
        The draft is pure full attention (validated in ``_init_spec``), so
        the prompt buckets to a pow2 length and the padded tail is masked by
        ``valid_len`` — one trace per bucket, like the target's prefill."""
        toks = req.prompt if tokens is None else tokens
        s = int(toks.shape[0])
        bucket = min(next_pow2(max(8, s)), self.max_len)
        if ("draft", bucket) not in self._seen_prefill_shapes:
            self._seen_prefill_shapes.add(("draft", bucket))
            self.stats.prefill_retraces += 1
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :s] = toks
        dcache1, _ = self._draft_prefill(
            self.draft_params, jnp.asarray(padded), jnp.int32(s))
        self.draft_cache = self._scatter_slot_cache(
            self.draft_cache, dcache1, slot)

    def _admit(self) -> None:
        while self.queue:
            if len(self.queue) > 1:
                self.sched.order_queue(self.queue, self._arrival)
            req = self.queue[0]
            slot = self._free_slot()
            if slot is None:
                # no slot: a strictly-lower-priority victim yields its seat
                # (uniform priorities — the default — never preempt here)
                victim = self._pick_victim(below=req.priority)
                if victim is None:
                    break
                self.preempt(victim)
                continue
            if self.backend == "paged":
                try:
                    self._paged_admit_slot(slot, req)
                except PoolExhausted:
                    victim = self._pick_victim(below=req.priority)
                    if victim is None:
                        # backpressure: the request stays queued; pages
                        # free as in-flight requests finish
                        self.stats.pool_stalls += 1
                        break
                    self.preempt(victim)
                    continue
                self.queue.pop(0)
            else:
                self.queue.pop(0)
                self._prefill_into_slot(slot, req)
        if self.backend == "paged":
            for slot in self.sched.prefill_order(
                    list(self._pending),
                    lambda i: self.slots[i].priority):
                self._prefill_tick(slot)

    # ------------------------------------------------------------------
    def _budgets(self, n: int) -> np.ndarray:
        """Per-slot token budget for an n-tick window: remaining request
        quota, capped by the cache length guard.  Pending-prefill slots sit
        at zero until their prompt completes."""
        budgets = np.zeros((self.bsz,), np.int64)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.backend == "paged" and i in self._pending:
                continue
            remaining = req.max_new_tokens - len(req.out_tokens)
            cap = self.max_len - 1 - self._hpos[i]
            budgets[i] = max(0, min(remaining, cap, n))
        return budgets

    def _reserve_window_pages(self, budgets: np.ndarray) -> np.ndarray:
        """Pre-allocate pages covering each slot's window budget on every
        pool the stack uses (page allocation is host-side; the fused loop
        must never need a page).  Ring pools rotate in place past their
        window, so steady-state windowed decode allocates nothing.  Pool
        pressure shrinks budgets (possibly to zero — the slot waits) after
        evicting prefix-cache pages nothing references."""
        blocked = np.zeros((self.bsz,), bool)
        for i, req in enumerate(self.slots):
            if req is None or budgets[i] == 0:
                continue
            target = int(self._hpos[i] + budgets[i])
            feasible = target
            if self.alloc is not None:
                feasible = self.alloc.can_grow(req.rid, target)
                if feasible < target and self.prefix is not None:
                    self.prefix.evict_unused(self.alloc)
                    feasible = self.alloc.can_grow(req.rid, target)
            if self.ralloc is not None:
                feasible = min(feasible,
                               self.ralloc.can_grow(req.rid, target))
            grant = max(0, feasible - int(self._hpos[i]))
            if grant < budgets[i]:
                budgets[i] = grant
                blocked[i] = grant == 0
            if budgets[i] > 0:
                target = int(self._hpos[i] + budgets[i])
                if self.alloc is not None:
                    fresh = self.alloc.reserve(req.rid, target)
                    if fresh:
                        row = self.alloc.tables[req.rid]
                        self._htable[i, :len(row)] = row
                        self._table_dirty = True
                if self.ralloc is not None:
                    fresh = self.ralloc.reserve(req.rid, target)
                    if fresh:
                        rring = self.ralloc.tables[req.rid]
                        self._hrtable[i, :len(rring)] = rring
                        self._table_dirty = True
        self._track_peaks()
        return blocked

    def decode_many(self, n: int) -> int:
        """Run up to ``n`` decode ticks in ONE fused dispatch (sampling on
        device, per-slot budgets masked in-loop), then harvest the produced
        token block with a single device->host transfer.  With a draft
        model attached the dispatch is one speculative draft->verify round
        instead, emitting up to ``spec_k + 1`` tokens per slot.  Returns
        the number of real tokens produced."""
        if self.draft is not None:
            n = min(n, self.spec_k + 1)
        budgets = self._budgets(n)
        blocked = (self._reserve_window_pages(budgets)
                   if self.backend == "paged"
                   else np.zeros((self.bsz,), bool))
        retired = 0
        for i, req in enumerate(self.slots):
            if req is None or budgets[i] != 0 or blocked[i]:
                continue
            if self.backend == "paged" and i in self._pending:
                continue
            # done already (e.g. max_new_tokens=1 satisfied by prefill)
            # or pinned at the cache-length guard: retire the slot now,
            # otherwise it would never advance and never free
            self._release_finished(i)
            retired += 1
        if retired and self.backend == "paged" and blocked.any():
            # retired slots returned pages: pool-blocked slots retry
            budgets = self._budgets(n)
            blocked = self._reserve_window_pages(budgets)
        top = int(budgets.max(initial=0))
        if top == 0:
            if blocked.any() and not self._pending:
                # controlled shedding before the hard stop: preempt ONE
                # victim (any priority — everyone is blocked) so the
                # survivors inherit its pages; a lone blocked slot has
                # nobody to yield to, so the raise below still guards the
                # truly-undersized pool
                active = [i for i, r in enumerate(self.slots) if r is not None]
                victim = (self._pick_victim() if len(active) > 1 else None)
                if victim is not None:
                    self.preempt(victim)
                    budgets = self._budgets(n)
                    blocked = self._reserve_window_pages(budgets)
                    top = int(budgets.max(initial=0))
            if top == 0:
                if blocked.any() and not self._pending:
                    in_use = sum(a.pages_in_use
                                 for a in (self.alloc, self.ralloc)
                                 if a is not None)
                    free = sum(len(a.free)
                               for a in (self.alloc, self.ralloc)
                               if a is not None)
                    raise PoolExhausted(
                        "every active slot is pool-blocked and nothing can "
                        "free pages: the pool is smaller than the live "
                        "working set", pool="engine",
                        num_pages=(self.num_pages
                                   + (self.num_ring_pages if self.ralloc
                                      else 0)),
                        live_pages=in_use, free_pages=free)
                return 0
        self.stats.prefill_burst_max = max(self.stats.prefill_burst_max,
                                           self._chunks_since_decode)
        self._chunks_since_decode = 0
        if self.draft is not None:
            return self._spec_dispatch(budgets)
        n_run = min(n, next_pow2(top))  # pow2 ticks: bounded trace count
        steps = jnp.asarray(np.minimum(budgets, n_run), jnp.int32)
        if self.backend == "paged":
            if self._table_dirty:
                self._sync_table()
            (self.cache, self.tokens, self.pos, self.keys,
             out) = self._paged_decode_many(
                n_run, self.params, self.cache, self.tokens, self.pos, steps,
                self.keys, self._table)
        else:
            (self.cache, self.tokens, self.pos, self.keys,
             out) = self._decode_many(
                n_run, self.params, self.cache, self.tokens, self.pos, steps,
                self.keys)
        self.stats.decode_steps += n_run
        self.stats.decode_dispatches += 1

        out_np = np.asarray(out)  # (n_run, B) — the one host sync
        produced = 0
        for i, req in enumerate(self.slots):
            if req is None or (self.backend == "paged" and i in self._pending):
                continue
            adv = int(min(budgets[i], n_run))
            req.out_tokens.extend(int(t) for t in out_np[:adv, i])
            self._hpos[i] += adv
            produced += adv
            if req.done or self._hpos[i] >= self.max_len - 1:
                self._release_finished(i)
        self.stats.tokens_out += produced
        return produced

    def _spec_dispatch(self, budgets: np.ndarray) -> int:
        """One speculative draft->verify round in a single fused dispatch.

        The draft proposes ``spec_k`` tokens, the target verifies them all
        (plus the pending token) in one batched multi-token
        ``paged_verify`` step, and each slot advances by its accepted
        prefix + 1 — coupled sampling (see :func:`_spec_decode_many_impl`)
        guarantees the emitted stream is exactly what vanilla decode would
        have produced.  Afterwards each slot's page reservation is rolled
        back to its accepted length: pages covering only rejected suffix
        rows return to the pool (shared prefix pages are refcounted, never
        mutated)."""
        if self._table_dirty:
            self._sync_table()
        steps = jnp.asarray(budgets, jnp.int32)
        (self.cache, self.draft_cache, self.tokens, self.pos, self.keys,
         out, meta) = self._spec_decode(
            self.params, self.draft_params, self.cache, self.draft_cache,
            self.tokens, self.pos, steps, self.keys, self._table)
        # one spec round always advances every unblocked slot >= 1 token,
        # so a "tick" for progress accounting is one dispatch
        self.stats.decode_steps += 1
        self.stats.decode_dispatches += 1
        self.stats.spec_steps += 1

        out_np = np.asarray(out)    # (B, k+1) — the one host sync
        meta_np = np.asarray(meta)  # (3, B): emitted / accepted / proposed
        produced = 0
        for i, req in enumerate(self.slots):
            if req is None or i in self._pending or budgets[i] == 0:
                continue
            adv = int(meta_np[0, i])
            req.out_tokens.extend(int(t) for t in out_np[i, :adv])
            self._hpos[i] += adv
            produced += adv
            self.stats.draft_tokens += int(meta_np[2, i])
            self.stats.draft_accepted += int(meta_np[1, i])
            # rejected-suffix rollback: the window reservation ran ahead to
            # hpos + budget; shrink it to what was actually emitted
            self.alloc.truncate(req.rid, int(self._hpos[i]))
            if req.done or self._hpos[i] >= self.max_len - 1:
                self._release_finished(i)
        self.stats.tokens_out += produced
        return produced

    def _release_finished(self, i: int) -> None:
        """Retire slot ``i``: paged pages go back to their pools
        *immediately* (prefix-pinned ones persist for future hits) and the
        slot's table rows revert to the null page so masked writes stay
        harmless."""
        req = self.slots[i]
        self.slots[i] = None
        if self.backend == "paged":
            if self.alloc is not None:
                self.alloc.release(req.rid)
            if self.ralloc is not None:
                self.ralloc.release(req.rid)
            self._hashes.pop(req.rid, None)
            self._htable[i, :] = 0
            self._hrtable[i, :] = 0
            self._table_dirty = True

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Admit queued requests, run one decode tick.  False when idle.
        (Compatibility wrapper: one-tick window of the fused path.)"""
        self._admit()
        if not any(s is not None for s in self.slots):
            return False
        self.decode_many(1)
        return True

    def run_to_completion(self, max_ticks: int = 10_000) -> ServeStats:
        """Serve until queue and slots drain; ``max_ticks`` bounds the device
        decode ticks executed (``ServeStats.decode_steps``)."""
        start = self.stats.decode_steps
        while self.stats.decode_steps - start < max_ticks:
            self._admit()
            if not any(s is not None for s in self.slots):
                break
            # every iteration makes progress: _admit advances each pending
            # prefill one chunk, decode_many produces tokens or retires
            # zero-budget slots (pool-blocked slots wait on those releases)
            self.decode_many(self.window)
        return self.stats


def _gather_pages_impl(cache, pids):
    """Take ``pids`` along every pool leaf's page axis: the device half of
    a swap-out.  Under TP the pools are sharded on kv-heads, the gather
    axis is pages — each shard gathers its own head stripe."""

    def take(path, leaf):
        return jnp.take(leaf, pids, axis=page_axis(path, leaf))

    return jax.tree_util.tree_map_with_path(take, cache)


def _scatter_pages_impl(cache, pids, data):
    """Write gathered page data back at (new) page ids: the device half of
    a swap-in.  Padding lanes all target the reserved null page with the
    bytes it held at gather time — duplicate writes of one value, so the
    scatter stays deterministic and live pages are never touched."""

    def put(path, leaf, upd):
        ax = page_axis(path, leaf)
        upd = jnp.asarray(upd, leaf.dtype)
        if ax == 0:
            return leaf.at[pids].set(upd)
        return leaf.at[:, pids].set(upd)

    return jax.tree_util.tree_map_with_path(put, cache, data)


def _gather_logits(bundle: ModelBundle, logits):
    """TP: constrain the step's final logits replicated — ONE all-gather
    per step, placed so token selection (argmax or sample) runs on full
    replicated rows.  The per-slot PRNG chains therefore never see the
    mesh, which is what keeps a sharded drain bitwise identical to the
    single-device engine.  No-op off-mesh."""
    mesh = getattr(bundle.flags, "mesh", None)
    if mesh is None:
        return logits
    return jax.lax.with_sharding_constraint(
        logits, jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()))


def _select_next(sampling: SamplingParams, logits, keys, act):
    """One in-loop token selection: greedy argmax (keys untouched — zero
    PRNG state consumed) or one split-and-draw per active slot.  Masked
    slots keep their key: a frozen slot replays identically no matter how
    many masked ticks pass over it."""
    if sampling.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), keys
    nk, sub = split_keys(keys)
    nxt = sample_tokens(sub, logits, sampling)
    return nxt, jnp.where(act[:, None], nk, keys)


def _decode_many_impl(bundle: ModelBundle, sampling: SamplingParams, n: int,
                      params, cache, tokens, pos, steps, keys):
    """n fused decode ticks.  ``steps`` (B,) caps each slot: past its
    budget a slot is masked — tokens/pos/keys freeze, and its (discarded)
    cache writes re-store the same k/v at the frozen position, which is
    idempotent.  Returns (cache, tokens, pos, keys, out) with out (n, B)
    int32 (-1 = masked)."""
    bsz = tokens.shape[0]

    def body(i, carry):
        cache, tokens, pos, keys, out = carry
        logits, cache = bundle.decode_step(params, cache, tokens, pos)
        act = i < steps
        nxt, keys = _select_next(sampling, logits, keys, act)
        tokens = jnp.where(act[:, None], nxt[:, None], tokens)
        pos = jnp.where(act, pos + 1, pos)
        out = out.at[i].set(jnp.where(act, nxt, -1))
        return cache, tokens, pos, keys, out

    out0 = jnp.full((n, bsz), -1, jnp.int32)
    return jax.lax.fori_loop(0, n, body, (cache, tokens, pos, keys, out0))


def _paged_decode_many_impl(bundle: ModelBundle, plan, sampling: SamplingParams,
                            n: int, params, cache, tokens, pos, steps, keys,
                            table):
    """The paged twin of :func:`_decode_many_impl`: each tick writes k/v
    through the (loop-constant) page table and dispatches the
    ``paged_attention`` kernel under the engine's tuned ``plan`` (the
    kernel asserts the pool layout matches it).  Masked slots freeze
    exactly as in the dense path — their re-writes land on the same page
    slot (idempotent) or on the reserved null page (retired rows), never
    on live data."""
    bsz = tokens.shape[0]

    def body(i, carry):
        cache, tokens, pos, keys, out = carry
        act = i < steps
        logits, cache = bundle.paged_decode_step(params, cache, tokens, pos,
                                                 table, plan, act)
        nxt, keys = _select_next(sampling, _gather_logits(bundle, logits),
                                 keys, act)
        tokens = jnp.where(act[:, None], nxt[:, None], tokens)
        pos = jnp.where(act, pos + 1, pos)
        out = out.at[i].set(jnp.where(act, nxt, -1))
        return cache, tokens, pos, keys, out

    out0 = jnp.full((n, bsz), -1, jnp.int32)
    return jax.lax.fori_loop(0, n, body, (cache, tokens, pos, keys, out0))


def _spec_decode_many_impl(bundle: ModelBundle, draft: ModelBundle, plan,
                           sampling: SamplingParams, k: int, params, dparams,
                           cache, dcache, tokens, pos, steps, keys, table):
    """One speculative round, fully on device.

    The draft proposes ``k`` tokens autoregressively from its dense cache;
    the target verifies ``[pending, d_0 .. d_{k-1}]`` in ONE multi-token
    ``paged_verify`` dispatch (per-position logits).  Coupled sampling
    makes acceptance exact rather than approximate: both models draw from
    the SAME per-position subkey chain the vanilla loop would walk (one
    split per emitted token), the emitted token is always the *target's*
    draw, and a draft proposal is accepted iff it equals that draw.  The
    emitted stream — and the carried key after it — is therefore
    bit-identical to vanilla decoding by construction; the draft only
    controls how many tokens each dispatch advances.

    steps (B,) budgets each slot's emission this round (0 = frozen).
    Returns (cache, dcache, tokens, pos, keys, out, meta):
      out  (B, k+1) int32 — emitted tokens left-packed, -1 past the count
      meta (3, B)   int32 — [emitted m, accepted draft tokens, proposed]
    """
    bsz = tokens.shape[0]
    cv = jnp.clip(steps, 0, k + 1)                 # verify width per slot
    act = steps > 0

    if sampling.greedy:
        subs = jnp.zeros((bsz, k + 1, 2), jnp.uint32)
        carried = jnp.zeros((bsz, k + 2, 2), jnp.uint32)
    else:
        subs, carried = subkey_chain(keys, k + 1)

    # -- draft: k proposals + one extra step that only lands d_{k-1}'s KV
    # row (the bonus token's next-round attention needs it) ---------------
    def dbody(i, carry):
        dcache, dtok, drafts = carry
        dlogits, dcache = draft.decode_step(dparams, dcache, dtok, pos + i)
        dlogits = _gather_logits(draft, dlogits)
        if sampling.greedy:
            d = jnp.argmax(dlogits, axis=-1).astype(jnp.int32)
        else:
            d = sample_tokens(subs[:, i], dlogits, sampling)
        d = jnp.where(i < k, d, -1)
        drafts = jax.lax.dynamic_update_slice_in_dim(
            drafts, d[None], i, axis=0)
        return dcache, jnp.where(i < k, d, dtok[:, 0])[:, None], drafts

    drafts0 = jnp.full((k + 1, bsz), -1, jnp.int32)
    dcache, _, drafts = jax.lax.fori_loop(
        0, k + 1, dbody, (dcache, tokens, drafts0))
    drafts = drafts[:k].T                          # (B, k)

    # -- target: one batched verify over [pending, d_0 .. d_{k-1}] --------
    verify_tokens = jnp.concatenate([tokens, drafts], axis=1)  # (B, k+1)
    cache, logits = bundle.paged_verify(params, cache, verify_tokens, pos,
                                        table, cv, plan)       # (B, k+1, V)
    logits = _gather_logits(bundle, logits)
    if sampling.greedy:
        tsamp = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, k+1)
    else:
        tsamp = jax.vmap(
            lambda s, l: sample_tokens(s, l, sampling))(subs, logits)

    # -- acceptance: longest matching prefix, then the target's token -----
    match = drafts == tsamp[:, :k]                 # (B, k)
    j = jnp.where(jnp.all(match, axis=1), k,
                  jnp.argmin(match.astype(jnp.int32), axis=1))  # first miss
    m = jnp.where(act, jnp.minimum(j + 1, cv), 0)  # emitted this round
    emit = jnp.arange(k + 1, dtype=jnp.int32)[None, :] < m[:, None]
    out = jnp.where(emit, tsamp, -1)               # (B, k+1)

    last = jnp.take_along_axis(
        tsamp, jnp.maximum(m - 1, 0)[:, None], axis=1)         # (B, 1)
    tokens = jnp.where((m > 0)[:, None], last, tokens)
    pos = pos + m
    if not sampling.greedy:
        nk = jnp.take_along_axis(
            carried, jnp.broadcast_to(m[:, None, None], (bsz, 1, 2)),
            axis=1)[:, 0]
        keys = jnp.where(act[:, None], nk, keys)

    acc = jnp.minimum(m, j)                        # bonus token isn't a draft
    prop = jnp.where(act, k, 0)
    meta = jnp.stack([m, acc, prop]).astype(jnp.int32)
    return cache, dcache, tokens, pos, keys, out, meta

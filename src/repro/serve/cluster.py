"""Fault-tolerant cluster front end: the host-side arbiter over engine
replicas.

In the paper's framing TP adds memory channels behind one request stream
while DP adds whole *ports*, and sustained throughput is set by how the
arbitration layer behaves under contention and pathological mixes — not
by peak per-port bandwidth.  :class:`ClusterFrontEnd` is that arbiter,
promoted from the bare least-loaded loop in ``launch/serve.py`` to a
router that survives the ports themselves failing:

- **health probes + circuit breaker** — every round each replica is
  probed; consecutive failures (crash) or slow probes (brownout) trip
  the replica into ``QUARANTINED``, its queued AND in-flight requests
  are evacuated and re-routed to survivors, and consecutive healthy
  probes close the circuit again.
- **lossless failover** — evacuation reuses the PR 8 preemption
  machinery (`ServeEngine.evacuate` / `ServeEngine.adopt`): a failed-over
  request resumes on a survivor via recompute-resume, and because the
  per-``(seed, rid)`` PRNG streams depend only on the request, the
  failed-over drain is **bitwise identical** to the undisturbed run.
- **cache-aware routing** — replicas are scored by predicted
  prefix-cache hit (``PrefixIndex.match_len`` over the request's chain
  hashes — rtp-llm's flexlb KV-status map is the exemplar) minus a
  committed-load term, with suspect replicas penalized.
- **deadline-aware admission** — requests carry a ``deadline`` (virtual
  rounds) and an SLO class (``priority``); when the predicted queue
  delay blows the deadline the router degrades (`max_new_tokens` shrunk
  to fit, floor-guarded) or sheds low-priority requests instead of
  wedging the pool.  High-priority requests are never shed — they route
  at risk and are counted in ``slo_risk``.
- **virtual clock** — one round = probe, route, one admit+decode window
  per healthy replica.  Scheduling depends only on request lengths and
  budgets, never on token *values*, so TTFT/TPOT percentiles measured
  in rounds are deterministic bench rows on any host.

Transient admission refusals (:class:`TransientAdmitError`) get bounded
retry with per-replica exponential backoff; a request that exhausts its
retries is shed, never silently dropped.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.serve.engine import Request, ServeEngine, ServeStats
from repro.serve.hosttier import HostKVEntry
from repro.serve.kvcache import page_hashes
from repro.serve.scheduler import PRIORITY_HIGH, SwapCostModel

# replica health states (circuit breaker)
HEALTHY = "healthy"
SUSPECT = "suspect"          # strikes accumulating; routed only as last resort
QUARANTINED = "quarantined"  # circuit open: evacuated, probing for recovery


class TransientAdmitError(RuntimeError):
    """A replica refused an admission transiently (RPC blip, admission
    hiccup).  The router retries with bounded backoff — never an outage,
    never a silent drop."""


def aggregate_stats(engines: Iterable[ServeEngine]) -> ServeStats:
    """Sum every ServeStats field across engines (peaks sum too: the
    total live-page commitment across the pool)."""
    agg = ServeStats()
    for eng in engines:
        for f in dataclasses.fields(ServeStats):
            setattr(agg, f.name,
                    getattr(agg, f.name) + getattr(eng.stats, f.name))
    return agg


@dataclass(frozen=True)
class ProbeResult:
    ok: bool
    latency_s: float = 0.0


@dataclass(frozen=True)
class ClusterConfig:
    # -- health / circuit breaker ---------------------------------------
    fail_threshold: int = 2      # consecutive failed probes -> quarantine
    slow_threshold: int = 3      # consecutive slow probes   -> quarantine
    slow_probe_s: float = 0.1    # probe latency beyond this is a strike
    recovery_probes: int = 2     # consecutive clean probes close the circuit
    # -- cache-aware routing --------------------------------------------
    cache_weight: float = 4.0    # per predicted prefix-hit token
    load_weight: float = 1.0     # per committed pending token-unit
    suspect_penalty: float = 1e5  # added cost while a replica is SUSPECT
    max_replica_queue: int = 4   # routed-but-unadmitted requests per replica
    # -- transient-admission retry policy -------------------------------
    max_retries: int = 8         # per request, across replicas
    backoff_base: int = 1        # rounds; doubles per consecutive refusal
    backoff_cap: int = 8
    # -- deadline admission ---------------------------------------------
    degrade: bool = True         # shrink max_new_tokens to fit a deadline
    degrade_floor: int = 1       # never degrade below this many tokens


@dataclass
class ClusterStats:
    """Router-level counters (engine-level counters stay in ServeStats)."""
    submitted: int = 0
    routed: int = 0           # successful dispatches (failovers re-count)
    completed: int = 0
    shed: int = 0             # deadline- or retry-shed, never served
    degraded: int = 0         # max_new_tokens shrunk to fit a deadline
    slo_risk: int = 0         # high-priority routed despite predicted miss
    failovers: int = 0        # requests moved off a quarantined replica
    quarantines: int = 0
    recoveries: int = 0
    probe_failures: int = 0
    slow_probes: int = 0
    retries: int = 0          # transient-admission refusals absorbed
    rounds: int = 0           # virtual clock at drain


@dataclass
class _Lat:
    """Per-request latency record in virtual rounds."""
    arrival: int
    first: Optional[int] = None    # round the first token appeared (TTFT)
    finish: Optional[int] = None
    tokens: int = 0


class Replica:
    """One engine port behind the router: health/backoff bookkeeping plus
    the fault-injection surface :class:`~repro.serve.chaos.ClusterChaos`
    arms (crash/stall timers, queued admission refusals).  A crashed
    replica loses device state but keeps host bookkeeping — exactly the
    split that makes recompute-based failover lossless."""

    def __init__(self, index: int, engine: ServeEngine):
        self.index = index
        self.engine = engine
        self.reset()

    def reset(self) -> None:
        self.state = HEALTHY
        self.failed_probes = 0
        self.slow_streak = 0
        self.ok_probes = 0
        self.admit_streak = 0       # consecutive transient refusals
        self.backoff_until = 0      # router round before which no routing
        self.routed = 0             # requests dispatched here (DP balance)
        # fault-injection surface (ClusterChaos writes these)
        self.crash_rounds = 0
        self.stall_rounds = 0
        self.probe_latency_s = 0.0
        self.admit_faults = 0

    # -- fault surface --------------------------------------------------
    @property
    def crashed(self) -> bool:
        return self.crash_rounds > 0

    def tick_faults(self) -> None:
        if self.crash_rounds > 0:
            self.crash_rounds -= 1
        if self.stall_rounds > 0:
            self.stall_rounds -= 1
            if self.stall_rounds == 0:
                self.probe_latency_s = 0.0

    # -- the router's view ----------------------------------------------
    def probe(self) -> ProbeResult:
        if self.crashed:
            return ProbeResult(False, float("inf"))
        return ProbeResult(True, self.probe_latency_s)

    def submit(self, req: Request) -> None:
        if self.admit_faults > 0:
            self.admit_faults -= 1
            raise TransientAdmitError(
                f"replica {self.index} refused rid {req.rid}")
        self.engine.adopt(req)
        self.routed += 1

    def step_round(self) -> None:
        """One admit + decode-window round, unless dark or stalled."""
        if self.crashed or self.stall_rounds > 0:
            return
        eng = self.engine
        eng._admit()
        if any(s is not None for s in eng.slots):
            eng.decode_many(eng.window)

    def load(self) -> int:
        eng = self.engine
        return len(eng.queue) + sum(s is not None for s in eng.slots)

    def pending_units(self) -> int:
        """Token-units of work already committed here: remaining new
        tokens plus the prefill chunks still owed, over queue + slots.
        This is the router's queue-delay currency — it depends only on
        lengths and budgets, never on token values."""
        eng = self.engine
        chunk = getattr(eng, "prefill_chunk", None) or eng.max_len
        units = 0
        for req in list(eng.queue) + [s for s in eng.slots if s is not None]:
            units += max(0, req.max_new_tokens - len(req.out_tokens))
            units += -(-len(req.prompt) // chunk)
        return units

    def predicted_hit_tokens(self, prompt: np.ndarray) -> int:
        """Prefix-cache tokens this replica would serve for ``prompt`` —
        the flexlb-style KV-status peek, priced from the same chain
        hashes admission uses (full pages only, and never the final
        page: the engine re-feeds the last prompt token)."""
        eng = self.engine
        prefix = getattr(eng, "prefix", None)
        if prefix is None:
            return 0
        usable = (len(prompt) - 1) // eng.page
        if usable < 1:
            return 0
        hashes = page_hashes(np.asarray(prompt, np.int32), eng.page)
        return prefix.match_len(hashes[:usable], eng.alloc) * eng.page


class ClusterFrontEnd:
    """The DP arbiter: submit requests (or an open-loop arrival
    schedule), :meth:`run` the virtual clock until drained, read
    :meth:`stats` / :meth:`percentiles`.  All replicas must share the
    sampling seed — per-``(seed, rid)`` streams are what make
    cross-replica failover lossless."""

    def __init__(self, engines: Sequence[ServeEngine],
                 config: Optional[ClusterConfig] = None):
        if not engines:
            raise ValueError("ClusterFrontEnd needs at least one engine")
        if len({e.seed for e in engines}) > 1:
            raise ValueError(
                "replicas must share the sampling seed: per-(seed, rid) "
                "PRNG streams are what make failover lossless")
        self.cfg = config or ClusterConfig()
        self.replicas = [Replica(i, e) for i, e in enumerate(engines)]
        self._init_state()

    def _init_state(self) -> None:
        self.round = 0
        self.backlog: Deque[Request] = deque()
        self.cstats = ClusterStats()
        self.owner: Dict[int, int] = {}      # rid -> replica index (last)
        self.shed_requests: List[Request] = []
        self._live: Dict[int, Request] = {}  # rid -> unfinished, tracked
        self._lat: Dict[int, _Lat] = {}
        self._retries: Dict[int, int] = {}

    def reset(self) -> None:
        """Fresh run over the same engines (jit caches survive)."""
        for rep in self.replicas:
            rep.engine.reset()
            rep.reset()
        self._init_state()

    @property
    def engines(self) -> List[ServeEngine]:
        return [rep.engine for rep in self.replicas]

    def stats(self) -> ServeStats:
        return aggregate_stats(self.engines)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.cstats.submitted += 1
        self._lat[req.rid] = _Lat(arrival=self.round)
        self._live[req.rid] = req
        self.backlog.append(req)

    # -- health ---------------------------------------------------------
    def _quarantine(self, rep: Replica, *, crash: bool) -> None:
        """Open the circuit: evacuate everything (queued + in-flight) for
        re-routing.  On a *crash* the HBM contents are gone — drop the
        prefix index's pins too, so recovery never serves ghost pages."""
        rep.state = QUARANTINED
        rep.ok_probes = 0
        self.cstats.quarantines += 1
        moved = rep.engine.evacuate()
        prefix = getattr(rep.engine, "prefix", None)
        if crash and prefix is not None and rep.engine.alloc is not None:
            prefix.evict_unused(rep.engine.alloc)
        live = [r for r in moved if not r.done and r.rid in self._live]
        self.cstats.failovers += len(live)
        for r in reversed(live):      # failovers re-route ahead of backlog
            self.backlog.appendleft(r)

    def _probe_round(self) -> None:
        cfg = self.cfg
        for rep in self.replicas:
            pr = rep.probe()
            if not pr.ok:
                self.cstats.probe_failures += 1
                rep.slow_streak = rep.ok_probes = 0
                rep.failed_probes += 1
                if rep.state == QUARANTINED:
                    continue
                if rep.failed_probes >= cfg.fail_threshold:
                    self._quarantine(rep, crash=True)
                else:
                    rep.state = SUSPECT
            elif pr.latency_s > cfg.slow_probe_s:
                self.cstats.slow_probes += 1
                rep.failed_probes = rep.ok_probes = 0
                rep.slow_streak += 1
                if rep.state == QUARANTINED:
                    continue
                if rep.slow_streak >= cfg.slow_threshold:
                    self._quarantine(rep, crash=False)
                else:
                    rep.state = SUSPECT
            else:
                rep.failed_probes = rep.slow_streak = 0
                if rep.state == QUARANTINED:
                    rep.ok_probes += 1
                    if rep.ok_probes >= cfg.recovery_probes:
                        rep.state = HEALTHY
                        self.cstats.recoveries += 1
                elif rep.state == SUSPECT:
                    rep.state = HEALTHY

    # -- routing --------------------------------------------------------
    def _routable(self, rep: Replica) -> bool:
        return (rep.state != QUARANTINED
                and self.round >= rep.backoff_until
                and rep.load() < rep.engine.bsz + self.cfg.max_replica_queue)

    def _score(self, rep: Replica, req: Request) -> float:
        s = (self.cfg.cache_weight * rep.predicted_hit_tokens(req.prompt)
             - self.cfg.load_weight * rep.pending_units())
        if rep.state == SUSPECT:
            s -= self.cfg.suspect_penalty
        return s

    def _shed(self, req: Request) -> None:
        self.cstats.shed += 1
        self.shed_requests.append(req)
        self._live.pop(req.rid, None)

    def _admit_deadline(self, req: Request, rep: Replica) -> bool:
        """Deadline check against the chosen replica's predicted queue
        delay.  Returns False when the request was shed instead."""
        if req.deadline is None:
            return True
        if req.out_tokens:
            return True   # mid-flight failover holds delivered tokens:
                          # re-routing must never shed it
        eng = rep.engine
        cap = max(1, eng.bsz * eng.window)       # token-units per round
        chunk = getattr(eng, "prefill_chunk", None) or eng.max_len
        prompt_cost = -(-len(req.prompt) // chunk)
        slack = ((req.deadline - self.round) * cap
                 - rep.pending_units() - prompt_cost)
        if slack >= req.max_new_tokens:
            return True
        if self.cfg.degrade and slack >= self.cfg.degrade_floor:
            req.max_new_tokens = int(slack)      # graceful degradation
            self.cstats.degraded += 1
            return True
        if req.priority >= PRIORITY_HIGH:
            self.cstats.slo_risk += 1            # never shed the high class
            return True
        self._shed(req)
        return False

    def _route_round(self) -> None:
        deferred: Deque[Request] = deque()
        while self.backlog:
            req = self.backlog.popleft()
            cands = [r for r in self.replicas if self._routable(r)]
            if not cands:
                deferred.append(req)
                deferred.extend(self.backlog)
                self.backlog.clear()
                break
            rep = max(cands, key=lambda r: (self._score(r, req), -r.index))
            if not self._admit_deadline(req, rep):
                continue
            try:
                rep.submit(req)
            except TransientAdmitError:
                self.cstats.retries += 1
                rep.admit_streak += 1
                rep.backoff_until = self.round + min(
                    self.cfg.backoff_base * (2 ** (rep.admit_streak - 1)),
                    self.cfg.backoff_cap)
                n = self._retries.get(req.rid, 0) + 1
                self._retries[req.rid] = n
                if n > self.cfg.max_retries:
                    self._shed(req)
                else:
                    deferred.append(req)
                continue
            rep.admit_streak = 0
            self.owner[req.rid] = rep.index
            self.cstats.routed += 1
        self.backlog = deferred

    # -- latency accounting ---------------------------------------------
    def _harvest(self) -> None:
        for rid in list(self._live):
            req = self._live[rid]
            lat = self._lat[rid]
            if lat.first is None and req.out_tokens:
                lat.first = self.round
            if req.done:
                lat.finish = self.round
                lat.tokens = len(req.out_tokens)
                self.cstats.completed += 1
                del self._live[rid]

    # ------------------------------------------------------------------
    def step(self, arrivals: Optional[Deque[Tuple[int, Request]]] = None
             ) -> bool:
        """One virtual-clock round.  Returns False once fully drained."""
        if arrivals is not None:
            while arrivals and arrivals[0][0] <= self.round:
                self.submit(arrivals.popleft()[1])
        self._probe_round()
        self._route_round()
        for rep in self.replicas:
            if rep.state != QUARANTINED:
                rep.step_round()
        self._harvest()
        for rep in self.replicas:
            rep.tick_faults()
        self.round += 1
        self.cstats.rounds = self.round
        return bool(self.backlog or self._live or arrivals)

    def run(self, schedule: Sequence[Tuple[int, Request]] = (),
            chaos=None, max_rounds: int = 100_000) -> ServeStats:
        """Drain an open-loop arrival schedule (``(round, request)``
        pairs) under optional :class:`ClusterChaos` injection."""
        arrivals = deque(sorted(schedule, key=lambda t: (t[0], t[1].rid)))
        for _ in range(max_rounds):
            if chaos is not None:
                chaos.inject(self)
            if not self.step(arrivals):
                return self.stats()
        agg = self.stats()
        raise RuntimeError(
            f"cluster failed to drain in {max_rounds} rounds: "
            f"{len(self._live)} live, {len(self.backlog)} backlogged, "
            f"states={[r.state for r in self.replicas]}, "
            f"aggregate tokens_out={agg.tokens_out}, "
            f"prefills={agg.prefills}")

    def percentiles(self) -> Dict[str, float]:
        """TTFT / TPOT p50/p99 in virtual rounds over completed requests
        — deterministic on any host (the clock never sees token values).
        TTFT is 1-based: a request whose first token lands in its arrival
        round scores 1, so the gated rows are always positive.  Shed
        requests are excluded; their rate is ``cstats.shed /
        cstats.submitted``."""
        return latency_percentiles(self._lat.values())


def latency_percentiles(lats: Iterable[_Lat]) -> Dict[str, float]:
    """TTFT/TPOT p50/p99 in virtual rounds (1-based TTFT; see
    :meth:`ClusterFrontEnd.percentiles`) — shared by every pool topology."""
    lats = list(lats)
    ttft = [lat.first - lat.arrival + 1 for lat in lats
            if lat.first is not None]
    done = [lat for lat in lats if lat.finish is not None]
    tpot = [(lat.finish - lat.first) / max(1, lat.tokens - 1)
            for lat in done]

    def pct(xs: List[float], q: float) -> float:
        return float(np.percentile(np.asarray(xs, np.float64), q)) \
            if xs else 0.0

    return dict(ttft_p50=pct(ttft, 50), ttft_p99=pct(ttft, 99),
                tpot_p50=pct(tpot, 50), tpot_p99=pct(tpot, 99))


# ----------------------------------------------------------------------
# disaggregated prefill/decode topology
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class DisaggConfig:
    """Knobs for :class:`DisaggPool`.

    ``link_bw`` prices the prefill->decode page shipment in the (fixed)
    :class:`~repro.serve.scheduler.SwapCostModel`: a transfer costs one
    gather off the prefill mesh plus one scatter into the decode mesh —
    the same two link traversals a local swap round-trip makes, so
    ``choose(prompt_len, swappable=True)`` is exactly the router's
    disagg-vs-colocated break-even.  ``transit_rounds`` is how many
    virtual-clock rounds a transfer spends in flight (the chaos harness
    corrupts buffers only while they are in transit)."""

    link_bw: float = 32e9
    transit_rounds: int = 1
    # force "disagg" / "colocated" routing for every request (tests and
    # bench gates); None defers to the cost model per prompt length
    force: Optional[str] = None


@dataclass
class DisaggStats:
    """Router-level counters for the disaggregated topology (engine-level
    counters — exports, imports, transfer bytes/fallbacks — stay in the
    aggregated :class:`~repro.serve.engine.ServeStats`)."""
    submitted: int = 0
    disagg_routed: int = 0       # sent to the prefill pool (will transfer)
    colocated_routed: int = 0    # cost model kept prefill+decode together
    transfers: int = 0           # buffers delivered to the decode pool
    completed: int = 0
    rounds: int = 0


@dataclass
class _Transfer:
    """One finished prefill in flight between the pools."""
    req: Request
    entry: HostKVEntry
    due: int                     # round at which it lands


class DisaggPool:
    """Disaggregated prefill/decode serving over two engine pools.

    The prefill pool runs chunked prefill only: the moment a request's
    prompt completes (seed token emitted), its pages — k/v plus int8
    scale lanes, gathered per-shard under TP — leave the mesh as a
    checksummed transfer buffer (:meth:`ServeEngine.export_finished_prefill`)
    and travel ``transit_rounds`` of the virtual clock.  The decode pool
    lands each buffer (:meth:`ServeEngine.import_prefill`) and drains it
    through the ordinary swap-in path: reserve pages, scatter through the
    page table, replay the ``(seed, rid)`` PRNG chain, re-feed the pending
    token.  Because every piece of carried state is either shipped exactly
    (pages, by checksum) or re-derived from ``(seed, rid)`` (PRNG), the
    disaggregated drain is **bitwise identical** to a colocated drain of
    the same requests — and a corrupted transfer merely downgrades to
    decode-side recompute of the prompt, which is the same stream again.

    Routing: the shared :class:`SwapCostModel` (with the staging link at
    ``link_bw`` — never rescaled by an HBM calibration) prices the
    shipment against re-prefilling on the decode side; when the link is
    the bottleneck the request is routed *colocated* onto the decode pool,
    which runs its own prefill.  ``force`` pins the decision for tests.
    """

    def __init__(self, prefill_engines: Sequence[ServeEngine],
                 decode_engines: Sequence[ServeEngine],
                 config: Optional[DisaggConfig] = None):
        if not prefill_engines or not decode_engines:
            raise ValueError("DisaggPool needs >= 1 prefill and >= 1 decode "
                             "engine")
        self.cfg = config or DisaggConfig()
        if self.cfg.force not in (None, "disagg", "colocated"):
            raise ValueError(f"unknown force policy {self.cfg.force!r}")
        engines = list(prefill_engines) + list(decode_engines)
        if len({e.seed for e in engines}) > 1:
            raise ValueError(
                "pools must share the sampling seed: per-(seed, rid) PRNG "
                "streams are what make the hand-off lossless")
        if len({e.max_len for e in engines}) > 1:
            raise ValueError("pools must share max_len")
        for eng in engines:
            if eng.backend != "paged" or eng.host_tier is None:
                raise ValueError(
                    "disaggregation requires paged engines with the host "
                    "swap tier (pure full-attention stack, swap enabled) "
                    "on both pools")
        if len({e.page for e in engines}) > 1:
            raise ValueError(
                "pools must share the page size: the transfer buffer is "
                "scattered page-for-page into the decode pool's table")
        self.prefill_engines = list(prefill_engines)
        self.decode_engines = list(decode_engines)
        # the shipment pricer, derived from decode-pool geometry: each
        # re-prefill chunk on the decode side re-streams the weights; each
        # shipped context row crosses the link twice (gather + scatter)
        eng = self.decode_engines[0]
        wb = sum(x.size * x.dtype.itemsize
                 for x in jax.tree_util.tree_leaves(eng.params))
        self.cost_model = SwapCostModel(
            weight_bytes=wb, kv_bytes_per_token=eng.bytes_per_page / eng.page,
            prefill_chunk=eng.prefill_chunk, host_link_bw=self.cfg.link_bw)
        self._init_state()

    def _init_state(self) -> None:
        self.round = 0
        self.dstats = DisaggStats()
        self._transit: List[_Transfer] = []
        self._live: Dict[int, Request] = {}
        self._lat: Dict[int, _Lat] = {}

    def reset(self) -> None:
        """Fresh run over the same engines (jit caches survive)."""
        for eng in self.engines:
            eng.reset()
        self._init_state()

    @property
    def engines(self) -> List[ServeEngine]:
        return self.prefill_engines + self.decode_engines

    def stats(self) -> ServeStats:
        return aggregate_stats(self.engines)

    def percentiles(self) -> Dict[str, float]:
        return latency_percentiles(self._lat.values())

    # ------------------------------------------------------------------
    @staticmethod
    def _least_loaded(engines: List[ServeEngine]) -> ServeEngine:
        return min(engines, key=lambda e: (
            len(e.queue) + sum(s is not None for s in e.slots)))

    def route(self, req: Request) -> str:
        """``"disagg"`` or ``"colocated"`` for this request."""
        if self.cfg.force is not None:
            return self.cfg.force
        choice = self.cost_model.choose(len(req.prompt), swappable=True)
        return "disagg" if choice == "swap" else "colocated"

    def submit(self, req: Request) -> None:
        self.dstats.submitted += 1
        self._lat[req.rid] = _Lat(arrival=self.round)
        self._live[req.rid] = req
        if self.route(req) == "disagg":
            self._least_loaded(self.prefill_engines).add_request(req)
            self.dstats.disagg_routed += 1
        else:
            self._least_loaded(self.decode_engines).add_request(req)
            self.dstats.colocated_routed += 1

    # ------------------------------------------------------------------
    def _deliver(self) -> None:
        landed = [t for t in self._transit if t.due <= self.round]
        if not landed:
            return
        self._transit = [t for t in self._transit if t.due > self.round]
        for t in landed:
            self._least_loaded(self.decode_engines).import_prefill(
                t.req, t.entry)
            self.dstats.transfers += 1

    def _prefill_round(self) -> None:
        for eng in self.prefill_engines:
            eng._admit()
            for i, req in enumerate(eng.slots):
                if req is None or i in eng._pending:
                    continue
                if req.done:
                    # satisfied by prefill alone (max_new_tokens == 1):
                    # retire in place, nothing to ship
                    eng._release_finished(i)
                    continue
                shipped, entry = eng.export_finished_prefill(i)
                self._transit.append(_Transfer(
                    shipped, entry, due=self.round + self.cfg.transit_rounds))

    def _decode_round(self) -> None:
        for eng in self.decode_engines:
            eng._admit()
            if any(s is not None for s in eng.slots):
                eng.decode_many(eng.window)

    def _harvest(self) -> None:
        for rid in list(self._live):
            req = self._live[rid]
            lat = self._lat[rid]
            if lat.first is None and req.out_tokens:
                lat.first = self.round
            if req.done:
                lat.finish = self.round
                lat.tokens = len(req.out_tokens)
                self.dstats.completed += 1
                del self._live[rid]

    def step(self, chaos=None) -> bool:
        """One virtual-clock round: chaos fires on in-transit buffers,
        due transfers land on the decode pool, the prefill pool advances
        one admit round and exports whatever finished, the decode pool
        runs one admit + decode window.  Returns False once drained."""
        if chaos is not None:
            chaos.inject(self)
        self._deliver()
        self._prefill_round()
        self._decode_round()
        self._harvest()
        self.round += 1
        self.dstats.rounds = self.round
        return bool(self._live or self._transit)

    def run(self, chaos=None, max_rounds: int = 10_000) -> ServeStats:
        """Drain everything submitted (under optional
        :class:`~repro.serve.chaos.DisaggChaos` injection)."""
        for _ in range(max_rounds):
            if not self.step(chaos=chaos):
                return self.stats()
        raise RuntimeError(
            f"disagg pool failed to drain in {max_rounds} rounds: "
            f"{len(self._live)} live, {len(self._transit)} in transit")

from repro.serve.engine import Request, ServeEngine, ServeStats  # noqa: F401
from repro.serve.kvcache import PagedKVCache  # noqa: F401

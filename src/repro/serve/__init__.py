from repro.serve.engine import Request, ServeEngine, ServeStats  # noqa: F401
from repro.serve.kvcache import (PageAllocator, PagedKVCache,  # noqa: F401
                                 PoolExhausted, PrefixIndex, page_hashes)

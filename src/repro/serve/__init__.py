from repro.serve.chaos import (ChaosConfig, ChaosEngine,  # noqa: F401
                               ClusterChaos, ClusterChaosConfig, DisaggChaos,
                               DisaggChaosConfig, fault_rng)
from repro.serve.cluster import (ClusterConfig, ClusterFrontEnd,  # noqa: F401
                                 ClusterStats, DisaggConfig, DisaggPool,
                                 DisaggStats, Replica, TransientAdmitError,
                                 aggregate_stats)
from repro.serve.engine import Request, ServeEngine, ServeStats  # noqa: F401
from repro.serve.hosttier import (HostKVEntry, HostKVTier,  # noqa: F401
                                  corrupt_entry, make_transfer_entry)
from repro.serve.kvcache import (PageAllocator, PagedKVCache,  # noqa: F401
                                 PoolExhausted, PrefixIndex, page_hashes)
from repro.serve.sampling import (GREEDY, SamplingParams,  # noqa: F401
                                  mask_logits, sample_token, sample_tokens)
from repro.serve.scheduler import (PRIORITY_HIGH, PRIORITY_LOW,  # noqa: F401
                                   Scheduler, SchedulerConfig, SwapCostModel)
from repro.serve.traffic import TrafficConfig, generate_traffic  # noqa: F401

from repro.serve.engine import Request, ServeEngine, ServeStats  # noqa: F401
from repro.serve.kvcache import (PageAllocator, PagedKVCache,  # noqa: F401
                                 PoolExhausted, PrefixIndex, page_hashes)
from repro.serve.sampling import (GREEDY, SamplingParams,  # noqa: F401
                                  mask_logits, sample_token, sample_tokens)

"""Int8 gradient compression with error feedback.

The distributed-optimization trick for DP gradient reductions: quantize to
int8 with a per-row scale before the all-reduce (4x wire bytes for fp32
grads), keep the quantization residual in an error-feedback buffer so the
bias cancels over steps (1-bit-Adam / EF-SGD lineage).  Used by the
shard_map data-parallel trainer (``repro.dist.dp_shardmap``); the pjit path
keeps XLA-native reductions.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """per-leading-row int8 quantization; scalars/vectors use one scale."""
    x32 = x.astype(jnp.float32)
    if x.ndim >= 2:
        amax = jnp.max(jnp.abs(x32), axis=tuple(range(1, x.ndim)), keepdims=True)
    else:
        amax = jnp.max(jnp.abs(x32), keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """(quantized, scale, new_error).  new_error = (g+err) - deq(quant)."""
    corrected = g.astype(jnp.float32) + err
    q, s = quantize(corrected)
    new_err = corrected - dequantize(q, s)
    return q, s, new_err


def compressed_psum(g: jax.Array, err: jax.Array, axis_name: str
                    ) -> Tuple[jax.Array, jax.Array]:
    """int8-compressed all-reduce over ``axis_name`` (inside shard_map).

    Each device contributes a dequantized int8 view; the psum runs on the
    dequantized values (semantically an all-gather of int8 + local reduce on
    real hardware; XLA fuses)."""
    q, s, new_err = ef_compress(g, err)
    deq = dequantize(q, s)
    n = jax.lax.psum(jnp.ones(()), axis_name)
    return jax.lax.psum(deq, axis_name) / n, new_err


def wire_bytes_saved(tree) -> int:
    """fp32 -> int8 wire savings for a gradient pytree (report metric)."""
    total = sum(x.size for x in jax.tree.leaves(tree))
    return total * 4 - total  # 3 bytes/elt

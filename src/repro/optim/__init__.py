from repro.optim.adamw import AdamWConfig, AdamWState, init, update  # noqa: F401
from repro.optim import compress, schedule  # noqa: F401

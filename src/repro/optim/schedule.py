"""LR schedules (multiplicative factors on the peak LR)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(warmup: int, total: int, floor: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return f


def wsd(warmup: int, total: int, decay_frac: float = 0.1, floor: float = 0.05):
    """warmup -> stable -> linear decay (the 'WSD' schedule)."""
    decay_start = int(total * (1 - decay_frac))

    def f(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        dec = 1.0 - (1 - floor) * jnp.clip(
            (s - decay_start) / jnp.maximum(total - decay_start, 1), 0.0, 1.0)
        out = jnp.where(s < warmup, warm, 1.0)
        return jnp.where(s > decay_start, dec, out)
    return f

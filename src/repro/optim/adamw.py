"""AdamW with global-norm clipping, built from scratch (no optax offline).

Optimizer state shards exactly like the parameters (the out_shardings of the
train step pin m/v to the params' FSDPxTP layout), so ZeRO-3-style optimizer
sharding falls out of the sharding policy for free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None  # step -> lr scale


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(grads, state: AdamWState, params, cfg: AdamWConfig
           ) -> Tuple[dict, AdamWState, dict]:
    """returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.schedule is not None:
        lr = lr * cfg.schedule(step)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd_flat(p, g, m, v, decay):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    # NOTE(hillclimb, refuted hypothesis): updating big scan-stacked leaves
    # layer-by-layer via lax.scan was tried to shrink fp32 temporaries; it
    # REGRESSED peak memory ~10GiB on grok (scan ys cannot alias xs, so the
    # whole m/v/p stacks get an extra copy).  Flat per-leaf updates win.
    def upd(p, g, m, v):
        decay = bool(cfg.weight_decay) and p.ndim >= 2
        return upd_flat(p, g, m, v, decay)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = dict(grad_norm=gnorm, lr=lr)
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics

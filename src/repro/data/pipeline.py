"""Deterministic synthetic token pipeline, host-sharded.

Production shape without a dataset dependency: each host generates only its
shard of the global batch (split by ``jax.process_index()``), steps are
reproducible from (seed, step) alone — which is what makes checkpoint/restart
and elastic re-sharding exactly resumable — and a background prefetch thread
keeps ``steps_ahead`` batches ready (the paper's outstanding parameter applied
to the input stream).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeCell


@dataclass
class DataConfig:
    seed: int = 0
    prefetch: int = 2
    kind: str = "uniform"   # uniform | markov (learnable bigram structure)
    branching: int = 4      # markov: successors per token


class SyntheticLM:
    """(tokens, labels) batches; labels are next-token shifted."""

    def __init__(self, cfg: ModelConfig, cell: ShapeCell, dcfg: DataConfig,
                 process_index: Optional[int] = None,
                 process_count: Optional[int] = None):
        self.cfg = cfg
        self.cell = cell
        self.dcfg = dcfg
        self.pi = jax.process_index() if process_index is None else process_index
        self.pc = jax.process_count() if process_count is None else process_count
        assert cell.global_batch % self.pc == 0
        self.local_batch = cell.global_batch // self.pc
        if dcfg.kind == "markov":
            # fixed random bigram structure: each token has `branching`
            # successors; optimal CE = log(branching) < log(V) — the loss
            # visibly drops as the model learns the table.
            rng = np.random.default_rng(np.random.SeedSequence([dcfg.seed, 7]))
            self.succ = rng.integers(
                0, cfg.vocab_size,
                size=(cfg.vocab_size, dcfg.branching)).astype(np.int32)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.dcfg.seed, step, self.pi]))
        b, s = self.local_batch, self.cell.seq_len
        if self.dcfg.kind == "markov":
            toks = np.empty((b, s + 1), np.int32)
            toks[:, 0] = rng.integers(0, self.cfg.vocab_size, size=b)
            picks = rng.integers(0, self.dcfg.branching, size=(b, s))
            for t in range(s):
                toks[:, t + 1] = self.succ[toks[:, t], picks[:, t]]
        else:
            toks = rng.integers(0, self.cfg.vocab_size, size=(b, s + 1),
                                dtype=np.int32)
        batch = dict(tokens=toks[:, :-1], labels=toks[:, 1:])
        if self.cfg.enc_dec:
            frames = rng.standard_normal((b, s, self.cfg.d_model)).astype(
                np.float32)
            batch = dict(frames=frames, dec_tokens=toks[:, :-1],
                         labels=toks[:, 1:])
        elif self.cfg.frontend:
            p = min(self.cfg.num_frontend_tokens, s // 2)
            pe = rng.standard_normal((b, p, self.cfg.d_model)).astype(np.float32)
            labels = toks[:, 1:].copy()
            batch = dict(tokens=toks[:, :s - p], patch_embeds=pe, labels=labels)
        return batch

    def __iter__(self) -> Iterator[dict]:
        return self.iterate(0)

    def iterate(self, start_step: int) -> Iterator[dict]:
        """Resumable iterator with a background prefetch thread."""
        q: queue.Queue = queue.Queue(maxsize=max(1, self.dcfg.prefetch))
        stop = threading.Event()

        def worker():
            step = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

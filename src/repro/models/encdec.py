"""Encoder-decoder assembly (seamless-m4t family).

Encoder: bidirectional self-attn + FFN over stubbed frame embeddings
(B, S, d) — the modality frontend is precomputed per the assignment.
Decoder: causal self-attn + cross-attn(encoder memory) + FFN.

Decode caches: self-attn KV (ring-free, full length) + per-layer cross K/V
computed once at prefill (the paper's `nest` with two independent cursors).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models.attention import AttnParams
from repro.models.common import (EMBED, HEADS, KV_HEADS, LAYERS, VOCAB,
                                 ParamBuilder, cross_entropy, rms_norm, rope)
from repro.models.transformer import RuntimeFlags, chunked_ce, compute_logits


def _init_attn(b, path, cfg, stacked):
    lead = (stacked,) if stacked else ()
    la = (LAYERS,) if stacked else ()
    d, hd = cfg.d_model, cfg.resolved_head_dim
    b.dense(f"{path}.wq", lead + (d, cfg.num_heads * hd), la + (EMBED, HEADS))
    b.dense(f"{path}.wk", lead + (d, cfg.num_kv_heads * hd), la + (EMBED, KV_HEADS))
    b.dense(f"{path}.wv", lead + (d, cfg.num_kv_heads * hd), la + (EMBED, KV_HEADS))
    b.dense(f"{path}.wo", lead + (cfg.num_heads * hd, d), la + (HEADS, EMBED))


def init_params(cfg: ModelConfig, key, abstract: bool = False) -> Tuple[dict, dict]:
    b = ParamBuilder(key, jnp.dtype(cfg.param_dtype), abstract=abstract)
    d = cfg.d_model
    ne, nd = cfg.num_encoder_layers, cfg.num_layers
    b.dense("embed.tok", (cfg.vocab_size, d), (VOCAB, EMBED), scale=d ** -0.5)
    b.zeros("enc.ln1", (ne, d), (LAYERS, EMBED))
    _init_attn(b, "enc.attn", cfg, ne)
    b.zeros("enc.ln2", (ne, d), (LAYERS, EMBED))
    mlp_mod.init(b, "enc.mlp", d, cfg.d_ff, cfg.activation, ne)
    b.zeros("enc_norm", (d,), (EMBED,))
    b.zeros("dec.ln1", (nd, d), (LAYERS, EMBED))
    _init_attn(b, "dec.self", cfg, nd)
    b.zeros("dec.lnx", (nd, d), (LAYERS, EMBED))
    _init_attn(b, "dec.cross", cfg, nd)
    b.zeros("dec.ln2", (nd, d), (LAYERS, EMBED))
    mlp_mod.init(b, "dec.mlp", d, cfg.d_ff, cfg.activation, nd)
    b.zeros("final_norm", (d,), (EMBED,))
    if not cfg.tie_embeddings:
        b.dense("lm_head", (d, cfg.vocab_size), (EMBED, VOCAB))
    return b.params, b.specs


def _qkv(p, x, cfg, positions=None):
    bsz, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(bsz, s, cfg.num_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(bsz, s, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(bsz, s, cfg.num_kv_heads, hd)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _proj_out(p, o, cfg):
    bsz, s = o.shape[:2]
    o = o.reshape(bsz, s, cfg.num_heads * cfg.resolved_head_dim)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"])


def encode(params, cfg: ModelConfig, flags: RuntimeFlags, frames: jax.Array):
    """frames: (B, S, d) -> encoder memory (B, S, d)."""
    ap = AttnParams(impl=flags.attn_impl, causal=False,
                    bq=flags.attn_bq, bkv=flags.attn_bkv)
    bsz, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))

    def body(x, bp):
        h = rms_norm(x, bp["ln1"])
        q, k, v = _qkv(bp["attn"], h, cfg, positions)
        x = x + _proj_out(bp["attn"], attn_mod.attention(q, k, v, ap), cfg)
        h = rms_norm(x, bp["ln2"])
        x = x + mlp_mod.apply(bp["mlp"], h, cfg.activation, flags.shd)
        x = flags.shd(x, ("batch", "seq", "embed"))
        return x, None

    if flags.remat != "none":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable,
            prevent_cse=False)
    x = flags.shd(frames.astype(jnp.dtype(cfg.compute_dtype)),
                  ("batch", "seq", "embed"))
    x, _ = jax.lax.scan(body, x, params["enc"])
    return rms_norm(x, params["enc_norm"])


def _decoder(params, cfg, flags, x, memory=None, cache=None, pos=None,
             mode="train"):
    """x: (B, St, d) token embeddings.  memory: (B, Se, d) (train/prefill)."""
    ap_self = AttnParams(impl=flags.attn_impl, causal=True,
                         bq=flags.attn_bq, bkv=flags.attn_bkv)
    ap_cross = AttnParams(impl=flags.attn_impl, causal=False,
                          bq=flags.attn_bq, bkv=flags.attn_bkv)
    bsz, st, _ = x.shape

    if mode == "decode":
        posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (bsz,))
        positions = posv[:, None]
    else:
        posv = None
        positions = jnp.broadcast_to(jnp.arange(st, dtype=jnp.int32)[None],
                                     (bsz, st))

    def body(carry, xs):
        x = carry
        bp, bc = xs
        # --- causal self-attention (cached in decode) ---
        h = rms_norm(x, bp["ln1"])
        q, k, v = _qkv(bp["self"], h, cfg, positions)
        if mode == "decode":
            if jnp.ndim(pos) == 0:  # batch-uniform: DUS, SPMD-friendly
                kc = jax.lax.dynamic_update_slice_in_dim(bc["k"], k, pos, 1)
                vc = jax.lax.dynamic_update_slice_in_dim(bc["v"], v, pos, 1)
            else:
                bidx = jnp.arange(bsz)
                kc = bc["k"].at[bidx, posv].set(k[:, 0])
                vc = bc["v"].at[bidx, posv].set(v[:, 0])
            o = attn_mod.naive_attention(q, kc, vc, ap_self, q_offset=posv,
                                         kv_valid_len=posv + 1)
            ck, cv = bc["ck"], bc["cv"]
            new_c = dict(k=kc, v=vc, ck=ck, cv=cv)
        else:
            o = attn_mod.attention(q, k, v, ap_self)
            ck, cv = _cross_kv(bp["cross"], memory, cfg)
            new_c = dict(k=k, v=v, ck=ck, cv=cv) if mode == "prefill" else None
        x = x + _proj_out(bp["self"], o, cfg)
        # --- cross-attention over encoder memory ---
        h = rms_norm(x, bp["lnx"])
        hd = cfg.resolved_head_dim
        qx = jnp.einsum("bsd,dh->bsh", h, bp["cross"]["wq"]).reshape(
            bsz, st, cfg.num_heads, hd)
        ox = attn_mod.attention(qx, ck, cv, ap_cross)
        x = x + _proj_out(bp["cross"], ox, cfg)
        # --- FFN ---
        h = rms_norm(x, bp["ln2"])
        x = x + mlp_mod.apply(bp["mlp"], h, cfg.activation, flags.shd)
        x = flags.shd(x, ("batch", "seq", "embed"))
        return x, new_c

    if flags.remat != "none" and mode == "train":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable,
            prevent_cse=False)
    xs = (params["dec"], cache["dec"] if cache is not None else None)
    x, new_cache = jax.lax.scan(body, x, xs)
    x = rms_norm(x, params["final_norm"])
    return x, (dict(dec=new_cache) if mode != "train" else None)


def _cross_kv(p, memory, cfg):
    bsz, se, _ = memory.shape
    hd = cfg.resolved_head_dim
    k = jnp.einsum("bsd,dh->bsh", memory, p["wk"]).reshape(
        bsz, se, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", memory, p["wv"]).reshape(
        bsz, se, cfg.num_kv_heads, hd)
    return k, v


def _embed(params, cfg, tokens):
    return jnp.take(params["embed"]["tok"], tokens, axis=0)


def train_loss(params, cfg: ModelConfig, flags: RuntimeFlags, batch: dict):
    memory = encode(params, cfg, flags, batch["frames"])
    x = _embed(params, cfg, batch["dec_tokens"])
    x, _ = _decoder(params, cfg, flags, x, memory=memory, mode="train")
    loss = chunked_ce(params, cfg, x, batch["labels"], flags)
    return loss, dict(ce=loss, aux=jnp.zeros((), jnp.float32))


def prefill(params, cfg: ModelConfig, flags: RuntimeFlags, batch: dict):
    memory = encode(params, cfg, flags, batch["frames"])
    x = _embed(params, cfg, batch["dec_tokens"])
    x, cache = _decoder(params, cfg, flags, x, memory=memory, mode="prefill")
    last_logits = compute_logits(params, cfg, x[:, -1:])[:, 0]
    return cache, last_logits


def decode_step(params, cfg: ModelConfig, flags: RuntimeFlags, cache: dict,
                tokens: jax.Array, pos: jax.Array):
    x = _embed(params, cfg, tokens)
    x, new_cache = _decoder(params, cfg, flags, x, cache=cache, pos=pos,
                            mode="decode")
    logits = compute_logits(params, cfg, x)[:, 0]
    return logits, new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int) -> dict:
    dtype = jnp.dtype(cfg.compute_dtype)
    nd, hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    return dict(dec=dict(
        k=jnp.zeros((nd, batch, max_len, hkv, hd), dtype),
        v=jnp.zeros((nd, batch, max_len, hkv, hd), dtype),
        ck=jnp.zeros((nd, batch, enc_len, hkv, hd), dtype),
        cv=jnp.zeros((nd, batch, enc_len, hkv, hd), dtype),
    ))

"""Griffin RG-LRU recurrent block [arXiv:2402.19427] (recurrentgemma).

Block = two branches from the residual stream:
  gate branch:      linear(d -> w) -> GeLU
  recurrent branch: linear(d -> w) -> causal conv1d (K=4) -> RG-LRU
merged:             (gate ⊙ lru_out) @ W_out

RG-LRU (per channel):
  r_t = sigmoid(BD_a(x_t));  i_t = sigmoid(BD_x(x_t))
  log a_t = -c * softplus(Lambda) * r_t          (c = 8)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t ⊙ x_t)

BD_* are block-diagonal linears (8 blocks) as in the reference model.  The
sequence recurrence is a DAG-structured ``lax.associative_scan`` (exact
cost_analysis, log-depth).  Decode is a single-step update.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (CONV, EMBED, FF, LAYERS, ParamBuilder,
                                 Sharder, causal_conv1d, conv_state_from,
                                 no_shard)

C_FACTOR = 8.0
N_BLOCKS = 8


def width(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


def init(b: ParamBuilder, path: str, cfg: ModelConfig, stacked: int = 0):
    d, w = cfg.d_model, width(cfg)
    lead = (stacked,) if stacked else ()
    la = (LAYERS,) if stacked else ()
    b.dense(f"{path}.w_gate_in", lead + (d, w), la + (EMBED, FF))
    b.dense(f"{path}.w_rec_in", lead + (d, w), la + (EMBED, FF))
    b.dense(f"{path}.conv_w", lead + (4, w), la + (CONV, FF), scale=0.5)
    b.zeros(f"{path}.conv_b", lead + (w,), la + (FF,))
    blk = w // N_BLOCKS
    b.dense(f"{path}.bd_a", lead + (N_BLOCKS, blk, blk), la + (None, FF, None))
    b.zeros(f"{path}.bd_a_bias", lead + (w,), la + (FF,))
    b.dense(f"{path}.bd_x", lead + (N_BLOCKS, blk, blk), la + (None, FF, None))
    b.zeros(f"{path}.bd_x_bias", lead + (w,), la + (FF,))
    # Lambda init so that a^c spans ~(0.9, 0.999) as in the paper
    b.const(f"{path}.lam", jnp.full(lead + (w,), 0.66), la + (FF,))
    b.dense(f"{path}.w_out", lead + (w, d), la + (FF, EMBED))


class LRUState(NamedTuple):
    h: jax.Array       # (B, w) fp32
    conv: jax.Array    # (B, 3, w)


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> LRUState:
    w = width(cfg)
    return LRUState(h=jnp.zeros((batch, w), jnp.float32),
                    conv=jnp.zeros((batch, 3, w), dtype))


def _block_diag(x, wmat, bias):
    """x: (..., w) with w = NB*blk; wmat: (NB, blk, blk)."""
    nb, blk, _ = wmat.shape
    xb = x.reshape(x.shape[:-1] + (nb, blk))
    out = jnp.einsum("...nb,nbc->...nc", xb, wmat)
    return out.reshape(x.shape) + bias


def _gates(p, xr):
    """returns (log_a, gated_input) both fp32; xr (..., w)."""
    r = jax.nn.sigmoid(_block_diag(xr, p["bd_a"], p["bd_a_bias"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(xr, p["bd_x"], p["bd_x_bias"]).astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xr.astype(jnp.float32))
    return a, gated


def forward(p, x, cfg: ModelConfig, shd: Sharder = no_shard,
            return_state: bool = False, state: Optional[LRUState] = None):
    """x: (B, S, d) -> (B, S, d).  ``state`` continues a previous segment
    (chunked prefill): the conv reads its trailing context and the
    recurrence folds ``state.h`` in as the h_0 term — mathematically
    identical to one unbroken sequence."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate_in"]))
    xr = jnp.einsum("bsd,dw->bsw", x, p["w_rec_in"])
    conv_prev = None if state is None else state.conv
    conv_state = conv_state_from(xr, 4, prev=conv_prev)
    xr = causal_conv1d(xr, p["conv_w"], p["conv_b"], state=conv_prev)
    a, gated = _gates(p, xr)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    cum_a, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if state is not None:
        h = h + cum_a * state.h[:, None]
    hlast = h[:, -1]
    h = h.astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", gate * h, p["w_out"])
    if return_state:
        return out, LRUState(h=hlast.astype(jnp.float32), conv=conv_state)
    return out


def decode_step(p, x, st: LRUState, cfg: ModelConfig):
    """x: (B, 1, d) -> (B, 1, d), new state."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate_in"]))
    xr = jnp.einsum("bsd,dw->bsw", x, p["w_rec_in"])
    new_conv = conv_state_from(xr, 4, prev=st.conv)
    xr = causal_conv1d(xr, p["conv_w"], p["conv_b"], state=st.conv)
    a, gated = _gates(p, xr)
    h = a[:, 0] * st.h + gated[:, 0]
    out = jnp.einsum("bsw,wd->bsd", gate * h[:, None].astype(x.dtype), p["w_out"])
    return out, LRUState(h=h, conv=new_conv)

"""Decoder-only LM assembly covering the dense / moe / ssm / hybrid / vlm
families.

Layer stacks are scanned: parameters for each *pattern position* are stacked
on a leading LAYERS axis and ``lax.scan`` iterates pattern blocks (gemma2
scans (local, global) pairs; recurrentgemma scans (rec, rec, attn) triples
plus 2 unrolled remainder layers).  ``flags.unroll_layers`` switches to a
python loop for roofline-mode compiles.

Three modes: ``train`` (full seq, no cache), ``prefill`` (full seq ->
cache), ``decode`` (one token, cache in/out).  Sliding-window layers keep
ring-buffer caches of window length (this is what makes recurrentgemma's
long_500k cell constant-memory).
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, DENSE, MOE, NONE, RGLRU, SSD, LayerSpec, ModelConfig
from repro.kernels import ops as kops
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import AttnParams
from repro.models.common import (EMBED, HEADS, KV_HEADS, LAYERS, VOCAB,
                                 ParamBuilder, Sharder, cross_entropy,
                                 no_shard, rms_norm, rope, softcap)


@dataclass(frozen=True)
class RuntimeFlags:
    """Execution knobs (never affect math, except kv_dtype quantization)."""

    attn_impl: str = "chunked"       # naive | chunked | pallas
    # None = blocks come from the tuned KernelPlan for the call shape
    # (repro.tune); ints pin them (tests / roofline compiles).
    attn_bq: Optional[int] = None
    attn_bkv: Optional[int] = None
    moe_impl: str = "sorted"         # dense | sorted
    moe_group: int = 1024
    remat: str = "none"              # none | full | dots
    unroll_layers: bool = False      # roofline mode
    loss_chunk: int = 512
    aux_loss_weight: float = 0.01
    kv_dtype: str = "native"         # native | int8  (decode-cache quant:
    #                                  the paper's unit-size lever on the KV
    #                                  stream — halves cache bytes)
    shd: Sharder = no_shard
    # serve-side tensor parallelism: a jax Mesh turns the paged dispatches
    # into shard_map islands (heads + KV pools partitioned over tp_axis,
    # page tables replicated — see attention.tp_paged_attention)
    mesh: Any = None
    tp_axis: str = "model"


def paged_supported(cfg: ModelConfig, kv_dtype: str = "native") -> bool:
    """The paged KV backend serves (nearly) every decoder-only stack:

    - full-attention layers grow a per-sequence page table;
    - sliding-window layers keep a *ring* of ``ceil(window/page)+1`` pages,
      rotating the trailing page in place as the window slides past it;
    - recurrent mixers (ssd/rglru) keep dense per-slot state beside the
      page pools (hybrid cache) — only attention layers read the table;
    - ``kv_dtype="int8"`` stores int8 pages with a per-token scale lane per
      page, dequantized inside the paged kernel (the paper's unit-size
      lever on the KV stream);
    - the paged kernel mirrors the dense ``attn_logit_softcap`` path.

    Only encoder-decoder stacks (split cache) and modality frontends fall
    back to the dense per-slot cache."""
    del kv_dtype  # int8 pages are first-class now; kept for call-site compat
    return not (cfg.enc_dec or cfg.frontend)


def _kv_quant(x):
    """(B,S,H,D) -> (int8, per-token scale (B,S) f32)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=(2, 3))
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[:, :, None, None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequant(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[:, :, None, None]).astype(dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(b: ParamBuilder, path: str, spec: LayerSpec, cfg: ModelConfig,
                stacked: int):
    lead = (stacked,) if stacked else ()
    la = (LAYERS,) if stacked else ()
    d, hd = cfg.d_model, cfg.resolved_head_dim
    b.zeros(f"{path}.ln1", lead + (d,), la + (EMBED,))
    if spec.mixer == ATTN:
        b.dense(f"{path}.attn.wq", lead + (d, cfg.num_heads * hd),
                la + (EMBED, HEADS))
        b.dense(f"{path}.attn.wk", lead + (d, cfg.num_kv_heads * hd),
                la + (EMBED, KV_HEADS))
        b.dense(f"{path}.attn.wv", lead + (d, cfg.num_kv_heads * hd),
                la + (EMBED, KV_HEADS))
        b.dense(f"{path}.attn.wo", lead + (cfg.num_heads * hd, d),
                la + (HEADS, EMBED))
    elif spec.mixer == SSD:
        ssm_mod.init(b, f"{path}.ssd", cfg, stacked)
    elif spec.mixer == RGLRU:
        rglru_mod.init(b, f"{path}.rglru", cfg, stacked)
    else:
        raise ValueError(spec.mixer)
    if spec.mlp == DENSE:
        b.zeros(f"{path}.ln2", lead + (d,), la + (EMBED,))
        mlp_mod.init(b, f"{path}.mlp", d, cfg.d_ff, cfg.activation, stacked)
    elif spec.mlp == MOE:
        b.zeros(f"{path}.ln2", lead + (d,), la + (EMBED,))
        moe_mod.init(b, f"{path}.moe", d, cfg.d_ff, cfg.num_experts,
                     cfg.activation, stacked)


def init_params(cfg: ModelConfig, key: Optional[jax.Array],
                abstract: bool = False) -> Tuple[dict, dict]:
    b = ParamBuilder(key, jnp.dtype(cfg.param_dtype), abstract=abstract)
    b.dense("embed.tok", (cfg.vocab_size, cfg.d_model), (VOCAB, EMBED),
            scale=cfg.d_model ** -0.5)
    nb = cfg.num_pattern_blocks
    for j, spec in enumerate(cfg.layer_pattern):
        _init_layer(b, f"blocks.p{j}", spec, cfg, nb)
    for j, spec in enumerate(cfg.remainder_specs):
        _init_layer(b, f"rem.r{j}", spec, cfg, 0)
    b.zeros("final_norm", (cfg.d_model,), (EMBED,))
    if not cfg.tie_embeddings:
        b.dense("lm_head", (cfg.d_model, cfg.vocab_size), (EMBED, VOCAB))
    return b.params, b.specs


# ---------------------------------------------------------------------------
# single-layer apply
# ---------------------------------------------------------------------------

def _attn_params(cfg: ModelConfig, spec: LayerSpec, flags: RuntimeFlags) -> AttnParams:
    scale = (cfg.query_pre_attn_scalar ** -0.5
             if cfg.query_pre_attn_scalar is not None
             else cfg.resolved_head_dim ** -0.5)
    return AttnParams(
        impl=flags.attn_impl, causal=True, window=spec.sliding_window,
        softcap=cfg.attn_logit_softcap, scale=scale,
        bq=flags.attn_bq, bkv=flags.attn_bkv)


def _ring_gather(cache, tbl, off, page, window, dtype):
    """Gather a ring table's live tokens into a contiguous view.

    Returns (k, v, k_positions) with k/v (B, R*page, Hkv, D) and positions
    (B, R*page) int32 (-1e9 = dead slot).  Ring slot ``j`` holds logical
    page ``cur_L - ((cur_L - j) mod R)`` where ``cur_L`` is the logical
    page of the last token *already written* (``off - 1``); stale tokens
    from rotated-out pages map to positions >= off and are masked."""
    b, r = tbl.shape
    kg = cache["k_pages"][tbl]                        # (B, R, page, Hkv, D)
    vg = cache["v_pages"][tbl]
    if "k_scale" in cache:
        kg = kg.astype(jnp.float32) * cache["k_scale"][tbl][..., None, None]
        vg = vg.astype(jnp.float32) * cache["v_scale"][tbl][..., None, None]
    cur = jnp.maximum(off - 1, 0)[:, None] // page    # (B, 1)
    j = jnp.arange(r, dtype=jnp.int32)[None, :]
    base = (cur - (cur - j) % r) * page               # (B, R)
    kpos = base[:, :, None] + jnp.arange(page, dtype=jnp.int32)[None, None, :]
    ok = (kpos < off[:, None, None]) & (kpos >= 0)
    kpos = jnp.where(ok, kpos, -10**9).reshape(b, r * page)
    kg = kg.reshape(b, r * page, *kg.shape[3:]).astype(dtype)
    vg = vg.reshape(b, r * page, *vg.shape[3:]).astype(dtype)
    return kg, vg, kpos


def _paged_attn(q, k, v, cache, ap, spec, pos, table, chunk_valid, cfg,
                flags, mode, plan=None):
    """The paged-cache mixer body (both paged modes).

    Full-attention layers read ``table["full"]`` (logical page j at absolute
    positions [j*page, (j+1)*page)); sliding-window layers read
    ``table["ring"]`` (rotating slots, positions recovered from the valid
    length).  Decode (S=1) writes the token through the table then
    dispatches the ``paged_attention`` Pallas kernel (softcap / window /
    int8-dequant paths included); extend (prefill chunks) attends over a
    gathered view — ring layers attend *before* writing, because a chunk
    crossing a page boundary rotates the trailing page that its own early
    queries still need.  ``kv_dtype="int8"`` quantizes per token before the
    scatter and stores the scales in per-page lanes.  Pad positions
    (bucketed chunks, masked decode ticks on retired slots) are steered to
    page 0 — the engine reserves it as a null page, so masked writes can
    never corrupt live data.
    """
    bsz, s = q.shape[:2]
    page = cache["k_pages"].shape[1]
    ring = spec.sliding_window is not None
    tbl = table["ring"] if ring else table["full"]
    n = tbl.shape[1]
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (bsz,))
    positions = posv[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if chunk_valid is None:
        valid = jnp.full((bsz,), s, jnp.int32)
    else:
        valid = jnp.broadcast_to(
            jnp.asarray(chunk_valid, jnp.int32).reshape(-1), (bsz,))
    in_chunk = jnp.arange(s, dtype=jnp.int32)[None, :] < valid[:, None]
    writable = in_chunk
    if ring:
        pidx = (positions // page) % n
        if s > 1:
            # a chunk wider than the ring would scatter two logical pages
            # through the same slot (duplicate indices, unspecified order);
            # only the trailing (R-1) pages of positions can matter to any
            # future query ((R-1)*page >= window), and that span cannot
            # alias — everything older is steered to the null page
            end = (posv + valid)[:, None]
            writable = in_chunk & (positions >= end - (n - 1) * page)
    else:
        pidx = jnp.minimum(positions // page, n - 1)
    pids = jnp.where(writable, tbl[jnp.arange(bsz)[:, None], pidx], 0)
    slots = jnp.where(writable, positions % page, 0)

    int8kv = flags.kv_dtype == "int8"
    if int8kv:
        kq, ks = _kv_quant(k)
        vq, vs = _kv_quant(v)
        # the cache is the source of truth: attend over what readers will
        # dequantize, so chunked and one-shot prefill agree bit-for-bit
        k = _kv_dequant(kq, ks, q.dtype)
        v = _kv_dequant(vq, vs, q.dtype)
    else:
        kq, vq = k, v

    if mode != "paged_decode" and ring:
        # attend BEFORE the write: the chunk may rotate out a page its own
        # early queries still need (window trailing edge)
        kg, vg, kpos = _ring_gather(cache, tbl, posv, page,
                                    spec.sliding_window, q.dtype)
        cpos = jnp.where(in_chunk, positions, -10**9)
        k_all = jnp.concatenate([kg, k.astype(q.dtype)], axis=1)
        v_all = jnp.concatenate([vg, v.astype(q.dtype)], axis=1)
        o = attn_mod.naive_attention(q, k_all, v_all, ap, q_offset=posv,
                                     k_positions=jnp.concatenate(
                                         [kpos, cpos], axis=1))

    kp = cache["k_pages"].at[pids, slots].set(
        kq.astype(cache["k_pages"].dtype))
    vp = cache["v_pages"].at[pids, slots].set(
        vq.astype(cache["v_pages"].dtype))
    new_cache = dict(cache)
    new_cache.update(k_pages=kp, v_pages=vp)
    if int8kv:
        new_cache["k_scale"] = cache["k_scale"].at[pids, slots].set(ks)
        new_cache["v_scale"] = cache["v_scale"].at[pids, slots].set(vs)

    tp = attn_mod.tp_shardable(flags.mesh, flags.tp_axis,
                               q.shape[2], kp.shape[2])
    if mode == "paged_decode":  # S == 1: the kernel's regime
        if tp:
            o = attn_mod.tp_paged_attention(
                flags.mesh, flags.tp_axis, q[:, 0], kp, vp, tbl, posv + 1,
                scale=ap.scale, softcap=ap.softcap,
                window=spec.sliding_window,
                k_scale=new_cache.get("k_scale"),
                v_scale=new_cache.get("v_scale"), plan=plan)[:, None]
        else:
            o = kops.paged_attention(
                q[:, 0], kp, vp, tbl, posv + 1, scale=ap.scale,
                softcap=ap.softcap, window=spec.sliding_window,
                k_scale=new_cache.get("k_scale"),
                v_scale=new_cache.get("v_scale"), plan=plan)[:, None]
    elif not ring:  # paged_extend: chunked prefill over the gathered view
        if tp:
            o = attn_mod.tp_paged_gather_attention(
                flags.mesh, flags.tp_axis, q, kp, vp, tbl, ap,
                q_offset=posv, kv_valid_len=posv + valid,
                k_scale=new_cache.get("k_scale"),
                v_scale=new_cache.get("v_scale"))
        else:
            o = attn_mod.paged_gather_attention(
                q, kp, vp, tbl, ap, q_offset=posv, kv_valid_len=posv + valid,
                k_scale=new_cache.get("k_scale"),
                v_scale=new_cache.get("v_scale"))
    return o, new_cache


def _apply_attn(p, x, cfg, spec, flags, mode, cache, pos, table=None,
                chunk_valid=None, plan=None):
    bsz, s, d = x.shape
    hd = cfg.resolved_head_dim
    shd = flags.shd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(bsz, s, cfg.num_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(bsz, s, cfg.num_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(bsz, s, cfg.num_kv_heads, hd)
    ap = _attn_params(cfg, spec, flags)

    if mode in ("paged_decode", "paged_extend"):
        o, new_cache = _paged_attn(q, k, v, cache, ap, spec, pos, table,
                                   chunk_valid, cfg, flags, mode, plan)
    elif mode == "decode":
        # scalar pos (batch-uniform decode, the dry-run/throughput path) uses
        # dynamic-update-slice — SPMD-friendly on seq-sharded caches; vector
        # pos (continuous batching) uses per-slot scatter.
        uniform = jnp.ndim(pos) == 0
        posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (bsz,))
        q = rope(q, posv[:, None], cfg.rope_theta)
        k = rope(k, posv[:, None], cfg.rope_theta)

        def _store(buf, val, idx):
            val = val.astype(buf.dtype)  # rope upcasts bf16 k to f32
            if uniform:
                return jax.lax.dynamic_update_slice_in_dim(buf, val, idx, 1)
            return buf.at[jnp.arange(bsz), idx].set(val[:, 0])

        def _store_scale(buf, val, idx):
            val = val.astype(buf.dtype)
            if uniform:
                return jax.lax.dynamic_update_slice(buf, val, (0, idx))
            return buf.at[jnp.arange(bsz), idx].set(val[:, 0])

        int8kv = flags.kv_dtype == "int8"
        if int8kv:
            kq, ks = _kv_quant(k)
            vq, vs = _kv_quant(v)
        else:
            kq, ks, vq, vs = k, None, v, None

        if spec.sliding_window is not None:
            w = cache["k"].shape[1]
            slot = (pos if uniform else posv) % w
            kc = _store(cache["k"], kq, slot)
            vc = _store(cache["v"], vq, slot)
            kpos = _store_scale(
                cache["kpos"],
                jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1, 1),
                                 (bsz, 1)), slot)
            new_cache = dict(k=kc, v=vc, kpos=kpos)
            if int8kv:
                new_cache["k_scale"] = _store_scale(cache["k_scale"], ks, slot)
                new_cache["v_scale"] = _store_scale(cache["v_scale"], vs, slot)
                kc = _kv_dequant(kc, new_cache["k_scale"], k.dtype)
                vc = _kv_dequant(vc, new_cache["v_scale"], v.dtype)
            o = attn_mod.naive_attention(
                q, kc, vc, ap, q_offset=posv, k_positions=kpos)
        else:
            idx = pos if uniform else posv
            kc = _store(cache["k"], kq, idx)
            vc = _store(cache["v"], vq, idx)
            new_cache = dict(k=kc, v=vc)
            if int8kv:
                new_cache["k_scale"] = _store_scale(cache["k_scale"], ks, idx)
                new_cache["v_scale"] = _store_scale(cache["v_scale"], vs, idx)
                kc = _kv_dequant(kc, new_cache["k_scale"], k.dtype)
                vc = _kv_dequant(vc, new_cache["v_scale"], v.dtype)
            o = attn_mod.naive_attention(
                q, kc, vc, ap, q_offset=posv, kv_valid_len=posv + 1)
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        k = shd(k, ("batch", "seq", "kv_heads", None))
        v = shd(v, ("batch", "seq", "kv_heads", None))
        int8kv = flags.kv_dtype == "int8" and mode == "prefill"
        if int8kv:
            # the cache is the source of truth: prefill attends over the
            # quantize->dequantize round trip it stores, so its logits agree
            # bit-for-bit with decode (and with paged chunked prefill, which
            # can only read earlier chunks back from int8 pages)
            kq, ks = _kv_quant(k)
            vq, vs = _kv_quant(v)
            k = _kv_dequant(kq, ks, q.dtype)
            v = _kv_dequant(vq, vs, q.dtype)
        o = attn_mod.attention(q, k, v, ap)
        new_cache = None
        if mode == "prefill":
            if spec.sliding_window is not None:
                w = min(spec.sliding_window, s)
                sl = slice(s - w, None)
                new_cache = dict(
                    kpos=jnp.broadcast_to(
                        jnp.arange(s - w, s, dtype=jnp.int32)[None], (bsz, w)))
            else:
                sl = slice(None)
                new_cache = {}
            if int8kv:
                new_cache["k"], new_cache["k_scale"] = kq[:, sl], ks[:, sl]
                new_cache["v"], new_cache["v_scale"] = vq[:, sl], vs[:, sl]
            else:
                new_cache["k"], new_cache["v"] = k[:, sl], v[:, sl]
    o = o.reshape(bsz, s, cfg.num_heads * hd)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return out, new_cache


def _recurrent_chunk(mod, p, h, cache, cfg, flags, pos, slot):
    """Hybrid-cache chunked prefill through a recurrent mixer: slice the
    per-slot state row out of the batch state tree, run the chunk forward
    from it, and scatter the updated row back.  ``pos == 0`` (the first
    chunk of a freshly admitted request) restarts the state from zeros —
    the slot may hold garbage from masked decode ticks of its previous
    occupant."""
    slot = jnp.asarray(slot, jnp.int32).reshape(())
    fresh = jnp.asarray(pos, jnp.int32).reshape(-1)[0] == 0
    st = jax.tree.map(
        lambda a: jnp.where(fresh, jnp.zeros_like(a[:1]),
                            jax.lax.dynamic_slice_in_dim(a, slot, 1, 0)),
        cache)
    mix, st1 = mod.forward(p, h, cfg, flags.shd, return_state=True, state=st)
    new_cache = jax.tree.map(
        lambda full, upd: jax.lax.dynamic_update_slice_in_dim(
            full, upd.astype(full.dtype), slot, 0),
        cache, st1)
    return mix, new_cache


def _freeze_inactive(new_state, old_state, active):
    """Freeze recurrent state rows of inactive slots.  Attention pages are
    write-idempotent under a frozen position (or steered to the null page),
    but a recurrent update is not — a pending-prefill slot's partial state
    must survive the masked decode ticks between its chunks."""
    if active is None:
        return new_state
    return jax.tree.map(
        lambda n, o: jnp.where(
            jnp.reshape(active, (-1,) + (1,) * (n.ndim - 1)), n, o),
        new_state, old_state)


def _apply_layer(p, x, cfg, spec, flags, mode, cache, pos, table=None,
                 chunk_valid=None, plan=None, slot=None, active=None):
    """returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"])
    if spec.mixer == ATTN:
        mix, new_cache = _apply_attn(p["attn"], h, cfg, spec, flags, mode,
                                     cache, pos, table, chunk_valid, plan)
    elif spec.mixer == SSD:
        if mode in ("decode", "paged_decode"):
            mix, new_cache = ssm_mod.decode_step(p["ssd"], h, cache, cfg)
            if mode == "paged_decode":
                new_cache = _freeze_inactive(new_cache, cache, active)
        elif mode == "paged_extend":
            mix, new_cache = _recurrent_chunk(ssm_mod, p["ssd"], h, cache,
                                              cfg, flags, pos, slot)
        elif mode == "prefill":
            mix, new_cache = ssm_mod.forward(p["ssd"], h, cfg, flags.shd,
                                             return_state=True)
        else:
            mix, new_cache = ssm_mod.forward(p["ssd"], h, cfg, flags.shd), None
    elif spec.mixer == RGLRU:
        if mode in ("decode", "paged_decode"):
            mix, new_cache = rglru_mod.decode_step(p["rglru"], h, cache, cfg)
            if mode == "paged_decode":
                new_cache = _freeze_inactive(new_cache, cache, active)
        elif mode == "paged_extend":
            mix, new_cache = _recurrent_chunk(rglru_mod, p["rglru"], h, cache,
                                              cfg, flags, pos, slot)
        elif mode == "prefill":
            mix, new_cache = rglru_mod.forward(p["rglru"], h, cfg, flags.shd,
                                               return_state=True)
        else:
            mix, new_cache = rglru_mod.forward(p["rglru"], h, cfg, flags.shd), None
    else:
        raise ValueError(spec.mixer)
    x = x + mix
    x = flags.shd(x, ("batch", "seq", "embed"))

    if spec.mlp == DENSE:
        h = rms_norm(x, p["ln2"])
        x = x + mlp_mod.apply(p["mlp"], h, cfg.activation, flags.shd)
    elif spec.mlp == MOE:
        h = rms_norm(x, p["ln2"])
        out, aux = moe_mod.apply(
            p["moe"], h, cfg.num_experts_per_tok, cfg.activation,
            impl=flags.moe_impl, shd=flags.shd, group_size=flags.moe_group,
            capacity_factor=cfg.moe_capacity_factor)
        x = x + out
    x = flags.shd(x, ("batch", "seq", "embed"))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stack
# ---------------------------------------------------------------------------

def _empty_cache_for(cfg, spec: LayerSpec, batch: int, max_len: int, dtype,
                     kv_dtype: str = "native"):
    hd = cfg.resolved_head_dim
    if spec.mixer == ATTN:
        kvd = jnp.int8 if kv_dtype == "int8" else dtype
        t = (min(spec.sliding_window, max_len)
             if spec.sliding_window is not None else max_len)
        c = dict(k=jnp.zeros((batch, t, cfg.num_kv_heads, hd), kvd),
                 v=jnp.zeros((batch, t, cfg.num_kv_heads, hd), kvd))
        if spec.sliding_window is not None:
            c["kpos"] = jnp.full((batch, t), -10**9, jnp.int32)
        if kv_dtype == "int8":
            c["k_scale"] = jnp.zeros((batch, t), jnp.float32)
            c["v_scale"] = jnp.zeros((batch, t), jnp.float32)
        return c
    if spec.mixer == SSD:
        return ssm_mod.init_state(cfg, batch, dtype)
    if spec.mixer == RGLRU:
        return rglru_mod.init_state(cfg, batch, dtype)
    raise ValueError(spec.mixer)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               kv_dtype: str = "native") -> dict:
    """Decode cache pytree: blocks stacked on LAYERS, remainder unstacked."""
    dtype = jnp.dtype(cfg.compute_dtype)
    nb = cfg.num_pattern_blocks

    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (nb,) + a.shape), tree)

    blocks = {f"p{j}": stack(_empty_cache_for(cfg, spec, batch, max_len,
                                              dtype, kv_dtype))
              for j, spec in enumerate(cfg.layer_pattern)}
    rem = {f"r{j}": _empty_cache_for(cfg, spec, batch, max_len, dtype, kv_dtype)
           for j, spec in enumerate(cfg.remainder_specs)}
    return dict(blocks=blocks, rem=rem)


def _empty_paged_for(cfg, spec: LayerSpec, num_pages: int, ring_pages: int,
                     page_size: int, batch: int, dtype, kv_dtype: str):
    """One layer's slice of the hybrid paged cache: page pools for attention
    (full layers share the ``num_pages`` pool, windowed layers the
    ``ring_pages`` pool), dense per-slot state for recurrent mixers."""
    if spec.mixer == SSD:
        return ssm_mod.init_state(cfg, batch, dtype)
    if spec.mixer == RGLRU:
        return rglru_mod.init_state(cfg, batch, dtype)
    if spec.mixer != ATTN:
        raise ValueError(spec.mixer)
    hd = cfg.resolved_head_dim
    p = ring_pages if spec.sliding_window is not None else num_pages
    kvd = jnp.int8 if kv_dtype == "int8" else dtype
    shape = (p, page_size, cfg.num_kv_heads, hd)
    c = dict(k_pages=jnp.zeros(shape, kvd), v_pages=jnp.zeros(shape, kvd))
    if kv_dtype == "int8":
        c["k_scale"] = jnp.zeros((p, page_size), jnp.float32)
        c["v_scale"] = jnp.zeros((p, page_size), jnp.float32)
    return c


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     batch: int = 1, ring_pages: int = 0,
                     kv_dtype: str = "native") -> dict:
    """Paged decode cache: per-layer page *pools* instead of per-slot dense
    buffers.  Page ids are shared across layers of the same kind (one
    host-side allocator + table for the full-attention pools, one for the
    windowed ring pools), recurrent mixers keep dense (batch, ...) state
    rows, and ``kv_dtype="int8"`` adds a per-token fp32 scale lane per
    page.  The pytree mirrors :func:`init_cache`'s stacking — blocks on
    LAYERS, remainder unstacked — with pools/state as leaves."""
    dtype = jnp.dtype(cfg.compute_dtype)
    nb = cfg.num_pattern_blocks
    ring_pages = ring_pages or num_pages

    def stack(tree):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (nb,) + a.shape), tree)

    blocks = {f"p{j}": stack(_empty_paged_for(cfg, spec, num_pages,
                                              ring_pages, page_size, batch,
                                              dtype, kv_dtype))
              for j, spec in enumerate(cfg.layer_pattern)}
    rem = {f"r{j}": _empty_paged_for(cfg, spec, num_pages, ring_pages,
                                     page_size, batch, dtype, kv_dtype)
           for j, spec in enumerate(cfg.remainder_specs)}
    return dict(blocks=blocks, rem=rem)


def _scan_blocks(params, x, cfg, flags, mode, cache, pos, table=None,
                 chunk_valid=None, plan=None, slot=None, active=None):
    """Apply the scanned pattern blocks + remainder layers.  ``table`` /
    ``chunk_valid`` / ``plan`` / ``slot`` / ``active`` (paged modes) are
    loop constants: every layer dereferences the same batched page table."""
    pattern = cfg.layer_pattern
    aux0 = jnp.zeros((), jnp.float32)

    def body(carry, xs):
        x, aux = carry
        bp, bc = xs
        new_caches = {}
        for j, spec in enumerate(pattern):
            c_in = bc.get(f"p{j}") if bc is not None else None
            x, c_out, a = _apply_layer(bp[f"p{j}"], x, cfg, spec, flags, mode,
                                       c_in, pos, table, chunk_valid, plan,
                                       slot, active)
            aux = aux + a
            new_caches[f"p{j}"] = c_out
        ys = new_caches if mode != "train" else None
        return (x, aux), ys

    if flags.remat != "none" and mode == "train":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if flags.remat == "dots" else
                  jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    blocks_p = params["blocks"]
    blocks_c = cache["blocks"] if cache is not None else None

    if flags.unroll_layers:
        carry = (x, aux0)
        ys_list = []
        for i in range(cfg.num_pattern_blocks):
            bp = jax.tree.map(lambda a: a[i], blocks_p)
            bc = (jax.tree.map(lambda a: a[i], blocks_c)
                  if blocks_c is not None else None)
            carry, ys = body(carry, (bp, bc))
            ys_list.append(ys)
        (x, aux) = carry
        new_blocks_c = (jax.tree.map(lambda *a: jnp.stack(a), *ys_list)
                        if mode != "train" else None)
    else:
        (x, aux), new_blocks_c = jax.lax.scan(
            body, (x, aux0), (blocks_p, blocks_c))

    new_rem = {}
    for j, spec in enumerate(cfg.remainder_specs):
        c_in = cache["rem"].get(f"r{j}") if cache is not None else None
        apply = _apply_layer
        if flags.remat != "none" and mode == "train":
            # remainder layers need remat exactly like the scanned ones
            apply = jax.checkpoint(
                _apply_layer,
                policy=jax.checkpoint_policies.nothing_saveable,
                prevent_cse=False,
                static_argnums=(2, 3, 4, 5, 7))
        x, c_out, a = apply(params["rem"][f"r{j}"], x, cfg, spec, flags,
                            mode, c_in, pos, table, chunk_valid, plan, slot,
                            active)
        aux = aux + a
        new_rem[f"r{j}"] = c_out
    new_cache = (dict(blocks=new_blocks_c, rem=new_rem)
                 if mode != "train" else None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# embedding / logits / losses
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)
    if cfg.normalize_embedding:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _head_weight(params):
    if "lm_head" in params:
        return params["lm_head"]
    return params["embed"]["tok"].T


def compute_logits(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    logits = jnp.einsum("bsd,dv->bsv", x, _head_weight(params))
    return softcap(logits, cfg.final_logit_softcap)


def chunked_ce(params, cfg, x, labels, flags: RuntimeFlags) -> jax.Array:
    """Sequence-chunked CE so (B,S,V) logits are never materialized.
    ``loss_chunk=0`` computes single-shot (roofline mode: no inner scan)."""
    bsz, s, _ = x.shape
    if flags.loss_chunk <= 0:
        logits = compute_logits(params, cfg, x)
        logits = flags.shd(logits, ("batch", "seq", "vocab"))
        return cross_entropy(logits, labels)
    c = min(flags.loss_chunk, s)
    assert s % c == 0
    n = s // c
    xc = jnp.moveaxis(x.reshape(bsz, n, c, -1), 1, 0)
    lc = jnp.moveaxis(labels.reshape(bsz, n, c), 1, 0)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def step(carry, xs):
        # checkpointed: without it the scan saves every (B, c, V) logits
        # chunk for backward, defeating the whole point of chunking.
        tot, cnt = carry
        xb, lb = xs
        logits = compute_logits(params, cfg, xb)
        logits = flags.shd(logits, ("batch", "seq", "vocab"))
        valid = (lb >= 0)
        safe = jnp.where(valid, lb, 0)
        lg = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        ll = jnp.take_along_axis(lg, safe[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum((lse - ll) * valid)
        cnt = cnt + jnp.sum(valid)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (xc, lc))
    return tot / jnp.maximum(cnt, 1)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, flags: RuntimeFlags, tokens: jax.Array,
            patch_embeds: Optional[jax.Array] = None, mode: str = "train",
            cache: Optional[dict] = None, pos=None, table=None,
            chunk_valid=None, plan=None, slot=None, active=None):
    """tokens: (B, S_text); patch_embeds: (B, P, d) for vlm frontends.
    ``table``/``chunk_valid``/``plan``/``slot``/``active`` only apply to
    the paged modes."""
    x = embed_tokens(params, cfg, tokens)
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    x = flags.shd(x, ("batch", "seq", "embed"))
    x, new_cache, aux = _scan_blocks(params, x, cfg, flags, mode, cache, pos,
                                     table, chunk_valid, plan, slot, active)
    x = rms_norm(x, params["final_norm"])
    return x, new_cache, aux


def train_loss(params, cfg: ModelConfig, flags: RuntimeFlags, batch: dict):
    x, _, aux = forward(params, cfg, flags, batch["tokens"],
                        batch.get("patch_embeds"), mode="train")
    loss = chunked_ce(params, cfg, x, batch["labels"], flags)
    return loss + flags.aux_loss_weight * aux, dict(ce=loss, aux=aux)


def prefill(params, cfg: ModelConfig, flags: RuntimeFlags, batch: dict):
    """``batch["valid_len"]`` (scalar or (B,) int32, optional) marks the true
    prompt length when tokens are right-padded to a bucket (the serve fast
    path): last-token logits are read at ``valid_len - 1`` instead of the pad
    tail.  Causal attention keeps positions < valid_len exact under right
    padding; cache rows past valid_len are masked downstream by the decode
    step's ``kv_valid_len``."""
    x, cache, _ = forward(params, cfg, flags, batch["tokens"],
                          batch.get("patch_embeds"), mode="prefill")
    vl = batch.get("valid_len")
    if vl is None:
        last = x[:, -1:]
    else:
        bsz = x.shape[0]
        idx = jnp.broadcast_to(
            jnp.asarray(vl, jnp.int32).reshape(-1), (bsz,)) - 1
        last = x[jnp.arange(bsz), idx][:, None]
    last_logits = compute_logits(params, cfg, last)[:, 0]
    return cache, last_logits


def decode_step(params, cfg: ModelConfig, flags: RuntimeFlags, cache: dict,
                tokens: jax.Array, pos: jax.Array):
    """tokens: (B, 1); pos: scalar int32 (uniform across batch)."""
    x, new_cache, _ = forward(params, cfg, flags, tokens, mode="decode",
                              cache=cache, pos=pos)
    logits = compute_logits(params, cfg, x)[:, 0]
    return logits, new_cache


def paged_decode_step(params, cfg: ModelConfig, flags: RuntimeFlags,
                      cache: dict, tokens: jax.Array, pos: jax.Array,
                      table, plan=None, active=None):
    """One decode tick against the page pool.  tokens: (B, 1); pos: (B,)
    per-slot positions; table: ``{"full": (B, N), "ring": (B, R)}`` page
    tables (padded entries -> the null page; windowed layers read the ring
    table, full-attention layers the full one).  Every attention layer
    appends k/v through its table and dispatches the ``paged_attention``
    kernel under ``plan`` (the engine's tuned :class:`repro.tune.
    KernelPlan`; the kernel asserts the pool layout matches it and executes
    its pinned interpret mode); recurrent mixers advance dense per-slot
    state exactly like the dense decode path, except rows where ``active``
    (B,) is False keep their previous state — a pending-prefill slot's
    partial state must survive the masked ticks between its chunks."""
    x, new_cache, _ = forward(params, cfg, flags, tokens, mode="paged_decode",
                              cache=cache, pos=pos, table=table, plan=plan,
                              active=active)
    logits = compute_logits(params, cfg, x)[:, 0]
    return logits, new_cache


def paged_prefill_chunk(params, cfg: ModelConfig, flags: RuntimeFlags,
                        cache: dict, tokens: jax.Array, pos: jax.Array,
                        table, chunk_valid: jax.Array, slot=None):
    """One chunked-prefill step: ``tokens`` (B, C) is a prompt chunk
    (right-padded to a bucket; ``chunk_valid`` (B,) marks true length) at
    absolute context offset ``pos`` (B,).  Appends the chunk's k/v into the
    pages (full tables and rotating ring tables alike) and returns logits
    at the chunk's last valid position — only the final chunk's logits seed
    decoding.  ``slot`` is the engine slot whose dense recurrent state rows
    this chunk continues (hybrid stacks); the first chunk (``pos == 0``)
    restarts them from zeros."""
    x, new_cache, _ = forward(params, cfg, flags, tokens, mode="paged_extend",
                              cache=cache, pos=pos, table=table,
                              chunk_valid=chunk_valid, slot=slot)
    bsz = x.shape[0]
    idx = jnp.broadcast_to(
        jnp.asarray(chunk_valid, jnp.int32).reshape(-1), (bsz,)) - 1
    last = x[jnp.arange(bsz), idx][:, None]
    logits = compute_logits(params, cfg, last)[:, 0]
    return new_cache, logits


def paged_verify(params, cfg: ModelConfig, flags: RuntimeFlags, cache: dict,
                 tokens: jax.Array, pos: jax.Array, table,
                 chunk_valid: jax.Array, plan=None):
    """Speculative k-token verification: one batched ``paged_extend`` read.

    ``tokens`` (B, C) is ``[pending, draft_0 .. draft_{C-2}]`` per slot at
    absolute offset ``pos`` (B,); ``chunk_valid`` (B,) caps how many
    positions each slot may write (masked positions steer to the null
    page exactly like chunked prefill).  Unlike
    :func:`paged_prefill_chunk` this returns logits at EVERY position —
    (B, C, V) — because the acceptance rule needs the target distribution
    at each drafted offset, not just the last one.  Query position i
    attends rows ``<= pos + i`` (causal over the gathered page view), so
    row i's logits are bit-for-bit what ``paged_decode_step`` would have
    produced after emitting the same prefix — one page-table gather
    amortized over C positions instead of C serial single-token walks
    (the paper's burst-length lever applied to verification).  ``plan``
    is the engine's tuned verify-step :class:`repro.tune.KernelPlan`
    (``bq`` = verify width, ``bkv`` = the pool's page)."""
    x, new_cache, _ = forward(params, cfg, flags, tokens, mode="paged_extend",
                              cache=cache, pos=pos, table=table,
                              chunk_valid=chunk_valid, plan=plan)
    logits = compute_logits(params, cfg, x)
    return new_cache, logits

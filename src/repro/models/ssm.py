"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Chunked algorithm (ngroups=1), following `ssd_minimal_discrete`:
  - intra-chunk ("diagonal block"): the quadratic-attention dual inside each
    Q-token chunk, with the decay matrix L[l,s] = exp(cum[l]-cum[s]), l>=s;
  - inter-chunk: per-chunk terminal states combined with a DAG-structured
    ``lax.associative_scan`` (no while loop -> exact cost_analysis and
    log-depth on hardware).

The chunk size is a paper-knob: it is the burst/tile size of the `nest`-like
traversal (intra bytes/token ~ Q*H, state bytes/token ~ H*P*N/Q), and the
hillclimb sweeps it.  Decode is a single recurrent state update.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (CONV, EMBED, FF, HEADS, LAYERS, STATE,
                                 ParamBuilder, Sharder, causal_conv1d,
                                 conv_state_from, no_shard, rms_norm)


def dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    nheads = d_in // cfg.ssm_head_dim
    return d_in, nheads, cfg.ssm_head_dim, cfg.ssm_state


def init(b: ParamBuilder, path: str, cfg: ModelConfig, stacked: int = 0):
    d = cfg.d_model
    d_in, h, p_, n = dims(cfg)
    lead = (stacked,) if stacked else ()
    la = (LAYERS,) if stacked else ()
    proj_out = 2 * d_in + 2 * n + h
    b.dense(f"{path}.w_in", lead + (d, proj_out), la + (EMBED, FF))
    b.dense(f"{path}.conv_w", lead + (cfg.ssm_conv_width, d_in + 2 * n),
            la + (CONV, FF), scale=0.5)
    b.zeros(f"{path}.conv_b", lead + (d_in + 2 * n,), la + (FF,))
    b.const(f"{path}.a_log", jnp.zeros(lead + (h,)), la + (HEADS,))
    b.ones(f"{path}.d_skip", lead + (h,), la + (HEADS,))
    b.zeros(f"{path}.dt_bias", lead + (h,), la + (HEADS,))
    b.ones(f"{path}.norm", lead + (d_in,), la + (FF,))
    b.dense(f"{path}.w_out", lead + (d_in, d), la + (FF, EMBED))


def _split(p, x, cfg):
    d_in, h, _, n = dims(cfg)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["w_in"])
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:2 * d_in + 2 * n]
    dt = zxbcdt[..., 2 * d_in + 2 * n:]
    return z, xbc, dt


class SSDState(NamedTuple):
    state: jax.Array   # (B, H, P, N) fp32
    conv: jax.Array    # (B, K-1, d_in+2N)


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSDState:
    d_in, h, p_, n = dims(cfg)
    return SSDState(
        state=jnp.zeros((batch, h, p_, n), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, d_in + 2 * n), dtype),
    )


def forward(p, x, cfg: ModelConfig, shd: Sharder = no_shard,
            return_state: bool = False, state: Optional[SSDState] = None):
    """x: (B, S, d) -> (B, S, d) [, SSDState].  ``state`` continues a
    previous segment (chunked prefill): the conv reads its trailing context
    and the associative state-passing scan is seeded with ``state.state`` —
    mathematically identical to one unbroken sequence."""
    bsz, orig_s, _ = x.shape
    d_in, h, hp, n = dims(cfg)
    q = min(cfg.ssm_chunk, orig_s)
    pad = (-orig_s) % q

    z, xbc, dt = _split(p, x, cfg)
    conv_prev = None if state is None else state.conv
    conv_state = conv_state_from(xbc, cfg.ssm_conv_width, prev=conv_prev)
    xbc = jax.nn.silu(causal_conv1d(xbc, p["conv_w"], p["conv_b"],
                                    state=conv_prev))
    if pad:
        # identity-pad: dt is forced to 0 on padded steps (decay 1, input 0),
        # so outputs and the final state are exact.
        xbc = jnp.pad(xbc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)),
                     constant_values=-1e9)  # softplus(-1e9 + bias) == 0
    s = orig_s + pad
    nc = s // q
    xs = xbc[..., :d_in].reshape(bsz, s, h, hp)
    bmat = xbc[..., d_in:d_in + n]
    cmat = xbc[..., d_in + n:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = dt * a                                    # (B,S,H) log-decay
    xdt = xs.astype(jnp.float32) * dt[..., None]   # discretized input

    # chunk views
    csh = lambda t, *rest: t.reshape(bsz, nc, q, *rest)
    xc = csh(xdt, h, hp)
    dac = csh(da, h)
    bc = csh(bmat.astype(jnp.float32), n)
    cc = csh(cmat.astype(jnp.float32), n)

    cum = jnp.cumsum(dac, axis=2)                  # (B,C,Q,H)
    # --- intra-chunk (quadratic dual) ---
    scores = jnp.einsum("bcln,bcsn->bcls", cc, bc)
    ldec = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,C,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    ldec = jnp.where(tri[None, None, :, :, None], ldec, 0.0)
    y_diag = jnp.einsum("bcls,bclsh,bcshp->bclhp", scores, ldec, xc)

    # --- per-chunk terminal states + associative prefix ---
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)             # (B,C,Q,H)
    states_loc = jnp.einsum("bcsn,bcsh,bcshp->bchpn", bc, decay_states, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # (B,C,H)

    def combine(left, right):
        dl, sl = left
        dr, sr = right
        return dl * dr, sr + dr[..., None, None] * sl

    dec_all, st_all = jax.lax.associative_scan(
        combine, (chunk_decay, states_loc), axis=1)
    if state is not None:
        # fold the carried-in state through every chunk's cumulative decay
        h0 = state.state[:, None]                               # (B,1,H,P,N)
        st_all = st_all + dec_all[..., None, None] * h0
        prev = jnp.concatenate([h0, st_all[:, :-1]], axis=1)
    else:
        prev = jnp.concatenate(
            [jnp.zeros_like(st_all[:, :1]), st_all[:, :-1]], axis=1)

    # --- off-diagonal (state-passing) ---
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", cc, prev, jnp.exp(cum))

    y = (y_diag + y_off).reshape(bsz, s, h, hp)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, s, d_in)[:, :orig_s].astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"] - 1.0)  # gated norm
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])
    if return_state:
        final = st_all[:, -1]                                   # (B,H,P,N)
        return out, SSDState(state=final, conv=conv_state)
    return out


def decode_step(p, x, st: SSDState, cfg: ModelConfig):
    """x: (B, 1, d) -> (B, 1, d), new state."""
    bsz = x.shape[0]
    d_in, h, hp, n = dims(cfg)
    z, xbc, dt = _split(p, x, cfg)
    new_conv = conv_state_from(xbc, cfg.ssm_conv_width, prev=st.conv)
    xbc = jax.nn.silu(
        causal_conv1d(xbc, p["conv_w"], p["conv_b"], state=st.conv))
    xs = xbc[:, 0, :d_in].reshape(bsz, h, hp)
    bvec = xbc[:, 0, d_in:d_in + n]
    cvec = xbc[:, 0, d_in + n:]

    dt = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)                                        # (B,H)
    xdt = xs.astype(jnp.float32) * dt[..., None]
    state = st.state * da[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xdt, bvec.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", cvec.astype(jnp.float32), state)
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"] - 1.0)
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])
    return out, SSDState(state=state, conv=new_conv)

"""ModelBundle: one uniform interface over all architecture families.

``build(cfg, flags)`` returns a bundle exposing init / train_loss / prefill /
decode_step plus the abstract input/param/cache specs the dry-run lowers with
(ShapeDtypeStruct stand-ins, zero allocation).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DECODE, PREFILL, TRAIN, ModelConfig, ShapeCell
from repro.models import encdec, transformer
from repro.models.transformer import RuntimeFlags


@dataclass
class ModelBundle:
    cfg: ModelConfig
    flags: RuntimeFlags

    # ------------------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        params, _ = self._init_fn()(self.cfg, key)
        return params

    def abstract_params(self) -> Tuple[dict, dict]:
        """(ShapeDtypeStruct tree, logical-axes tree) — no allocation."""
        return self._init_fn()(self.cfg, None, abstract=True)

    def _init_fn(self):
        return encdec.init_params if self.cfg.enc_dec else transformer.init_params

    # ------------------------------------------------------------------
    def train_loss(self, params, batch):
        if self.cfg.enc_dec:
            return encdec.train_loss(params, self.cfg, self.flags, batch)
        return transformer.train_loss(params, self.cfg, self.flags, batch)

    def prefill(self, params, batch):
        if self.cfg.enc_dec:
            return encdec.prefill(params, self.cfg, self.flags, batch)
        return transformer.prefill(params, self.cfg, self.flags, batch)

    def decode_step(self, params, cache, tokens, pos):
        if self.cfg.enc_dec:
            return encdec.decode_step(params, self.cfg, self.flags, cache,
                                      tokens, pos)
        return transformer.decode_step(params, self.cfg, self.flags, cache,
                                       tokens, pos)

    # ------------------------------------------------------------------
    # paged KV backend (pure full-attention stacks; see paged_supported)
    # ------------------------------------------------------------------
    def paged_supported(self) -> bool:
        """True when the stack can serve from the shared page pools: every
        decoder-only stack qualifies — full attention grows a page table,
        sliding windows keep a rotating ring of pages, recurrent mixers
        (ssd/rglru) keep dense per-slot state beside the pools, int8 KV
        stores scale lanes, and the kernel has a softcap path.  Only
        enc-dec (split cache) and modality frontends fall back to the dense
        per-slot cache."""
        return transformer.paged_supported(self.cfg, self.flags.kv_dtype)

    def init_paged_cache(self, num_pages: int, page_size: int,
                         batch: int = 1, ring_pages: int = 0):
        return transformer.init_paged_cache(self.cfg, num_pages, page_size,
                                            batch=batch,
                                            ring_pages=ring_pages,
                                            kv_dtype=self.flags.kv_dtype)

    def paged_decode_step(self, params, cache, tokens, pos, table, plan=None,
                          active=None):
        return transformer.paged_decode_step(params, self.cfg, self.flags,
                                             cache, tokens, pos, table, plan,
                                             active)

    def paged_prefill_chunk(self, params, cache, tokens, pos, table,
                            chunk_valid, slot=None):
        return transformer.paged_prefill_chunk(params, self.cfg, self.flags,
                                               cache, tokens, pos, table,
                                               chunk_valid, slot)

    def paged_verify(self, params, cache, tokens, pos, table, chunk_valid,
                     plan=None):
        """Multi-token speculative verify: per-position logits (B, C, V)."""
        return transformer.paged_verify(params, self.cfg, self.flags, cache,
                                        tokens, pos, table, chunk_valid, plan)

    # ------------------------------------------------------------------
    # abstract specs for the dry-run
    # ------------------------------------------------------------------
    def input_specs(self, cell: ShapeCell) -> dict:
        """ShapeDtypeStruct stand-ins for every data input of the cell."""
        cfg = self.cfg
        b = cell.global_batch
        s = cell.seq_len
        i32 = jnp.int32
        cdt = jnp.dtype(cfg.compute_dtype)
        if cfg.enc_dec:
            if cell.kind == TRAIN or cell.kind == PREFILL:
                d = dict(
                    frames=jax.ShapeDtypeStruct((b, s, cfg.d_model), cdt),
                    dec_tokens=jax.ShapeDtypeStruct((b, s), i32))
                if cell.kind == TRAIN:
                    d["labels"] = jax.ShapeDtypeStruct((b, s), i32)
                return d
            return dict(tokens=jax.ShapeDtypeStruct((b, 1), i32),
                        pos=jax.ShapeDtypeStruct((), i32))
        if cell.kind in (TRAIN, PREFILL):
            d = {}
            if cfg.frontend:
                p = min(cfg.num_frontend_tokens, s // 2)
                d["patch_embeds"] = jax.ShapeDtypeStruct((b, p, cfg.d_model), cdt)
                d["tokens"] = jax.ShapeDtypeStruct((b, s - p), i32)
            else:
                d["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
            if cell.kind == TRAIN:
                d["labels"] = jax.ShapeDtypeStruct((b, s), i32)
            return d
        return dict(tokens=jax.ShapeDtypeStruct((b, 1), i32),
                    pos=jax.ShapeDtypeStruct((), i32))

    def cache_specs(self, cell: ShapeCell):
        """Abstract decode-cache tree for the cell (eval_shape, no alloc)."""
        cfg = self.cfg
        if cfg.enc_dec:
            fn = lambda: encdec.init_cache(cfg, cell.global_batch, cell.seq_len,
                                           cell.seq_len)
        else:
            fn = lambda: transformer.init_cache(cfg, cell.global_batch,
                                                cell.seq_len,
                                                self.flags.kv_dtype)
        return jax.eval_shape(fn)

    def init_cache(self, batch: int, max_len: int, enc_len: Optional[int] = None):
        if self.cfg.enc_dec:
            return encdec.init_cache(self.cfg, batch, max_len,
                                     enc_len or max_len)
        return transformer.init_cache(self.cfg, batch, max_len,
                                      self.flags.kv_dtype)


def build(cfg: ModelConfig, flags: Optional[RuntimeFlags] = None) -> ModelBundle:
    return ModelBundle(cfg=cfg, flags=flags or RuntimeFlags())

"""Mixture-of-Experts FFN with two dispatch strategies.

``dense``  — every expert computes every token, combined by gate weights.
             Robust and shape-static, but HLO FLOPs inflate by E/k: the
             advisor flags this as the `r_acc -> rs_tra` conversion, sensible
             only for tiny experts (used by smoke tests).
``sorted``  — capacity-based sort dispatch (MegaBlocks-style): tokens are
             grouped, argsorted by expert id within each group, packed into a
             (groups, E, capacity, d) buffer, run through batched expert
             GEGLU matmuls, and combined back with gates.  This keeps HLO
             FLOPs ~ cf * active FLOPs and keeps the sort local to a group
             (no cross-device sort when groups shard over data).

Both produce identical outputs when capacity is not exceeded (property-tested).
Routing: softmax router, top-k, renormalized gates; Switch-style load-balance
aux loss returned alongside.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (EMBED, EXPERT, FF, LAYERS, ParamBuilder,
                                 Sharder, no_shard)
from repro.models import mlp as dense_mlp

_ACT = {
    "swiglu": jax.nn.silu,
    "geglu": lambda x: jax.nn.gelu(x, approximate=True),
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
}


def init(b: ParamBuilder, path: str, d: int, f: int, n_exp: int,
         activation: str, stacked: int = 0):
    lead = (stacked,) if stacked else ()
    lax_ = (LAYERS,) if stacked else ()
    gated = activation in ("swiglu", "geglu")
    b.dense(f"{path}.router", lead + (d, n_exp), lax_ + (EMBED, None))
    if gated:
        b.dense(f"{path}.w_gate", lead + (n_exp, d, f), lax_ + (EXPERT, EMBED, FF))
    b.dense(f"{path}.w_up", lead + (n_exp, d, f), lax_ + (EXPERT, EMBED, FF))
    b.dense(f"{path}.w_down", lead + (n_exp, f, d), lax_ + (EXPERT, FF, EMBED))


def _route(p, x, k: int):
    """x: (..., d) -> (gates (..., k), ids (..., k), router probs)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, ids, probs


def _lb_loss(probs, ids, n_exp: int) -> jax.Array:
    """Switch load-balance loss: E * dot(mean_prob, mean_assign)."""
    me = jnp.mean(probs.reshape(-1, n_exp), axis=0)
    assign = jax.nn.one_hot(ids.reshape(-1), n_exp).mean(axis=0)
    return n_exp * jnp.sum(me * assign)


def _expert_ffn(p, h, activation):
    """h: (..., E, C, d) batched per-expert FFN."""
    act = _ACT[activation]
    up = jnp.einsum("gecd,edf->gecf", h, p["w_up"])
    if "w_gate" in p:
        gate = jnp.einsum("gecd,edf->gecf", h, p["w_gate"])
        hh = act(gate) * up
    else:
        hh = act(up)
    return jnp.einsum("gecf,efd->gecd", hh, p["w_down"])


def apply_dense(p, x, k: int, activation: str, shd: Sharder = no_shard):
    """Weighted sum over all experts (smoke-scale)."""
    n_exp = p["router"].shape[-1]
    gates, ids, probs = _route(p, x, k)
    w = (jax.nn.one_hot(ids, n_exp) * gates[..., None]).sum(-2)  # (b,s,E)
    act = _ACT[activation]
    up = jnp.einsum("bsd,edf->bsef", x, p["w_up"])
    if "w_gate" in p:
        hh = act(jnp.einsum("bsd,edf->bsef", x, p["w_gate"])) * up
    else:
        hh = act(up)
    out = jnp.einsum("bsef,efd,bse->bsd", hh, p["w_down"], w.astype(x.dtype))
    return out, _lb_loss(probs, ids, n_exp)


def apply_sorted(p, x, k: int, activation: str, shd: Sharder = no_shard,
                 group_size: int = 1024, capacity_factor: float = 1.25):
    """Capacity-based sort dispatch.  x: (B, S, d)."""
    bsz, s, d = x.shape
    n_exp = p["router"].shape[-1]
    gates, ids, probs = _route(p, x, k)
    aux = _lb_loss(probs, ids, n_exp)

    g_sz = min(group_size, s)
    n_grp = (bsz * s) // g_sz
    cap = int(max(k, k * g_sz * capacity_factor // n_exp))

    xt = x.reshape(n_grp, g_sz, d)
    ids_g = ids.reshape(n_grp, g_sz * k)
    gates_g = gates.reshape(n_grp, g_sz * k).astype(x.dtype)

    order = jnp.argsort(ids_g, axis=-1)                      # (G, g*k)
    sorted_ids = jnp.take_along_axis(ids_g, order, axis=-1)
    tok_of = order // k                                      # source token
    # rank within expert = position - first occurrence of that expert id
    first = jax.vmap(lambda a: jnp.searchsorted(a, a, side="left"))(sorted_ids)
    rank = jnp.arange(g_sz * k)[None, :] - first
    keep = rank < cap
    slot = jnp.where(keep, sorted_ids * cap + rank, n_exp * cap)  # overflow row

    # pack -> (G, E*cap + 1, d)
    src = jnp.take_along_axis(
        xt, tok_of[..., None].clip(0, g_sz - 1), axis=1)     # (G, g*k, d)
    buf = jnp.zeros((n_grp, n_exp * cap + 1, d), x.dtype)
    buf = jax.vmap(lambda b_, s_, v_: b_.at[s_].set(v_))(buf, slot, src)
    h = buf[:, :-1].reshape(n_grp, n_exp, cap, d)
    h = shd(h, ("batch", None, None, None))

    out_e = _expert_ffn(p, h, activation)                    # (G, E, cap, d)

    flat = out_e.reshape(n_grp, n_exp * cap, d)
    flat = jnp.concatenate(
        [flat, jnp.zeros((n_grp, 1, d), x.dtype)], axis=1)   # overflow -> 0
    picked = jax.vmap(lambda f_, s_: f_[s_])(flat, slot)     # (G, g*k, d)
    sorted_gates = jnp.take_along_axis(gates_g, order, axis=-1)
    contrib = picked * jnp.where(keep, sorted_gates, 0.0)[..., None]
    out = jnp.zeros((n_grp, g_sz, d), x.dtype)
    out = jax.vmap(lambda o_, t_, c_: o_.at[t_].add(c_))(out, tok_of, contrib)
    return out.reshape(bsz, s, d), aux


def apply(p, x, k: int, activation: str, impl: str = "sorted",
          shd: Sharder = no_shard, group_size: int = 1024,
          capacity_factor: float = 1.25):
    if impl == "dense":
        return apply_dense(p, x, k, activation, shd)
    return apply_sorted(p, x, k, activation, shd, group_size, capacity_factor)

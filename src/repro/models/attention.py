"""GQA/MQA attention with three interchangeable inner loops:

- ``naive``   — materialized scores; exact oracle; used for decode (Sq=1) and
                roofline-mode compiles (no inner while loop -> exact
                cost_analysis; identical matmul FLOPs to chunked).
- ``chunked`` — double lax.scan (q blocks x kv blocks) online softmax; the
                paper's `nest` blocking in pure JAX: differentiable, O(bq*bkv)
                memory, default for train/prefill.
- ``pallas``  — the flash-attention kernel (TPU target; oracle-checked).

All support causal masks, sliding windows, softcap, GQA grouping and an
absolute position offset (decode / right-aligned caches).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import common

NEG_INF = -1e30


class AttnParams(NamedTuple):
    impl: str = "chunked"          # naive | chunked | pallas
    causal: bool = True
    window: Optional[int] = None
    softcap: Optional[float] = None
    scale: Optional[float] = None
    # None = derive from the tuned KernelPlan for this call's shape/dtype
    # (repro.tune — the closed tune->execute loop); ints pin the blocks.
    bq: Optional[int] = None
    bkv: Optional[int] = None


def resolve_blocks(p: AttnParams, q, k) -> tuple:
    """(bq, bkv) for a blocked impl: explicit AttnParams win; ``None`` falls
    back to the cached :class:`repro.tune.KernelPlan` for
    ``(Sq, Skv, D, dtype)`` — the autotuner's choice applied as the default."""
    if p.bq is not None and p.bkv is not None:
        return p.bq, p.bkv
    from repro.tune import plan_for
    plan = plan_for("flash_attention",
                    shape_sig=(q.shape[1], k.shape[1], q.shape[-1]),
                    dtype=str(q.dtype))
    return (p.bq if p.bq is not None else plan.bq,
            p.bkv if p.bkv is not None else plan.bkv)


def _mask(q_pos, k_pos, causal, window, kv_valid_len=None):
    m = jnp.ones(jnp.broadcast_shapes(q_pos.shape, k_pos.shape), bool)
    if causal:
        m &= q_pos >= k_pos
    if window is not None:
        m &= (q_pos - k_pos) < window
    if kv_valid_len is not None:
        m &= k_pos < kv_valid_len
    return m


def naive_attention(q, k, v, p: AttnParams, q_offset=0, kv_valid_len=None,
                    k_positions=None):
    """q: (B,Sq,Hq,D); k/v: (B,Skv,Hkv,D) -> (B,Sq,Hq,D).

    ``q_offset`` / ``kv_valid_len``: scalar or per-batch (B,) — continuous
    batching serves requests at different positions in one step.
    ``k_positions``: explicit kv positions (B, Skv) for ring-buffer caches
    (negative = empty slot).
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = p.scale if p.scale is not None else d ** -0.5
    qg = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    s = common.softcap(s, p.softcap)
    q_off = jnp.reshape(jnp.asarray(q_offset, jnp.int32), (-1, 1, 1))
    q_pos = q_off + jnp.arange(sq, dtype=jnp.int32)[None, :, None]  # (B?,sq,1)
    if k_positions is None:
        k_pos = jnp.arange(skv, dtype=jnp.int32)[None, None, :]
    else:
        k_pos = jnp.asarray(k_positions, jnp.int32)[:, None, :]     # (B,1,skv)
    kvl = (None if kv_valid_len is None
           else jnp.reshape(jnp.asarray(kv_valid_len, jnp.int32), (-1, 1, 1)))
    m = _mask(q_pos, k_pos, p.causal, p.window, kvl)
    if k_positions is not None:
        m &= k_pos >= 0
    s = jnp.where(m[:, None, None], s, NEG_INF)   # (B?,hkv,g,sq,skv)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pr, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, d).astype(q.dtype)


def paged_gather_attention(q, k_pages, v_pages, page_table, p: AttnParams,
                           q_offset, kv_valid_len, k_scale=None,
                           v_scale=None):
    """Chunked-prefill (extend) attention over a paged KV cache.

    q: (B, C, Hq, D) — a prompt *chunk* at absolute offset ``q_offset``;
    k/v_pages: (P, page, Hkv, D); page_table: (B, N).  The table is
    dereferenced with a dense gather — logical page j of row b covers
    absolute positions ``[j*page, (j+1)*page)``, so the gathered view is
    position-exact and the oracle's causal mask + ``kv_valid_len`` apply
    unchanged.  ``k_scale``/``v_scale`` (P, page) dequantize int8 pages per
    token.  Decode (C=1) uses the Pallas ``paged_attention`` kernel
    instead; prefill chunks are wide enough that the gather amortizes (the
    paper's unit-size rule is already baked into the page size).
    """
    b, n = page_table.shape
    page = k_pages.shape[1]
    kd = k_pages[page_table]
    vd = v_pages[page_table]
    if k_scale is not None:
        kd = kd.astype(jnp.float32) * k_scale[page_table][..., None, None]
        vd = vd.astype(jnp.float32) * v_scale[page_table][..., None, None]
    kd = kd.reshape(b, n * page, *k_pages.shape[2:])
    vd = vd.reshape(b, n * page, *v_pages.shape[2:])
    return naive_attention(q, kd.astype(q.dtype), vd.astype(q.dtype), p,
                           q_offset=q_offset, kv_valid_len=kv_valid_len)


# ---------------------------------------------------------------------------
# TP shard_map islands over the paged dispatches
# ---------------------------------------------------------------------------
# The serve-side tensor-parallel split (the paper's multi-bank / channel-
# interleaving axis): attention heads and the KV page pools partition over
# one mesh axis, page tables and valid lengths replicate, and each shard
# walks ITS OWN slice of the pools — every device streams pages from its
# own HBM stack, so aggregate KV bandwidth scales with the axis size.
# Placement is explicit (shard_map, not GSPMD inference) because the Pallas
# kernel's BlockSpec index_map dereferences the table: the partitioner
# cannot see that page ids are head-invariant, so left to itself it would
# all-gather the pools.  GQA stays shard-local: with tp dividing both Hq
# and Hkv, contiguous head blocks keep every query group and its kv head on
# the same shard (group size g = Hq/Hkv is shard-invariant).

def tp_shardable(mesh, axis: str, hq: int, hkv: int) -> bool:
    """True when the paged dispatches can run as per-shard islands."""
    if mesh is None or axis not in mesh.shape:
        return False
    tp = mesh.shape[axis]
    return tp > 1 and hq % tp == 0 and hkv % tp == 0


def _tp_island(mesh, axis, body, args, in_specs, out_spec):
    from jax.experimental.shard_map import shard_map
    fn = shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=out_spec, check_rep=False)
    return fn(*args)


def tp_paged_attention(mesh, axis: str, q, k_pages, v_pages, page_table,
                       valid_len, *, scale=None, softcap=None, window=None,
                       k_scale=None, v_scale=None, plan=None):
    """Decode-mode island: q (B, Hq, D) and the pools partition on heads,
    table/valid_len replicate; each shard dispatches the Pallas kernel over
    its head slice.  Output stays head-partitioned — the o-projection's
    contraction (GSPMD) reduces across shards."""
    from jax.sharding import PartitionSpec as P
    quant = k_scale is not None

    def body(q_, kp_, vp_, tbl_, vl_, *sc):
        ks_, vs_ = sc if quant else (None, None)
        return kops.paged_attention(q_, kp_, vp_, tbl_, vl_, scale=scale,
                                    softcap=softcap, window=window,
                                    k_scale=ks_, v_scale=vs_, plan=plan)

    pool = P(None, None, axis, None)
    args = [q, k_pages, v_pages, page_table, valid_len]
    specs = [P(None, axis, None), pool, pool, P(None, None), P(None)]
    if quant:
        args += [k_scale, v_scale]
        specs += [P(None, None), P(None, None)]
    return _tp_island(mesh, axis, body, args, specs, P(None, axis, None))


def tp_paged_gather_attention(mesh, axis: str, q, k_pages, v_pages,
                              page_table, p: AttnParams, q_offset,
                              kv_valid_len, k_scale=None, v_scale=None):
    """Extend/verify-mode island: q (B, C, Hq, D) partitions on heads; the
    dense table gather runs per shard over its own pool slice, so chunked
    prefill and multi-token verify never move another shard's pages."""
    from jax.sharding import PartitionSpec as P
    quant = k_scale is not None

    def body(q_, kp_, vp_, tbl_, off_, vl_, *sc):
        ks_, vs_ = sc if quant else (None, None)
        return paged_gather_attention(q_, kp_, vp_, tbl_, p, q_offset=off_,
                                      kv_valid_len=vl_, k_scale=ks_,
                                      v_scale=vs_)

    pool = P(None, None, axis, None)
    args = [q, k_pages, v_pages, page_table, q_offset, kv_valid_len]
    specs = [P(None, None, axis, None), pool, pool, P(None, None), P(None),
             P(None)]
    if quant:
        args += [k_scale, v_scale]
        specs += [P(None, None), P(None, None)]
    return _tp_island(mesh, axis, body, args, specs,
                      P(None, None, axis, None))


def chunked_attention(q, k, v, p: AttnParams, q_offset=0, kv_valid_len=None):
    """Online-softmax double scan (the `nest` transformation) with a
    flash-style custom VJP: the backward recomputes score blocks from
    (q, k, v, out, lse) residuals instead of letting autodiff save every
    inner-scan accumulator (which costs O(nq*nkv) fp32 blocks per layer).
    Non-divisible lengths are padded internally and masked out."""
    orig_sq, orig_skv = q.shape[1], k.shape[1]
    bq, bkv = resolve_blocks(p, q, k)
    bq = min(bq, orig_sq)
    bkv = min(bkv, orig_skv)
    pad_q = (-orig_sq) % bq
    pad_kv = (-orig_skv) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        if kv_valid_len is None:
            kv_valid_len = orig_skv
    meta = _FlashMeta(
        causal=p.causal, window=p.window, softcap=p.softcap,
        scale=p.scale if p.scale is not None else q.shape[-1] ** -0.5,
        bq=bq, bkv=bkv, q_offset=int(q_offset),
        kv_valid_len=None if kv_valid_len is None else int(kv_valid_len))
    out = _flash(meta, q, k, v)
    return out[:, :orig_sq]


class _FlashMeta(NamedTuple):
    causal: bool
    window: Optional[int]
    softcap: Optional[float]
    scale: float
    bq: int
    bkv: int
    q_offset: int
    kv_valid_len: Optional[int]


def _blocks(meta, q, k, v):
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    nq, nkv = sq // meta.bq, skv // meta.bkv
    qb = jnp.moveaxis(
        q.reshape(b, nq, meta.bq, hkv, g, d).astype(jnp.float32)
        * meta.scale, 1, 0)
    kb = jnp.moveaxis(k.reshape(b, nkv, meta.bkv, hkv, d).astype(jnp.float32), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nkv, meta.bkv, hkv, d).astype(jnp.float32), 1, 0)
    return qb, kb, vb, (b, sq, hq, d, skv, hkv, g, nq, nkv)


def _block_scores(meta, q_blk, k_blk, qi, kj):
    """returns (s_capped, dsoftcap, mask) for block (qi, kj)."""
    s = jnp.einsum("bqhgd,bkhd->bqhgk", q_blk, k_blk)
    if meta.softcap is not None:
        s_c = common.softcap(s, meta.softcap)
        dsoft = 1.0 - jnp.square(s_c / meta.softcap)
    else:
        s_c, dsoft = s, None
    q_pos = meta.q_offset + qi * meta.bq + jnp.arange(meta.bq)[:, None]
    k_pos = kj * meta.bkv + jnp.arange(meta.bkv)[None, :]
    msk = _mask(q_pos, k_pos, meta.causal, meta.window, meta.kv_valid_len)
    return s_c, dsoft, msk[None, :, None, None, :]


def _flash_fwd_impl(meta: _FlashMeta, q, k, v):
    qb, kb, vb, (b, sq, hq, d, skv, hkv, g, nq, nkv) = _blocks(meta, q, k, v)

    def q_step(_, q_blk_i):
        q_blk, qi = q_blk_i

        def kv_step(carry, kv_blk_j):
            m_p, l_p, acc = carry
            k_blk, v_blk, kj = kv_blk_j
            s_c, _, msk = _block_scores(meta, q_blk, k_blk, qi, kj)
            s_c = jnp.where(msk, s_c, NEG_INF)
            m_n = jnp.maximum(m_p, jnp.max(s_c, axis=-1))
            pr = jnp.exp(s_c - m_n[..., None])
            alpha = jnp.exp(m_p - m_n)
            l_n = l_p * alpha + jnp.sum(pr, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", pr, v_blk)
            return (m_n, l_n, acc), None

        init = (jnp.full((b, meta.bq, hkv, g), NEG_INF, jnp.float32),
                jnp.zeros((b, meta.bq, hkv, g), jnp.float32),
                jnp.zeros((b, meta.bq, hkv, g, d), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, init, (kb, vb, jnp.arange(nkv)))
        out_i = acc / jnp.maximum(l_f, 1e-30)[..., None]
        # +LARGE on empty rows so recomputed p underflows to exactly 0
        lse_i = jnp.where(l_f > 0, m_f + jnp.log(jnp.maximum(l_f, 1e-30)),
                          jnp.float32(1e30))
        return None, (out_i, lse_i)

    _, (ob, lseb) = jax.lax.scan(q_step, None, (qb, jnp.arange(nq)))
    out = jnp.moveaxis(ob, 0, 1).reshape(b, sq, hq, d).astype(q.dtype)
    return out, lseb  # lseb: (nq, b, bq, hkv, g) fp32


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(meta: _FlashMeta, q, k, v):
    return _flash_fwd_impl(meta, q, k, v)[0]


def _flash_fwd(meta, q, k, v):
    out, lseb = _flash_fwd_impl(meta, q, k, v)
    return out, (q, k, v, out, lseb)


def _flash_bwd(meta, res, dout):
    q, k, v, out, lseb = res
    qb, kb, vb, (b, sq, hq, d, skv, hkv, g, nq, nkv) = _blocks(meta, q, k, v)
    dob = jnp.moveaxis(
        dout.reshape(b, nq, meta.bq, hkv, g, d).astype(jnp.float32), 1, 0)
    outb = jnp.moveaxis(
        out.reshape(b, nq, meta.bq, hkv, g, d).astype(jnp.float32), 1, 0)
    # D_i = rowsum(dO ∘ O)
    db = jnp.sum(dob * outb, axis=-1)  # (nq, b, bq, hkv, g)

    def q_step(carry, xs):
        dk_acc, dv_acc = carry            # (nkv, b, bkv, hkv, d) fp32
        q_blk, do_blk, d_blk, lse_blk, qi = xs

        def kv_step(inner, kv_blk_j):
            dq_i, dk_acc, dv_acc = inner
            k_blk, v_blk, kj = kv_blk_j
            s_c, dsoft, msk = _block_scores(meta, q_blk, k_blk, qi, kj)
            pr = jnp.where(msk, jnp.exp(s_c - lse_blk[..., None]), 0.0)
            dv_j = jnp.einsum("bqhgk,bqhgd->bkhd", pr, do_blk)
            dp = jnp.einsum("bqhgd,bkhd->bqhgk", do_blk, v_blk)
            ds = pr * (dp - d_blk[..., None])
            if dsoft is not None:
                ds = ds * dsoft
            dq_i = dq_i + jnp.einsum("bqhgk,bkhd->bqhgd", ds, k_blk)
            dk_j = jnp.einsum("bqhgk,bqhgd->bkhd", ds, q_blk)
            dk_acc = dk_acc.at[kj].add(dk_j)
            dv_acc = dv_acc.at[kj].add(dv_j)
            return (dq_i, dk_acc, dv_acc), None

        dq0 = jnp.zeros((b, meta.bq, hkv, g, d), jnp.float32)
        (dq_i, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), (kb, vb, jnp.arange(nkv)))
        return (dk_acc, dv_acc), dq_i

    zeros_kv = jnp.zeros((nkv, b, meta.bkv, hkv, d), jnp.float32)
    (dk_acc, dv_acc), dqb = jax.lax.scan(
        q_step, (zeros_kv, zeros_kv),
        (qb, dob, db, lseb, jnp.arange(nq)))
    # dq was computed on q*scale
    dq = (jnp.moveaxis(dqb, 0, 1).reshape(b, sq, hq, d)
          * meta.scale).astype(q.dtype)
    dk = jnp.moveaxis(dk_acc, 0, 1).reshape(b, skv, hkv, d).astype(k.dtype)
    dv = jnp.moveaxis(dv_acc, 0, 1).reshape(b, skv, hkv, d).astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def unrolled_attention(q, k, v, p: AttnParams, q_offset=0, kv_valid_len=None):
    """Roofline-mode impl: identical blocking/math to ``chunked`` but with
    python-unrolled block loops (no lax.scan), so XLA cost_analysis counts
    every block.  Statically skips fully-masked (causal / out-of-window)
    blocks — what a production kernel grid does."""
    orig_sq, orig_skv = q.shape[1], k.shape[1]
    bq, bkv = resolve_blocks(p, q, k)
    bq = min(bq, orig_sq)
    bkv = min(bkv, orig_skv)
    pad_q = (-orig_sq) % bq
    pad_kv = (-orig_skv) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        if kv_valid_len is None:
            kv_valid_len = orig_skv
    meta = _FlashMeta(
        causal=p.causal, window=p.window, softcap=p.softcap,
        scale=p.scale if p.scale is not None else q.shape[-1] ** -0.5,
        bq=bq, bkv=bkv, q_offset=int(q_offset),
        kv_valid_len=None if kv_valid_len is None else int(kv_valid_len))
    qb, kb, vb, (b, sq, hq, d, skv, hkv, g, nq, nkv) = _blocks(meta, q, k, v)

    outs = []
    for i in range(nq):
        q_lo = meta.q_offset + i * bq
        q_hi = q_lo + bq - 1
        m_p = jnp.full((b, bq, hkv, g), NEG_INF, jnp.float32)
        l_p = jnp.zeros((b, bq, hkv, g), jnp.float32)
        acc = jnp.zeros((b, bq, hkv, g, d), jnp.float32)
        for j in range(nkv):
            k_lo, k_hi = j * bkv, (j + 1) * bkv - 1
            if meta.causal and k_lo > q_hi:
                continue  # block entirely in the future
            if meta.window is not None and (q_lo - k_hi) >= meta.window:
                continue  # block entirely out of the window
            if meta.kv_valid_len is not None and k_lo >= meta.kv_valid_len:
                continue
            # the named scope lets core.roofline attribute these bytes to the
            # kernel-fusable inner loop (VMEM-resident in the Pallas version)
            with jax.named_scope("flash_inner"):
                s_c, _, msk = _block_scores(meta, qb[i], kb[j], i, j)
                s_c = jnp.where(msk, s_c, NEG_INF)
                m_n = jnp.maximum(m_p, jnp.max(s_c, axis=-1))
                pr = jnp.exp(s_c - m_n[..., None])
                alpha = jnp.exp(m_p - m_n)
                l_p = l_p * alpha + jnp.sum(pr, axis=-1)
                acc = acc * alpha[..., None] + jnp.einsum(
                    "bqhgk,bkhd->bqhgd", pr, vb[j])
                m_p = m_n
        outs.append(acc / jnp.maximum(l_p, 1e-30)[..., None])
    out = jnp.stack(outs)  # (nq, b, bq, hkv, g, d)
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hq, d)
    return out[:, :orig_sq].astype(q.dtype)


def pallas_attention(q, k, v, p: AttnParams, q_offset=0, kv_valid_len=None):
    assert q_offset == 0 and kv_valid_len is None, (
        "pallas path serves full-block prefill; decode uses naive")
    bq, bkv = resolve_blocks(p, q, k)
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = kops.flash_attention(
        qt, kt, vt, causal=p.causal, window=p.window, softcap=p.softcap,
        scale=p.scale, bq=min(bq, q.shape[1]), bkv=min(bkv, k.shape[1]))
    return jnp.swapaxes(o, 1, 2)


IMPLS = {
    "naive": naive_attention,
    "chunked": chunked_attention,
    "unrolled": unrolled_attention,
    "pallas": pallas_attention,
}


def attention(q, k, v, p: AttnParams, q_offset=0, kv_valid_len=None):
    if q.shape[1] == 1:  # decode: one query — naive is optimal
        return naive_attention(q, k, v, p, q_offset, kv_valid_len)
    return IMPLS[p.impl](q, k, v, p, q_offset, kv_valid_len)

"""Dense FFN: SwiGLU / GeGLU / plain-GELU variants."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import EMBED, FF, LAYERS, ParamBuilder, Sharder, no_shard

_ACT = {
    "swiglu": jax.nn.silu,
    "geglu": lambda x: jax.nn.gelu(x, approximate=True),
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
}


def init(b: ParamBuilder, path: str, d: int, f: int, activation: str,
         stacked: int = 0):
    """stacked>0 prepends a LAYERS axis (scan-stacked params)."""
    lead = (stacked,) if stacked else ()
    lax_ = (LAYERS,) if stacked else ()
    gated = activation in ("swiglu", "geglu")
    if gated:
        b.dense(f"{path}.w_gate", lead + (d, f), lax_ + (EMBED, FF))
        b.dense(f"{path}.w_up", lead + (d, f), lax_ + (EMBED, FF))
    else:
        b.dense(f"{path}.w_up", lead + (d, f), lax_ + (EMBED, FF))
    b.dense(f"{path}.w_down", lead + (f, d), lax_ + (FF, EMBED))


def apply(p: dict, x: jax.Array, activation: str, shd: Sharder = no_shard) -> jax.Array:
    act = _ACT[activation]
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = act(gate) * up
    else:
        h = act(up)
    h = shd(h, ("batch", None, "ff"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])

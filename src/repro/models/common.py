"""Shared model components: norms, RoPE, embeddings, init helpers.

Parameter trees are built through :class:`ParamBuilder`, which records a
parallel tree of *logical axis names* for every tensor; ``repro.dist.sharding``
maps logical axes -> mesh axes (with divisibility fallback).  Model code never
mentions mesh axes directly.
"""
from __future__ import annotations

import math
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# logical axis names
BATCH, SEQ, EMBED, HEADS, KV_HEADS, HEAD_DIM, FF, VOCAB = (
    "batch", "seq", "embed", "heads", "kv_heads", "head_dim", "ff", "vocab")
EXPERT, LAYERS, STATE, CONV = "expert", "layers", "state", "conv"

Sharder = Callable[[jax.Array, Tuple[Optional[str], ...]], jax.Array]


def no_shard(x: jax.Array, axes) -> jax.Array:
    return x


class ParamBuilder:
    """Collects (param, logical-axes) pairs under nested dict paths.

    ``abstract=True`` builds ShapeDtypeStructs instead of arrays (zero
    compute/memory) — how the dry-run gets 314B-parameter trees."""

    def __init__(self, key: Optional[jax.Array], param_dtype, abstract: bool = False):
        self.key = key
        self.dtype = param_dtype
        self.abstract = abstract
        self.params: dict = {}
        self.specs: dict = {}

    def _split(self):
        if self.abstract:
            return None
        self.key, sub = jax.random.split(self.key)
        return sub

    def _put(self, path: str, value, axes):
        parts = path.split(".")
        p, s = self.params, self.specs
        for part in parts[:-1]:
            p = p.setdefault(part, {})
            s = s.setdefault(part, {})
        p[parts[-1]] = value
        s[parts[-1]] = tuple(axes)

    def dense(self, path: str, shape: Sequence[int], axes: Sequence[Optional[str]],
              scale: Optional[float] = None):
        if self.abstract:
            self._put(path, jax.ShapeDtypeStruct(tuple(shape), self.dtype), axes)
            return
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
        w = (jax.random.truncated_normal(self._split(), -2, 2, shape, jnp.float32)
             * std).astype(self.dtype)
        self._put(path, w, axes)

    def zeros(self, path: str, shape, axes):
        if self.abstract:
            self._put(path, jax.ShapeDtypeStruct(tuple(shape), self.dtype), axes)
            return
        self._put(path, jnp.zeros(shape, self.dtype), axes)

    def ones(self, path: str, shape, axes):
        if self.abstract:
            self._put(path, jax.ShapeDtypeStruct(tuple(shape), self.dtype), axes)
            return
        self._put(path, jnp.ones(shape, self.dtype), axes)

    def const(self, path: str, value, axes, dtype=None):
        if self.abstract:
            shape = jnp.shape(value)
            self._put(path, jax.ShapeDtypeStruct(shape, dtype or self.dtype), axes)
            return
        self._put(path, jnp.asarray(value, dtype or self.dtype), axes)


# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def causal_conv1d(x: jax.Array, w: jax.Array, bias: Optional[jax.Array] = None,
                  state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv.  x: (B, S, C); w: (K, C).  ``state``: (B, K-1, C)
    trailing context from a previous segment (decode), else zero-padded."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+K-1, C)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    if bias is not None:
        out = out + bias[None, None, :]
    return out


def conv_state_from(x: jax.Array, k: int, prev: Optional[jax.Array] = None) -> jax.Array:
    """Trailing (K-1) inputs to carry as decode conv state."""
    if prev is not None:
        x = jnp.concatenate([prev, x], axis=1)
    return x[:, -(k - 1):, :]


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None,
                  z_loss: float = 0.0) -> jax.Array:
    """Mean next-token CE in fp32.  labels -100 are ignored."""
    logits = logits.astype(jnp.float32)
    valid = labels >= 0
    if mask is not None:
        valid &= mask.astype(bool)
    safe = jnp.where(valid, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * valid
    if z_loss:
        nll = nll + z_loss * jnp.square(lse) * valid
    denom = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(nll) / denom

from repro.models.registry import ModelBundle, build  # noqa: F401
from repro.models.transformer import RuntimeFlags  # noqa: F401

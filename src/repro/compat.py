"""Version shims so one codebase runs across jax releases.

The sharding surface this repo codes against (``jax.set_mesh``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``) stabilized
after jax 0.4.x.  On older jaxlib builds (the pinned CI/toolchain version is
0.4.37) those names are absent, so :func:`install` backfills them with
behavior-compatible equivalents:

- ``jax.sharding.AxisType`` -> a placeholder enum (Auto/Explicit/Manual).
  Pre-0.5 meshes have no per-axis type; every axis behaves as ``Auto``,
  which is the only mode this repo uses.
- ``jax.make_mesh`` -> wrapped to accept and drop ``axis_types``.
- ``jax.set_mesh`` -> a context manager entering the ``Mesh`` context
  (the ambient-mesh mechanism of that era; ``repro.dist`` always passes
  explicit ``NamedSharding``s, so the ambient mesh only needs to exist).

``install()`` is idempotent and a no-op on jax versions that already ship
the real APIs.  It runs from ``repro/__init__`` so any ``import repro.*``
guarantees the surface exists before model/test code touches it.
"""
from __future__ import annotations

import contextlib
import enum
import functools
import inspect

import jax


def _has_axis_types_kwarg() -> bool:
    try:
        return "axis_types" in inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):
        return True  # can't introspect -> assume modern jax


def install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if not _has_axis_types_kwarg():
        _orig_make_mesh = jax.make_mesh

        @functools.wraps(_orig_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            del axis_types  # pre-0.5 meshes are implicitly Auto on every axis
            return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    if not hasattr(jax, "set_mesh"):
        @contextlib.contextmanager
        def set_mesh(mesh):
            with mesh:
                yield mesh

        jax.set_mesh = set_mesh

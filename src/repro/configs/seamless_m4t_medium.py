"""seamless-m4t-medium — enc-dec multimodal backbone [arXiv:2308.11596].

12L d_model=1024 16H (kv=16) d_ff=4096 vocab=256206.  Per the assignment, the
audio frontend is a STUB: the encoder consumes precomputed frame embeddings
(B, S, d_model).  12 encoder + 12 decoder layers; decoder layers add
cross-attention over the encoder memory.  Decode shapes lower ``serve_step``
(decoder self-attn KV cache + cross-attn to a seq_len-long encoder memory).
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256_206,
    layer_pattern=(LayerSpec(),),
    activation="gelu",
    enc_dec=True,
    num_encoder_layers=12,
    frontend="frames",
    rope_theta=10_000.0,
)

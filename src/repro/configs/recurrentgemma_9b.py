"""recurrentgemma-9b — Griffin: RG-LRU + local attention, 1:2 [arXiv:2402.19427].

38L d_model=4096 16H (GQA kv=1, i.e. MQA) d_ff=12288 vocab=256000.  Pattern is
(recurrent, recurrent, local-attn window 2048); 38 = 12 triples + 2 remainder
recurrent layers (matches the HF ``block_types[i % 3]`` layout exactly).
Sub-quadratic (bounded attention window) => runs long_500k.
"""
from repro.configs.base import ATTN, RGLRU, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12_288,
    vocab_size=256_000,
    layer_pattern=(
        LayerSpec(mixer=RGLRU),
        LayerSpec(mixer=RGLRU),
        LayerSpec(mixer=ATTN, sliding_window=2048),
    ),
    lru_width=4096,
    activation="geglu",
    tie_embeddings=True,
    normalize_embedding=True,
    rope_theta=10_000.0,
)

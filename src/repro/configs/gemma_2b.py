"""gemma-2b — GeGLU, head_dim=256, MQA [arXiv:2403.08295].

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=256000.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16_384,
    vocab_size=256_000,
    layer_pattern=(LayerSpec(),),
    activation="geglu",
    tie_embeddings=True,
    normalize_embedding=True,
    rope_theta=10_000.0,
)

"""grok-1-314b — 8 experts top-2 [hf:xai-org/grok-1].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 (per expert) vocab=131072, MoE 8e
top-2, GeGLU-style gated experts (3 matrices — this is what lands the total at
~314B params; 6·64·3·6144·32768·8 ≈ 309B + attention + embeddings).
"""
from repro.configs.base import MOE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32_768,
    vocab_size=131_072,
    layer_pattern=(LayerSpec(mlp=MOE),),
    num_experts=8,
    num_experts_per_tok=2,
    activation="geglu",
    attn_logit_softcap=30.0,   # grok uses attn logit softcapping
    final_logit_softcap=30.0,
    rope_theta=10_000.0,
)

"""phi4-mini-3.8b — RoPE SwiGLU GQA [arXiv:2412.08905].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200_064,
    layer_pattern=(LayerSpec(),),
    activation="swiglu",
    tie_embeddings=True,
    rope_theta=10_000.0,
)

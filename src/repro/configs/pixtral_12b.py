"""pixtral-12b — pixtral-ViT frontend + mistral-nemo backbone [hf:mistralai/Pixtral-12B-2409].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.  Per the assignment,
the vision frontend is a STUB: ``input_specs()`` provides precomputed patch
embeddings (B, P, d_model) that the backbone prepends to the text tokens; the
cell's seq_len is the total (patch + text) sequence length.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=131_072,
    layer_pattern=(LayerSpec(),),
    activation="swiglu",
    rope_theta=1_000_000.0,
    frontend="patches",
    num_frontend_tokens=1024,   # e.g. a 512x512 image at patch 16 => 32x32
)

"""Configuration dataclasses for the memroof framework.

Every architecture in ``repro.configs`` is expressed as a :class:`ModelConfig`;
every benchmark/dry-run cell is a (:class:`ModelConfig`, :class:`ShapeCell`)
pair.  Configs are plain frozen dataclasses so they hash, print, and diff
cleanly, and so the dry-run can enumerate the full cartesian table.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

# mixer kinds
ATTN = "attn"            # softmax attention (GQA / MQA / MHA)
SSD = "ssd"              # Mamba-2 state-space-duality mixer
RGLRU = "rglru"          # Griffin RG-LRU recurrent mixer

# mlp kinds
DENSE = "dense"
MOE = "moe"
NONE = "none"


@dataclass(frozen=True)
class LayerSpec:
    """One decoder layer: a (mixer, mlp) pair.

    ``layer_pattern`` in :class:`ModelConfig` is the repeating unit that
    ``lax.scan`` iterates over; heterogeneous stacks (gemma2's local/global
    alternation, recurrentgemma's rec/rec/attn triple) put several LayerSpecs
    in the pattern.
    """

    mixer: str = ATTN
    mlp: str = DENSE
    # attention-only options
    sliding_window: Optional[int] = None     # None = full (global) attention

    @property
    def is_local_attn(self) -> bool:
        return self.mixer == ATTN and self.sliding_window is not None


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (exact values from the assignment table)."""

    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # None => d_model // num_heads

    # layer pattern (repeats to num_layers); default = uniform attn+dense
    layer_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)

    # ffn / embedding
    activation: str = "swiglu"       # swiglu | geglu | gelu
    tie_embeddings: bool = False
    normalize_embedding: bool = False  # gemma scales embeddings by sqrt(d_model)

    # attention extras
    rope_theta: float = 10_000.0
    attn_logit_softcap: Optional[float] = None   # gemma2: 50.0
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0
    query_pre_attn_scalar: Optional[float] = None  # gemma2 uses d_model/num_heads

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25

    # Mamba-2 / SSD
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # RG-LRU (recurrentgemma / griffin)
    lru_width: Optional[int] = None

    # encoder-decoder (seamless)
    enc_dec: bool = False
    num_encoder_layers: int = 0

    # modality frontend stubs (pixtral / seamless): inputs arrive as embeddings
    frontend: Optional[str] = None   # None | "patches" | "frames"
    num_frontend_tokens: int = 0     # patches/frames prepended per example

    # dtypes
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def pattern_len(self) -> int:
        return len(self.layer_pattern)

    @property
    def num_pattern_blocks(self) -> int:
        """Full pattern repetitions (scanned).  Remainder layers are unrolled."""
        return self.num_layers // self.pattern_len

    @property
    def remainder_specs(self) -> Tuple[LayerSpec, ...]:
        """Trailing layers beyond the scanned blocks (recurrentgemma: 38 = 12*3+2;
        layer i has type ``pattern[i % len]``, matching HF block_types layout)."""
        rem = self.num_layers % self.pattern_len
        return tuple(self.layer_pattern[i] for i in range(rem))

    # ------------------------------------------------------------------
    # analytic parameter / FLOP accounting (used by core.roofline)
    # ------------------------------------------------------------------
    def _mixer_params(self, spec: LayerSpec) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        if spec.mixer == ATTN:
            return d * hd * (nq + 2 * nkv) + nq * hd * d
        if spec.mixer == SSD:
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            zxbcdt = d * (2 * d_in + 2 * self.ssm_state + nheads)
            conv = (d_in + 2 * self.ssm_state) * self.ssm_conv_width
            out = d_in * d
            return zxbcdt + conv + out + 2 * nheads  # + A_log, D, dt_bias~nheads
        if spec.mixer == RGLRU:
            w = self.lru_width or self.d_model
            # in-proj (2 branches), conv1d, gates (2 diag-blocks), out-proj
            return d * 2 * w + w * 4 + 2 * w * (w // 8) * 8 // 8 + w * d + 2 * w
        raise ValueError(spec.mixer)

    def _mlp_params(self, spec: LayerSpec) -> Tuple[int, int]:
        """returns (total, active) mlp params."""
        d, f = self.d_model, self.d_ff
        if spec.mlp == NONE or f == 0:
            return 0, 0
        gates = 3 if self.activation in ("swiglu", "geglu") else 2
        dense = gates * d * f
        if spec.mlp == MOE:
            total = self.num_experts * dense + d * self.num_experts  # + router
            active = self.num_experts_per_tok * dense + d * self.num_experts
            return total, active
        return dense, dense

    def param_count(self) -> Tuple[int, int]:
        """(total, active) parameter counts, embeddings included once if tied."""
        per_total = per_active = 0
        for spec in self.layer_pattern:
            m = self._mixer_params(spec)
            t, a = self._mlp_params(spec)
            norms = 2 * self.d_model
            per_total += m + t + norms
            per_active += m + a + norms
        total = per_total * self.num_pattern_blocks
        active = per_active * self.num_pattern_blocks
        for spec in self.remainder_specs:
            m = self._mixer_params(spec)
            t, a = self._mlp_params(spec)
            total += m + t + 2 * self.d_model
            active += m + a + 2 * self.d_model
        if self.enc_dec:
            # encoder stack: self-attn + dense mlp per layer; decoder adds cross-attn
            enc = self.num_encoder_layers * (
                self._mixer_params(LayerSpec()) + self._mlp_params(LayerSpec())[0]
                + 2 * self.d_model)
            cross = self.num_layers * (self._mixer_params(LayerSpec()) + self.d_model)
            total += enc + cross
            active += enc + cross
        emb = self.vocab_size * self.d_model
        total += emb if self.tie_embeddings else 2 * emb
        active += emb if self.tie_embeddings else 2 * emb
        total += self.d_model  # final norm
        active += self.d_model
        return total, active

    def flops_per_token(self) -> int:
        """MODEL_FLOPS/token = 6·N_active (forward+backward), matmul params only."""
        _, active = self.param_count()
        return 6 * active


# ---------------------------------------------------------------------------
# Shapes (the assigned LM shape set)
# ---------------------------------------------------------------------------

TRAIN = "train"
PREFILL = "prefill"
DECODE = "decode"


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int       # train/prefill: tokens processed; decode: KV cache length
    global_batch: int

    @property
    def tokens(self) -> int:
        """new tokens processed per step."""
        if self.kind == DECODE:
            return self.global_batch
        return self.global_batch * self.seq_len


LM_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", TRAIN, 4_096, 256),
    ShapeCell("prefill_32k", PREFILL, 32_768, 32),
    ShapeCell("decode_32k", DECODE, 32_768, 128),
    ShapeCell("long_500k", DECODE, 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in LM_SHAPES}


def shape_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """Implements the assignment's skip rules.

    ``long_500k`` needs sub-quadratic attention: run for SSM/hybrid archs whose
    every attention layer is windowed; skip when any full-attention layer
    exists (the 500k KV cache is the quadratic-family cost).
    """
    if cell.name == "long_500k":
        has_full_attn = any(
            s.mixer == ATTN and s.sliding_window is None for s in cfg.layer_pattern)
        if cfg.enc_dec:
            return False, "enc-dec full attention (quadratic family)"
        if has_full_attn:
            return False, "full-attention layers present (quadratic family)"
        return True, ""
    return True, ""


# ---------------------------------------------------------------------------
# Smoke-config reducer
# ---------------------------------------------------------------------------

def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests (forward + train step)."""
    pat = cfg.layer_pattern
    updates = dict(
        num_layers=len(pat) if not cfg.enc_dec else 2,
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        num_encoder_layers=2 if cfg.enc_dec else 0,
        num_frontend_tokens=8 if cfg.frontend else 0,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.num_experts:
        updates.update(num_experts=4, num_experts_per_tok=2)
    if cfg.ssm_state:
        updates.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.lru_width:
        updates.update(lru_width=64)
    new_pat = tuple(
        replace(s, sliding_window=(16 if s.sliding_window is not None else None))
        for s in pat)
    return replace(cfg, layer_pattern=new_pat, **updates)


def override(cfg: ModelConfig, **kw) -> ModelConfig:
    return replace(cfg, **kw)


def asdict(cfg: ModelConfig) -> dict:
    return dataclasses.asdict(cfg)

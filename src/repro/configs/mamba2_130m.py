"""mamba2-130m — SSD (state-space duality), attention-free [arXiv:2405.21060].

24L d_model=768 d_ff=0 vocab=50280 ssm_state=128.  Pure SSM: every layer is a
Mamba-2 mixer with no FFN (d_ff=0).  Sub-quadratic => runs long_500k.
"""
from repro.configs.base import SSD, NONE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=24,            # d_inner / ssm_head_dim = 1536/64
    num_kv_heads=24,
    head_dim=64,
    d_ff=0,
    vocab_size=50_280,
    layer_pattern=(LayerSpec(mixer=SSD, mlp=NONE),),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_conv_width=4,
    tie_embeddings=True,
    activation="gelu",
)

"""granite-moe-3b-a800m — fine-grained MoE [hf:ibm-granite].

32L d_model=1536 24H (GQA kv=8) d_ff=512 (per expert) vocab=49155, MoE 40
experts top-8.  (The assignment's config line says 40e top-8; its prose says
32e — we follow the config line, noted in DESIGN.md.)
"""
from repro.configs.base import MOE, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    layer_pattern=(LayerSpec(mlp=MOE),),
    num_experts=40,
    num_experts_per_tok=8,
    activation="swiglu",
    tie_embeddings=True,
    rope_theta=10_000.0,
)

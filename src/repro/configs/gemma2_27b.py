"""gemma2-27b — local+global alternating attention, logit softcaps [arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.  Layer pattern is a
(local sliding-window 4096, global) pair scanned 23 times.
"""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36_864,
    vocab_size=256_000,
    layer_pattern=(
        LayerSpec(sliding_window=4096),
        LayerSpec(sliding_window=None),
    ),
    activation="geglu",
    tie_embeddings=True,
    normalize_embedding=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    query_pre_attn_scalar=144.0,   # d_model / num_heads = 4608/32
    rope_theta=10_000.0,
)

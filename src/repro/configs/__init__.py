"""Architecture registry: ``--arch <id>`` resolves through :data:`ARCHS`."""
from repro.configs.base import (  # noqa: F401
    ATTN, DENSE, MOE, NONE, RGLRU, SSD, TRAIN, PREFILL, DECODE,
    LM_SHAPES, SHAPES_BY_NAME, LayerSpec, ModelConfig, ShapeCell,
    override, shape_applicable, smoke_config,
)

from repro.configs.mamba2_130m import CONFIG as MAMBA2_130M
from repro.configs.gemma_2b import CONFIG as GEMMA_2B
from repro.configs.gemma2_27b import CONFIG as GEMMA2_27B
from repro.configs.phi4_mini_3p8b import CONFIG as PHI4_MINI_3P8B
from repro.configs.internlm2_20b import CONFIG as INTERNLM2_20B
from repro.configs.recurrentgemma_9b import CONFIG as RECURRENTGEMMA_9B
from repro.configs.granite_moe_3b_a800m import CONFIG as GRANITE_MOE_3B
from repro.configs.grok1_314b import CONFIG as GROK1_314B
from repro.configs.pixtral_12b import CONFIG as PIXTRAL_12B
from repro.configs.seamless_m4t_medium import CONFIG as SEAMLESS_M4T_MEDIUM

ARCHS = {
    c.name: c
    for c in (
        MAMBA2_130M, GEMMA_2B, GEMMA2_27B, PHI4_MINI_3P8B, INTERNLM2_20B,
        RECURRENTGEMMA_9B, GRANITE_MOE_3B, GROK1_314B, PIXTRAL_12B,
        SEAMLESS_M4T_MEDIUM,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells():
    """Yield every applicable (config, shape) cell with skip reasons."""
    for cfg in ARCHS.values():
        for cell in LM_SHAPES:
            ok, why = shape_applicable(cfg, cell)
            yield cfg, cell, ok, why

"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --seq 256 --batch 8 --steps 50 --ckpt /tmp/run1

Runs on whatever devices the host exposes (data x model mesh); on a real
TPU pod slice the same entry point runs under ``jax.distributed`` with the
production mesh from ``repro.launch.mesh``.  Fault tolerance: automatic
retry-with-restore (``--max-failures``); deterministic data makes recovery
bit-exact with an uninterrupted run.
"""
import argparse
import logging
import sys

import jax

from repro.configs import ARCHS, ShapeCell, override, smoke_config
from repro.dist import POLICIES
from repro.models import RuntimeFlags, build
from repro.optim import AdamWConfig, schedule
from repro.train import TrainConfig, Trainer, run_with_recovery


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--policy", default="fsdp_tp", choices=sorted(POLICIES))
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--data", default="markov", choices=["markov", "uniform"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--max-failures", type=int, default=3)
    ap.add_argument("--mesh-model", type=int, default=1)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_config(cfg)
    else:
        cfg = override(cfg, param_dtype="float32", compute_dtype="float32")

    n_dev = jax.device_count()
    dm = args.mesh_model
    mesh = jax.make_mesh((n_dev // dm, dm), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=128, attn_bkv=128,
                         loss_chunk=128, moe_impl="dense")
    bundle = build(cfg, flags)
    cell = ShapeCell("cli", "train", args.seq, args.batch)
    opt = AdamWConfig(lr=args.lr,
                      schedule=schedule.warmup_cosine(10, args.steps))
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt,
                       ckpt_every=max(10, args.steps // 5), log_every=5,
                       data_kind=args.data, microbatches=args.micro)
    tr = Trainer(bundle, cell, mesh, POLICIES[args.policy], opt, tcfg)

    def run(resume):
        with jax.set_mesh(mesh):
            return tr.run(resume if resume is not None
                          else (-1 if args.resume else None))

    final = run_with_recovery(run, max_failures=args.max_failures)
    print(f"finished at step {final}; last metrics: "
          f"{tr.history[-1] if tr.history else {}}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches jax device state.  Single pod = (16, 16) v5e-256; multi-pod = 2 pods
(512 chips) with a leading 'pod' axis carrying pure data parallelism across
the inter-pod (DCN) boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 4, model: int = 2):
    """CI-scale mesh over however many (fake) devices the host exposes."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)

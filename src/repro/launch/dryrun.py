import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory/cost/collective evidence.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both
  PYTHONPATH=src python -m repro.launch.dryrun --all --roofline --out runs/dryrun.json

Per cell this produces:
  - single-pod (16x16) and/or multi-pod (2x16x16) full-depth compile:
    memory_analysis (fits/chip?), cost_analysis, collective histogram;
  - with --roofline: two reduced-depth UNROLLED compiles (nb=1,2; naive
    attention; unchunked loss) -> affine extrapolation to full depth ->
    compute/memory/collective roofline terms (see core.roofline docstring).

Results append into a JSON file so the full table builds incrementally.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import (ARCHS, LM_SHAPES, SHAPES_BY_NAME, override,
                           shape_applicable)
from repro.configs.base import DECODE, PREFILL, TRAIN, ModelConfig, ShapeCell
import repro.core.roofline as rl
from repro.core.memmodel import V5E
from repro.dist import POLICIES
from repro.dist.steps import make_decode_step, make_prefill_step, make_train_step
from repro.launch.mesh import make_production_mesh
from repro.models import RuntimeFlags, build
from repro.optim import AdamWConfig, adamw


def default_flags(roofline: bool = False) -> RuntimeFlags:
    if roofline:
        # unrolled + scan-free inner ops so cost_analysis counts everything;
        # remat stays on so the recompute cost is measured like deployment.
        # attention keeps the DEPLOYED block sizes, python-unrolled.
        return RuntimeFlags(attn_impl="unrolled", attn_bq=2048, attn_bkv=2048,
                            unroll_layers=True, loss_chunk=0,
                            moe_impl="sorted", remat="full")
    # attn blocks from core.autotune.tune_attention_blocks (VMEM-budgeted)
    return RuntimeFlags(attn_impl="chunked", attn_bq=2048, attn_bkv=2048,
                        moe_impl="sorted", loss_chunk=512, remat="full")


# optimized-preset microbatch counts (hillclimb iteration 2: grad accumulation
# scales activation memory 1/m; chosen so train cells fit 16GiB — grok-1
# additionally requires the 2-pod mesh: params+opt are 12.3GiB/chip on one)
TRAIN_MICRO = {
    "grok-1-314b": 32, "internlm2-20b": 4, "gemma2-27b": 8, "pixtral-12b": 4,
    "granite-moe-3b-a800m": 4, "recurrentgemma-9b": 8,
    "seamless-m4t-medium": 4, "phi4-mini-3.8b": 2, "gemma-2b": 2,
    "mamba2-130m": 1,
}


def lower_cell(cfg: ModelConfig, cell: ShapeCell, mesh, policy,
               flags: RuntimeFlags, microbatches: int = 1):
    bundle = build(cfg, flags)
    abs_params, _ = bundle.abstract_params()
    inputs = bundle.input_specs(cell)
    with jax.set_mesh(mesh):
        if cell.kind == TRAIN:
            step, p_sh, o_sh, bsh = make_train_step(
                bundle, mesh, policy, AdamWConfig(), microbatches=microbatches)
            opt_abs = jax.eval_shape(adamw.init, abs_params)
            lowered = step.lower(abs_params, opt_abs, inputs)
        elif cell.kind == PREFILL:
            step, _ = make_prefill_step(bundle, mesh, policy, cell)
            lowered = step.lower(abs_params, inputs)
        else:  # decode
            step, _, c_sh = make_decode_step(bundle, mesh, policy, cell)
            cache_abs = bundle.cache_specs(cell)
            lowered = step.lower(abs_params, cache_abs, inputs["tokens"],
                                 inputs["pos"])
        compiled = lowered.compile()
    return compiled


def model_flops_per_chip(cfg: ModelConfig, cell: ShapeCell, chips: int) -> float:
    _, active = cfg.param_count()
    mult = 6 if cell.kind == TRAIN else 2
    return mult * active * cell.tokens / chips


def reduced_cfg(cfg: ModelConfig, nb: int) -> ModelConfig:
    kw = dict(num_layers=cfg.pattern_len * nb + len(cfg.remainder_specs))
    if cfg.enc_dec:
        kw["num_encoder_layers"] = nb
    return override(cfg, **kw)


def preset_for(cfg: ModelConfig, cell: ShapeCell, preset: str):
    """(policy_name, flags, microbatches) for a cell under a preset.

    ``baseline``  — the paper-naive deployable config (hillclimb iteration 0).
    ``opt``       — after the §Perf iterations: sequence-parallel activations
                    + grad-accumulation microbatching for train cells; int8
                    KV caches for decode cells.
    """
    if preset == "baseline":
        return "fsdp_tp", default_flags(), 1
    if cell.kind == TRAIN:
        # iteration 3: loss_chunk 512->128 (CE pipeline holds ~4GiB less)
        return ("fsdp_tp_sp",
                dataclasses.replace(default_flags(), loss_chunk=128),
                TRAIN_MICRO.get(cfg.name, 4))
    if cell.kind == DECODE:
        return ("fsdp_tp",
                dataclasses.replace(default_flags(), kv_dtype="int8"), 1)
    return "fsdp_tp", default_flags(), 1


def run_cell(cfg: ModelConfig, cell: ShapeCell, *, pods: str, roofline: bool,
             policy_name: str = "fsdp_tp", flags=None, preset=None) -> dict:
    if preset is not None:
        policy_name, flags, micro = preset_for(cfg, cell, preset)
    else:
        micro = 1
    rec = dict(arch=cfg.name, shape=cell.name, kind=cell.kind,
               policy=policy_name, status="ok", meshes={},
               preset=preset or "baseline", microbatches=micro)
    policy = POLICIES[policy_name]
    flags = flags or default_flags()
    mesh_list = {"single": False, "multi": True, "both": None}[pods]
    todo = [False, True] if mesh_list is None else [mesh_list]
    for mp in todo:
        mesh = make_production_mesh(multi_pod=mp)
        chips = mesh.size
        t0 = time.time()
        compiled = lower_cell(cfg, cell, mesh, policy, flags, micro)
        dt = time.time() - t0
        mem = rl.memory_summary(compiled)
        cost = rl.cost_of(compiled)
        _, per_coll = rl.collective_stats(compiled.as_text())
        key = "multi_pod" if mp else "single_pod"
        rec["meshes"][key] = dict(
            chips=chips, engines=policy.engines(mesh), compile_s=round(dt, 1),
            peak_gib=round(mem.get("peak_bytes_per_device", 0) / 2**30, 3),
            arg_gib=round(mem.get("argument_size_in_bytes", 0) / 2**30, 3),
            temp_gib=round(mem.get("temp_size_in_bytes", 0) / 2**30, 3),
            out_gib=round(mem.get("output_size_in_bytes", 0) / 2**30, 3),
            hlo_flops_per_dev=cost.flops, hlo_bytes_per_dev=cost.bytes_raw,
            hlo_bytes_fused_per_dev=cost.bytes_fused,
            collective_bytes_per_dev=cost.collective,
            collectives={k: v for k, v in per_coll.items()},
        )
        print(f"  [{key}] chips={chips} compile={dt:.1f}s "
              f"peak/dev={rec['meshes'][key]['peak_gib']:.2f}GiB "
              f"colls={sorted(per_coll)}", flush=True)
        del compiled

    if roofline:
        mesh = make_production_mesh(multi_pod=False)
        chips = mesh.size
        rflags = default_flags(roofline=True)
        costs = {}
        for nb in (1, 2):
            rcfg = reduced_cfg(cfg, nb)
            t0 = time.time()
            compiled = lower_cell(rcfg, cell, mesh, policy, rflags)
            costs[nb] = rl.cost_of(compiled)
            print(f"  [roofline nb={nb}] compile={time.time()-t0:.1f}s "
                  f"flops={costs[nb].flops:.3e}", flush=True)
            del compiled
        nb_t = cfg.num_pattern_blocks
        full = rl.affine_extrapolate(costs[1], costs[2], 1, 2, nb_t)
        mf = model_flops_per_chip(cfg, cell, chips)
        terms = rl.terms_from_cost(full, chips, mf)
        rec["roofline"] = dict(
            chips=chips, engines=policy.engines(mesh),
            hlo_flops=full.flops, hlo_bytes_raw=full.bytes_raw,
            hlo_bytes=full.bytes_fused,
            bytes_flash_inner=full.bytes_flash_inner,
            collective_bytes=full.collective,
            compute_s=terms.compute_s, memory_s=terms.memory_s,
            collective_s=terms.collective_s, dominant=terms.dominant,
            model_flops=mf, useful_ratio=terms.useful_flops_ratio,
            roofline_fraction=terms.roofline_fraction,
        )
        print(f"  [roofline] dominant={terms.dominant} "
              f"compute={terms.compute_s*1e3:.2f}ms "
              f"memory={terms.memory_s*1e3:.2f}ms "
              f"collective={terms.collective_s*1e3:.2f}ms "
              f"useful={terms.useful_flops_ratio:.3f} "
              f"frac={terms.roofline_fraction:.3f}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default=None, choices=sorted(SHAPES_BY_NAME))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", dest="pods", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--roofline", action="store_true")
    ap.add_argument("--policy", default="fsdp_tp", choices=sorted(POLICIES))
    ap.add_argument("--preset", default=None, choices=["baseline", "opt"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells = []
    for cfg in ARCHS.values():
        if args.arch and cfg.name != args.arch:
            continue
        for cell in LM_SHAPES:
            if args.shape and cell.name != args.shape:
                continue
            ok, why = shape_applicable(cfg, cell)
            cells.append((cfg, cell, ok, why))
    if not args.all and not args.arch and not args.shape:
        ap.error("pass --all or --arch/--shape")

    results = []
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["policy"]) for r in results
            if r.get("status") == "ok" and (not args.roofline or "roofline" in r)
            and (args.pods == "single" or "multi_pod" in r.get("meshes", {}))}

    failures = 0
    for cfg, cell, ok, why in cells:
        tag = f"{cfg.name} x {cell.name}"
        if not ok:
            print(f"SKIP {tag}: {why}", flush=True)
            rec = dict(arch=cfg.name, shape=cell.name, policy=args.policy,
                       status="skip", reason=why)
            results = [r for r in results if not (
                r["arch"] == cfg.name and r["shape"] == cell.name)] + [rec]
            continue
        if (cfg.name, cell.name, args.policy) in done:
            print(f"CACHED {tag}", flush=True)
            continue
        print(f"CELL {tag}", flush=True)
        try:
            rec = run_cell(cfg, cell, pods=args.pods, roofline=args.roofline,
                           policy_name=args.policy, preset=args.preset)
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            rec = dict(arch=cfg.name, shape=cell.name, policy=args.policy,
                       status="fail", error=str(e)[:500])
            failures += 1
        results = [r for r in results if not (
            r["arch"] == cfg.name and r["shape"] == cell.name
            and r["policy"] == args.policy)] + [rec]
        if args.out:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    print(f"done: {len(results)} records, {failures} failures", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

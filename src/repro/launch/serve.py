"""Serving launcher: continuous-batching engine(s) over a checkpoint (or
fresh init at smoke scale), optionally spread across a TP x DP device mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b --smoke \
        --requests 8 --batch 4

    # one engine sharded over 2 devices (TP), two such replicas (DP):
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --tp 2 --dp 2 --requests 16

TP shards a single engine's params and KV page pools across a mesh axis
(``dist.ServeMesh``); DP runs independent engine replicas — each on its own
device group — behind one shared admission queue (:class:`ReplicaPool`),
which dispatches every request to the least-loaded replica.  Replicas share
no device state, so the DP axis is pure scheduling: in the paper's framing
TP adds memory channels behind one request stream while DP adds whole
ports, and the admission queue is the host-side arbiter between them.
"""
import argparse
import sys
import time
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.models import RuntimeFlags, build
from repro.serve import (DisaggConfig, DisaggPool, Request, ServeEngine,
                         ServeStats, aggregate_stats)
from repro.train import CheckpointManager

# request i's scheduler class under each --priority mix (matches
# examples/serve_lm.py)
_PRIORITY_MIX = {"off": lambda i: 0, "low": lambda i: 0,
                 "high": lambda i: 1, "mixed": lambda i: i % 2}


def device_groups(tp: int, dp: int,
                  devices: Optional[Sequence] = None) -> List[list]:
    """Split the visible devices into ``dp`` disjoint TP groups of ``tp``
    devices each (replica ``i`` owns ``devices[i*tp:(i+1)*tp]``)."""
    devs = list(jax.devices() if devices is None else devices)
    if tp < 1 or dp < 1:
        raise ValueError(f"tp={tp} and dp={dp} must be >= 1")
    if tp * dp > len(devs):
        raise ValueError(
            f"tp={tp} x dp={dp} needs {tp * dp} devices, have {len(devs)}")
    return [devs[i * tp:(i + 1) * tp] for i in range(dp)]


class ReplicaPool:
    """A shared admission queue over independent engine replicas (the DP
    axis).  ``submit`` routes each request to the least-loaded replica
    (queued + in-flight requests; ties go to the lowest replica index, so
    an idle pool round-robins).  Replicas never share device state — the
    pool is scheduling only, which is what makes DP scale linearly."""

    def __init__(self, engines: Sequence[ServeEngine]):
        if not engines:
            raise ValueError("ReplicaPool needs at least one engine")
        self.engines = list(engines)
        self.routed = [0] * len(self.engines)   # per-replica request counts

    @staticmethod
    def _load(eng: ServeEngine) -> int:
        return len(eng.queue) + sum(s is not None for s in eng.slots)

    def submit(self, req: Request) -> int:
        """Admit ``req`` to the least-loaded replica; returns its index."""
        i = min(range(len(self.engines)),
                key=lambda j: self._load(self.engines[j]))
        self.engines[i].add_request(req)
        self.routed[i] += 1
        return i

    def drain(self, max_rounds: int = 100_000) -> ServeStats:
        """Tick every replica that still has work until all are idle.
        The budget counts drain *rounds* — one step of every busy replica
        — so the effective per-replica budget no longer shrinks as ``dp``
        grows."""
        for _ in range(max_rounds):
            busy = [e for e in self.engines
                    if e.queue or any(s is not None for s in e.slots)]
            if not busy:
                return self.stats()
            for eng in busy:
                eng.step()
        busy = [e for e in self.engines
                if e.queue or any(s is not None for s in e.slots)]
        agg = self.stats()
        raise RuntimeError(
            f"replica pool failed to drain in {max_rounds} rounds: "
            f"{len(busy)}/{len(self.engines)} replicas busy, "
            f"{sum(len(e.queue) for e in self.engines)} queued; partial "
            f"aggregate: tokens_out={agg.tokens_out}, "
            f"prefills={agg.prefills}, decode_steps={agg.decode_steps}, "
            f"pool_stalls={agg.pool_stalls}")

    def stats(self) -> ServeStats:
        """Aggregate counters across replicas (sums every ServeStats
        field — peaks sum too: the pool's total live-page commitment)."""
        return aggregate_stats(self.engines)


def build_pool(bundle, params, *, tp: int = 1, dp: int = 1,
               devices: Optional[Sequence] = None,
               **engine_kw) -> ReplicaPool:
    """``dp`` engine replicas, each TP-sharded over its own ``tp``-device
    group.  With ``tp * dp == 1`` the single engine runs undistributed
    (no mesh, any backend); any wider layout shards/pins KV page pools,
    so the paged backend is required."""
    from repro.dist import ServeMesh

    if tp * dp == 1:
        return ReplicaPool([ServeEngine(bundle, params, **engine_kw)])
    engine_kw.setdefault("cache_backend", "paged")
    groups = device_groups(tp, dp, devices)
    engines = [ServeEngine(bundle, params, **engine_kw,
                           dist=ServeMesh.tp(tp, devices=g))
               for g in groups]
    return ReplicaPool(engines)


def build_disagg_pool(bundle, params, *, tp: int = 1,
                      prefill_replicas: int = 1, decode_replicas: int = 1,
                      devices: Optional[Sequence] = None,
                      disagg_config: Optional[DisaggConfig] = None,
                      **engine_kw) -> DisaggPool:
    """The ``disagg`` topology: a prefill pool that ships every finished
    prompt's pages to a decode pool as a checksummed transfer buffer
    (:class:`~repro.serve.cluster.DisaggPool`).  Requires the paged
    backend with the host swap tier on both sides.  Disaggregation is a
    scheduling topology, so pools may share devices: with ``tp == 1``
    every engine runs undistributed (single-device smoke runs both pools
    on one chip); with ``tp > 1`` each engine gets its own disjoint
    ``tp``-device group when enough devices exist (prefill groups first),
    and otherwise all engines TP-shard over the *same* ``tp`` devices —
    the hand-off is still a real gather/scatter across meshes."""
    import jax

    from repro.dist import ServeMesh

    if prefill_replicas < 1 or decode_replicas < 1:
        raise ValueError("disagg topology needs >= 1 prefill and >= 1 "
                         "decode replica")
    engine_kw.setdefault("cache_backend", "paged")
    n = prefill_replicas + decode_replicas
    if tp == 1:
        engines = [ServeEngine(bundle, params, **engine_kw)
                   for _ in range(n)]
    else:
        pool = list(devices) if devices is not None else list(jax.devices())
        if len(pool) >= tp * n:
            groups = device_groups(tp, n, devices)
        else:
            if len(pool) < tp:
                raise ValueError(f"tp={tp} needs {tp} devices, have "
                                 f"{len(pool)}")
            groups = [pool[:tp]] * n
        engines = [ServeEngine(bundle, params, **engine_kw,
                               dist=ServeMesh.tp(tp, devices=g))
                   for g in groups]
    return DisaggPool(engines[:prefill_replicas],
                      engines[prefill_replicas:], config=disagg_config)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--window", type=int, default=8,
                    help="fused decode ticks per dispatch")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel width per engine replica")
    ap.add_argument("--dp", type=int, default=1,
                    help="independent engine replicas (device groups)")
    ap.add_argument("--seed", type=int, default=0,
                    help="traffic + sampling PRNG seed")
    ap.add_argument("--priority", default="off",
                    choices=sorted(_PRIORITY_MIX),
                    help="scheduler priority classes for the request mix "
                         "(matches examples/serve_lm.py)")
    ap.add_argument("--cache", default="auto",
                    choices=("auto", "dense", "paged"),
                    help="KV backend; auto lets the engine pick (paged is "
                         "forced whenever tp*dp > 1)")
    ap.add_argument("--topology", default="colocated",
                    choices=("colocated", "disagg"),
                    help="colocated: every replica prefills and decodes "
                         "(ReplicaPool).  disagg: a prefill pool ships "
                         "finished prompts' pages to a decode pool "
                         "(DisaggPool); --dp counts decode replicas")
    ap.add_argument("--prefill-replicas", type=int, default=1,
                    help="prefill-pool replicas under --topology disagg")
    ap.add_argument("--link-bw", type=float, default=32e9,
                    help="prefill->decode transfer link bandwidth (prices "
                         "the disagg-vs-colocated routing break-even)")
    ap.add_argument("--route", default="auto",
                    choices=("auto", "disagg", "colocated"),
                    help="pin the disagg router's per-request decision "
                         "(auto defers to the swap cost model)")
    args = ap.parse_args(argv)

    cfg = smoke_config(ARCHS[args.arch]) if args.smoke else ARCHS[args.arch]
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=64, attn_bkv=64,
                         moe_impl="dense", loss_chunk=64,
                         kv_dtype="int8" if args.kv_int8 else "native")
    bundle = build(cfg, flags)
    if args.ckpt:
        abs_params, _ = bundle.abstract_params()
        params = CheckpointManager(args.ckpt).restore(
            None, dict(params=abs_params))["params"]
    else:
        params = bundle.init(jax.random.PRNGKey(0))

    engine_kw = dict(batch_size=args.batch, max_len=args.max_len,
                     window=args.window, seed=args.seed)
    if args.cache != "auto":
        engine_kw["cache_backend"] = args.cache
    if args.topology == "disagg":
        pool = build_disagg_pool(
            bundle, params, tp=args.tp,
            prefill_replicas=args.prefill_replicas, decode_replicas=args.dp,
            disagg_config=DisaggConfig(
                link_bw=args.link_bw,
                force=None if args.route == "auto" else args.route),
            **engine_kw)
    else:
        pool = build_pool(bundle, params, tp=args.tp, dp=args.dp, **engine_kw)
    rng = np.random.default_rng(args.seed)
    mix = _PRIORITY_MIX[args.priority]
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 24))).astype(np.int32)
        pool.submit(Request(rid=i, prompt=prompt,
                            max_new_tokens=args.max_new,
                            priority=mix(i)))
    t0 = time.perf_counter()
    stats = pool.drain() if args.topology == "colocated" else pool.run()
    dt = time.perf_counter() - t0
    print(f"{stats.tokens_out} tokens in {dt:.2f}s "
          f"({stats.tokens_out/dt:.1f} tok/s) across "
          f"{len(pool.engines)} replica(s) x tp={args.tp}, "
          f"prefills={stats.prefills}, decode_steps={stats.decode_steps}, "
          f"decode_dispatches={stats.decode_dispatches}")
    if args.topology == "disagg":
        d = pool.dstats
        print(f"disagg: {d.disagg_routed} shipped / {d.colocated_routed} "
              f"colocated, {d.transfers} transfers "
              f"({stats.transfer_bytes} bytes), "
              f"{stats.transfer_fallbacks} recompute fallbacks, "
              f"{d.rounds} rounds")
    else:
        print("per-replica requests: "
              + ", ".join(f"r{i}={n}" for i, n in enumerate(pool.routed)))
    return 0


if __name__ == "__main__":
    sys.exit(main())

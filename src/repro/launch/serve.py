"""Serving launcher: continuous-batching engine over a checkpoint (or fresh
init at smoke scale).

    PYTHONPATH=src python -m repro.launch.serve --arch phi4-mini-3.8b --smoke \
        --requests 8 --batch 4
"""
import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.models import RuntimeFlags, build
from repro.serve import Request, ServeEngine
from repro.train import CheckpointManager


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--window", type=int, default=8,
                    help="fused decode ticks per dispatch")
    ap.add_argument("--kv-int8", action="store_true")
    args = ap.parse_args(argv)

    cfg = smoke_config(ARCHS[args.arch]) if args.smoke else ARCHS[args.arch]
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=64, attn_bkv=64,
                         moe_impl="dense", loss_chunk=64,
                         kv_dtype="int8" if args.kv_int8 else "native")
    bundle = build(cfg, flags)
    if args.ckpt:
        abs_params, _ = bundle.abstract_params()
        params = CheckpointManager(args.ckpt).restore(
            None, dict(params=abs_params))["params"]
    else:
        params = bundle.init(jax.random.PRNGKey(0))

    eng = ServeEngine(bundle, params, batch_size=args.batch,
                      max_len=args.max_len, window=args.window)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 24))).astype(np.int32)
        eng.add_request(Request(rid=i, prompt=prompt,
                                max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    stats = eng.run_to_completion()
    dt = time.perf_counter() - t0
    print(f"{stats.tokens_out} tokens in {dt:.2f}s "
          f"({stats.tokens_out/dt:.1f} tok/s), prefills={stats.prefills}, "
          f"decode_steps={stats.decode_steps}, "
          f"decode_dispatches={stats.decode_dispatches}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Serve-side distribution policies: one ServeEngine across a device mesh.

The serving twin of ``dist.steps``: where training shards a *step*
function, serving shards the *engine state* — model params by the ``tp``
policy's rules, the KV page pools on their kv-heads dimension, page tables
and sampling state replicated.  In the paper's terms each TP shard is one
more memory channel behind the same request stream: the page pools split
across HBM stacks exactly like a buffer interleaved over DDR banks, so
aggregate KV bandwidth scales with the axis width while the host-side
:class:`~repro.serve.kvcache.PageAllocator` keeps a single global page-id
space (tables stay valid on every shard verbatim).

Determinism contract: the shard_map islands partition only the head
dimension, logits are all-gathered (constrained replicated) before token
selection, and the per-slot PRNG chains never see the mesh — a TP=N drain
is token-identical to the single-device paged engine, greedy and sampled.

DP is deliberately *outside* this class: independent engine replicas
(each optionally TP-sharded) behind one admission queue — see
``launch/serve.py``.  Replicas share no device state, so scaling them is
pure scheduling, not sharding.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import POLICIES, ShardingPolicy

# pool leaves partition on their kv-heads dim; everything else in the paged
# cache (scale lanes, recurrent state, position rows) replicates
_POOL_LEAVES = ("k_pages", "v_pages")


def _leaf_name(path) -> str:
    names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
    return names[-1] if names else ""


@dataclass(frozen=True)
class ServeMesh:
    """A TP mesh + policy bundle the ServeEngine threads through its state.

    ``mesh`` carries the devices, ``axis`` the mesh axis heads/pools
    partition over, ``policy`` the param-sharding rules (default: the
    train stack's ``tp`` policy, so serve and train agree on layouts).
    """

    mesh: Mesh
    axis: str = "model"
    policy: ShardingPolicy = dataclasses.field(
        default_factory=lambda: POLICIES["tp"])

    # ------------------------------------------------------------------
    @classmethod
    def tp(cls, tp: Optional[int] = None, devices: Optional[Sequence] = None,
           axis: str = "model") -> "ServeMesh":
        """A 1-D TP mesh over ``tp`` devices (default: all of them)."""
        devs: List = list(devices if devices is not None else jax.devices())
        width = int(tp if tp is not None else len(devs))
        if not 1 <= width <= len(devs):
            raise ValueError(
                f"tp={width} needs {width} devices, have {len(devs)}")
        return cls(mesh=Mesh(np.asarray(devs[:width]), (axis,)), axis=axis)

    @property
    def tp_degree(self) -> int:
        return int(self.mesh.shape[self.axis])

    # ------------------------------------------------------------------
    def validate(self, cfg) -> None:
        """The islands need contiguous head blocks per shard: tp must
        divide both head counts (GQA group size stays shard-invariant)."""
        tp = self.tp_degree
        for name, val in (("num_heads", cfg.num_heads),
                          ("num_kv_heads", cfg.num_kv_heads)):
            if val % tp:
                raise ValueError(
                    f"{cfg.name}: {name}={val} not divisible by tp={tp} — "
                    "the paged shard_map islands partition heads in "
                    "contiguous blocks (pad heads or lower tp)")

    def bind(self, bundle):
        """Rebind the bundle's RuntimeFlags for this mesh: the policy's
        activation sharder (GSPMD constraints inside the model) plus the
        mesh/axis the paged dispatches turn into shard_map islands."""
        flags = dataclasses.replace(bundle.flags,
                                    shd=self.policy.sharder(self.mesh),
                                    mesh=self.mesh, tp_axis=self.axis)
        return dataclasses.replace(bundle, flags=flags)

    # ------------------------------------------------------------------
    def shard_params(self, bundle, params):
        abs_params, specs = bundle.abstract_params()
        shardings = self.policy.param_shardings(self.mesh, abs_params, specs)
        return jax.device_put(params, shardings)

    def replicated(self, x):
        return jax.device_put(x, NamedSharding(self.mesh, P()))

    def paged_cache_shardings(self, cache):
        """NamedSharding tree for a paged cache: k/v pools partitioned on
        their kv-heads dim (axis ndim-2: pools are (..., pages, page_size,
        Hkv, head_dim), stacked or not), the rest replicated."""

        def one(path, leaf):
            if _leaf_name(path) in _POOL_LEAVES and leaf.ndim >= 4:
                spec = [None] * leaf.ndim
                spec[leaf.ndim - 2] = self.axis
                return NamedSharding(self.mesh, P(*spec))
            return NamedSharding(self.mesh, P())

        return jax.tree_util.tree_map_with_path(one, cache)

    def shard_paged_cache(self, cache):
        return jax.device_put(cache, self.paged_cache_shardings(cache))

    # ------------------------------------------------------------------
    def page_swap_shardings(self, cache):
        """Shardings governing the host-tier page swap on this mesh.

        Swap-out gathers whole pages along the *page* axis while the pools
        shard on *kv-heads*, so the gather's output keeps the same
        head-stripe layout as the resident pools — each shard moves only
        its own stripe, and the engine's ``device_get`` assembles full
        pages host-side.  Swap-in is the transpose: the scatter's output
        is pinned to these shardings (``jit(..., out_shardings=...)``) so
        streaming host bytes back can never silently replicate a pool
        across the mesh.  This per-shard gather/scatter pair is the page
        transfer primitive disaggregated prefill/decode will reuse to move
        KV between meshes."""
        return self.paged_cache_shardings(cache)

"""shard_map data-parallel trainer with int8 + error-feedback gradients.

The pjit path (``dist.steps``) leaves gradient reductions to XLA; this path
makes the reduction explicit with ``shard_map`` so the wire format can be
changed — ``optim.compress`` quantizes each device's local gradient to int8
(with a per-row scale) before the all-reduce, a 4x cut in collective bytes,
and keeps the quantization residual in a per-device error-feedback buffer so
the bias cancels across steps (EF-SGD / 1-bit-Adam lineage).

In the paper's vocabulary this is the unit-size lever applied to the
*collective* stream: the gradient all-reduce is the dominant inter-engine
traffic of a data-parallel step, and shrinking its transaction unit from
fp32 to int8 raises effective inter-chip bandwidth the same way wider HBM
transactions raise DRAM throughput (Fig. 7).

Error-feedback buffers carry a leading per-device axis (``init_error_feedback``
returns ``(n_devices, *param.shape)`` leaves, sharded over "data"): each
device owns its own residual, which is what makes the compression unbiased
per contributor.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.optim import adamw, compress

AXIS = "data"


def init_error_feedback(params, num_devices: Optional[int] = None):
    """Zero residuals, one slice per data-parallel shard (fp32).

    ``num_devices`` must equal the size of the mesh axis the step reduces
    over (``mesh.shape["data"]``); the default of every visible device is
    only right when the whole host is one data-parallel axis."""
    n = num_devices if num_devices is not None else jax.device_count()
    return jax.tree.map(
        lambda p: jnp.zeros((n,) + tuple(p.shape), jnp.float32), params)


def make_dp_train_step(loss_fn: Callable, mesh,
                       opt_cfg: adamw.AdamWConfig,
                       compress_grads: bool = False,
                       axis_name: str = AXIS):
    """step(params, opt_state, err, batch) -> (params, opt_state, err, metrics).

    ``loss_fn(params, batch) -> scalar``; ``batch`` leaves are sharded along
    axis 0 over ``axis_name``; params/opt replicate.  With
    ``compress_grads=True`` each device contributes a dequantized int8 view
    of its (error-corrected) local gradient to the mean; otherwise a plain
    ``pmean``.  Metrics include the modeled wire savings so benchmarks can
    report the collective-bytes column.

    Mesh axes other than ``axis_name`` replicate the batch and therefore
    compute redundantly — this path is data parallelism only; combine it
    with model axes through ``dist.steps`` instead.
    """
    sizes = dict(mesh.shape)
    if axis_name not in sizes:
        raise ValueError(
            f"mesh has axes {sorted(sizes)}, expected data axis "
            f"{axis_name!r}")
    n_shards = sizes[axis_name]

    def local_step(params, opt_state, err, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        loss = jax.lax.pmean(loss, axis_name)
        if compress_grads:
            flat_g, treedef = jax.tree.flatten(grads)
            flat_e = treedef.flatten_up_to(err)
            red, new_e = [], []
            for g, e in zip(flat_g, flat_e):
                r, ne = compress.compressed_psum(
                    g.astype(jnp.float32), e[0], axis_name)
                red.append(r)
                new_e.append(ne[None])
            grads = jax.tree.unflatten(treedef, red)
            new_err = jax.tree.unflatten(treedef, new_e)
        else:
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(g.astype(jnp.float32), axis_name),
                grads)
            new_err = err
        new_p, new_opt, om = adamw.update(grads, opt_state, params, opt_cfg)
        metrics = dict(loss=loss, **om)
        return new_p, new_opt, new_err, metrics

    def batch_specs(batch):
        return jax.tree.map(lambda _: P(axis_name), batch)

    def err_specs(err):
        return jax.tree.map(lambda _: P(axis_name), err)

    def rep(tree):
        return jax.tree.map(lambda _: P(), tree)

    @functools.partial(jax.jit, donate_argnums=(1, 2))
    def step(params, opt_state, err, batch):
        for e in jax.tree.leaves(err):
            if e.shape[0] != n_shards:
                raise ValueError(
                    f"error-feedback leaves carry {e.shape[0]} residual "
                    f"slices but mesh axis {axis_name!r} has {n_shards} "
                    f"shard(s); build them with init_error_feedback(params, "
                    f"num_devices={n_shards})")
        fn = shard_map(
            local_step, mesh,
            in_specs=(rep(params), rep(opt_state), err_specs(err),
                      batch_specs(batch)),
            out_specs=(rep(params), rep(opt_state), err_specs(err),
                       P()),
            check_rep=False)
        new_p, new_opt, new_err, metrics = fn(params, opt_state, err, batch)
        if compress_grads:
            metrics = dict(metrics,
                           wire_bytes_saved=jnp.asarray(
                               compress.wire_bytes_saved(params), jnp.float32))
        return new_p, new_opt, new_err, metrics

    return step

"""Distribution layer: sharding policies, pjit step builders, shard_map DP.

The TPU translation of the paper's parallel-access-engine lever: a
:class:`~repro.dist.sharding.ShardingPolicy` maps the models' *logical* axis
names onto mesh axes (with divisibility fallback), ``dist.steps`` builds
pjit-sharded train/prefill/decode steps from a policy + mesh, and
``dist.dp_shardmap`` is the explicit-collective data-parallel path with int8
error-feedback gradient compression.  See docs/architecture.md.
"""
from repro.dist.sharding import (  # noqa: F401
    ACT_RULES_SP, ACT_RULES_TP, BATCH_RULES, PARAM_RULES_FSDP, PARAM_RULES_TP,
    POLICIES, ShardingPolicy, param_shardings, spec_for,
)
from repro.dist.serve import ServeMesh  # noqa: F401
from repro.dist import sharding  # noqa: F401

"""pjit-sharded train / prefill / decode steps over a launch.mesh mesh.

Each builder returns a jitted step plus the sharding trees callers use to
place state (``Trainer._put_tree``, checkpoint restore, the dry-run's
abstract lowering).  Layout is pinned with ``with_sharding_constraint``
against explicit ``NamedSharding``s rather than jit in/out_shardings, so the
same step lowers identically from committed arrays (training) and from bare
``ShapeDtypeStruct``s (the dry-run compiles 314B-param trees this way).

Gradient accumulation (``microbatches=m``) scans m equal slices of the
batch and averages: with the synthetic LM's always-valid labels this is
numerically the full-batch step (mean of per-slice means), which
``tests/test_train.py::test_microbatched_step_matches_full_batch`` pins.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import ShardingPolicy, spec_for
from repro.optim import adamw


def _with_policy_sharder(bundle, mesh, policy: ShardingPolicy):
    """Rebind the bundle's RuntimeFlags.shd to this policy's activation
    sharder so intra-model constraints follow the active policy."""
    flags = dataclasses.replace(bundle.flags, shd=policy.sharder(mesh))
    return dataclasses.replace(bundle, flags=flags)


def _constrain(tree, shardings):
    flat, treedef = jax.tree.flatten(tree)
    flat_s = treedef.flatten_up_to(shardings)
    return jax.tree.unflatten(
        treedef,
        [jax.lax.with_sharding_constraint(x, s)
         for x, s in zip(flat, flat_s)])


def _constrain_batch(batch, mesh, policy):
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(
            x, policy.batch_sharding(mesh, x)), batch)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_train_step(bundle, mesh, policy: ShardingPolicy,
                    opt_cfg: adamw.AdamWConfig, microbatches: int = 1):
    """(step_fn, param_shardings, opt_shardings, batch_sharder).

    ``step_fn(params, opt_state, batch) -> (params, opt_state, metrics)``.
    Optimizer m/v shard exactly like the params (ZeRO-3 for free); the
    scalar optimizer step stays replicated.  ``batch_sharder`` maps an
    abstract batch tree to the policy's data-parallel shardings.
    """
    bundle = _with_policy_sharder(bundle, mesh, policy)
    abs_params, specs = bundle.abstract_params()
    p_shard = policy.param_shardings(mesh, abs_params, specs)
    o_shard = adamw.AdamWState(
        step=NamedSharding(mesh, P()), m=p_shard, v=p_shard)

    def batch_sharder(abs_batch):
        return policy.batch_shardings(mesh, abs_batch)

    m = max(1, int(microbatches))

    def grad_fn(params, batch):
        (loss, aux), grads = jax.value_and_grad(
            bundle.train_loss, has_aux=True)(params, batch)
        return loss, aux, grads

    def step(params, opt_state, batch):
        params = _constrain(params, p_shard)
        batch = _constrain_batch(batch, mesh, policy)
        if m == 1:
            loss, aux, grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                batch)

            def body(carry, mb):
                loss_sum, aux_sum, gsum = carry
                loss, aux, grads = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                aux_sum = jax.tree.map(lambda a, b: a + b, aux_sum, aux)
                return (loss_sum + loss, aux_sum, gsum), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mb0 = jax.tree.map(lambda x: x[0], micro)
            aux_abs = jax.eval_shape(lambda p, b: grad_fn(p, b)[1],
                                     params, mb0)
            aux0 = jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), aux_abs)
            (loss, aux, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), aux0, zeros), micro)
            loss = loss / m
            aux = jax.tree.map(lambda a: a / m, aux)
            grads = jax.tree.map(lambda g: g / m, grads)
        grads = _constrain(grads, p_shard)
        new_p, new_opt, om = adamw.update(grads, opt_state, params, opt_cfg)
        new_p = _constrain(new_p, p_shard)
        new_opt = adamw.AdamWState(step=new_opt.step,
                                   m=_constrain(new_opt.m, p_shard),
                                   v=_constrain(new_opt.v, p_shard))
        metrics = dict(loss=loss, **aux, **om)
        return new_p, new_opt, metrics

    return jax.jit(step, donate_argnums=(0, 1)), p_shard, o_shard, batch_sharder


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------

def _cache_shardings(mesh, cache_abs, policy: ShardingPolicy):
    """Batch-dim data-parallel shardings for a decode-cache tree.

    Stacked leaves (under ``blocks``/``dec``) carry a leading LAYERS axis
    with batch at axis 1; remainder/encoder leaves carry batch at axis 0 —
    the same layout contract the serve engine's slot scatter uses.  Only the
    batch dim is sharded (KV length/heads stay local so the per-slot decode
    scatter never crosses shards); non-divisible batches replicate.
    """
    def leaf(path, a):
        names = [str(getattr(p, "key", getattr(p, "name", ""))) for p in path]
        batch_ax = 1 if any(n in ("blocks", "dec") for n in names) else 0
        axes = [None] * a.ndim
        if a.ndim > batch_ax:
            axes[batch_ax] = "batch"
        return NamedSharding(
            mesh, spec_for(a.shape, axes, policy.batch_rules, mesh))

    return jax.tree_util.tree_map_with_path(leaf, cache_abs)


def make_prefill_step(bundle, mesh, policy: ShardingPolicy, cell):
    """(step, param_shardings); step(params, batch) -> (cache, last_logits)."""
    bundle = _with_policy_sharder(bundle, mesh, policy)
    abs_params, specs = bundle.abstract_params()
    p_shard = policy.param_shardings(mesh, abs_params, specs)

    def step(params, batch):
        params = _constrain(params, p_shard)
        batch = _constrain_batch(batch, mesh, policy)
        return bundle.prefill(params, batch)

    return jax.jit(step), p_shard


def make_decode_step(bundle, mesh, policy: ShardingPolicy, cell):
    """(step, param_shardings, cache_shardings).

    ``step(params, cache, tokens, pos) -> (logits, cache)`` with the cache
    donated (decode is the steady-state loop; the cache buffer is reused
    in place).  ``pos`` may be a scalar (batch-uniform decode) or a per-slot
    vector (continuous batching).
    """
    bundle = _with_policy_sharder(bundle, mesh, policy)
    abs_params, specs = bundle.abstract_params()
    p_shard = policy.param_shardings(mesh, abs_params, specs)
    c_shard = _cache_shardings(mesh, bundle.cache_specs(cell), policy)

    def step(params, cache, tokens, pos):
        params = _constrain(params, p_shard)
        cache = _constrain(cache, c_shard)
        tokens = jax.lax.with_sharding_constraint(
            tokens, policy.batch_sharding(mesh, tokens))
        return bundle.decode_step(params, cache, tokens, pos)

    return jax.jit(step, donate_argnums=(1,)), p_shard, c_shard

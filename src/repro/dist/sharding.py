"""Logical-axis sharding: rules, specs, and the policy registry.

This is the repo's translation of the paper's *parallel access engines*
lever (Tables 3-5): on an FPGA, aggregate bandwidth comes from spreading
independent engines over HBM banks; on a TPU mesh it comes from spreading
shards over chips, each streaming from its own HBM stack.  Model code never
names mesh axes — ``ParamBuilder`` records *logical* axis names per tensor
(``repro.models.common``), and a :class:`ShardingPolicy` maps logical axes
onto mesh axes here.

The mapping is rule-driven with a divisibility fallback: a rule only fires
when the dimension divides by the mesh-axis size and the mesh axis is not
already consumed by an earlier dimension of the same tensor.  Anything
unmatched stays replicated, so a policy written for the (16, 16) production
mesh degrades gracefully to a (1, 1) CI mesh or an odd-sized smoke model.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# A rule maps one logical axis name -> one mesh axis or an ordered tuple of
# mesh axes (e.g. batch -> ("pod", "data"): data parallelism spans the DCN
# boundary and the intra-pod data axis).
Rule = Tuple[str, Union[str, Tuple[str, ...]]]
Rules = Tuple[Rule, ...]

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# FSDP x TP parameter layout: tensor parallelism (the "model" mesh axis)
# splits the per-layer wide dims — heads / kv_heads / ff / experts / vocab —
# and FSDP (the "data" mesh axis) additionally splits the embed dim, so every
# large matrix is sharded twice and ZeRO-3-style optimizer sharding falls out
# of the same layout (optimizer state mirrors the params, see dist.steps).
PARAM_RULES_FSDP: Rules = (
    ("heads", "model"),
    ("kv_heads", "model"),
    ("ff", "model"),
    ("expert", "model"),
    ("vocab", "model"),
    ("embed", "data"),
)

# Pure tensor parallelism (params replicated across data, split across model).
PARAM_RULES_TP: Rules = tuple(
    (l, m) for l, m in PARAM_RULES_FSDP if l != "embed")

# Activation rules.  Batch always spans the data-parallel axes; the wide
# activation dims follow the TP split of the weights producing them.
ACT_RULES_TP: Rules = (
    ("batch", ("pod", "data")),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("ff", "model"),
    ("expert", "model"),
    ("vocab", "model"),
)

# Sequence parallelism: residual-stream activations are additionally split
# along seq over the model axis (the norm/elementwise regions between
# matmuls).  Because allocation walks tensor dims left-to-right, "seq" wins
# the model axis on (batch, seq, embed) tensors while (batch, seq, heads, _)
# attention tensors fall back to replicated seq — exactly the
# all-gather/reduce-scatter boundary sequence parallelism introduces.
ACT_RULES_SP: Rules = (("batch", ("pod", "data")), ("seq", "model")) + tuple(
    r for r in ACT_RULES_TP if r[0] != "batch")

# Data-parallel batch rule on its own (batch sharders, decode tokens).
BATCH_RULES: Rules = (("batch", ("pod", "data")),)


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------

def _mesh_sizes(mesh) -> dict:
    """{axis name: size} for a Mesh (or any object with a ``.shape`` map)."""
    return dict(mesh.shape)


def spec_for(shape: Sequence[int], axes: Sequence[Optional[str]],
             rules: Rules, mesh) -> P:
    """PartitionSpec for one tensor from its logical axes.

    ``shape``/``axes`` are parallel (``axes`` entries may be None =
    never sharded).  For each dimension, left to right, the first rule whose
    logical name matches contributes its mesh axes; a mesh axis is used at
    most once per tensor and only when the running product of assigned axis
    sizes still divides the dimension.  Scalars yield ``P()``; unmatched
    dims yield ``None`` (replicated).
    """
    sizes = _mesh_sizes(mesh)
    rule_map = {}
    for logical, mesh_axes in rules:
        rule_map.setdefault(
            logical,
            (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes))
    used: set = set()
    parts = []
    for dim, logical in zip(shape, axes):
        assigned: Tuple[str, ...] = ()
        total = 1
        for axis in rule_map.get(logical, ()):
            size = sizes.get(axis)
            if size is None or axis in used:
                continue
            if dim % (total * size) != 0:
                continue
            assigned += (axis,)
            total *= size
        used.update(assigned)
        if not assigned:
            parts.append(None)
        elif len(assigned) == 1:
            parts.append(assigned[0])
        else:
            parts.append(assigned)
    return P(*parts)


def param_shardings(mesh, abs_params, specs, rules: Rules):
    """NamedSharding tree matching ``abs_params``.

    ``abs_params`` is the ShapeDtypeStruct tree from
    ``ModelBundle.abstract_params()``; ``specs`` is its parallel tree of
    logical-axes tuples (tuple leaves, hence the flatten_up_to dance).
    """
    flat_p, treedef = jax.tree.flatten(abs_params)
    flat_ax = treedef.flatten_up_to(specs)
    return jax.tree.unflatten(
        treedef,
        [NamedSharding(mesh, spec_for(p.shape, ax, rules, mesh))
         for p, ax in zip(flat_p, flat_ax)])


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardingPolicy:
    """One named distribution strategy: how params, activations, and the
    data batch map onto mesh axes, plus the paper-model bookkeeping
    (how many parallel access engines the mesh provides)."""

    name: str
    param_rules: Rules
    act_rules: Rules
    batch_rules: Rules = BATCH_RULES
    description: str = ""

    # ------------------------------------------------------------------
    def param_shardings(self, mesh, abs_params, specs):
        return param_shardings(mesh, abs_params, specs, self.param_rules)

    def sharder(self, mesh):
        """A ``repro.models.common.Sharder``: (array, logical axes) -> array
        constrained to this policy's activation layout.  Injected into
        ``RuntimeFlags.shd`` by ``dist.steps`` so model code stays
        mesh-agnostic."""
        def shd(x, axes):
            spec = spec_for(x.shape, axes, self.act_rules, mesh)
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        return shd

    def batch_sharding(self, mesh, aval) -> NamedSharding:
        """Sharding for one data-batch leaf: axis 0 is the global batch."""
        axes = ("batch",) + (None,) * (aval.ndim - 1) if aval.ndim else ()
        return NamedSharding(
            mesh, spec_for(aval.shape, axes, self.batch_rules, mesh))

    def batch_shardings(self, mesh, abs_batch):
        return jax.tree.map(lambda a: self.batch_sharding(mesh, a), abs_batch)

    # ------------------------------------------------------------------
    @staticmethod
    def _axes_product(mesh, rules: Rules) -> int:
        sizes = _mesh_sizes(mesh)
        known = {a for _, axes in rules
                 for a in ((axes,) if isinstance(axes, str) else axes)}
        n = 1
        for axis, size in sizes.items():
            if axis in known:
                n *= size
        return max(1, n)

    def engines(self, mesh) -> int:
        """Parallel access engines this policy runs on ``mesh`` — the TPU
        analogue of the paper's multi-engine knob (Tables 3-5): every mesh
        shard streams from its own HBM stack, so aggregate bandwidth scales
        with the product of the mesh axes the policy's rules name.

        This is the analytic model's idealization: it assumes tensor dims
        divide the mesh axes.  ``spec_for``'s divisibility fallback may
        replicate odd-sized dims of a particular tensor, in which case that
        tensor sees fewer effective engines than reported here."""
        return self._axes_product(
            mesh, self.param_rules + self.act_rules + self.batch_rules)

    def param_engines(self, mesh) -> int:
        """Shards each *parameter* is split across (1 for pure DP: params
        replicate, so weight streaming is not divided among engines)."""
        return self._axes_product(mesh, self.param_rules)

    def data_engines(self, mesh) -> int:
        """Shards the data batch is split across (the DP degree)."""
        return self._axes_product(mesh, self.batch_rules)


POLICIES = {
    p.name: p
    for p in (
        ShardingPolicy(
            name="dp", param_rules=(), act_rules=BATCH_RULES,
            description="pure data parallelism: params/opt replicated, "
                        "batch split over (pod, data)"),
        ShardingPolicy(
            name="tp", param_rules=PARAM_RULES_TP, act_rules=ACT_RULES_TP,
            description="tensor parallelism only: wide dims over 'model', "
                        "params replicated across 'data'"),
        ShardingPolicy(
            name="fsdp_tp", param_rules=PARAM_RULES_FSDP,
            act_rules=ACT_RULES_TP,
            description="FSDP over 'data' x TP over 'model' (the deployable "
                        "default; optimizer state shards like params)"),
        ShardingPolicy(
            name="fsdp_tp_sp", param_rules=PARAM_RULES_FSDP,
            act_rules=ACT_RULES_SP,
            description="fsdp_tp + sequence-parallel residual activations "
                        "(seq over 'model' between matmul regions)"),
    )
}

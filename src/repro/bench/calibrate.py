"""Measured mode: fit the memory model's constants to observed sweeps.

The analytic model (:mod:`repro.core.memmodel`) predicts bandwidth from two
hardware constants — DMA transaction latency ``T_l`` and peak HBM bandwidth.
``calibrate()`` runs the micro-sweeps (or consumes a persisted
:class:`~repro.bench.schema.BenchRun`), then least-squares-fits those two
constants over the latency/outstanding/unit-size curves so that the same
equations describe *this host*.  The fitted :class:`TPUSpec` threads into
``core.autotune.tune_pattern`` and ``core.advisor.advise_model`` via
:class:`CalibrationResult`, and every prediction downstream can then carry a
``measured_vs_predicted`` ratio per pattern.

The fit is an exhaustive log-space grid refine (no scipy dependency): the
loss surface over (log T_l, log BW) is piecewise-smooth and unimodal for
samples spanning both the latency-limited regime (chase, small bursts) and
the bandwidth-limited regime (large sequential bursts), which the sample
sets here always include.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.memmodel import TPUSpec, V5E, predict_bw
from repro.core.patterns import Knobs, Pattern


@dataclass(frozen=True)
class CalibSample:
    """One observation: ``pattern`` run with ``knobs`` achieved ``gbps``."""

    pattern: Pattern
    knobs: Knobs
    gbps: float


# micro-pattern family fallback for ratio lookup (predict_bw's grouping)
_RATIO_FAMILY = {
    Pattern.RS_TRA.value: Pattern.SEQUENTIAL.value,
    Pattern.NEST.value: Pattern.SEQUENTIAL.value,
    Pattern.R_ACC.value: Pattern.RANDOM.value,
    Pattern.RR_TRA.value: Pattern.RANDOM.value,
    Pattern.STRIDED.value: Pattern.RANDOM.value,
}


@dataclass
class CalibrationResult:
    spec: TPUSpec                     # fitted constants
    base_spec: TPUSpec                # what the fit started from
    rms_log_error: float              # residual of the fit (log-space RMS)
    n_samples: int
    ratios: Dict[str, float] = field(default_factory=dict)

    @property
    def latency_scale(self) -> float:
        """Fitted T_l over the base spec's T_l."""
        return self.spec.dma_latency_s / self.base_spec.dma_latency_s

    @property
    def bandwidth_scale(self) -> float:
        """Fitted HBM bandwidth over the base spec's."""
        return self.spec.hbm_bw / self.base_spec.hbm_bw

    def measured_vs_predicted(self, pattern: Pattern) -> Optional[float]:
        """Mean observed/predicted (base spec) ratio for ``pattern``.

        Application patterns the micro-sweeps don't measure directly fall
        back to their micro-pattern family — the same grouping
        ``predict_bw`` uses (rs_tra/nest share the sequential burst formula,
        r_acc/rr_tra/strided the random unit formula)."""
        key = pattern.value if isinstance(pattern, Pattern) else str(pattern)
        if key in self.ratios:
            return self.ratios[key]
        family = _RATIO_FAMILY.get(key)
        return self.ratios.get(family) if family else None

    def to_dict(self) -> Dict:
        return {
            "fitted": {"dma_latency_s": self.spec.dma_latency_s,
                       "hbm_bw": self.spec.hbm_bw},
            "base": {"dma_latency_s": self.base_spec.dma_latency_s,
                     "hbm_bw": self.base_spec.hbm_bw},
            "latency_scale": self.latency_scale,
            "bandwidth_scale": self.bandwidth_scale,
            "rms_log_error": self.rms_log_error,
            "n_samples": self.n_samples,
            "ratios": dict(self.ratios),
        }


# ---------------------------------------------------------------------------
# Sample generation
# ---------------------------------------------------------------------------

def synthetic_samples(spec: TPUSpec, noise: float = 0.0,
                      seed: int = 0) -> List[CalibSample]:
    """Samples generated *from the model itself* — the property-test probe:
    fitting them must recover ``spec``'s constants.  Covers the
    latency-limited (chase / small-burst low-NO) and bandwidth-limited
    (large sequential burst) regimes so both constants are identifiable."""
    import random as _random
    rng = _random.Random(seed)
    samples: List[CalibSample] = []

    def jitter() -> float:
        return 1.0 + rng.uniform(-noise, noise) if noise else 1.0

    for unit in (4, 64, 256):
        k = Knobs(unit_bytes=unit, outstanding=1)
        samples.append(CalibSample(
            Pattern.CHASE, k,
            predict_bw(Pattern.CHASE, k, spec) / 1e9 * jitter()))
    for burst in (1 << 12, 1 << 16, 1 << 20, 1 << 22):
        for no in (1, 2, 8, 32):
            k = Knobs(burst_bytes=burst, outstanding=no)
            samples.append(CalibSample(
                Pattern.SEQUENTIAL, k,
                predict_bw(Pattern.SEQUENTIAL, k, spec) / 1e9 * jitter()))
    for unit in (64, 512, 4096):
        k = Knobs(unit_bytes=unit, outstanding=8)
        samples.append(CalibSample(
            Pattern.RANDOM, k,
            predict_bw(Pattern.RANDOM, k, spec) / 1e9 * jitter()))
    return samples


# sweeps whose rows carry knobs that faithfully describe the measured access
# (outstanding/num_kernels measure hops or dispatch effects, roofline rows
#  are artifact-derived, and the database rs_tra/nest rows carry nominal
#  default knobs — none of those identify T_l / BW cleanly)
CALIBRATION_SWEEPS = ("latency", "unit_size", "stride", "random")


def samples_from_run(run, sweeps: Sequence[str] = CALIBRATION_SWEEPS
                     ) -> List[CalibSample]:
    """Extract fit-worthy samples from a persisted :class:`BenchRun`."""
    samples: List[CalibSample] = []
    for r in run.results:
        if r.sweep not in sweeps or not r.pattern or r.gbps_measured <= 0:
            continue
        try:
            knobs = Knobs(**r.knobs) if r.knobs else Knobs()
            pattern = Pattern(r.pattern)
        except (TypeError, ValueError):
            continue
        samples.append(CalibSample(pattern, knobs, r.gbps_measured))
    return samples


def measured_samples(fast: bool = True) -> List[CalibSample]:
    """Run the micro-sweeps directly (no persistence) and return samples —
    the quick path for ``calibrate()`` without a saved run."""
    from repro.core import engines

    samples: List[CalibSample] = []
    chase = engines.latency_chase(n_entries=1 << (14 if fast else 18),
                                  steps=1 << (11 if fast else 13))
    samples.append(CalibSample(Pattern.CHASE, Knobs(unit_bytes=4, outstanding=1),
                               chase.gbps_measured))
    for rows, cols in ((1024, 512), (4096, 1024)) if fast else \
            ((4096, 1024), (16384, 1024)):
        r = engines.bw_sequential(rows=rows, cols=cols)
        samples.append(CalibSample(
            Pattern.SEQUENTIAL,
            Knobs(unit_bytes=128 * 4, burst_bytes=cols * 4 * 8, outstanding=2),
            r.gbps_measured))
    for unit in (64, 256, 1024):
        r = engines.bw_random(n_rows=1 << (13 if fast else 17),
                              cols=max(1, unit // 4),
                              n_idx=1 << (12 if fast else 14))
        samples.append(CalibSample(
            Pattern.RANDOM, Knobs(unit_bytes=unit, outstanding=8),
            r.gbps_measured))
    return samples


# ---------------------------------------------------------------------------
# The fit
# ---------------------------------------------------------------------------

def _loss(samples: List[Tuple[Pattern, Knobs, float]], spec: TPUSpec) -> float:
    tot = 0.0
    for pattern, knobs, log_obs in samples:
        pred = predict_bw(pattern, knobs, spec)
        tot += (math.log(max(pred, 1e-30)) - log_obs) ** 2
    return tot / len(samples)


def fit_spec(samples: Iterable[CalibSample], base: TPUSpec = V5E,
             rounds: int = 4, grid: int = 17,
             lat_bounds: Tuple[float, float] = (1e-9, 1e-4),
             bw_bounds: Tuple[float, float] = (1e8, 1e13)
             ) -> CalibrationResult:
    """Least-squares over log bandwidth: refine a (T_l, BW) grid ``rounds``
    times.  Final resolution ~0.2% — far inside the 5% recovery target."""
    samples = list(samples)
    if not samples:
        raise ValueError("no calibration samples")
    obs = [(s.pattern, s.knobs, math.log(max(s.gbps, 1e-12) * 1e9))
           for s in samples]

    lo_l, hi_l = (math.log(b) for b in lat_bounds)
    lo_b, hi_b = (math.log(b) for b in bw_bounds)
    best_l = best_b = 0.0
    best_loss = float("inf")
    for _ in range(rounds):
        step_l = (hi_l - lo_l) / (grid - 1)
        step_b = (hi_b - lo_b) / (grid - 1)
        for i in range(grid):
            for j in range(grid):
                l, b = lo_l + i * step_l, lo_b + j * step_b
                spec = replace(base, dma_latency_s=math.exp(l),
                               hbm_bw=math.exp(b))
                cur = _loss(obs, spec)
                if cur < best_loss:
                    best_loss, best_l, best_b = cur, l, b
        # zoom around the incumbent with a 2-step margin so a flat valley
        # cannot push the true optimum outside the next window
        lo_l, hi_l = best_l - 2 * step_l, best_l + 2 * step_l
        lo_b, hi_b = best_b - 2 * step_b, best_b + 2 * step_b

    fitted = replace(base, name=base.name + "-calibrated",
                     dma_latency_s=math.exp(best_l), hbm_bw=math.exp(best_b))

    ratios: Dict[str, List[float]] = {}
    for s in samples:
        pred = predict_bw(s.pattern, s.knobs, base) / 1e9
        if pred > 0:
            ratios.setdefault(s.pattern.value, []).append(s.gbps / pred)
    return CalibrationResult(
        spec=fitted, base_spec=base,
        rms_log_error=math.sqrt(best_loss), n_samples=len(samples),
        ratios={p: sum(v) / len(v) for p, v in ratios.items()})


def calibrate(run=None, samples: Optional[Iterable[CalibSample]] = None,
              base: TPUSpec = V5E, fast: bool = True) -> CalibrationResult:
    """Measured mode, one call.

    Priority: explicit ``samples`` > persisted ``run`` > run the micro-sweeps
    now.  Returns the fitted spec + per-pattern measured/predicted ratios.
    """
    if samples is None:
        samples = samples_from_run(run) if run is not None else \
            measured_samples(fast=fast)
    return fit_spec(samples, base=base)

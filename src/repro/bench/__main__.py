"""CLI: run the registered sweeps, persist BENCH_<timestamp>.json.

  PYTHONPATH=src python -m repro.bench                  # full campaign
  PYTHONPATH=src python -m repro.bench --fast           # CI scale
  PYTHONPATH=src python -m repro.bench --sweeps latency,stride
  PYTHONPATH=src python -m repro.bench --calibrate      # measured mode
"""
import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweeps", default="",
                    help="comma-separated subset (default: all)")
    ap.add_argument("--fast", action="store_true",
                    help="CI-scale problem sizes (same as BENCH_FAST=1)")
    ap.add_argument("--out", default="runs",
                    help="directory for BENCH_<timestamp>.json ('' = no file)")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit memmodel constants to this host first and "
                         "attach the calibration record to the run")
    args = ap.parse_args(argv)

    from repro.bench import calibrate, run_sweeps

    calibration = None
    if args.calibrate:
        cal = calibrate(fast=args.fast)
        calibration = cal.to_dict()
        print(f"# calibrated: T_l={cal.spec.dma_latency_s*1e9:.1f}ns "
              f"BW={cal.spec.hbm_bw/1e9:.2f}GB/s "
              f"(rms log err {cal.rms_log_error:.3f})", flush=True)

    names = [s for s in args.sweeps.split(",") if s] or None
    print("name,us_per_call,derived")
    run = run_sweeps(names=names, fast=args.fast or None,
                     out_dir=args.out or None, calibration=calibration)
    if "path" in run.env:
        print(f"# wrote {run.env['path']}", flush=True)
    if run.failures:
        print(f"# {len(run.failures)} sweep(s) FAILED: "
              f"{sorted(run.failures)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark result schema + JSON persistence.

The paper's value is *recorded, comparable* sweeps: every measurement row is
a :class:`BenchResult` (pattern, knobs, timing, measured + model-predicted
bandwidth) and a whole campaign is a :class:`BenchRun` (results + environment
fingerprint + the spec constants the predictions used).  Runs serialize to
``BENCH_<timestamp>.json`` under ``runs/`` so two campaigns can be diffed by
:mod:`repro.bench.compare` and fed to :mod:`repro.bench.calibrate`.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional

SCHEMA_VERSION = 1


@dataclass(frozen=True)
class Timing:
    """Wall-clock statistics so noise is visible in persisted results."""

    best_s: float
    mean_s: float
    trials: int

    @property
    def noise(self) -> float:
        """Relative spread (mean - best) / best; 0.0 when degenerate."""
        if self.best_s <= 0:
            return 0.0
        return max(0.0, self.mean_s - self.best_s) / self.best_s

    def to_dict(self) -> Dict[str, Any]:
        return {"best_s": self.best_s, "mean_s": self.mean_s,
                "trials": self.trials}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Timing":
        return cls(best_s=float(d["best_s"]), mean_s=float(d["mean_s"]),
                   trials=int(d["trials"]))


@dataclass
class BenchResult:
    """One measurement row.

    ``gbps_measured`` is Eq. 5 on this host; ``gbps_predicted`` is
    ``predict_bw`` under the run's spec constants.  Rows that carry no
    meaningful host timing (status rows, artifact-derived rows) still carry
    both columns so downstream consumers never branch on missing keys.
    """

    name: str
    sweep: str
    pattern: Optional[str] = None
    knobs: Dict[str, Any] = field(default_factory=dict)
    us_per_call: float = 0.0
    gbps_measured: float = 0.0
    gbps_predicted: float = 0.0
    timing: Optional[Timing] = None
    extras: Dict[str, Any] = field(default_factory=dict)

    @property
    def measured_vs_predicted(self) -> float:
        """Host-measured over model-predicted bandwidth (0.0 if unknown)."""
        if self.gbps_predicted <= 0:
            return 0.0
        return self.gbps_measured / self.gbps_predicted

    def to_dict(self) -> Dict[str, Any]:
        d = {
            "name": self.name,
            "sweep": self.sweep,
            "pattern": self.pattern,
            "knobs": dict(self.knobs),
            "us_per_call": self.us_per_call,
            "gbps_measured": self.gbps_measured,
            "gbps_predicted": self.gbps_predicted,
            "measured_vs_predicted": self.measured_vs_predicted,
            "extras": dict(self.extras),
        }
        if self.timing is not None:
            d["timing"] = self.timing.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BenchResult":
        return cls(
            name=d["name"], sweep=d["sweep"], pattern=d.get("pattern"),
            knobs=dict(d.get("knobs", {})),
            us_per_call=float(d.get("us_per_call", 0.0)),
            gbps_measured=float(d.get("gbps_measured", 0.0)),
            gbps_predicted=float(d.get("gbps_predicted", 0.0)),
            timing=Timing.from_dict(d["timing"]) if d.get("timing") else None,
            extras=dict(d.get("extras", {})),
        )

    def csv(self) -> str:
        """Legacy stdout row: ``name,us_per_call,derived``."""
        derived = {
            "gbps_measured": f"{self.gbps_measured:.3f}",
            "gbps_tpu_model": f"{self.gbps_predicted:.3f}",
            **self.extras,
        }
        d = ";".join(f"{k}={v}" for k, v in derived.items())
        return f"{self.name},{self.us_per_call:.2f},{d}"


def env_fingerprint() -> Dict[str, Any]:
    """What produced these numbers — enough to judge comparability."""
    fp: Dict[str, Any] = {
        "platform": platform.platform(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "cpu_count": os.cpu_count(),
        "bench_fast": bool(int(os.environ.get("BENCH_FAST", "0"))),
    }
    try:
        import jax
        fp["jax"] = jax.__version__
        fp["jax_backend"] = jax.default_backend()
        fp["jax_device_count"] = jax.device_count()
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        fp["jax"] = None
    return fp


@dataclass
class BenchRun:
    """A full campaign: results + provenance, serializable to one JSON file."""

    results: List[BenchResult] = field(default_factory=list)
    env: Dict[str, Any] = field(default_factory=env_fingerprint)
    spec: Dict[str, Any] = field(default_factory=dict)
    calibration: Optional[Dict[str, Any]] = None
    failures: Dict[str, str] = field(default_factory=dict)
    created: str = ""
    schema_version: int = SCHEMA_VERSION

    def __post_init__(self):
        if not self.created:
            self.created = time.strftime("%Y-%m-%dT%H:%M:%S")

    # -- access -------------------------------------------------------------

    def sweeps(self) -> List[str]:
        return sorted({r.sweep for r in self.results})

    def by_sweep(self, sweep: str) -> List[BenchResult]:
        return [r for r in self.results if r.sweep == sweep]

    def by_name(self) -> Dict[str, BenchResult]:
        return {r.name: r for r in self.results}

    # -- persistence --------------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema_version": self.schema_version,
            "created": self.created,
            "env": self.env,
            "spec": self.spec,
            "calibration": self.calibration,
            "failures": self.failures,
            "results": [r.to_dict() for r in self.results],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BenchRun":
        return cls(
            results=[BenchResult.from_dict(r) for r in d.get("results", [])],
            env=dict(d.get("env", {})),
            spec=dict(d.get("spec", {})),
            calibration=d.get("calibration"),
            failures=dict(d.get("failures", {})),
            created=d.get("created", ""),
            schema_version=int(d.get("schema_version", SCHEMA_VERSION)),
        )

    def dump(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
            f.write("\n")
        return path

    @classmethod
    def load(cls, path: str) -> "BenchRun":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def save(self, out_dir: str = "runs") -> str:
        """Persist under ``out_dir`` as ``BENCH_<timestamp>.json``.  The
        chosen path is recorded in ``env["path"]`` *before* dumping so the
        file on disk carries its own provenance."""
        stamp = time.strftime("%Y%m%d_%H%M%S")
        path = os.path.join(out_dir, f"BENCH_{stamp}.json")
        # never clobber a run written within the same second
        n = 0
        while os.path.exists(path):
            n += 1
            path = os.path.join(out_dir, f"BENCH_{stamp}_{n}.json")
        self.env["path"] = path
        return self.dump(path)


def spec_to_dict(spec) -> Dict[str, Any]:
    """TPUSpec -> plain dict (provenance for the prediction columns)."""
    return dataclasses.asdict(spec)

"""Structured benchmark subsystem (the paper's measurement campaign).

- :mod:`repro.bench.schema` — ``BenchResult``/``BenchRun`` + JSON persistence
- :mod:`repro.bench.registry` — sweep registry + :func:`run_sweeps` runner
- :mod:`repro.bench.sweeps` — the fourteen registered sweeps (paper tables,
  figures, and the PR 3 serve / kernel_plan proof sweeps)
- :mod:`repro.bench.compare` — regression comparator over two saved runs
- :mod:`repro.bench.calibrate` — measured mode: fit the memmodel constants

CLI: ``PYTHONPATH=src python -m repro.bench [--fast] [--out runs]``.
"""
from repro.bench.calibrate import (CalibrationResult, CalibSample,  # noqa: F401
                                   calibrate, fit_spec, samples_from_run,
                                   synthetic_samples)
from repro.bench.compare import CompareReport, compare_runs  # noqa: F401
from repro.bench.registry import (ORDER, REGISTRY, SweepContext,  # noqa: F401
                                  register, run_sweeps)
from repro.bench.schema import (BenchResult, BenchRun, Timing,  # noqa: F401
                                env_fingerprint)
from repro.bench import sweeps as _sweeps  # noqa: F401  (populate REGISTRY)

__all__ = [
    "BenchResult", "BenchRun", "Timing", "env_fingerprint",
    "REGISTRY", "ORDER", "SweepContext", "register", "run_sweeps",
    "CompareReport", "compare_runs",
    "CalibrationResult", "CalibSample", "calibrate", "fit_spec",
    "samples_from_run", "synthetic_samples",
]

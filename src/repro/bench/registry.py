"""Sweep registry + runner.

Each paper table/figure is one registered sweep: a function
``fn(ctx: SweepContext) -> None`` that measures and calls ``ctx.emit``.
``run_sweeps`` executes a selection, collects a :class:`BenchRun`, optionally
persists it as ``runs/BENCH_<timestamp>.json``, and echoes the legacy
``name,us_per_call,derived`` CSV so existing log scrapers keep working.
"""
from __future__ import annotations

import dataclasses
import time
import traceback
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

from repro.bench.schema import (BenchResult, BenchRun, Timing, env_fingerprint,
                                spec_to_dict)
from repro.core.memmodel import TPUSpec, V5E
from repro.core.patterns import Knobs, Pattern


@dataclass(frozen=True)
class SweepSpec:
    name: str
    paper_ref: str
    fn: Callable[["SweepContext"], None]
    doc: str = ""


REGISTRY: Dict[str, SweepSpec] = {}

# canonical execution order == the paper's presentation order
ORDER: List[str] = []


def register(name: str, paper_ref: str = ""):
    """Decorator: ``@register("latency", "Table 2 / Fig 6")``."""

    def deco(fn: Callable[["SweepContext"], None]):
        if name in REGISTRY:
            raise ValueError(f"duplicate sweep {name!r}")
        REGISTRY[name] = SweepSpec(name=name, paper_ref=paper_ref, fn=fn,
                                   doc=(fn.__doc__ or "").strip())
        ORDER.append(name)
        return fn

    return deco


class SweepContext:
    """Handed to each sweep: scale flag, spec, timing, and the emit sink."""

    def __init__(self, sweep: str, fast: bool, spec: TPUSpec = V5E,
                 echo: bool = True):
        self.sweep = sweep
        self.fast = fast
        self.spec = spec
        self.echo = echo
        self.results: List[BenchResult] = []

    # -- measurement --------------------------------------------------------

    def timeit(self, fn, *args, trials: int = 3, warmup: int = 1) -> Timing:
        """Best/mean of ``trials`` wall-clocked calls (jax-synchronized)."""
        import jax
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
        walls = []
        for _ in range(trials):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            walls.append(time.perf_counter() - t0)
        return Timing(best_s=min(walls), mean_s=sum(walls) / len(walls),
                      trials=trials)

    # -- emission -----------------------------------------------------------

    def header(self, title: str) -> None:
        if self.echo:
            print(f"# --- {title} ---", flush=True)

    def emit(self, name: str, *, pattern: Optional[Pattern] = None,
             knobs: Optional[Knobs] = None, timing: Optional[Timing] = None,
             us: Optional[float] = None, bytes_moved: float = 0.0,
             gbps_measured: Optional[float] = None,
             gbps_predicted: Optional[float] = None,
             **extras) -> BenchResult:
        """Record one row.  ``gbps_measured`` defaults to Eq. 5
        (``bytes_moved / best wall``) and ``gbps_predicted`` to
        ``predict_bw(pattern, knobs)`` under the context spec, so every row
        carries both columns."""
        from repro.core.memmodel import predict_bw

        wall = timing.best_s if timing else (us or 0.0) * 1e-6
        if gbps_measured is None:
            gbps_measured = (bytes_moved / wall / 1e9) if wall > 0 else 0.0
        if gbps_predicted is None:
            if pattern is not None:
                gbps_predicted = predict_bw(pattern, knobs or Knobs(),
                                            self.spec) / 1e9
            else:
                gbps_predicted = 0.0
        r = BenchResult(
            name=name, sweep=self.sweep,
            pattern=pattern.value if pattern is not None else None,
            knobs=dataclasses.asdict(knobs) if knobs is not None else {},
            us_per_call=wall * 1e6 if us is None else us,
            gbps_measured=float(gbps_measured),
            gbps_predicted=float(gbps_predicted),
            timing=timing,
            extras={k: v for k, v in extras.items()},
        )
        if timing is not None:
            r.extras.setdefault("mean_us", f"{timing.mean_s * 1e6:.2f}")
            r.extras.setdefault("trials", timing.trials)
        self.results.append(r)
        if self.echo:
            print(r.csv(), flush=True)
        return r


def _fast_from_env() -> bool:
    import os
    return bool(int(os.environ.get("BENCH_FAST", "0")))


def run_sweeps(names: Optional[Sequence[str]] = None,
               fast: Optional[bool] = None, spec: TPUSpec = V5E,
               echo: bool = True, out_dir: Optional[str] = None,
               calibration: Optional[Dict] = None) -> BenchRun:
    """Run the selected sweeps (default: all, in registration order).

    Per-sweep exceptions are caught and recorded in ``run.failures`` —
    the CLI turns those into a nonzero exit, the library API never throws
    mid-campaign.  With ``out_dir`` the run is persisted as
    ``BENCH_<timestamp>.json`` and the path stored in ``run.env["path"]``.
    """
    import repro.bench.sweeps  # noqa: F401  (registers every sweep)

    fast = _fast_from_env() if fast is None else fast
    selected = list(names) if names else list(ORDER)
    unknown = [n for n in selected if n not in REGISTRY]
    if unknown:
        raise KeyError(f"unknown sweeps {unknown}; known: {sorted(REGISTRY)}")

    run = BenchRun(env=env_fingerprint(), spec=spec_to_dict(spec),
                   calibration=calibration)
    run.env["fast"] = fast
    for name in selected:
        sw = REGISTRY[name]
        ctx = SweepContext(sweep=name, fast=fast, spec=spec, echo=echo)
        ctx.header(f"{name} ({sw.paper_ref})" if sw.paper_ref else name)
        try:
            sw.fn(ctx)
        except Exception:  # noqa: BLE001 — one bad sweep must not kill the run
            run.failures[name] = traceback.format_exc()
            if echo:
                print(f"# FAILED {name}", flush=True)
                traceback.print_exc()
        run.results.extend(ctx.results)
    if out_dir:
        run.save(out_dir)  # records the path in run.env["path"] pre-dump
    return run

"""Preemptive-scheduling serving sweep (PR 8): the memory hierarchy's
tier-movement argument applied to whole requests.

Under pool pressure the scheduler evicts a victim's pages and brings the
request back by whichever move the hierarchy prices cheaper — recompute
(re-stream the weights per prefill chunk) or host-tier swap (the KV
bytes cross the device<->host staging link twice).  This sweep proves
the robustness story end to end and prices the swap decision:

- timed rows: warm tokens/s for the undisturbed drain and for the same
  drain under a seeded preemption storm (advisory — wall clock);
- deterministic gated rows the CI structural gate trusts on any host:
  preempted/swapped/corrupted drains complete and match the undisturbed
  drain bitwise (the sweep raises otherwise), forced-swap and
  forced-recompute fault coverage counters, the cost model's
  swap-over-recompute advantage at long context under production
  numbers (must exceed 1.0), the SLO prefill-burst bound under a
  chunk-cap scheduler, and high-priority-finishes-first under a pool
  sized too small for the offered load;
- advisory rows: p99 per-dispatch wall under the storm, measured
  swap-resume vs recompute-resume wall on a long-prompt victim.
"""
import dataclasses
import time

import jax
import numpy as np

from repro.bench.registry import SweepContext, register
from repro.bench.schema import Timing
from repro.core.patterns import Knobs, Pattern


def _mix(cfg, n_req: int, max_new: int, priorities=False):
    """Deterministic request mix: even rids share a 16-token prefix."""
    from repro.serve import Request

    rng = np.random.default_rng(8)
    common = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    reqs = []
    for i in range(n_req):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 9))).astype(np.int32)
        prompt = (np.concatenate([common, tail]) if i % 2 == 0
                  else np.concatenate([tail, tail]))
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=max_new,
                            priority=(i % 2) if priorities else 0))
    return reqs


def _drain(eng, cfg, n_req, max_new, chaos_cfg=None):
    from repro.serve import ChaosEngine

    reqs = _mix(cfg, n_req, max_new)
    for r in reqs:
        eng.add_request(r)
    t0 = time.perf_counter()
    if chaos_cfg is None:
        stats = eng.run_to_completion()
    else:
        stats = ChaosEngine(eng, chaos_cfg).run_to_completion()
    wall = time.perf_counter() - t0
    return stats, wall, {r.rid: list(r.out_tokens) for r in reqs}


@register("preempt_serve", "§2 memory hierarchy: KV tier movement")
def run_preempt_serve(ctx: SweepContext) -> None:
    from repro.configs import ARCHS, smoke_config
    from repro.models import RuntimeFlags, build
    from repro.serve import (ChaosConfig, Request, Scheduler, SchedulerConfig,
                             ServeEngine, ServeStats, SwapCostModel)

    cfg = smoke_config(ARCHS["gemma-2b"])
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16)
    bundle = build(cfg, flags)
    params = bundle.init(jax.random.PRNGKey(0))
    n_req, max_new = (4, 8) if ctx.fast else (8, 16)
    max_len = 64 if ctx.fast else 128
    trials = 2 if ctx.fast else 3

    eng = ServeEngine(bundle, params, batch_size=2, max_len=max_len,
                      window=4, prefill_chunk=8, cache_backend="paged")

    # -- reference drain + timed baseline --------------------------------
    _drain(eng, cfg, n_req, max_new)       # cold: compiles; reset keeps jits
    walls = []
    for _ in range(trials):
        eng.reset()
        ref_stats, wall, ref_outs = _drain(eng, cfg, n_req, max_new)
        walls.append(wall)
    timing = Timing(best_s=min(walls), mean_s=sum(walls) / len(walls),
                    trials=trials)
    ctx.emit("preempt_serve_undisturbed", pattern=Pattern.R_ACC,
             knobs=Knobs(burst_bytes=eng.bytes_per_page), timing=timing,
             us=timing.best_s / max(1, ref_stats.tokens_out) * 1e6,
             tok_s=f"{ref_stats.tokens_out / max(timing.best_s, 1e-9):.1f}",
             tokens_out=ref_stats.tokens_out)

    # -- chaos drains: storms + forced exhaustion + corruption, each
    #    resume mode, every one gated bitwise against the reference ------
    # fault coverage is gated on the SUM across trials, not the last trial
    # alone: a seed whose only storm lands mid-prefill (restart, no swap)
    # is legitimate chaos, and per-fault-kind sub-streams mean new kinds
    # never re-pin these schedules to dodge it
    fault_counts = {}
    for mode in (None, "swap", "recompute"):
        tag = mode or "costmodel"
        walls = []
        totals = ServeStats()
        for t in range(trials):
            eng.reset()
            ccfg = ChaosConfig(seed=13 + t, preempt_prob=0.4,
                               exhaust_prob=0.3, corrupt_prob=0.3, mode=mode)
            stats, wall, outs = _drain(eng, cfg, n_req, max_new, ccfg)
            walls.append(wall)
            for f in dataclasses.fields(ServeStats):
                setattr(totals, f.name,
                        getattr(totals, f.name) + getattr(stats, f.name))
            if outs != ref_outs:
                bad = [rid for rid in ref_outs if outs.get(rid)
                       != ref_outs[rid]]
                raise AssertionError(
                    f"preempted drain (mode={tag}) diverged from the "
                    f"undisturbed drain on rids {bad}: recovery lost "
                    "bitwise equivalence")
        fault_counts[tag] = totals
        timing = Timing(best_s=min(walls), mean_s=sum(walls) / len(walls),
                        trials=trials)
        ctx.emit(f"preempt_serve_chaos_{tag}", pattern=Pattern.R_ACC,
                 knobs=Knobs(burst_bytes=eng.bytes_per_page), timing=timing,
                 us=timing.best_s / max(1, stats.tokens_out) * 1e6,
                 tok_s=f"{stats.tokens_out / max(timing.best_s, 1e-9):.1f}",
                 preemptions=totals.preemptions,
                 swap_outs=totals.swap_outs,
                 recompute_resumes=totals.recompute_resumes)

    ctx.emit("preempt_serve_tokens_match",
             gbps_measured=1.0, gbps_predicted=1.0, deterministic=True,
             tokens_out=ref_stats.tokens_out,
             metric="chaos drains (storm + forced exhaustion + swap "
                    "corruption, all resume modes) == undisturbed drain, "
                    "bitwise (1.0 or the sweep raises)")

    swap_stats = fault_counts["swap"]
    rec_stats = fault_counts["recompute"]
    if swap_stats.preemptions == 0 or rec_stats.preemptions == 0:
        raise AssertionError("chaos storm never preempted a request")
    if swap_stats.swap_outs == 0 or swap_stats.swap_ins == 0:
        raise AssertionError(
            f"forced-swap chaos moved no pages through the host tier "
            f"(outs={swap_stats.swap_outs}, ins={swap_stats.swap_ins})")
    if rec_stats.recompute_resumes == 0:
        raise AssertionError("forced-recompute chaos never resumed a victim")
    ctx.emit("preempt_serve_fault_coverage",
             gbps_measured=float(swap_stats.swap_ins
                                 + rec_stats.recompute_resumes),
             gbps_predicted=1.0, deterministic=True,
             swap_outs=swap_stats.swap_outs,
             swap_ins=swap_stats.swap_ins,
             swap_fallbacks=swap_stats.swap_fallbacks,
             recompute_resumes=rec_stats.recompute_resumes,
             swap_bytes=swap_stats.swap_bytes,
             metric="swap-ins + recompute-resumes exercised by the final "
                    "chaos trials (hard-gated >= 1 of each in-sweep)")

    # -- cost model: swap beats recompute on long prompts -----------------
    # production-scale numbers (2.5B bf16 weights, gemma-2b KV rows,
    # PCIe-class staging link) under the context's — possibly calibrated —
    # TPUSpec: the break-even the paper's tier-movement story predicts
    cm = SwapCostModel(weight_bytes=5e9, kv_bytes_per_token=18_432,
                       prefill_chunk=256, spec=ctx.spec)
    long_ctx = 8192
    advantage = cm.recompute_s(long_ctx) / max(cm.swap_s(long_ctx), 1e-12)
    if advantage <= 1.0:
        raise AssertionError(
            f"swap-resume does not beat recompute-resume at ctx="
            f"{long_ctx} (advantage {advantage:.2f}x <= 1.0)")
    ctx.emit("preempt_serve_swap_advantage",
             gbps_measured=advantage, gbps_predicted=1.0, deterministic=True,
             recompute_ms=cm.recompute_s(long_ctx) * 1e3,
             swap_ms=cm.swap_s(long_ctx) * 1e3,
             choice=cm.choose(long_ctx, swappable=True),
             metric=f"modeled recompute/swap resume-time ratio at "
                    f"ctx={long_ctx} (hard-gated > 1.0: swap-resume beats "
                    "recompute-resume on long prompts)")

    # advisory: measured resume walls on a long-prompt victim (smoke-scale
    # weights are tiny, so recompute may win here — the gate above prices
    # production scale; this row shows the same machinery measured)
    long_prompt = np.arange(1, 49, dtype=np.int32) % cfg.vocab_size
    measured = {}
    for mode in ("swap", "recompute"):
        eng.reset()
        victim = Request(rid=0, prompt=long_prompt,
                         max_new_tokens=max_new + 4)
        eng.add_request(victim)
        while not victim.out_tokens:
            eng.step()
        eng.preempt(0, mode=mode)
        t0 = time.perf_counter()
        eng.run_to_completion()
        measured[mode] = time.perf_counter() - t0
    ctx.emit("preempt_serve_resume_walls",
             us=measured["swap"] * 1e6,
             swap_resume_ms=f"{measured['swap'] * 1e3:.2f}",
             recompute_resume_ms=f"{measured['recompute'] * 1e3:.2f}",
             metric="measured drain-after-preemption walls (advisory: "
                    "smoke weights are KB-scale, so the production "
                    "break-even does not apply)")

    # -- SLO: prefill-burst bound + p99 dispatch wall under a storm -------
    capped = ServeEngine(bundle, params, batch_size=3, max_len=max_len,
                         window=4, prefill_chunk=8, cache_backend="paged",
                         scheduler=Scheduler(
                             SchedulerConfig(prefill_chunks_per_tick=1)))
    rng = np.random.default_rng(9)
    decode_req = Request(rid=0, prompt=rng.integers(
        1, cfg.vocab_size, size=8).astype(np.int32),
        max_new_tokens=max_len - 24)
    capped.add_request(decode_req)
    while capped._pending:
        capped.step()
    for rid in (1, 2):
        capped.add_request(Request(rid=rid, prompt=rng.integers(
            1, cfg.vocab_size, size=32).astype(np.int32), max_new_tokens=2))
    tick_walls = []
    while any(s is not None for s in capped.slots) or capped.queue:
        t0 = time.perf_counter()
        capped._admit()
        if not any(s is not None for s in capped.slots):
            break
        capped.decode_many(capped.window)
        tick_walls.append(time.perf_counter() - t0)
    burst = capped.stats.prefill_burst_max
    if burst > 1:
        raise AssertionError(
            f"prefill burst {burst} exceeded the 1-chunk-per-tick SLO cap "
            "while a decode slot was active")
    ctx.emit("preempt_serve_burst_bound",
             gbps_measured=float(burst), gbps_predicted=1.0,
             deterministic=True,
             prefill_chunks=capped.stats.prefill_chunks,
             metric="max prefill chunks between decode windows under "
                    "prefill_chunks_per_tick=1 (hard-gated <= 1: the "
                    "decode-tick gap — the TPOT tail — is bounded)")
    p99 = float(np.percentile(tick_walls, 99)) if tick_walls else 0.0
    ctx.emit("preempt_serve_p99_tick",
             us=p99 * 1e6,
             p50_us=f"{np.percentile(tick_walls, 50) * 1e6:.0f}",
             ticks=len(tick_walls),
             metric="p99 admit+decode round wall under the capped "
                    "scheduler (advisory: wall clock)")

    # -- priorities: high finishes first under an undersized pool ---------
    tight = ServeEngine(bundle, params, batch_size=2, max_len=max_len,
                        window=4, prefill_chunk=8, cache_backend="paged",
                        num_pages=9)
    rng = np.random.default_rng(10)
    low = [Request(rid=i, prompt=rng.integers(
        1, cfg.vocab_size, size=20).astype(np.int32),
        max_new_tokens=max_new * 3, priority=0) for i in range(2)]
    hi = Request(rid=99, prompt=rng.integers(
        1, cfg.vocab_size, size=20).astype(np.int32),
        max_new_tokens=4, priority=1)
    for r in low:
        tight.add_request(r)
    for _ in range(4):
        tight.step()
    tight.add_request(hi)
    finish_order = []
    seen = set()
    while any(s is not None for s in tight.slots) or tight.queue:
        tight.step()
        for r in (hi, *low):
            if r.done and r.rid not in seen:
                seen.add(r.rid)
                finish_order.append(r.rid)
    if not (hi.done and all(r.done for r in low)):
        raise AssertionError("priority drain did not complete")
    if finish_order[0] != hi.rid:
        raise AssertionError(
            f"high-priority request finished {finish_order.index(hi.rid)} "
            f"places late (order {finish_order}): preemption failed to "
            "clear its path")
    if tight.stats.preemptions == 0:
        raise AssertionError(
            "high-priority admission never preempted under pool pressure")
    ctx.emit("preempt_serve_priority_first",
             gbps_measured=1.0, gbps_predicted=1.0, deterministic=True,
             preemptions=tight.stats.preemptions,
             pool_stalls=tight.stats.pool_stalls,
             metric="late-arriving high-priority request preempts and "
                    "finishes before the low-priority drains it displaced "
                    "(1.0 or the sweep raises)")

"""Paper Tables 7/8: random access (LFSR + pointer-chase) vs sequential.

The paper's headline ordering — sequential 421 GB/s >> LFSR-random 5.8 GB/s
>> pointer-chase 0.99 GB/s — is the ratio structure we reproduce (measured on
this host + modeled on v5e).
"""
from repro.bench.registry import SweepContext, register
from repro.core import engines
from repro.core.patterns import Knobs, Pattern


@register("random", "Tables 7-8")
def run(ctx: SweepContext) -> None:
    fast = ctx.fast
    # working sets must exceed the host LLC or 'random' hits cache and the
    # paper's ordering inverts (an instance of its own page-hit effect!)
    seq = engines.bw_sequential(rows=4096 if fast else 16384, cols=1024)
    # knobs mirror engines.bw_sequential's own model point so calibration
    # fits predict_bw at the measured configuration, not a nominal default
    ctx.emit("seq", pattern=Pattern.SEQUENTIAL,
             knobs=Knobs(unit_bytes=128 * 4, burst_bytes=1024 * 4 * 8,
                         outstanding=2),
             us=seq.wall_s * 1e6,
             gbps_measured=seq.gbps_measured,
             gbps_predicted=seq.gbps_tpu_model,
             paper_u280_gbps=421.68)
    r = None
    for gen in ("lfsr", "prng"):
        # one-cache-line rows (64B ~ the paper's 256-bit units) from a
        # table larger than LLC: each touch pays the latency, not the burst
        r = engines.bw_random(n_rows=1 << (17 if fast else 20), cols=16,
                              n_idx=1 << (13 if fast else 16), generator=gen)
        ctx.emit(f"random_{gen}", pattern=Pattern.RANDOM,
                 knobs=Knobs(unit_bytes=64, outstanding=8),
                 us=r.wall_s * 1e6,
                 gbps_measured=r.gbps_measured,
                 gbps_predicted=r.gbps_tpu_model,
                 paper_u280_gbps=5.82)
    chase = engines.latency_chase(n_entries=1 << (20 if fast else 22),
                                  steps=1 << 13)
    # paper's ratio claim: seq >> random >> chase.  The chase relations are
    # host-independent (serialized loads cannot be hidden anywhere); the
    # seq-vs-random gap needs real DRAM behaviour — virtualized hosts with a
    # low streaming ceiling can flatten it, so it is reported, not asserted.
    hard = (seq.gbps_measured > chase.gbps_measured
            and r.gbps_measured > chase.gbps_measured)
    ctx.emit("random_pointer_chase", pattern=Pattern.CHASE,
             knobs=Knobs(unit_bytes=4, outstanding=1),
             us=chase.wall_s * 1e6,
             gbps_measured=chase.gbps_measured,
             gbps_predicted=chase.gbps_tpu_model,
             paper_u280_gbps=0.994,
             chase_slowest=hard,
             seq_over_random=f"{seq.gbps_measured/r.gbps_measured:.2f}x",
             v5e_model_seq_over_random=
             f"{seq.gbps_tpu_model/r.gbps_tpu_model:.0f}x")
    assert hard, "pointer chase must be slowest everywhere"

"""Paper Table 6: number of kernels vs throughput.

TPU analogue: split one stream over k separately-dispatched programs.  Fewer,
wider engines win (dispatch overhead + lost fusion) — same conclusion as the
paper's 1-2 kernel sweet spot.  The model column is the idealized linear
multi-engine aggregate (``aggregate_bw``); measured falling below it at high
k IS the paper's dispatch-overhead finding.
"""
import jax
import jax.numpy as jnp

from repro.bench.registry import SweepContext, register
from repro.core.memmodel import aggregate_bw
from repro.core.patterns import Knobs, Pattern
from repro.kernels import ref


@register("num_kernels", "Table 6")
def run(ctx: SweepContext) -> None:
    rows, cols = (2048, 512) if ctx.fast else (8192, 1024)
    x = jnp.ones((rows, cols), jnp.float32)
    nbytes = x.size * 4 * 2
    for k in (1, 2, 4, 8, 16, 32):
        parts = jnp.split(x, k, axis=0)
        fns = [jax.jit(ref.stream_copy) for _ in range(k)]
        for f, p in zip(fns, parts):
            f(p).block_until_ready()  # warm

        def run_all():
            outs = [f(p) for f, p in zip(fns, parts)]
            return outs[-1]

        t = ctx.timeit(run_all)
        knobs = Knobs(burst_bytes=(rows // k) * cols * 4, engines=k)
        ctx.emit(f"kernels_{k}", pattern=Pattern.SEQUENTIAL, knobs=knobs,
                 timing=t, bytes_moved=nbytes,
                 gbps_predicted=aggregate_bw(Pattern.SEQUENTIAL, knobs,
                                             ctx.spec) / 1e9,
                 note="fewer_wider_engines_win")

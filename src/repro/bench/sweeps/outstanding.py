"""Paper Fig. 5 + Table 5: effect of outstanding transactions.

TPU analogue: requests in flight = independent chase chains serviced in
parallel (vmap) — per-chain latency is constant, so aggregate hops/s scale
with the in-flight count until the bandwidth knee.  The model column gives
the v5e knee NO* = ceil(T_l * BW / burst) (Eq. 4); the VMEM column is the
paper's BRAM-consumption column.
"""
import jax
import jax.numpy as jnp

from repro.bench.registry import SweepContext, register
from repro.core.memmodel import min_outstanding_for_peak
from repro.core.patterns import Knobs, Pattern
from repro.kernels import ops


def _multi_chase(tables, steps):
    flat = tables[:, :, 0]

    def one(tbl):
        def body(addr, _):
            nxt = tbl[addr]
            return nxt, nxt
        _, tr = jax.lax.scan(body, jnp.int32(0), None, length=steps)
        return tr

    return jax.vmap(one)(flat)


@register("outstanding", "Fig 5 / Table 5")
def run(ctx: SweepContext) -> None:
    n = 1 << (10 if ctx.fast else 13)
    steps = 1 << (9 if ctx.fast else 12)
    base = None
    burst = 64 * 1024
    no_star = min_outstanding_for_peak(burst, ctx.spec)
    for no in (1, 2, 4, 8, 16, 32, 64):
        tables = jnp.stack([ops.make_chain(n, seed=i) for i in range(no)])
        fn = jax.jit(lambda t: _multi_chase(t, steps))
        t = ctx.timeit(fn, tables)
        hops_s = no * steps / t.best_s
        base = base or hops_s
        knobs = Knobs(burst_bytes=burst, outstanding=no)
        ctx.emit(f"outstanding_{no}", pattern=Pattern.SEQUENTIAL, knobs=knobs,
                 timing=t, bytes_moved=no * steps * 4,
                 hops_per_s=f"{hops_s:.2e}",
                 speedup_vs_1=f"{hops_s/base:.2f}",
                 vmem_bytes=knobs.vmem_bytes(),
                 no_star_64kb=no_star,
                 no_star_1mb=min_outstanding_for_peak(1 << 20, ctx.spec))

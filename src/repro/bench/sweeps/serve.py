"""Tune->execute proof sweeps (PR 3): serve throughput + applied kernel plans.

Two registered sweeps close the loop the paper's §5 describes — measured
knob choices must reach the datapath:

- ``serve``: tokens/s of the continuous-batching engine with the legacy
  per-token host loop (`chase` over PCIe: one dispatch + one host sync per
  token) vs the device-resident fast path (fused ``decode_many`` windows,
  bucketed prefill).  The decode regime is `rs_tra` — every tick streams the
  KV cache once — so GB/s is cache-bytes x ticks / wall.
- ``kernel_plan``: the blocked attention hot loop with the old hardcoded
  128x128 blocks vs the :class:`repro.tune.KernelPlan` blocks for the same
  shape (`nest` — both cursors tiled).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.registry import SweepContext, register
from repro.bench.schema import Timing
from repro.core.patterns import Knobs, Pattern


def _cache_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(x.size * x.dtype.itemsize for x in leaves))


def _drain(eng, n_req, max_new):
    """Enqueue the deterministic request mix and serve it to completion."""
    from repro.serve import Request

    rng = np.random.default_rng(0)
    for i in range(n_req):
        prompt = rng.integers(
            0, eng.bundle.cfg.vocab_size, size=int(rng.integers(4, 17))
        ).astype(np.int32)
        eng.add_request(Request(rid=i, prompt=prompt, max_new_tokens=max_new))
    t0 = time.perf_counter()
    stats = eng.run_to_completion()
    return stats, time.perf_counter() - t0


@register("serve", "§5 pointer-chase fix: device-resident decode")
def run_serve(ctx: SweepContext) -> None:
    from repro.configs import ARCHS, smoke_config
    from repro.models import RuntimeFlags, build
    from repro.serve import ServeEngine

    cfg = smoke_config(ARCHS["gemma-2b"])
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16)
    bundle = build(cfg, flags)
    params = bundle.init(jax.random.PRNGKey(0))
    n_req, max_new = (4, 8) if ctx.fast else (12, 24)
    max_len = 64 if ctx.fast else 128
    trials = 2 if ctx.fast else 3

    variants = {
        # window=1 + exact-length prefill == the old per-token host loop
        "serve_default": dict(window=1, bucket_prompts=False),
        # fused windows + pow2 prompt buckets == the fast path
        "serve_fastpath": dict(window=8, bucket_prompts=True),
    }
    for name, kw in variants.items():
        eng = ServeEngine(bundle, params, batch_size=2, max_len=max_len, **kw)
        # cold drain compiles every prefill bucket + decode window; reset()
        # keeps those traces so the timed drains measure dispatch cost
        cold_stats, _ = _drain(eng, n_req, max_new)
        walls = []
        for _ in range(trials):
            eng.reset()
            stats, wall = _drain(eng, n_req, max_new)
            walls.append(wall)
        timing = Timing(best_s=min(walls), mean_s=sum(walls) / len(walls),
                        trials=trials)
        # rs_tra: each decode tick streams the whole batch KV cache once
        bytes_moved = _cache_bytes(eng.cache) * max(1, stats.decode_steps)
        knobs = Knobs(burst_bytes=_cache_bytes(eng.cache) // max(
            1, cfg.num_layers), outstanding=kw["window"])
        ctx.emit(name, pattern=Pattern.RS_TRA, knobs=knobs, timing=timing,
                 us=timing.best_s / max(1, stats.tokens_out) * 1e6,
                 gbps_measured=bytes_moved / max(timing.best_s, 1e-9) / 1e9,
                 tok_s=f"{stats.tokens_out / max(timing.best_s, 1e-9):.1f}",
                 tokens_out=stats.tokens_out,
                 decode_dispatches=stats.decode_dispatches,
                 ticks_per_dispatch=f"{stats.decode_steps / max(1, stats.decode_dispatches):.2f}",
                 prefill_compiles_cold=cold_stats.prefill_retraces)
        if name == "serve_fastpath":
            # deterministic figure-of-merit rows (no timing => the
            # comparator's structural gate trusts them on any host):
            # ticks/dispatch collapsing to ~1 means the fast path fell back
            # to per-token dispatch; cold prefill compiles growing means
            # prompt bucketing stopped deduplicating traces
            ctx.emit("serve_ticks_per_dispatch",
                     gbps_measured=stats.decode_steps
                     / max(1, stats.decode_dispatches),
                     gbps_predicted=float(kw["window"]),
                     deterministic=True,
                     metric="decode ticks per fused dispatch (higher=better)")
            ctx.emit("serve_prefill_compiles",
                     us=float(cold_stats.prefill_retraces),
                     deterministic=True,
                     metric="distinct prefill shapes compiled cold "
                            "(lower=better)")


@register("kernel_plan", "§5 knobs applied: tuned vs default blocks")
def run_kernel_plan(ctx: SweepContext) -> None:
    from repro.models.attention import AttnParams, chunked_attention
    from repro.tune import plan_for

    b, hq, hkv, d = (1, 4, 2, 64)
    s = 512 if ctx.fast else 2048
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    nbytes = (q.size + 2 * k.size + q.size) * 4  # q+k+v read, o written

    plan = plan_for("flash_attention", shape_sig=(s, s, d),
                    dtype=str(q.dtype), spec=ctx.spec)
    variants = {
        "kernel_plan_default": AttnParams(bq=128, bkv=128),   # old hardcode
        # pin the ctx.spec-derived plan's blocks explicitly so the timed
        # variant executes exactly what the row reports (resolve_blocks
        # would re-derive under the default spec, not ctx.spec)
        "kernel_plan_tuned": AttnParams(bq=plan.bq, bkv=plan.bkv),
    }
    for name, p in variants.items():
        fn = jax.jit(lambda q, k, v, p=p: chunked_attention(q, k, v, p))
        t = ctx.timeit(fn, q, k, v)
        bq, bkv = (p.bq or plan.bq), (p.bkv or plan.bkv)
        knobs = Knobs(unit_bytes=d * 4, burst_bytes=min(bkv, s) * d * 4,
                      outstanding=plan.pipeline_depth)
        ctx.emit(name, pattern=Pattern.NEST, knobs=knobs, timing=t,
                 bytes_moved=nbytes, bq=min(bq, s), bkv=min(bkv, s),
                 plan_source=plan.source,
                 plan_predicted_gbps=f"{plan.predicted_gbps:.1f}")
    # deterministic: the tuner's predicted bandwidth for the applied plan —
    # regression here means the tune->plan derivation itself got worse
    ctx.emit("kernel_plan_predicted", gbps_measured=plan.predicted_gbps,
             gbps_predicted=plan.predicted_gbps,
             bq=plan.bq, bkv=plan.bkv, plan_source=plan.source,
             deterministic=True,
             metric="model-predicted GB/s of the applied plan")

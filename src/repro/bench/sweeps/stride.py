"""Paper Figs. 8/9: throughput vs stride (Loop + Dataflow engines).

Loop analogue = XLA-fused strided traversal; Dataflow analogue = explicit
index-vector gather (address generation decoupled from access, like the
paper's FIFO-linked dataflow kernel).
"""
import jax
import jax.numpy as jnp

from repro.bench.registry import SweepContext, register
from repro.core.patterns import Knobs, Pattern
from repro.kernels import ref


@register("stride", "Figs 8-9")
def run(ctx: SweepContext) -> None:
    rows, cols = (2048, 256) if ctx.fast else (8192, 512)
    x = jnp.ones((rows, cols), jnp.float32)
    nbytes = x.size * 4 * 2
    for stride in (1, 2, 4, 8, 16, 32):
        knobs = Knobs(unit_bytes=8 * cols * 4, stride=stride)
        # Loop engine (fused traversal)
        fn = jax.jit(lambda a, s=stride: ref.strided_copy(a, block_rows=8,
                                                          stride=s))
        t = ctx.timeit(fn, x)
        # Dataflow engine (explicit address vector -> gather)
        idx = (jnp.arange(rows // 8) * stride) % (rows // 8)
        xf = x.reshape(rows // 8, 8 * cols)
        fn2 = jax.jit(lambda a, i: a[i])
        t2 = ctx.timeit(fn2, xf, idx)
        ctx.emit(f"stride_{stride}_loop", pattern=Pattern.STRIDED,
                 knobs=knobs, timing=t, bytes_moved=nbytes)
        ctx.emit(f"stride_{stride}_dataflow", pattern=Pattern.STRIDED,
                 knobs=knobs, timing=t2, bytes_moved=nbytes)

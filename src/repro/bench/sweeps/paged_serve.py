"""Paged-KV serving sweep (PR 4): the page pool as the r_acc engine.

Dense per-slot serving commits ``batch x max_len`` KV bytes up front and
streams them every tick (`rs_tra`); the paged backend allocates
transaction-optimum pages on demand and dereferences a per-sequence table
inside the ``paged_attention`` kernel (`r_acc` over page-sized units —
exactly what the ``random`` sweep benchmarks).  This sweep drains the same
deterministic request mix (half the prompts share a two-page prefix)
through both backends and emits:

- timed rows: warm tokens/s per backend;
- deterministic figure-of-merit rows the CI structural gate trusts on any
  host: live-token HBM bytes vs the dense footprint (must stay > 1x),
  prefix-cache hit rate, and decode ticks per fused dispatch (the paged
  path must keep the PR 3 fast-path dispatch regime);
- windowed-stack rows (gemma2 ring paging): the live-bytes ratio must
  *beat* the full-attention baseline (eager ring release is the headline
  HBM win) and peak ring pages must stay within batch x (ceil(w/page)+1);
- int8-KV rows: live-bytes ratio for quantized pages, and the derived page
  doubling its token count (the paper's data-width lever on the r_acc
  transaction unit).
"""
import time

import jax
import numpy as np

from repro.bench.registry import SweepContext, register
from repro.bench.schema import Timing
from repro.core.patterns import Knobs, Pattern


def _mix(cfg, n_req: int, max_new: int):
    """Deterministic request mix: even rids share a 16-token (2-page)
    prefix, odd rids are fully distinct."""
    from repro.serve import Request

    rng = np.random.default_rng(0)
    common = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    reqs = []
    for i in range(n_req):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 9))).astype(np.int32)
        prompt = (np.concatenate([common, tail]) if i % 2 == 0
                  else np.concatenate([tail, tail, tail]))
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=max_new))
    return reqs


def _drain(eng, cfg, n_req, max_new):
    for r in _mix(cfg, n_req, max_new):
        eng.add_request(r)
    t0 = time.perf_counter()
    stats = eng.run_to_completion()
    return stats, time.perf_counter() - t0


@register("paged_serve", "§6 r_acc applied: paged-KV continuous batching")
def run_paged_serve(ctx: SweepContext) -> None:
    from repro.configs import ARCHS, smoke_config
    from repro.models import RuntimeFlags, build
    from repro.serve import ServeEngine

    cfg = smoke_config(ARCHS["gemma-2b"])
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16)
    bundle = build(cfg, flags)
    params = bundle.init(jax.random.PRNGKey(0))
    n_req, max_new = (4, 8) if ctx.fast else (10, 16)
    max_len = 64 if ctx.fast else 128
    window = 8
    trials = 2 if ctx.fast else 3

    engines = {
        "paged_serve_dense": ServeEngine(
            bundle, params, batch_size=2, max_len=max_len, window=window,
            cache_backend="dense"),
        "paged_serve_paged": ServeEngine(
            bundle, params, batch_size=2, max_len=max_len, window=window,
            cache_backend="paged"),
    }
    stats_by = {}
    for name, eng in engines.items():
        _drain(eng, cfg, n_req, max_new)    # cold: compiles; reset keeps jits
        walls = []
        for _ in range(trials):
            eng.reset()
            stats, wall = _drain(eng, cfg, n_req, max_new)
            walls.append(wall)
        stats_by[name] = (eng, stats)
        timing = Timing(best_s=min(walls), mean_s=sum(walls) / len(walls),
                        trials=trials)
        pattern = (Pattern.R_ACC if name.endswith("paged")
                   else Pattern.RS_TRA)
        burst = (eng.bytes_per_page if name.endswith("paged")
                 else eng.kv_bytes() // max(1, cfg.num_layers))
        # per tick the dense path streams its full commitment; the paged
        # path touches only live pages
        bytes_moved = eng.live_kv_bytes_peak() * max(1, stats.decode_steps)
        ctx.emit(name, pattern=pattern,
                 knobs=Knobs(burst_bytes=burst, outstanding=window),
                 timing=timing,
                 us=timing.best_s / max(1, stats.tokens_out) * 1e6,
                 gbps_measured=bytes_moved / max(timing.best_s, 1e-9) / 1e9,
                 tok_s=f"{stats.tokens_out / max(timing.best_s, 1e-9):.1f}",
                 tokens_out=stats.tokens_out,
                 decode_dispatches=stats.decode_dispatches,
                 kv_bytes=eng.kv_bytes(),
                 live_bytes_peak=eng.live_kv_bytes_peak())

    dense_eng, _ = stats_by["paged_serve_dense"]
    paged_eng, pstats = stats_by["paged_serve_paged"]
    # deterministic figure-of-merit rows (scheduling is host-independent):
    # the structural gate fails CI if live bytes stop beating the dense
    # footprint, the prefix cache stops hitting, or the paged path falls
    # out of the PR 3 fused-dispatch regime
    ctx.emit("paged_serve_live_bytes_ratio",
             gbps_measured=dense_eng.kv_bytes()
             / max(1, paged_eng.live_kv_bytes_peak()),
             gbps_predicted=1.0,
             deterministic=True,
             pages_peak=pstats.pages_peak,
             page_size=paged_eng.page,
             pool_pages=paged_eng.num_pages,
             metric="dense batch*max_len bytes / paged live-token peak "
                    "bytes (must stay > 1)")
    ctx.emit("paged_serve_prefix_hit_rate",
             gbps_measured=pstats.prefix_hit_tokens
             / max(1, pstats.prompt_tokens),
             deterministic=True,
             hit_tokens=pstats.prefix_hit_tokens,
             prompt_tokens=pstats.prompt_tokens,
             metric="prompt tokens served from shared prefix pages "
                    "(higher=better)")
    ctx.emit("paged_serve_ticks_per_dispatch",
             gbps_measured=pstats.decode_steps
             / max(1, pstats.decode_dispatches),
             gbps_predicted=float(window),
             deterministic=True,
             metric="paged decode ticks per fused dispatch (parity with "
                    "the PR 3 fast path)")
    full_ratio = (dense_eng.kv_bytes()
                  / max(1, paged_eng.live_kv_bytes_peak()))

    # ----------------------------------------------------------------
    # windowed stack (gemma2: local/global pairs): ring pages bound the
    # windowed layers at ceil(window/page)+1 live pages per slot, so the
    # live-bytes win must beat the full-attention baseline above
    # ----------------------------------------------------------------
    cfg_w = smoke_config(ARCHS["gemma2-27b"])
    bundle_w = build(cfg_w, flags)
    params_w = bundle_w.init(jax.random.PRNGKey(1))
    win_len = 128
    dense_w = ServeEngine(bundle_w, params_w, batch_size=2, max_len=win_len,
                          window=window, cache_backend="dense")
    paged_w = ServeEngine(bundle_w, params_w, batch_size=2, max_len=win_len,
                          window=window, cache_backend="paged")
    wstats, _ = _drain(paged_w, cfg_w, n_req, max_new)
    ratio_w = dense_w.kv_bytes() / max(1, paged_w.live_kv_bytes_peak())
    # the acceptance figure: at serving-scale max_len (128; the baseline
    # rows above run at the PR 4 shapes) the windowed stack must beat the
    # full-attention 2.0x baseline — the dense engine still commits
    # batch x max_len on its global layers while ring + paged-full stay at
    # live tokens.  NOTE this is a whole-stack figure across different
    # max_len; the eager-release property itself is gated by the bytes
    # bound below (and exactly, per-slot, in tests/test_serve_paged.py).
    if ratio_w <= full_ratio:
        raise AssertionError(
            f"windowed live-bytes ratio {ratio_w:.2f} must beat the "
            f"full-attention baseline {full_ratio:.2f}: ring paging lost "
            "its eager-release win")
    # eager release, bound against the *window itself* (not ring_slots,
    # which is code under test): however long the drain runs, live ring
    # bytes per slot may never exceed window tokens + 2 pages of slack
    win_tokens = max(s.sliding_window for s in cfg_w.layer_pattern
                     if s.sliding_window is not None)
    ring_cap_tokens = 2 * (win_tokens + 2 * paged_w.page)   # batch_size=2
    if wstats.ring_pages_peak * paged_w.page > ring_cap_tokens:
        raise AssertionError(
            f"peak ring pages {wstats.ring_pages_peak} x page "
            f"{paged_w.page} exceed the window bound {ring_cap_tokens} "
            "tokens: the ring stopped releasing the trailing page")
    ctx.emit("paged_serve_windowed_live_bytes_ratio",
             gbps_measured=ratio_w,
             gbps_predicted=full_ratio,
             deterministic=True,
             ring_slots=paged_w.ring_slots,
             ring_pages_peak=wstats.ring_pages_peak,
             pages_peak=wstats.pages_peak,
             page_size=paged_w.page,
             metric="windowed-stack dense footprint / paged live peak "
                    "(must stay above the full-attention baseline ratio)")
    ctx.emit("paged_serve_windowed_ring_bound",
             gbps_measured=float(wstats.ring_pages_peak),
             gbps_predicted=float(2 * paged_w.ring_slots),
             deterministic=True,
             metric="peak live ring pages (must stay <= "
                    "batch x (ceil(window/page)+1))")

    # ----------------------------------------------------------------
    # int8 KV pages: half the unit size -> double the transaction-optimum
    # page (tokens) and half the live bytes per token
    # ----------------------------------------------------------------
    flags8 = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                          moe_impl="dense", loss_chunk=16, kv_dtype="int8")
    bundle8 = build(cfg, flags8)
    params8 = bundle8.init(jax.random.PRNGKey(0))
    dense8 = ServeEngine(bundle8, params8, batch_size=2, max_len=max_len,
                         window=window, cache_backend="dense")
    paged8 = ServeEngine(bundle8, params8, batch_size=2, max_len=max_len,
                         window=window, cache_backend="paged")
    s8, _ = _drain(paged8, cfg, n_req, max_new)
    ctx.emit("paged_serve_int8_live_bytes_ratio",
             gbps_measured=dense8.kv_bytes()
             / max(1, paged8.live_kv_bytes_peak()),
             gbps_predicted=1.0,
             deterministic=True,
             pages_peak=s8.pages_peak,
             page_size=paged8.page,
             native_page_size=paged_eng.page,
             metric="int8-KV dense footprint / paged live peak (must stay "
                    "> 1); int8 pages hold more tokens per transaction")
    import jax.numpy as jnp
    ctx.emit("paged_serve_int8_page_tokens_ratio",
             gbps_measured=paged8.page / max(1, paged_eng.page),
             gbps_predicted=float(jnp.dtype(cfg.compute_dtype).itemsize),
             deterministic=True,
             metric="int8 page tokens / native page tokens: the paper's "
                    "data-width lever widens the r_acc transaction unit by "
                    "the dtype-bytes ratio")

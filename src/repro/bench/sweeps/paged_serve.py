"""Paged-KV serving sweep (PR 4): the page pool as the r_acc engine.

Dense per-slot serving commits ``batch x max_len`` KV bytes up front and
streams them every tick (`rs_tra`); the paged backend allocates
transaction-optimum pages on demand and dereferences a per-sequence table
inside the ``paged_attention`` kernel (`r_acc` over page-sized units —
exactly what the ``random`` sweep benchmarks).  This sweep drains the same
deterministic request mix (half the prompts share a two-page prefix)
through both backends and emits:

- timed rows: warm tokens/s per backend;
- deterministic figure-of-merit rows the CI structural gate trusts on any
  host: live-token HBM bytes vs the dense footprint (must stay > 1x),
  prefix-cache hit rate, and decode ticks per fused dispatch (the paged
  path must keep the PR 3 fast-path dispatch regime).
"""
import time

import jax
import numpy as np

from repro.bench.registry import SweepContext, register
from repro.bench.schema import Timing
from repro.core.patterns import Knobs, Pattern


def _mix(cfg, n_req: int, max_new: int):
    """Deterministic request mix: even rids share a 16-token (2-page)
    prefix, odd rids are fully distinct."""
    from repro.serve import Request

    rng = np.random.default_rng(0)
    common = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    reqs = []
    for i in range(n_req):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 9))).astype(np.int32)
        prompt = (np.concatenate([common, tail]) if i % 2 == 0
                  else np.concatenate([tail, tail, tail]))
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=max_new))
    return reqs


def _drain(eng, cfg, n_req, max_new):
    for r in _mix(cfg, n_req, max_new):
        eng.add_request(r)
    t0 = time.perf_counter()
    stats = eng.run_to_completion()
    return stats, time.perf_counter() - t0


@register("paged_serve", "§6 r_acc applied: paged-KV continuous batching")
def run_paged_serve(ctx: SweepContext) -> None:
    from repro.configs import ARCHS, smoke_config
    from repro.models import RuntimeFlags, build
    from repro.serve import ServeEngine

    cfg = smoke_config(ARCHS["gemma-2b"])
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16)
    bundle = build(cfg, flags)
    params = bundle.init(jax.random.PRNGKey(0))
    n_req, max_new = (4, 8) if ctx.fast else (10, 16)
    max_len = 64 if ctx.fast else 128
    window = 8
    trials = 2 if ctx.fast else 3

    engines = {
        "paged_serve_dense": ServeEngine(
            bundle, params, batch_size=2, max_len=max_len, window=window,
            cache_backend="dense"),
        "paged_serve_paged": ServeEngine(
            bundle, params, batch_size=2, max_len=max_len, window=window,
            cache_backend="paged"),
    }
    stats_by = {}
    for name, eng in engines.items():
        _drain(eng, cfg, n_req, max_new)    # cold: compiles; reset keeps jits
        walls = []
        for _ in range(trials):
            eng.reset()
            stats, wall = _drain(eng, cfg, n_req, max_new)
            walls.append(wall)
        stats_by[name] = (eng, stats)
        timing = Timing(best_s=min(walls), mean_s=sum(walls) / len(walls),
                        trials=trials)
        pattern = (Pattern.R_ACC if name.endswith("paged")
                   else Pattern.RS_TRA)
        burst = (eng.bytes_per_page if name.endswith("paged")
                 else eng.kv_bytes() // max(1, cfg.num_layers))
        # per tick the dense path streams its full commitment; the paged
        # path touches only live pages
        bytes_moved = eng.live_kv_bytes_peak() * max(1, stats.decode_steps)
        ctx.emit(name, pattern=pattern,
                 knobs=Knobs(burst_bytes=burst, outstanding=window),
                 timing=timing,
                 us=timing.best_s / max(1, stats.tokens_out) * 1e6,
                 gbps_measured=bytes_moved / max(timing.best_s, 1e-9) / 1e9,
                 tok_s=f"{stats.tokens_out / max(timing.best_s, 1e-9):.1f}",
                 tokens_out=stats.tokens_out,
                 decode_dispatches=stats.decode_dispatches,
                 kv_bytes=eng.kv_bytes(),
                 live_bytes_peak=eng.live_kv_bytes_peak())

    dense_eng, _ = stats_by["paged_serve_dense"]
    paged_eng, pstats = stats_by["paged_serve_paged"]
    # deterministic figure-of-merit rows (scheduling is host-independent):
    # the structural gate fails CI if live bytes stop beating the dense
    # footprint, the prefix cache stops hitting, or the paged path falls
    # out of the PR 3 fused-dispatch regime
    ctx.emit("paged_serve_live_bytes_ratio",
             gbps_measured=dense_eng.kv_bytes()
             / max(1, paged_eng.live_kv_bytes_peak()),
             gbps_predicted=1.0,
             deterministic=True,
             pages_peak=pstats.pages_peak,
             page_size=paged_eng.page,
             pool_pages=paged_eng.num_pages,
             metric="dense batch*max_len bytes / paged live-token peak "
                    "bytes (must stay > 1)")
    ctx.emit("paged_serve_prefix_hit_rate",
             gbps_measured=pstats.prefix_hit_tokens
             / max(1, pstats.prompt_tokens),
             deterministic=True,
             hit_tokens=pstats.prefix_hit_tokens,
             prompt_tokens=pstats.prompt_tokens,
             metric="prompt tokens served from shared prefix pages "
                    "(higher=better)")
    ctx.emit("paged_serve_ticks_per_dispatch",
             gbps_measured=pstats.decode_steps
             / max(1, pstats.decode_dispatches),
             gbps_predicted=float(window),
             deterministic=True,
             metric="paged decode ticks per fused dispatch (parity with "
                    "the PR 3 fast path)")

"""Paper Table 10 + §6.1: 11x11 convolution over a 1920x1080 matrix.

Rows mirror the paper's three implementations:
  cpu       — naive numpy sliding-window (the paper's CPU row)
  fused     — XLA conv (single wide engine; the paper's 2-channel FPGA row)
  split     — row-partitioned conv (the paper's 32-channel row;
              per-shard dispatch overhead vs parallelism)

Bandwidth columns count input read + output write once per pass — an
*effective* streaming bandwidth, so the conv rows calibrate against the
sequential model like every other sweep.
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.bench.registry import SweepContext, register
from repro.bench.schema import Timing
from repro.core.patterns import Knobs, Pattern


@register("conv", "Table 10")
def run(ctx: SweepContext) -> None:
    H, W = (480, 270) if ctx.fast else (1080, 1920)
    K = 11
    img = np.random.default_rng(0).standard_normal((H, W)).astype(np.float32)
    ker = np.ones((K, K), np.float32) / (K * K)
    out_hw = (H - K + 1) * (W - K + 1)
    nbytes = (H * W + out_hw) * 4  # read image once + write result once
    flops = 2 * H * W * K * K

    # cpu: naive strided windows (small tile to keep runtime sane)
    th, tw = (64, 64)
    tile = img[:th + K - 1, :tw + K - 1]
    t0 = time.perf_counter()
    out = np.zeros((th, tw), np.float32)
    for i in range(K):
        for j in range(K):
            out += tile[i:i + th, j:j + tw] * ker[i, j]
    cpu_wall = (time.perf_counter() - t0) * (H * W) / (th * tw)
    ctx.emit("conv_cpu_naive", pattern=Pattern.STRIDED,
             knobs=Knobs(unit_bytes=tw * 4, stride=K),
             timing=Timing(best_s=cpu_wall, mean_s=cpu_wall, trials=1),
             bytes_moved=nbytes,
             gflops=f"{flops/cpu_wall/1e9:.2f}", paper_cpu_s=0.06)

    x = jnp.asarray(img)[None, :, :, None]
    kk = jnp.asarray(ker)[:, :, None, None]
    conv_fn = jax.jit(lambda a, b: jax.lax.conv_general_dilated(
        a, b, (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")))
    t = ctx.timeit(conv_fn, x, kk)
    ctx.emit("conv_xla_fused", pattern=Pattern.SEQUENTIAL,
             knobs=Knobs(burst_bytes=W * 4 * 8), timing=t, bytes_moved=nbytes,
             gflops=f"{flops/t.best_s/1e9:.2f}", paper_fpga2ch_s=2.04,
             speedup_vs_cpu=f"{cpu_wall/t.best_s:.1f}")

    # split: row-shards, separate dispatches (multi-kernel analogue)
    shards = jnp.split(jnp.asarray(img), 8, axis=0)
    pads = [jnp.pad(s, ((0, K - 1), (0, 0)))[None, :, :, None] for s in shards]

    def run_split():
        outs = [conv_fn(p, kk) for p in pads]
        return outs[-1]

    run_split()
    t = ctx.timeit(run_split)
    ctx.emit("conv_split_16", pattern=Pattern.SEQUENTIAL,
             knobs=Knobs(burst_bytes=W * 4 * 8, engines=8), timing=t,
             bytes_moved=nbytes,
             gflops=f"{flops/t.best_s/1e9:.2f}", paper_fpga32ch_s=21.0,
             note="per_shard_dispatch_overhead")

"""Paper Fig. 7: throughput vs unit size (transaction width).

TPU analogue: random row gather with growing row bytes — the paper's claim
(throughput ~ linear in unit size until the bandwidth roof) reproduces on
both the measured CPU engine and the analytic v5e model.
"""
import jax.numpy as jnp

from repro.bench.registry import SweepContext, register
from repro.core import engines
from repro.core.patterns import Knobs, Pattern


@register("unit_size", "Fig 7")
def run(ctx: SweepContext) -> None:
    units = (4, 16, 64, 256, 1024) if ctx.fast else (4, 16, 64, 256, 1024, 4096)
    for u in units:
        r = engines.bw_random(n_rows=1 << 12, cols=max(1, u // 4),
                              n_idx=1 << 12)
        ctx.emit(f"unit_{u}B", pattern=Pattern.RANDOM,
                 knobs=Knobs(unit_bytes=u, outstanding=8),
                 us=r.wall_s * 1e6,
                 gbps_measured=r.gbps_measured,
                 gbps_predicted=r.gbps_tpu_model)
    # dtype variant of unit size (int8 vs bf16 vs f32 rows)
    for dt, tag in ((jnp.int8, "s8"), (jnp.bfloat16, "bf16"),
                    (jnp.float32, "f32")):
        r = engines.bw_sequential(rows=2048, cols=1024, dtype=dt)
        ctx.emit(f"unit_dtype_{tag}", pattern=Pattern.SEQUENTIAL,
                 knobs=Knobs(unit_bytes=128 * jnp.dtype(dt).itemsize),
                 us=r.wall_s * 1e6,
                 gbps_measured=r.gbps_measured,
                 gbps_predicted=r.gbps_tpu_model)

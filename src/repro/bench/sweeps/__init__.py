"""The eighteen registered sweeps — one module per paper table/figure,
plus the PR 3 tune->execute proof sweeps (``serve`` + ``kernel_plan``),
the PR 4 paged-KV serving sweep (``paged_serve``), the PR 6 speculative
draft->verify sweep (``spec_serve``), the PR 7 sharded-serving sweep
(``dist_serve``), the PR 8 preemptive-scheduling sweep
(``preempt_serve``), the PR 9 fault-tolerant cluster front-end sweep
(``cluster_serve``), and the PR 10 disaggregated prefill/decode sweep
(``disagg_serve``).

Importing this package populates :data:`repro.bench.registry.REGISTRY` in
the paper's presentation order.  ``benchmarks/bench_*.py`` are thin shims
over these modules; the implementations live here so library users can run
any sweep programmatically via :func:`repro.bench.run_sweeps`.
"""
from repro.bench.sweeps import (  # noqa: F401  (import order == run order)
    latency, outstanding, unit_size, stride, burst, num_kernels,
    random_access, database, conv, roofline, serve, paged_serve, spec_serve,
    dist_serve, preempt_serve, cluster_serve, disagg_serve,
)

__all__ = [
    "latency", "outstanding", "unit_size", "stride", "burst", "num_kernels",
    "random_access", "database", "conv", "roofline", "serve", "paged_serve",
    "spec_serve", "dist_serve", "preempt_serve", "cluster_serve",
    "disagg_serve",
]

"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads runs/dryrun.json (written by repro.launch.dryrun --all --roofline) and
emits one row per (arch x shape) cell with the three terms, dominant
bottleneck, and MODEL_FLOPS/HLO_FLOPs ratio.  When no artifact exists (fresh
checkout, CI) it falls back to *analytic* cells — flops from
``ModelConfig.flops_per_token`` and bytes from the advisor's site reports —
so the sweep always emits comparable rows.  ``gbps_measured`` here is the
effective HBM bandwidth at the modeled bound (hlo_bytes / bound_s);
``gbps_predicted`` is the spec's peak HBM bandwidth.
"""
import json
import os

from repro.bench.registry import SweepContext, register
from repro.core.patterns import Pattern

_HERE = os.path.dirname(os.path.abspath(__file__))
_REPO_ROOT = os.path.abspath(os.path.join(_HERE, "..", "..", "..", ".."))


def _artifact_path() -> str:
    env = os.environ.get("DRYRUN_JSON")
    if env:
        return env
    for base in (os.getcwd(), _REPO_ROOT):
        for name in ("dryrun_opt.json", "dryrun.json"):
            p = os.path.join(base, "runs", name)
            if os.path.exists(p):
                return p
    return os.path.join(_REPO_ROOT, "runs", "dryrun.json")


def _emit_terms(ctx: SweepContext, name: str, compute_s: float,
                memory_s: float, collective_s: float, hlo_bytes: float,
                useful_ratio: float, dominant: str, **extras) -> None:
    bound = max(compute_s, memory_s, collective_s)
    ideal = compute_s * useful_ratio
    ctx.emit(name, pattern=Pattern.SEQUENTIAL,
             us=compute_s * 1e6,
             gbps_measured=(hlo_bytes / bound / 1e9) if bound else 0.0,
             gbps_predicted=ctx.spec.hbm_bw / 1e9,
             compute_ms=f"{compute_s*1e3:.2f}",
             memory_ms=f"{memory_s*1e3:.2f}",
             collective_ms=f"{collective_s*1e3:.2f}",
             dominant=dominant,
             useful_flops_ratio=f"{useful_ratio:.3f}",
             frac=f"{ideal/bound:.3f}" if bound else "0",
             **extras)


def _from_artifact(ctx: SweepContext, path: str) -> None:
    with open(path) as f:
        records = json.load(f)
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        name = f"roofline_{r['arch']}_{r['shape']}"
        if r.get("status") == "skip":
            ctx.emit(name, status="skip", reason=r.get("reason", ""))
            continue
        if r.get("status") != "ok" or "roofline" not in r:
            ctx.emit(name, status=r.get("status", "missing"))
            continue
        rf = r["roofline"]
        c, m, co = rf["compute_s"], rf["memory_s"], rf["collective_s"]
        sp = r.get("meshes", {}).get("single_pod", {})
        mp = r.get("meshes", {}).get("multi_pod", {})
        ideal = c * rf["useful_ratio"]
        m_k = m - rf.get("bytes_flash_inner", 0.0) / ctx.spec.hbm_bw
        _emit_terms(
            ctx, name, c, m, co, rf.get("hlo_bytes", 0.0),
            rf["useful_ratio"], rf["dominant"],
            frac_serial=f"{ideal/(c+m+co):.3f}" if (c + m + co) else "0",
            frac_kernel=f"{ideal/max(c,m_k,co):.3f}" if max(c, m_k, co) else "0",
            peak_gib_per_dev=sp.get("peak_gib", ""),
            fits_16g_1pod=sp.get("peak_gib", 99) < 16.0,
            fits_16g_2pod=mp.get("peak_gib", 99) < 16.0,
            source=os.path.basename(path))


def _analytic_fallback(ctx: SweepContext) -> None:
    """No compiled artifact: derive the three terms from the analytic model
    (advisor bytes + 6N flops) for a small arch subset so the sweep still
    produces comparable rows on a fresh checkout."""
    from repro.configs import ARCHS, SHAPES_BY_NAME, shape_applicable
    from repro.core.advisor import advise_model
    from repro.core.memmodel import roofline as roofline_terms

    archs = ("mamba2-130m", "gemma-2b") if ctx.fast else tuple(sorted(ARCHS))
    shapes = ("train_4k",) if ctx.fast else ("train_4k", "decode_32k")
    for arch in archs:
        cfg = ARCHS.get(arch)
        if cfg is None:
            continue
        for shape in shapes:
            cell = SHAPES_BY_NAME[shape]
            ok, why = shape_applicable(cfg, cell)
            if not ok:
                ctx.emit(f"roofline_{arch}_{shape}", status="skip", reason=why)
                continue
            reports = advise_model(cfg, cell)
            hlo_bytes = float(sum(r.bytes_moved for r in reports))
            model_flops = float(cfg.flops_per_token() * cell.tokens)
            terms = roofline_terms(hlo_flops=model_flops, hlo_bytes=hlo_bytes,
                                   collective_bytes=0.0, chips=1,
                                   model_flops=model_flops, spec=ctx.spec)
            _emit_terms(ctx, f"roofline_{arch}_{shape}", terms.compute_s,
                        terms.memory_s, terms.collective_s, hlo_bytes,
                        terms.useful_flops_ratio, terms.dominant,
                        source="analytic_fallback")


@register("roofline", "EXPERIMENTS §Roofline")
def run(ctx: SweepContext) -> None:
    path = _artifact_path()
    if os.path.exists(path):
        _from_artifact(ctx, path)
    else:
        _analytic_fallback(ctx)

"""Sharded paged serving sweep (PR 7): TP shards as memory channels.

The paper scales bandwidth by spreading one buffer over multiple banks /
channels behind independent AXI ports; the serving twin shards the KV page
pools (and attention heads) of ONE engine across a TP mesh axis, while DP
adds whole engine replicas behind a shared admission queue.  This sweep
drains the same deterministic request mix through a single-device paged
engine, a TP=2 sharded engine, and a DP=2 replica pool, and emits:

- timed rows: warm tokens/s per layout (tp1 / tp2 / dp2) plus the
  per-axis scaling ratios (advisory on CPU hosts — two fake devices on
  one core time-slice rather than scale);
- deterministic gate rows the CI structural gate trusts on any host:
  TP=2 drains must be *token-identical* to the single-device engine
  (greedy AND sampled — logits are all-gathered before selection so the
  per-slot PRNG chains never see the mesh), the DP pool must reproduce
  the single-engine streams per request, and one shard's live-KV bytes
  must be exactly half the global figure (pools split on kv-heads; the
  paper's per-channel footprint).

With fewer than two visible devices the sweep emits nothing: the CI
bench-smoke job forces a 2-device host platform, so the gate rows always
exist where the baseline comparison runs.
"""
import time

import jax
import numpy as np

from repro.bench.registry import SweepContext, register
from repro.bench.schema import Timing


def _mix(cfg, n_req: int, max_new: int):
    """Even rids share a two-page prefix, odd rids are distinct (same
    shape as the paged_serve mix, so prefix machinery stays exercised)."""
    from repro.serve import Request

    rng = np.random.default_rng(7)
    common = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    reqs = []
    for i in range(n_req):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 9))).astype(np.int32)
        prompt = (np.concatenate([common, tail]) if i % 2 == 0
                  else np.concatenate([tail, tail, tail]))
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=max_new))
    return reqs


def _drain(target, cfg, n_req, max_new):
    """Drain the mix through an engine or a ReplicaPool; returns
    (per-rid token streams, stats, wall seconds)."""
    reqs = _mix(cfg, n_req, max_new)
    submit = getattr(target, "submit", None) or target.add_request
    for r in reqs:
        submit(r)
    t0 = time.perf_counter()
    if hasattr(target, "drain"):
        stats = target.drain()
    else:
        stats = target.run_to_completion()
    return [r.out_tokens for r in reqs], stats, time.perf_counter() - t0


def _timed(ctx, name, target, cfg, n_req, max_new, trials):
    """Warm-drain ``trials`` times (reset keeps jit traces) and emit a
    timed tok/s row; returns (streams, stats, engines-list)."""
    engines = getattr(target, "engines", [target])
    streams = stats = None
    walls = []
    for i in range(trials + 1):               # +1 cold drain to compile
        for e in engines:
            e.reset()
        streams, stats, wall = _drain(target, cfg, n_req, max_new)
        if i > 0:
            walls.append(wall)
    timing = Timing(best_s=min(walls), mean_s=sum(walls) / len(walls),
                    trials=trials)
    ctx.emit(name, timing=timing,
             us=timing.best_s / max(1, stats.tokens_out) * 1e6,
             tok_s=f"{stats.tokens_out / max(timing.best_s, 1e-9):.1f}",
             tokens_out=stats.tokens_out,
             decode_dispatches=stats.decode_dispatches)
    return streams, stats, timing


@register("dist_serve", "§6 multi-channel: TP x DP sharded paged serving")
def run_dist_serve(ctx: SweepContext) -> None:
    if len(jax.devices()) < 2:
        return  # CI forces a 2-device host platform; nothing to gate here

    from repro.configs import ARCHS, override, smoke_config
    from repro.dist import ServeMesh
    from repro.launch.serve import ReplicaPool, build_pool
    from repro.models import RuntimeFlags, build
    from repro.serve import SamplingParams, ServeEngine

    # gemma-2b smoke is MQA; TP=2 needs both head counts divisible by 2
    cfg = override(smoke_config(ARCHS["gemma-2b"]), num_kv_heads=2)
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16)
    bundle = build(cfg, flags)
    params = bundle.init(jax.random.PRNGKey(0))
    n_req, max_new = (4, 8) if ctx.fast else (8, 16)
    max_len = 64
    trials = 2 if ctx.fast else 3
    kw = dict(batch_size=2, max_len=max_len, cache_backend="paged",
              prefill_chunk=8, seed=0)

    single = ServeEngine(bundle, params, **kw)
    tp2 = ServeEngine(bundle, params, **kw, dist=ServeMesh.tp(2))
    want, sstats, stiming = _timed(ctx, "dist_serve_tp1", single, cfg,
                                   n_req, max_new, trials)
    got, tstats, ttiming = _timed(ctx, "dist_serve_tp2", tp2, cfg,
                                  n_req, max_new, trials)

    # ---- determinism gates: the headline acceptance criteria ----------
    if got != want:
        raise AssertionError(
            "TP=2 greedy drain diverged from the single-device paged "
            f"engine: {got} != {want}")
    samp = SamplingParams(temperature=0.9, top_k=11)
    kw_s = dict(kw, sampling=samp)
    want_s, _, _ = _drain(ServeEngine(bundle, params, **kw_s),
                          cfg, n_req, max_new)
    got_s, _, _ = _drain(
        ServeEngine(bundle, params, **kw_s, dist=ServeMesh.tp(2)),
        cfg, n_req, max_new)
    if got_s != want_s:
        raise AssertionError(
            "TP=2 sampled drain diverged: the per-slot PRNG chains must "
            "never see the mesh (logits all-gathered before selection)")
    ctx.emit("dist_serve_tp2_token_parity",
             gbps_measured=1.0, gbps_predicted=1.0,
             deterministic=True,
             metric="TP=2 drains token-identical to single-device "
                    "(greedy and sampled; 1.0 = bitwise match)")

    # one shard holds exactly half the live KV bytes: the pools split on
    # their kv-heads dim, and this config carries no replicated
    # recurrent state or scale lanes to dilute the ratio
    g = tp2.live_kv_bytes_peak()
    p = tp2.live_kv_bytes_peak(per_shard=True)
    if g != 2 * p:
        raise AssertionError(
            f"per-shard live-KV bytes {p} must be exactly half the "
            f"global {g}: the page pools stopped splitting on kv-heads")
    ctx.emit("dist_serve_per_shard_live_bytes_ratio",
             gbps_measured=g / max(1, p), gbps_predicted=2.0,
             deterministic=True,
             live_bytes_global=g, live_bytes_per_shard=p,
             metric="global / per-shard live-KV peak bytes (must equal "
                    "the TP width: each shard is one memory channel)")

    # ---- DP axis: replica pool behind the shared admission queue ------
    pool = build_pool(bundle, params, tp=1, dp=2,
                      devices=jax.devices()[:2], **kw)
    got_dp, dstats, dtiming = _timed(ctx, "dist_serve_dp2", pool, cfg,
                                     n_req, max_new, trials)
    if got_dp != want:
        raise AssertionError(
            "DP=2 pool drain diverged from the single-engine streams: "
            "replicas share params and greedy decode is "
            f"schedule-invariant: {got_dp} != {want}")
    if len({id(e.cache) for e in pool.engines}) != len(pool.engines):
        raise AssertionError("DP replicas must not share cache state")
    ctx.emit("dist_serve_dp2_token_parity",
             gbps_measured=1.0, gbps_predicted=1.0,
             deterministic=True,
             replicas=len(pool.engines),
             metric="DP=2 replica-pool drain reproduces the single-engine "
                    "streams per request (1.0 = exact)")

    # ---- per-axis scaling (advisory: fake devices time-slice a CPU) ---
    base = sstats.tokens_out / max(stiming.best_s, 1e-9)
    for name, st, tm in (("tp", tstats, ttiming), ("dp", dstats, dtiming)):
        ctx.emit(f"dist_serve_{name}_scaling",
                 gbps_measured=(st.tokens_out / max(tm.best_s, 1e-9)),
                 gbps_predicted=base,
                 metric=f"{name}=2 warm tok/s vs single-device (advisory "
                        "on CPU hosts: fake devices time-slice one core)")

"""Paper Table 9: database access patterns (rs_tra / rr_tra / r_acc / nest).

Framework-level instantiations:
  rs_tra — repeated sequential weight streaming (epoch re-reads)
  rr_tra — repeated random traversal (shuffled epochs over the same table)
  r_acc  — embedding-row gather
  nest   — interleaved multi-cursor sequential = chunked attention
"""
import jax
import jax.numpy as jnp

from repro.bench.registry import SweepContext, register
from repro.core.patterns import ADVICE, Knobs, Pattern
from repro.kernels import ops
from repro.models.attention import AttnParams, chunked_attention


@register("database", "Table 9")
def run(ctx: SweepContext) -> None:
    n, d = (1 << 12, 256) if ctx.fast else (1 << 14, 512)
    table = jnp.ones((n, d), jnp.float32)
    nbytes = table.size * 4

    # rs_tra: stream the table repeatedly (3 epochs)
    fn = jax.jit(lambda t: sum(jnp.sum(t * (i + 1)) for i in range(3)))
    t = ctx.timeit(fn, table)
    ctx.emit("rs_tra", pattern=Pattern.RS_TRA, knobs=Knobs(),
             timing=t, bytes_moved=3 * nbytes,
             paper_u280_gbps=13.26,
             advice=ADVICE[Pattern.RS_TRA].knob_moves[0])

    # rr_tra: shuffled traversal each epoch
    perm = jax.random.permutation(jax.random.PRNGKey(0), n)
    fn = jax.jit(lambda t, p: jnp.sum(t[p]))
    t = ctx.timeit(fn, table, perm)
    ctx.emit("rr_tra", pattern=Pattern.RR_TRA, knobs=Knobs(unit_bytes=d * 4),
             timing=t, bytes_moved=nbytes,
             paper_u280_gbps=3.51,
             advice=ADVICE[Pattern.RR_TRA].knob_moves[0])

    # r_acc: sparse random row access (small working fraction)
    idx = ops.lfsr_indices(n // 8, bits=24) % n
    fn = jax.jit(lambda t, i: t[i])
    t = ctx.timeit(fn, table, idx)
    ctx.emit("r_acc", pattern=Pattern.R_ACC, knobs=Knobs(unit_bytes=d * 4),
             timing=t, bytes_moved=idx.shape[0] * d * 4 * 2,
             paper_u280_gbps=0.68,
             advice=ADVICE[Pattern.R_ACC].knob_moves[0])

    # nest: blocked multi-cursor (chunked attention)
    b, s, h, hd = (1, 512, 4, 64) if ctx.fast else (2, 1024, 8, 64)
    q = jnp.ones((b, s, h, hd), jnp.float32)
    k = jnp.ones((b, s, h, hd), jnp.float32)
    v = jnp.ones((b, s, h, hd), jnp.float32)
    p = AttnParams(bq=256, bkv=256)
    fn = jax.jit(lambda *a: chunked_attention(*a, p))
    t = ctx.timeit(fn, q, k, v)
    moved = (q.size + 2 * (s // 256) * k.size + q.size) * 4
    ctx.emit("nest", pattern=Pattern.NEST, knobs=Knobs(),
             timing=t, bytes_moved=moved,
             paper_u280_gbps=421.89,
             advice=ADVICE[Pattern.NEST].knob_moves[0])

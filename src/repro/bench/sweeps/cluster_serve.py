"""Fault-tolerant cluster front-end sweep (PR 9): the DP arbiter under
open-loop traffic and replica-kill schedules.

The paper's cluster framing: DP replicas are whole memory *ports* and
the host-side router is the port arbiter — sustained throughput is set
by how that arbiter behaves under contention and faults, not by peak
per-port bandwidth.  This sweep drives a 2-replica
:class:`~repro.serve.cluster.ClusterFrontEnd` with a deterministic
open-loop workload (Poisson + bursty arrivals, Zipf-shared prefixes,
mixed lengths — all on the virtual clock) and emits:

- a timed row: warm tokens/s for the undisturbed open-loop drain;
- deterministic gate rows the CI structural gate trusts on any host:
  TTFT/TPOT p50/p99 in virtual rounds (scheduling depends only on
  lengths and budgets, never token values), the failover count under a
  pinned replica-kill + brownout + admission-fault schedule, the
  **bitwise equality** of that chaos drain against the undisturbed one
  (the headline acceptance criterion: recompute-failover on a survivor
  replays the per-``(seed, rid)`` PRNG chain exactly), and the shed
  rate of a deadline-bearing workload (graceful degradation instead of
  a wedged pool).

Unlike ``dist_serve`` this sweep needs no mesh — replicas are plain
engines — so its gate rows exist on ANY device count.
"""
import time

import jax

from repro.bench.registry import SweepContext, register
from repro.bench.schema import Timing


@register("cluster_serve",
          "§6 port arbiter: fault-tolerant DP front end, open-loop SLOs")
def run_cluster_serve(ctx: SweepContext) -> None:
    from repro.configs import ARCHS, smoke_config
    from repro.models import RuntimeFlags, build
    from repro.serve import (ClusterChaos, ClusterChaosConfig,
                             ClusterFrontEnd, ServeEngine, TrafficConfig,
                             generate_traffic)

    cfg = smoke_config(ARCHS["gemma-2b"])
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16)
    bundle = build(cfg, flags)
    params = bundle.init(jax.random.PRNGKey(0))
    n_req = 8 if ctx.fast else 16
    trials = 2 if ctx.fast else 3
    kw = dict(batch_size=2, max_len=64, cache_backend="paged",
              prefill_chunk=8, window=4, seed=0)
    front = ClusterFrontEnd([ServeEngine(bundle, params, **kw),
                             ServeEngine(bundle, params, **kw)])

    # out_lo > window so every request spans >= 2 decode rounds and the
    # TPOT percentiles stay positive (zero would gate nothing)
    tcfg = TrafficConfig(seed=23, n_requests=n_req, rate=1.2,
                         burst_rate_mult=3.0, phase_rounds=4.0,
                         n_prefixes=3, prefix_len=16, tail_lo=3, tail_hi=9,
                         out_lo=6, out_hi=12)

    def drain(traffic, chaos=None):
        """Fresh schedule (requests are mutated by serving) over reset
        engines; returns (rid -> stream, wall seconds)."""
        front.reset()
        sched = generate_traffic(traffic, cfg.vocab_size)
        t0 = time.perf_counter()
        front.run(sched, chaos=chaos)
        wall = time.perf_counter() - t0
        return {r.rid: list(r.out_tokens) for _, r in sched}, wall

    # ---- undisturbed open-loop drain: timed + SLO percentiles ---------
    want = None
    walls = []
    for i in range(trials + 1):            # +1 cold drain to compile
        want, wall = drain(tcfg)
        if i > 0:
            walls.append(wall)
    stats = front.stats()
    pct = front.percentiles()
    rounds = front.cstats.rounds
    timing = Timing(best_s=min(walls), mean_s=sum(walls) / len(walls),
                    trials=trials)
    ctx.emit("cluster_serve_open_loop", timing=timing,
             us=timing.best_s / max(1, stats.tokens_out) * 1e6,
             tok_s=f"{stats.tokens_out / max(timing.best_s, 1e-9):.1f}",
             tokens_out=stats.tokens_out, rounds=rounds,
             replicas=len(front.replicas))
    if front.cstats.completed != n_req:
        raise AssertionError(
            f"undisturbed open-loop drain completed "
            f"{front.cstats.completed}/{n_req} requests")
    for mname, val in sorted(pct.items()):
        if val <= 0:
            raise AssertionError(f"{mname} = {val}: virtual-clock "
                                 "percentiles must be positive")
        ctx.emit(f"cluster_serve_{mname}",
                 gbps_measured=val, gbps_predicted=val,
                 deterministic=True, rounds=rounds,
                 metric=f"{mname} in virtual rounds under the open-loop "
                        "Poisson/Zipf workload (deterministic: the clock "
                        "never sees token values)")

    # ---- replica-kill + brownout + admission-fault schedule ------------
    # crash replica 1 early (its queued + in-flight work fails over),
    # brown out replica 0 later (slow probes -> quarantine), and arm one
    # transient admission refusal per replica (bounded retry/backoff)
    chaos = ClusterChaos(ClusterChaosConfig(
        seed=5, crash_rounds=4, brownout_rounds=4, brownout_latency_s=1.0,
        kill_at=((0, 0, "admit"), (0, 1, "admit"),
                 (2, 1, "crash"), (12, 0, "brownout"))))
    got, _ = drain(tcfg, chaos=chaos)
    c = front.cstats
    if got != want:
        diverged = sorted(r for r in want if got.get(r) != want[r])
        raise AssertionError(
            f"chaos drain diverged from the undisturbed run on rids "
            f"{diverged}: failover must replay the per-(seed, rid) "
            "PRNG chain bitwise")
    if c.failovers < 1 or c.quarantines < 1:
        raise AssertionError(
            f"kill schedule injected no failovers (failovers="
            f"{c.failovers}, quarantines={c.quarantines}): the gate "
            "proved nothing")
    if c.retries < 1:
        raise AssertionError("armed admission faults were never consumed")
    ctx.emit("cluster_serve_chaos_match",
             gbps_measured=1.0, gbps_predicted=1.0, deterministic=True,
             crashes=chaos.crashes, brownouts=chaos.brownouts,
             retries=c.retries, quarantines=c.quarantines,
             recoveries=c.recoveries,
             metric="replica-kill + brownout + admission-fault drain is "
                    "bitwise identical to the undisturbed run "
                    "(1.0 = exact)")
    ctx.emit("cluster_serve_failover_count",
             gbps_measured=float(c.failovers), gbps_predicted=float(c.failovers),
             deterministic=True,
             metric="requests failed over off quarantined replicas under "
                    "the pinned kill schedule (deterministic)")

    # ---- deadline workload: shed rate under congestion -----------------
    # a hotter arrival rate + tight deadlines forces the router to shed
    # low-priority requests and degrade borderline ones instead of
    # wedging; high-priority requests are never shed (slo_risk counts
    # their at-risk routes)
    dcfg = TrafficConfig(seed=29, n_requests=max(12, n_req), rate=6.0,
                         burst_rate_mult=2.0, phase_rounds=4.0,
                         n_prefixes=3, prefix_len=16, tail_lo=3, tail_hi=9,
                         out_lo=6, out_hi=12, deadline_rounds=(2, 10),
                         high_priority_frac=0.25)
    drain(dcfg)
    d = front.cstats
    n_sub = d.submitted
    shed_rate = d.shed / max(1, n_sub)
    if not 0.0 < shed_rate < 1.0:
        raise AssertionError(
            f"deadline workload shed {d.shed}/{n_sub}: the shed-rate "
            "gate needs congestion that sheds some but not all requests")
    if d.completed + d.shed != n_sub:
        raise AssertionError(
            f"request conservation broke: {d.completed} completed + "
            f"{d.shed} shed != {n_sub} submitted")
    ctx.emit("cluster_serve_shed_rate",
             gbps_measured=shed_rate, gbps_predicted=shed_rate,
             deterministic=True, shed=d.shed, submitted=n_sub,
             degraded=d.degraded, slo_risk=d.slo_risk,
             metric="deadline-shed fraction under the congested workload "
                    "(deterministic: low-priority blown-deadline requests "
                    "shed, borderline ones degrade)")

"""Speculative-decoding serving sweep (PR 6): burst-length on r_acc.

The paged fast path dereferences the page table once per decoded token
(`r_acc` at page granularity).  Speculative decoding widens that burst:
a draft model proposes ``k`` tokens per tick and the target verifies all
``k+1`` positions in ONE ``paged_verify`` dispatch — the same pool pages
are touched once per *burst* instead of once per token, exactly the
paper's burst-length lever applied to the serving loop.  This sweep
drains the same deterministic mix through the vanilla paged engine and a
self-draft speculative engine (every proposal accepted — the pure
upper-bound regime) and emits:

- timed rows: warm tokens/s per engine;
- deterministic figure-of-merit rows the CI structural gate trusts on
  any host: accepted draft tokens per verify dispatch (hard-gated
  >= 1.0 in-sweep), accept rate, emitted tokens per verify dispatch
  (burst length, predicted ``k+1`` for self-draft), decode ticks per
  dispatch, and bitwise spec==vanilla output equality.
"""
import time

import jax
import numpy as np

from repro.bench.registry import SweepContext, register
from repro.bench.schema import Timing
from repro.core.patterns import Knobs, Pattern

SPEC_K = 3


def _mix(cfg, n_req: int, max_new: int):
    """Deterministic request mix: even rids share a 16-token prefix."""
    from repro.serve import Request

    rng = np.random.default_rng(6)
    common = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    reqs = []
    for i in range(n_req):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 9))).astype(np.int32)
        prompt = (np.concatenate([common, tail]) if i % 2 == 0
                  else np.concatenate([tail, tail]))
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=max_new))
    return reqs


def _drain(eng, cfg, n_req, max_new):
    outs = {}
    for r in _mix(cfg, n_req, max_new):
        eng.add_request(r)
        outs[r.rid] = r
    t0 = time.perf_counter()
    stats = eng.run_to_completion()
    wall = time.perf_counter() - t0
    return stats, wall, {rid: list(r.out_tokens) for rid, r in outs.items()}


@register("spec_serve", "§6 burst length applied: speculative verify")
def run_spec_serve(ctx: SweepContext) -> None:
    from repro.configs import ARCHS, smoke_config
    from repro.models import RuntimeFlags, build
    from repro.serve import ServeEngine

    cfg = smoke_config(ARCHS["gemma-2b"])
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16)
    bundle = build(cfg, flags)
    params = bundle.init(jax.random.PRNGKey(0))
    n_req, max_new = (4, 8) if ctx.fast else (8, 16)
    max_len = 64 if ctx.fast else 128
    trials = 2 if ctx.fast else 3

    def mk(spec: bool) -> ServeEngine:
        kw = (dict(draft_bundle=bundle, draft_params=params, spec_k=SPEC_K)
              if spec else {})
        return ServeEngine(bundle, params, batch_size=2, max_len=max_len,
                           window=SPEC_K + 1, cache_backend="paged", **kw)

    engines = {"spec_serve_vanilla": mk(False), "spec_serve_spec": mk(True)}
    stats_by = {}
    for name, eng in engines.items():
        _drain(eng, cfg, n_req, max_new)   # cold: compiles; reset keeps jits
        walls = []
        for _ in range(trials):
            eng.reset()
            stats, wall, outs = _drain(eng, cfg, n_req, max_new)
            walls.append(wall)
        stats_by[name] = (stats, outs)
        timing = Timing(best_s=min(walls), mean_s=sum(walls) / len(walls),
                        trials=trials)
        # one verify dispatch reads each live page once for a k+1 burst:
        # burst bytes = page bytes, reuse = verify width
        ctx.emit(name, pattern=Pattern.R_ACC,
                 knobs=Knobs(burst_bytes=eng.bytes_per_page,
                             outstanding=SPEC_K + 1),
                 timing=timing,
                 us=timing.best_s / max(1, stats.tokens_out) * 1e6,
                 tok_s=f"{stats.tokens_out / max(timing.best_s, 1e-9):.1f}",
                 tokens_out=stats.tokens_out,
                 decode_dispatches=stats.decode_dispatches,
                 spec_steps=stats.spec_steps)

    vstats, vouts = stats_by["spec_serve_vanilla"]
    sstats, souts = stats_by["spec_serve_spec"]
    # deterministic figure-of-merit rows (scheduling is host-independent)
    if sstats.spec_steps == 0:
        raise AssertionError("speculative engine never dispatched a "
                             "draft->verify step")
    aps = sstats.accepted_per_step
    if aps < 1.0:
        raise AssertionError(
            f"accepted draft tokens per verify dispatch {aps:.2f} < 1.0: "
            "speculation is emitting no more than plain decode per step")
    ctx.emit("spec_serve_accept_per_step",
             gbps_measured=aps,
             gbps_predicted=float(SPEC_K),
             deterministic=True,
             spec_steps=sstats.spec_steps,
             draft_accepted=sstats.draft_accepted,
             metric="accepted draft tokens per verify dispatch, summed "
                    "across batch slots (hard-gated >= 1.0; a full "
                    "self-draft slot contributes k)")
    ctx.emit("spec_serve_accept_rate",
             gbps_measured=sstats.accept_rate,
             gbps_predicted=1.0,
             deterministic=True,
             draft_accepted=sstats.draft_accepted,
             draft_tokens=sstats.draft_tokens,
             metric="accepted/proposed draft tokens (self-draft greedy "
                    "must accept everything)")
    seeds = n_req  # one prefill-seeded token per request, per drain
    ctx.emit("spec_serve_verify_tokens_per_dispatch",
             gbps_measured=(sstats.tokens_out - seeds)
             / max(1, sstats.spec_steps),
             gbps_predicted=float(SPEC_K + 1),
             deterministic=True,
             metric="decode tokens emitted per verify dispatch, summed "
                    "across batch slots — the burst the paper's r_acc "
                    "lever widens (a full slot contributes k+1)")
    ctx.emit("spec_serve_ticks_per_dispatch",
             gbps_measured=sstats.decode_steps
             / max(1, sstats.decode_dispatches),
             gbps_predicted=1.0,
             deterministic=True,
             metric="host->device dispatches per verify step (one fused "
                    "draft+verify launch per tick)")
    match = float(souts == vouts)
    if match != 1.0:
        bad = [rid for rid in vouts if souts.get(rid) != vouts[rid]]
        raise AssertionError(
            f"speculative drain diverged from vanilla on rids {bad}: "
            "rollback/verify lost bitwise equivalence")
    ctx.emit("spec_serve_tokens_match",
             gbps_measured=match,
             gbps_predicted=1.0,
             deterministic=True,
             tokens_out=sstats.tokens_out,
             metric="speculative == vanilla drained tokens, bitwise "
                    "(1.0 or the sweep raises)")

"""Paper Fig. 10 + Tables 3/4: burst size effect + buffer (BRAM/VMEM) cost.

TPU analogue: BlockSpec block bytes per DMA.  Measured column uses the
Pallas stream engine in interpret mode for CORRECTNESS of the block walk and
XLA for timing; the VMEM column is the paper's BRAM column (grows with
burst x outstanding while throughput saturates) — the resource-throughput
tradeoff the paper highlights.
"""
import jax
import jax.numpy as jnp

from repro.bench.registry import SweepContext, register
from repro.core.memmodel import vmem_ok
from repro.core.patterns import Knobs, Pattern
from repro.kernels import ops, ref


@register("burst", "Fig 10 / Tables 3-4")
def run(ctx: SweepContext) -> None:
    rows, cols = (1024, 512) if ctx.fast else (4096, 1024)
    x = jnp.ones((rows, cols), jnp.float32)
    nbytes = x.size * 4 * 2
    fn = jax.jit(ref.stream_copy)
    t = ctx.timeit(fn, x)  # XLA copy timing is block-independent
    for block_rows in (2, 4, 8, 16, 32, 64, 128):
        # correctness of the blocked walk (the Pallas engine)
        got = ops.stream_copy(x[:256], block_rows=block_rows)
        assert bool(jnp.all(got == x[:256]))
        knobs = Knobs(burst_bytes=block_rows * cols * 4, outstanding=2)
        ctx.emit(f"burst_{block_rows}rows", pattern=Pattern.SEQUENTIAL,
                 knobs=knobs, timing=t, bytes_moved=nbytes,
                 burst_bytes=knobs.burst_bytes,
                 vmem_bytes=knobs.vmem_bytes(),
                 fits_vmem=vmem_ok(knobs, ctx.spec))

"""Paper Table 2 (latency per channel) + Fig. 6 (latency vs stride).

TPU analogue: pointer-chase ns/hop per HBM address region (channel analogue)
and vs chain stride.  Measured = XLA:CPU chase; model = T_l (memmodel).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.registry import SweepContext, register
from repro.core.patterns import Knobs, Pattern
from repro.kernels import ops, ref


def _strided_chain(n, stride):
    """next = (cur + stride) mod n; full cycle when gcd(stride, n) == 1."""
    idx = (np.arange(n) + stride) % n
    return jnp.asarray(idx, jnp.int32)[:, None]


@register("latency", "Table 2 / Fig 6")
def run(ctx: SweepContext) -> None:
    steps = 1 << (10 if ctx.fast else 13)
    n = 1 << (12 if ctx.fast else 15)
    knobs = Knobs(unit_bytes=4, outstanding=1)
    for region in range(4 if ctx.fast else 8):
        table = ops.make_chain(n, seed=region)
        fn = jax.jit(lambda t: ref.pointer_chase(t, steps))
        t = ctx.timeit(fn, table)
        ctx.emit(f"latency_region_{region}", pattern=Pattern.CHASE,
                 knobs=knobs, timing=t, bytes_moved=steps * 4,
                 ns_per_hop=f"{t.best_s/steps*1e9:.1f}",
                 t_l_model_ns=f"{ctx.spec.dma_latency_s*1e9:.0f}")

    for stride in (1, 2, 3, 4, 8, 9, 10, 18):
        table = _strided_chain(n, stride) if np.gcd(stride, n) == 1 else \
            _strided_chain(n + 1, stride)
        fn = jax.jit(lambda t: ref.pointer_chase(t, steps))
        t = ctx.timeit(fn, table)
        ctx.emit(f"latency_stride_{stride}", pattern=Pattern.CHASE,
                 knobs=Knobs(unit_bytes=4, stride=stride, outstanding=1),
                 timing=t, bytes_moved=steps * 4,
                 ns_per_hop=f"{t.best_s/steps*1e9:.1f}")

"""Disaggregated prefill/decode sweep (PR 10): tier movement across
meshes.

The paper's achievable-bandwidth story is about which tier data lives in
and how it moves — transaction unit, burst length, outstanding transfers.
This sweep ships whole finished-prefill page sets between engine pools
(the cross-replica generalization of the PR 8 host-tier swap) and gates
that the movement is free of correctness cost:

- timed rows: warm tokens/s for the colocated drain and the same mix
  through a prefill-pool -> decode-pool hand-off (advisory wall clock);
- deterministic gated rows the CI structural gate trusts on any host:
  the disaggregated drain is **bitwise identical** to the colocated one
  for greedy, sampled, and int8-KV backends (and under TP=2 sharding
  when two devices are visible — per-shard gathers assembling full
  pages); the transfer-byte ledger matches the page geometry exactly;
  TTFT/TPOT percentiles in deterministic virtual rounds; chaos-injected
  transfer corruption recovers by decode-side recompute without token
  divergence; and the (fixed) SwapCostModel routes long prompts to the
  prefill pool on a healthy link but falls back to colocated prefill
  when the link is the bottleneck.
"""
import time

import jax
import numpy as np

from repro.bench.registry import SweepContext, register
from repro.bench.schema import Timing
from repro.core.memmodel import next_pow2


def _mix(cfg, n_req: int, max_new: int):
    """Deterministic request mix (same shape as the dist_serve mix)."""
    from repro.serve import Request

    rng = np.random.default_rng(12)
    common = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    reqs = []
    for i in range(n_req):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(3, 9))).astype(np.int32)
        prompt = (np.concatenate([common, tail]) if i % 2 == 0
                  else np.concatenate([tail, tail, tail]))
        reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=max_new))
    return reqs


def _drain(target, cfg, n_req, max_new, chaos=None):
    """Drain the mix through an engine or a DisaggPool; returns
    (per-rid streams, stats, wall seconds)."""
    reqs = _mix(cfg, n_req, max_new)
    submit = getattr(target, "submit", None) or target.add_request
    for r in reqs:
        submit(r)
    t0 = time.perf_counter()
    if hasattr(target, "run"):
        stats = target.run(chaos=chaos)
    else:
        stats = target.run_to_completion()
    wall = time.perf_counter() - t0
    return {r.rid: list(r.out_tokens) for r in reqs}, stats, wall


def _timed(ctx, name, target, cfg, n_req, max_new, trials):
    engines = getattr(target, "engines", [target])
    streams = stats = None
    walls = []
    for i in range(trials + 1):               # +1 cold drain to compile
        if hasattr(target, "reset"):
            target.reset()
        else:
            for e in engines:
                e.reset()
        streams, stats, wall = _drain(target, cfg, n_req, max_new)
        if i > 0:
            walls.append(wall)
    timing = Timing(best_s=min(walls), mean_s=sum(walls) / len(walls),
                    trials=trials)
    ctx.emit(name, timing=timing,
             us=timing.best_s / max(1, stats.tokens_out) * 1e6,
             tok_s=f"{stats.tokens_out / max(timing.best_s, 1e-9):.1f}",
             tokens_out=stats.tokens_out)
    return streams, stats


@register("disagg_serve", "§2 memory hierarchy: cross-mesh page shipment")
def run_disagg_serve(ctx: SweepContext) -> None:
    from repro.configs import ARCHS, override, smoke_config
    from repro.models import RuntimeFlags, build
    from repro.serve import (DisaggChaos, DisaggChaosConfig, DisaggConfig,
                             DisaggPool, SamplingParams, ServeEngine,
                             SwapCostModel)

    cfg = smoke_config(ARCHS["gemma-2b"])
    base_flags = dict(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                      moe_impl="dense", loss_chunk=16)
    bundle = build(cfg, RuntimeFlags(**base_flags))
    params = bundle.init(jax.random.PRNGKey(0))
    n_req, max_new = (4, 8) if ctx.fast else (8, 16)
    max_len = 64
    trials = 2 if ctx.fast else 3
    kw = dict(batch_size=2, max_len=max_len, window=4, prefill_chunk=8,
              cache_backend="paged", seed=0)

    def pool_of(b, p, **extra):
        return DisaggPool([ServeEngine(b, p, **kw, **extra)],
                          [ServeEngine(b, p, **kw, **extra)],
                          DisaggConfig(force="disagg"))

    # ---- timed: colocated vs disaggregated, same mix -------------------
    single = ServeEngine(bundle, params, **kw)
    pool = pool_of(bundle, params)
    want, ref_stats = _timed(ctx, "disagg_serve_colocated", single, cfg,
                             n_req, max_new, trials)
    got, dstats = _timed(ctx, "disagg_serve_disagg", pool, cfg,
                         n_req, max_new, trials)

    # ---- headline gate: bitwise parity, greedy + sampled + int8 --------
    if got != want:
        raise AssertionError(
            f"disaggregated greedy drain diverged from colocated: "
            f"{got} != {want}")
    samp = SamplingParams(temperature=0.9, top_k=11)
    want_s, _, _ = _drain(ServeEngine(bundle, params, **kw, sampling=samp),
                          cfg, n_req, max_new)
    got_s, sstats, _ = _drain(pool_of(bundle, params, sampling=samp),
                              cfg, n_req, max_new)
    if got_s != want_s:
        raise AssertionError(
            "disaggregated sampled drain diverged: the (seed, rid) PRNG "
            "chain must replay identically after the hand-off")
    bundle8 = build(cfg, RuntimeFlags(**base_flags, kv_dtype="int8"))
    params8 = bundle8.init(jax.random.PRNGKey(0))
    want8, _, _ = _drain(ServeEngine(bundle8, params8, **kw),
                         cfg, n_req, max_new)
    got8, stats8, _ = _drain(pool_of(bundle8, params8), cfg, n_req, max_new)
    if got8 != want8:
        raise AssertionError(
            "disaggregated int8-KV drain diverged: the transfer buffer "
            "must carry the scale lanes with the pages")
    if min(sstats.prefill_imports, stats8.prefill_imports) < 1:
        raise AssertionError("a gated drain shipped no prefill at all")
    ctx.emit("disagg_serve_bitwise_match",
             gbps_measured=1.0, gbps_predicted=1.0, deterministic=True,
             backends="greedy+sampled+int8",
             metric="prefill-pool -> decode-pool drain == colocated drain, "
                    "bitwise, across backends (1.0 or the sweep raises)")

    # ---- transfer-byte ledger matches the page geometry ----------------
    # each hand-off is counted twice (export gather + import scatter) over
    # the pow2-padded page list — the same two link traversals the cost
    # model prices
    per_tok = single.bytes_per_page / single.page
    predicted = 2 * sum(
        next_pow2(max(1, -(-len(r.prompt) // single.page)))
        * single.bytes_per_page for r in _mix(cfg, n_req, max_new))
    if dstats.transfer_bytes != predicted:
        raise AssertionError(
            f"transfer ledger {dstats.transfer_bytes} != predicted "
            f"{predicted} from page geometry")
    ctx.emit("disagg_serve_transfer_bytes",
             gbps_measured=float(dstats.transfer_bytes),
             gbps_predicted=float(predicted), deterministic=True,
             transfers=dstats.prefill_imports,
             kv_bytes_per_token=per_tok,
             metric="bytes across the prefill->decode link (gather + "
                    "scatter of pow2-padded pages; hard-gated == geometry)")

    # ---- TTFT/TPOT in deterministic virtual rounds ---------------------
    pool.reset()
    _drain(pool, cfg, n_req, max_new)
    pct = pool.percentiles()
    for mname in ("ttft_p50", "ttft_p99", "tpot_p50"):
        val = pct[mname]
        if val <= 0:
            raise AssertionError(f"{mname} = {val}: virtual-clock "
                                 "percentiles must be positive")
        ctx.emit(f"disagg_serve_{mname}",
                 gbps_measured=val, gbps_predicted=val, deterministic=True,
                 rounds=pool.dstats.rounds,
                 metric=f"{mname} in virtual rounds under the disaggregated "
                        "topology (deterministic: the clock never sees "
                        "token values)")

    # ---- chaos: corrupt every in-transit buffer ------------------------
    pool.reset()
    chaos = DisaggChaos(DisaggChaosConfig(seed=5, corrupt_prob=1.0))
    got_c, cstats, _ = _drain(pool, cfg, n_req, max_new, chaos=chaos)
    if got_c != want:
        raise AssertionError(
            "corrupted-transfer drain diverged from colocated: decode-side "
            f"recompute lost bitwise equivalence ({got_c} != {want})")
    if cstats.transfer_fallbacks < 1 or chaos.corruptions < 1:
        raise AssertionError(
            f"transfer chaos injected nothing (corruptions="
            f"{chaos.corruptions}, fallbacks={cstats.transfer_fallbacks})")
    ctx.emit("disagg_serve_chaos_recovery",
             gbps_measured=1.0, gbps_predicted=1.0, deterministic=True,
             corruptions=chaos.corruptions,
             transfer_fallbacks=cstats.transfer_fallbacks,
             recompute_resumes=cstats.recompute_resumes,
             metric="every transfer corrupted in transit -> checksum "
                    "catches it at import, decode-side recompute drains "
                    "bitwise (1.0 or the sweep raises)")

    # ---- routing: the cost model's disagg-vs-colocated break-even ------
    # production-scale numbers: shipping 8k rows of KV beats re-streaming
    # 2.5B bf16 weights per chunk on a healthy PCIe-class link, but a
    # glacial link flips the router back to colocated prefill
    cm_fast = SwapCostModel(weight_bytes=5e9, kv_bytes_per_token=18_432,
                            prefill_chunk=256, spec=ctx.spec,
                            host_link_bw=32e9)
    cm_slow = SwapCostModel(weight_bytes=5e9, kv_bytes_per_token=18_432,
                            prefill_chunk=256, spec=ctx.spec,
                            host_link_bw=32e6)
    long_ctx = 8192
    if cm_fast.choose(long_ctx, swappable=True) != "swap":
        raise AssertionError(
            "healthy link must route long prompts to the prefill pool")
    if cm_slow.choose(long_ctx, swappable=True) != "recompute":
        raise AssertionError(
            "bottleneck link must fall back to colocated prefill")
    ctx.emit("disagg_serve_routing_break_even",
             gbps_measured=1.0, gbps_predicted=1.0, deterministic=True,
             ship_ms=cm_fast.swap_s(long_ctx) * 1e3,
             reprefill_ms=cm_fast.recompute_s(long_ctx) * 1e3,
             metric="router ships on a healthy link, colocates on a "
                    "bottleneck link at ctx=8192 (1.0 or the sweep raises)")

    # ---- TP=2: per-shard gathers assemble full pages -------------------
    if len(jax.devices()) < 2:
        return  # CI forces a 2-device host platform for the TP gate
    from repro.dist import ServeMesh

    # gemma-2b smoke is MQA; TP=2 needs both head counts divisible by 2
    cfg2 = override(smoke_config(ARCHS["gemma-2b"]), num_kv_heads=2)
    bundle2 = build(cfg2, RuntimeFlags(**base_flags))
    params2 = bundle2.init(jax.random.PRNGKey(0))
    want_tp, _, _ = _drain(
        ServeEngine(bundle2, params2, **kw, dist=ServeMesh.tp(2)),
        cfg2, n_req, max_new)
    pool_tp = DisaggPool(
        [ServeEngine(bundle2, params2, **kw, dist=ServeMesh.tp(2))],
        [ServeEngine(bundle2, params2, **kw, dist=ServeMesh.tp(2))],
        DisaggConfig(force="disagg"))
    got_tp, tstats, _ = _drain(pool_tp, cfg2, n_req, max_new)
    if got_tp != want_tp:
        raise AssertionError(
            "TP=2 disaggregated drain diverged from the TP=2 colocated "
            f"engine: {got_tp} != {want_tp}")
    if tstats.prefill_imports < 1:
        raise AssertionError("TP=2 disagg drain shipped no prefill")
    ctx.emit("disagg_serve_tp2_bitwise",
             gbps_measured=1.0, gbps_predicted=1.0, deterministic=True,
             transfers=tstats.prefill_imports,
             metric="TP=2 prefill mesh -> TP=2 decode mesh drain == TP=2 "
                    "colocated drain (per-shard gathers via "
                    "page_swap_shardings; 1.0 or the sweep raises)")

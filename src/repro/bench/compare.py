"""Diff two persisted bench runs and flag regressions beyond noise.

Matching is by row ``name``.  The primary metric is ``gbps_measured``
(higher is better); rows with no bandwidth fall back to ``us_per_call``
(lower is better).  The noise threshold is the comparator's floor; each
row's own recorded timing spread (``Timing.noise``) widens it further, so a
jittery row must move more than a steady one before it counts.

CLI:
  python -m repro.bench.compare runs/BENCH_a.json runs/BENCH_b.json
  (exit 1 when any regression verdict is produced)
"""
from __future__ import annotations

import argparse
import sys
from dataclasses import dataclass, field
from typing import Dict, List

from repro.bench.schema import BenchResult, BenchRun

REGRESSION = "regression"
IMPROVEMENT = "improvement"
UNCHANGED = "unchanged"
ADDED = "added"
REMOVED = "removed"


@dataclass
class RowDiff:
    name: str
    verdict: str
    metric: str = ""
    old: float = 0.0
    new: float = 0.0
    rel_change: float = 0.0  # signed; positive = better
    threshold: float = 0.0


@dataclass
class CompareReport:
    rows: List[RowDiff] = field(default_factory=list)
    noise_threshold: float = 0.15

    @property
    def regressions(self) -> List[RowDiff]:
        return [r for r in self.rows if r.verdict == REGRESSION]

    @property
    def improvements(self) -> List[RowDiff]:
        return [r for r in self.rows if r.verdict == IMPROVEMENT]

    def verdicts(self) -> Dict[str, str]:
        return {r.name: r.verdict for r in self.rows}

    def render(self) -> str:
        lines = [f"{'name':40s} {'verdict':12s} {'metric':14s} "
                 f"{'old':>12s} {'new':>12s} {'change':>8s}"]
        for r in sorted(self.rows, key=lambda r: (r.verdict, r.name)):
            if r.verdict in (ADDED, REMOVED):
                lines.append(f"{r.name:40s} {r.verdict:12s}")
                continue
            lines.append(
                f"{r.name:40s} {r.verdict:12s} {r.metric:14s} "
                f"{r.old:12.3f} {r.new:12.3f} {r.rel_change:+7.1%}")
        n_reg = len(self.regressions)
        lines.append(f"# {len(self.rows)} rows compared, "
                     f"{n_reg} regression(s), "
                     f"{len(self.improvements)} improvement(s), "
                     f"noise floor {self.noise_threshold:.0%}")
        return "\n".join(lines)


def _row_threshold(old: BenchResult, new: BenchResult, floor: float) -> float:
    """Noise floor widened by the rows' own recorded trial spread."""
    spread = 0.0
    for r in (old, new):
        if r.timing is not None:
            spread = max(spread, r.timing.noise)
    return floor + spread


def _diff_row(old: BenchResult, new: BenchResult, floor: float) -> RowDiff:
    thresh = _row_threshold(old, new, floor)
    if old.gbps_measured > 0 and new.gbps_measured <= 0:
        # the primary metric vanished — that IS a regression, never let it
        # fall through to the wall-clock comparison
        return RowDiff(name=old.name, verdict=REGRESSION,
                       metric="gbps_measured", old=old.gbps_measured,
                       new=0.0, rel_change=-1.0, threshold=thresh)
    if old.gbps_measured <= 0 and new.gbps_measured > 0:
        return RowDiff(name=old.name, verdict=IMPROVEMENT,
                       metric="gbps_measured", old=0.0,
                       new=new.gbps_measured, rel_change=1.0,
                       threshold=thresh)
    if old.gbps_measured > 0 and new.gbps_measured > 0:
        metric, o, n = "gbps_measured", old.gbps_measured, new.gbps_measured
        rel = (n - o) / o  # positive = faster
    elif old.us_per_call > 0 and new.us_per_call > 0:
        metric, o, n = "us_per_call", old.us_per_call, new.us_per_call
        rel = (o - n) / o  # lower is better -> positive = faster
    else:
        return RowDiff(name=old.name, verdict=UNCHANGED, metric="none",
                       threshold=thresh)
    if rel < -thresh:
        verdict = REGRESSION
    elif rel > thresh:
        verdict = IMPROVEMENT
    else:
        verdict = UNCHANGED
    return RowDiff(name=old.name, verdict=verdict, metric=metric, old=o,
                   new=n, rel_change=rel, threshold=thresh)


def compare_runs(old: BenchRun, new: BenchRun,
                 noise_threshold: float = 0.15) -> CompareReport:
    """Row-by-row diff; verdicts: regression / improvement / unchanged /
    added / removed."""
    report = CompareReport(noise_threshold=noise_threshold)
    old_by, new_by = old.by_name(), new.by_name()
    for name, o in old_by.items():
        if name in new_by:
            report.rows.append(_diff_row(o, new_by[name], noise_threshold))
        else:
            report.rows.append(RowDiff(name=name, verdict=REMOVED))
    for name in new_by:
        if name not in old_by:
            report.rows.append(RowDiff(name=name, verdict=ADDED))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", help="baseline BENCH_*.json")
    ap.add_argument("new", help="candidate BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="relative noise floor (default 0.15)")
    args = ap.parse_args(argv)
    report = compare_runs(BenchRun.load(args.old), BenchRun.load(args.new),
                          noise_threshold=args.threshold)
    print(report.render())
    return 1 if report.regressions else 0


if __name__ == "__main__":
    sys.exit(main())

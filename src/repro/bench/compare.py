"""Diff two persisted bench runs and flag regressions beyond noise.

Matching is by row ``name``.  The primary metric is ``gbps_measured``
(higher is better); rows with no bandwidth fall back to ``us_per_call``
(lower is better).  The noise threshold is the comparator's floor; each
row's own recorded timing spread (``Timing.noise``) widens it further, so a
jittery row must move more than a steady one before it counts.

CLI:
  python -m repro.bench.compare runs/BENCH_a.json runs/BENCH_b.json
  (exit 1 when any regression verdict is produced)
"""
from __future__ import annotations

import argparse
import sys
from dataclasses import dataclass, field
from typing import Dict, List

from repro.bench.schema import BenchResult, BenchRun

REGRESSION = "regression"
IMPROVEMENT = "improvement"
UNCHANGED = "unchanged"
ADDED = "added"
REMOVED = "removed"


@dataclass
class RowDiff:
    name: str
    verdict: str
    metric: str = ""
    old: float = 0.0
    new: float = 0.0
    rel_change: float = 0.0  # signed; positive = better
    threshold: float = 0.0
    # True when both rows are flagged ``extras["deterministic"]``: the
    # metric is a derived/counted figure (dispatch counts, model-predicted
    # plan bandwidth), so any regression on it is real, not timer noise
    deterministic: bool = False

    @property
    def structural(self) -> bool:
        """A regression the gate can trust on a noisy host: the bandwidth
        metric vanished outright, the row is deterministic, or a
        deterministic row disappeared from the candidate run entirely
        (dropping a gated invariant must not read as a pass)."""
        if self.verdict == REMOVED:
            return self.deterministic
        if self.verdict != REGRESSION:
            return False
        # rel_change <= -1.0 means "vanished" only for higher-is-better
        # bandwidth; for us_per_call any 2x slowdown hits -1.0, which is
        # still just timing noise across hosts
        vanished = self.metric == "gbps_measured" and self.rel_change <= -1.0
        return self.deterministic or vanished


@dataclass
class CompareReport:
    rows: List[RowDiff] = field(default_factory=list)
    noise_threshold: float = 0.15

    @property
    def regressions(self) -> List[RowDiff]:
        return [r for r in self.rows if r.verdict == REGRESSION]

    @property
    def structural_regressions(self) -> List[RowDiff]:
        """Regressions that survive host timing noise: vanished metrics and
        rows flagged ``extras["deterministic"]``."""
        return [r for r in self.rows if r.structural]

    @property
    def improvements(self) -> List[RowDiff]:
        return [r for r in self.rows if r.verdict == IMPROVEMENT]

    def verdicts(self) -> Dict[str, str]:
        return {r.name: r.verdict for r in self.rows}

    def render(self) -> str:
        lines = [f"{'name':40s} {'verdict':12s} {'metric':14s} "
                 f"{'old':>12s} {'new':>12s} {'change':>8s}"]
        for r in sorted(self.rows, key=lambda r: (r.verdict, r.name)):
            if r.verdict in (ADDED, REMOVED):
                lines.append(f"{r.name:40s} {r.verdict:12s}")
                continue
            lines.append(
                f"{r.name:40s} {r.verdict:12s} {r.metric:14s} "
                f"{r.old:12.3f} {r.new:12.3f} {r.rel_change:+7.1%}")
        n_reg = len(self.regressions)
        lines.append(f"# {len(self.rows)} rows compared, "
                     f"{n_reg} regression(s), "
                     f"{len(self.improvements)} improvement(s), "
                     f"noise floor {self.noise_threshold:.0%}")
        return "\n".join(lines)


def _row_threshold(old: BenchResult, new: BenchResult, floor: float) -> float:
    """Noise floor widened by the rows' own recorded trial spread."""
    spread = 0.0
    for r in (old, new):
        if r.timing is not None:
            spread = max(spread, r.timing.noise)
    return floor + spread


def _diff_row(old: BenchResult, new: BenchResult, floor: float) -> RowDiff:
    thresh = _row_threshold(old, new, floor)
    det = (bool(old.extras.get("deterministic"))
           and bool(new.extras.get("deterministic")))
    if old.gbps_measured > 0 and new.gbps_measured <= 0:
        # the primary metric vanished — that IS a regression, never let it
        # fall through to the wall-clock comparison
        return RowDiff(name=old.name, verdict=REGRESSION,
                       metric="gbps_measured", old=old.gbps_measured,
                       new=0.0, rel_change=-1.0, threshold=thresh,
                       deterministic=det)
    if old.gbps_measured <= 0 and new.gbps_measured > 0:
        return RowDiff(name=old.name, verdict=IMPROVEMENT,
                       metric="gbps_measured", old=0.0,
                       new=new.gbps_measured, rel_change=1.0,
                       threshold=thresh, deterministic=det)
    if old.gbps_measured > 0 and new.gbps_measured > 0:
        metric, o, n = "gbps_measured", old.gbps_measured, new.gbps_measured
        rel = (n - o) / o  # positive = faster
    elif old.us_per_call > 0 and new.us_per_call > 0:
        metric, o, n = "us_per_call", old.us_per_call, new.us_per_call
        rel = (o - n) / o  # lower is better -> positive = faster
    else:
        return RowDiff(name=old.name, verdict=UNCHANGED, metric="none",
                       threshold=thresh, deterministic=det)
    if rel < -thresh:
        verdict = REGRESSION
    elif rel > thresh:
        verdict = IMPROVEMENT
    else:
        verdict = UNCHANGED
    return RowDiff(name=old.name, verdict=verdict, metric=metric, old=o,
                   new=n, rel_change=rel, threshold=thresh,
                   deterministic=det)


def compare_runs(old: BenchRun, new: BenchRun,
                 noise_threshold: float = 0.15) -> CompareReport:
    """Row-by-row diff; verdicts: regression / improvement / unchanged /
    added / removed."""
    report = CompareReport(noise_threshold=noise_threshold)
    old_by, new_by = old.by_name(), new.by_name()
    for name, o in old_by.items():
        if name in new_by:
            report.rows.append(_diff_row(o, new_by[name], noise_threshold))
        else:
            report.rows.append(RowDiff(
                name=name, verdict=REMOVED,
                deterministic=bool(o.extras.get("deterministic"))))
    for name in new_by:
        if name not in old_by:
            report.rows.append(RowDiff(name=name, verdict=ADDED))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", help="baseline BENCH_*.json")
    ap.add_argument("new", help="candidate BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="relative noise floor (default 0.15)")
    ap.add_argument("--gate", choices=("all", "structural"), default="all",
                    help="which regression verdicts set a nonzero exit: "
                         "'all' (default), or 'structural' — only vanished "
                         "metrics and rows flagged extras['deterministic']; "
                         "wall-clock regressions still print but are "
                         "advisory.  Use 'structural' when baseline and "
                         "candidate ran on different hosts (CI).")
    args = ap.parse_args(argv)
    report = compare_runs(BenchRun.load(args.old), BenchRun.load(args.new),
                          noise_threshold=args.threshold)
    print(report.render())
    # a dropped deterministic row gates under EVERY mode — removing an
    # invariant from the candidate run must never read as a pass
    removed_det = [r for r in report.structural_regressions
                   if r.verdict == REMOVED]
    gating = (report.structural_regressions if args.gate == "structural"
              else report.regressions + removed_det)
    if args.gate == "structural" and (gating or report.regressions):
        print(f"# gate=structural: {len(gating)} gating verdict(s) out of "
              f"{len(report.regressions)} regression(s) + "
              f"{len(removed_det)} dropped deterministic row(s)")
    return 1 if gating else 0


if __name__ == "__main__":
    sys.exit(main())

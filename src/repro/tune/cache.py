"""Plan cache: derive once, persist to ``runs/tuneplans.json``, reuse.

The cache key is ``kernel|shape_sig|dtype|spec_fingerprint``; a calibration
(or any change to the spec constants) changes the fingerprint, so stale
plans are never served — they just age out in the file.  Persistence is
best-effort: an unwritable directory degrades to a process-local memory
cache (kernels must keep working from read-only checkouts and inside
traced/jitted code).
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, Optional, Tuple

from repro.core.memmodel import TPUSpec, V5E
from repro.tune.plan import KernelPlan, derive_plan, plan_key

DEFAULT_PATH = os.path.join("runs", "tuneplans.json")
ENV_VAR = "REPRO_TUNEPLANS"
_SCHEMA = 1


class PlanCache:
    """JSON-backed map ``plan_key -> KernelPlan``.

    ``path=None`` keeps the cache memory-only.  The file layout is
    ``{"schema_version": 1, "plans": {key: plan_dict}}``.
    """

    def __init__(self, path: Optional[str] = DEFAULT_PATH):
        self.path = path
        self._plans: Dict[str, KernelPlan] = {}
        self._loaded = path is None
        self._lock = threading.Lock()

    # -- persistence --------------------------------------------------------

    def _load(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        try:
            with open(self.path) as f:
                raw = json.load(f)
            for key, d in raw.get("plans", {}).items():
                self._plans[key] = KernelPlan.from_dict(d)
        except (OSError, ValueError, KeyError, TypeError):
            pass  # missing or corrupt file: start fresh

    def _save(self) -> None:
        if self.path is None:
            return
        if (self.path == DEFAULT_PATH
                and not os.path.isdir(os.path.dirname(self.path))):
            # default CWD-relative path outside a repo checkout (no runs/
            # directory): a pure compute call must not scatter files around
            # the caller's working directory — stay memory-only.  Explicit
            # paths ($REPRO_TUNEPLANS / constructor) still create dirs.
            return
        try:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            tmp = self.path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"schema_version": _SCHEMA,
                           "plans": {k: p.to_dict()
                                     for k, p in sorted(self._plans.items())}},
                          f, indent=1, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)
        except OSError:
            pass  # read-only checkout: stay memory-only

    # -- access -------------------------------------------------------------

    def __len__(self) -> int:
        with self._lock:
            self._load()
            return len(self._plans)

    def plans(self) -> Dict[str, KernelPlan]:
        with self._lock:
            self._load()
            return dict(self._plans)

    def get(self, key: str) -> Optional[KernelPlan]:
        with self._lock:
            self._load()
            return self._plans.get(key)

    def put(self, key: str, plan: KernelPlan) -> KernelPlan:
        with self._lock:
            self._load()
            self._plans[key] = plan
            self._save()
            return plan

    def clear(self) -> None:
        with self._lock:
            self._plans.clear()
            self._loaded = self.path is None
            if self.path is not None:
                try:
                    os.remove(self.path)
                except OSError:
                    pass

    def get_or_derive(self, kernel: str, *, shape_sig: Tuple[int, ...],
                      dtype: str, spec: Optional[TPUSpec] = None,
                      calibration=None) -> KernelPlan:
        eff_spec = calibration.spec if calibration is not None else (spec or V5E)
        key = plan_key(kernel, shape_sig, dtype, eff_spec)
        plan = self.get(key)
        if plan is None:
            plan = derive_plan(kernel, shape_sig=shape_sig, dtype=dtype,
                               spec=spec, calibration=calibration)
            self.put(key, plan)
        return plan


# ---------------------------------------------------------------------------
# process-default cache + the one-call lookup the kernels use
# ---------------------------------------------------------------------------

_default: Optional[PlanCache] = None
_default_lock = threading.Lock()


def default_cache() -> PlanCache:
    """Lazy singleton over ``$REPRO_TUNEPLANS`` or ``runs/tuneplans.json``."""
    global _default
    with _default_lock:
        if _default is None:
            _default = PlanCache(os.environ.get(ENV_VAR, DEFAULT_PATH))
        return _default


def set_default_cache(cache: Optional[PlanCache]) -> None:
    """Swap the process-default cache (tests; memory-only runs)."""
    global _default
    with _default_lock:
        _default = cache


def plan_for(kernel: str, *, shape_sig: Tuple[int, ...], dtype: str = "bfloat16",
             spec: Optional[TPUSpec] = None, calibration=None) -> KernelPlan:
    """The kernels' entry point: cached plan for one call site.

    Shape signatures per kernel:
      flash_attention   (sq, skv, head_dim)
      decode_attention  (cache_len, head_dim)
      paged_attention   (max_len, head_dim)   -- plan.page_size shapes the pool
      paged_verify      (verify_tokens, max_len, head_dim)
      matmul            (m, n, k)
    """
    return default_cache().get_or_derive(kernel, shape_sig=shape_sig,
                                         dtype=dtype, spec=spec,
                                         calibration=calibration)

"""KernelPlan: the applied output of the autotuner (paper §5, closed-loop).

PR 2 built the measurement machinery (sweeps, calibration); this module is
the missing half of the loop: it turns ``tune_attention_blocks`` /
``tune_pattern`` output into a concrete, serializable *plan* — block sizes,
pipeline depth, dtype, interpret flag — that the Pallas kernels and their
model call sites consume as their default.  A plan is derived once per
``(kernel, shape signature, dtype, TPUSpec fingerprint)`` and cached
(:mod:`repro.tune.cache`); when a :class:`~repro.bench.calibrate.
CalibrationResult` is supplied the derivation runs against the *fitted*
spec, so measured mode changes the plans (and the fingerprint, so stale
analytic plans are never reused).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from repro.core.autotune import tune_attention_blocks, tune_pattern
from repro.core.memmodel import (TPUSpec, V5E, next_pow2, predict_bw,
                                 vmem_ok)
from repro.core.patterns import Knobs, Pattern

# the kernels a plan can target (ops.py wrappers consume these; for the
# paged kernels the plan's bkv IS the page size — the pool is laid out from
# the plan, so tuning reshapes serving memory itself; paged_verify is the
# k-token speculative verify step over the same pool)
KERNELS = ("flash_attention", "decode_attention", "matmul", "paged_attention",
           "paged_verify")


def auto_interpret() -> bool:
    """The single backend heuristic every consumer shares: compile the
    Pallas kernel on a real TPU backend, run interpret mode elsewhere."""
    import jax
    return jax.default_backend() != "tpu"


def spec_fingerprint(spec: TPUSpec) -> str:
    """Short stable id of the constants that shape a tuning decision.

    Calibration replaces the spec (name + fitted constants), so a calibrated
    run fingerprints differently from the analytic one — that is the cache
    invalidation rule: new constants => new key => plans re-derived.
    """
    raw = (f"{spec.name}|{spec.hbm_bw:.6g}|{spec.dma_latency_s:.6g}"
           f"|{spec.vmem_bytes}|{spec.clock_hz:.6g}")
    return hashlib.sha1(raw.encode()).hexdigest()[:12]


@dataclass(frozen=True)
class KernelPlan:
    """One tuned kernel configuration, ready to execute.

    Paper §5 knob -> plan field:
      burst size       -> ``bkv`` (the contiguous kv/rhs tile per DMA)
      outstanding (NO) -> ``pipeline_depth`` (multiple-buffering depth)
      unit width       -> ``dtype`` x lane tile (``unit_bytes`` property)
    ``interpret=None`` means auto: compile the Pallas kernel on a real TPU
    backend, run interpret mode elsewhere (CPU CI).
    """

    kernel: str
    bq: int
    bkv: int
    pipeline_depth: int = 2
    dtype: str = "bfloat16"
    interpret: Optional[bool] = None
    head_dim: int = 128
    predicted_gbps: float = 0.0
    source: str = "analytic"            # analytic | calibrated

    # ------------------------------------------------------------------
    @property
    def dtype_bytes(self) -> int:
        import jax.numpy as jnp
        return jnp.dtype(self.dtype).itemsize

    @property
    def unit_bytes(self) -> int:
        """Transaction width: one head row of the plan's dtype."""
        return max(1, self.head_dim * self.dtype_bytes)

    @property
    def burst_bytes(self) -> int:
        """Contiguous DMA size: the kv/rhs tile."""
        return max(1, self.bkv * self.head_dim * self.dtype_bytes)

    @property
    def page_size(self) -> int:
        """Paged-attention reading of ``bkv``: tokens per KV page.  The
        serving engine shapes its page pool from this, so the r_acc
        transaction-optimum rule reaches HBM layout, not just the kernel."""
        return self.bkv

    def knobs(self) -> Knobs:
        """The plan expressed in the paper's knob vocabulary (for vmem_ok /
        predict_bw round-trips)."""
        return Knobs(unit_bytes=self.unit_bytes, burst_bytes=self.burst_bytes,
                     outstanding=self.pipeline_depth)

    def vmem_bytes(self) -> int:
        """Resident buffering: q tile + f32 scratch rows + double-buffered
        kv tiles (mirrors ``tune_attention_blocks``'s budget formula)."""
        db = self.dtype_bytes
        return (self.bq * (self.head_dim + 4) * 4
                + self.pipeline_depth * self.bkv * self.head_dim * db * 2)

    def resolve_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return auto_interpret()

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "kernel": self.kernel, "bq": self.bq, "bkv": self.bkv,
            "pipeline_depth": self.pipeline_depth, "dtype": self.dtype,
            "interpret": self.interpret, "head_dim": self.head_dim,
            "predicted_gbps": self.predicted_gbps, "source": self.source,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "KernelPlan":
        return cls(kernel=d["kernel"], bq=int(d["bq"]), bkv=int(d["bkv"]),
                   pipeline_depth=int(d.get("pipeline_depth", 2)),
                   dtype=d.get("dtype", "bfloat16"),
                   interpret=d.get("interpret"),
                   head_dim=int(d.get("head_dim", 128)),
                   predicted_gbps=float(d.get("predicted_gbps", 0.0)),
                   source=d.get("source", "analytic"))


# ---------------------------------------------------------------------------
# Derivation (the tune -> plan step)
# ---------------------------------------------------------------------------

def plan_key(kernel: str, shape_sig: Tuple[int, ...], dtype: str,
             spec: TPUSpec) -> str:
    sig = "x".join(str(int(s)) for s in shape_sig)
    return f"{kernel}|{sig}|{dtype}|{spec_fingerprint(spec)}"


def _resolve_spec(spec: Optional[TPUSpec], calibration) -> Tuple[TPUSpec, str]:
    if calibration is not None:
        return calibration.spec, "calibrated"
    return (spec or V5E), "analytic"


def _shrink_to_budget(bq: int, bkv: int, head_dim: int, db: int,
                      budget: float, depth: int) -> Tuple[int, int]:
    """Halve the kv (then q) tile until the scratch+buffer estimate fits —
    the tuner's feasibility guarantee must survive seq-length clamping and
    odd head dims the candidate grid never saw."""
    def vmem(bq_, bkv_):
        return bq_ * (head_dim + 4) * 4 + depth * bkv_ * head_dim * db * 2
    while vmem(bq, bkv) > budget and bkv > 8:
        bkv //= 2
    while vmem(bq, bkv) > budget and bq > 8:
        bq //= 2
    return max(8, bq), max(8, bkv)


def derive_attention_plan(*, sq: int, skv: int, head_dim: int,
                          dtype: str = "bfloat16",
                          kernel: str = "flash_attention",
                          spec: Optional[TPUSpec] = None, calibration=None,
                          vmem_budget_fraction: float = 0.4) -> KernelPlan:
    """(bq, bkv) for the nest tiling from ``tune_attention_blocks`` under the
    (possibly calibrated) spec, clamped to the actual sequence lengths."""
    import jax.numpy as jnp
    spec, source = _resolve_spec(spec, calibration)
    db = jnp.dtype(dtype).itemsize
    bq, bkv = tune_attention_blocks(head_dim, dtype_bytes=db, spec=spec,
                                    vmem_budget_fraction=vmem_budget_fraction)
    bq, bkv = min(bq, max(8, sq)), min(bkv, max(8, skv))
    bq, bkv = _shrink_to_budget(bq, bkv, head_dim, db,
                                spec.vmem_bytes * vmem_budget_fraction, 2)
    knobs = Knobs(unit_bytes=head_dim * db, burst_bytes=bkv * head_dim * db,
                  outstanding=2)
    return KernelPlan(
        kernel=kernel, bq=bq, bkv=bkv, pipeline_depth=2, dtype=dtype,
        interpret=None, head_dim=head_dim,
        predicted_gbps=predict_bw(Pattern.NEST, knobs, spec) / 1e9,
        source=source)


def derive_decode_plan(*, seq_len: int, head_dim: int, dtype: str = "bfloat16",
                       spec: Optional[TPUSpec] = None, calibration=None,
                       vmem_budget_fraction: float = 0.4) -> KernelPlan:
    """Split-KV block for flash-decode: decode streams the whole cache once
    per token (the paper's `rs_tra` pure-bandwidth regime), so the kv block
    is the tuned sequential burst divided by the row width."""
    import jax.numpy as jnp
    spec, source = _resolve_spec(spec, calibration)
    db = jnp.dtype(dtype).itemsize
    tuned = tune_pattern(Pattern.RS_TRA, spec=spec,
                         vmem_budget_fraction=vmem_budget_fraction,
                         calibration=calibration)
    bkv = max(8, tuned.knobs.burst_bytes // max(1, head_dim * db))
    bkv = min(bkv, max(8, seq_len))
    _, bkv = _shrink_to_budget(8, bkv, head_dim, db,
                               spec.vmem_bytes * vmem_budget_fraction,
                               tuned.knobs.outstanding)
    return KernelPlan(
        kernel="decode_attention", bq=1, bkv=bkv,
        pipeline_depth=tuned.knobs.outstanding, dtype=dtype, interpret=None,
        head_dim=head_dim, predicted_gbps=tuned.predicted_gbps, source=source)


def derive_paged_plan(*, max_len: int, head_dim: int, dtype: str = "bfloat16",
                      spec: Optional[TPUSpec] = None, calibration=None,
                      vmem_budget_fraction: float = 0.4) -> KernelPlan:
    """Page size (``bkv``) for the paged-KV pool + kernel.

    Paged decode is the paper's `r_acc` engine: each sequence gathers its
    pages through a table indirection, so the *page* is the transaction.
    The advisor's rule is ``unit_bytes >= 512B``; bigger pages only add
    internal fragmentation (the resource axis of the paper's
    throughput-vs-resources tradeoff), so the page is the *smallest* pow2
    token count whose row block crosses that optimum — clamped to the
    sequence budget so a short ``max_len`` is never a single page.
    ``dtype`` is the dtype the pool *stores*: int8 KV pages halve the row
    width, so the derived page holds proportionally more tokens — the
    paper's data-width lever applied to HBM layout.  Pipeline depth
    (outstanding gathers) comes from the tuned r_acc knobs.
    """
    import jax.numpy as jnp
    spec, source = _resolve_spec(spec, calibration)
    db = jnp.dtype(dtype).itemsize
    row = max(1, head_dim * db)
    tuned = tune_pattern(Pattern.R_ACC, spec=spec,
                         vmem_budget_fraction=vmem_budget_fraction,
                         calibration=calibration)
    page = next_pow2(-(-512 // row))
    page = max(8, min(page, max(8, next_pow2(max_len) // 2)))
    return KernelPlan(
        kernel="paged_attention", bq=1, bkv=page,
        pipeline_depth=tuned.knobs.outstanding, dtype=dtype, interpret=None,
        head_dim=head_dim, predicted_gbps=tuned.predicted_gbps, source=source)


def derive_verify_plan(*, verify_tokens: int, max_len: int, head_dim: int,
                       dtype: str = "bfloat16",
                       spec: Optional[TPUSpec] = None, calibration=None,
                       vmem_budget_fraction: float = 0.4) -> KernelPlan:
    """Plan for the speculative k-token verify step.

    Verification reads the page pool exactly like paged decode (`r_acc`
    through the table), so the transaction unit — ``bkv``, the page —
    must match the pool the engine laid out from
    :func:`derive_paged_plan`.  The lever verification adds is *burst
    length*: ``bq`` becomes the verify width (pending token + k drafts),
    so one table walk serves ``verify_tokens`` query positions instead
    of one — the paper's tokens-per-transaction amortization.  The
    predicted bandwidth is the r_acc gather rate scaled by the reuse
    factor (each fetched page row now feeds up to ``verify_tokens``
    queries)."""
    base = derive_paged_plan(max_len=max_len, head_dim=head_dim, dtype=dtype,
                             spec=spec, calibration=calibration,
                             vmem_budget_fraction=vmem_budget_fraction)
    vt = max(1, int(verify_tokens))
    return KernelPlan(
        kernel="paged_verify", bq=vt, bkv=base.bkv,
        pipeline_depth=base.pipeline_depth, dtype=dtype, interpret=None,
        head_dim=head_dim, predicted_gbps=base.predicted_gbps * vt,
        source=base.source)


def derive_matmul_plan(*, m: int, n: int, k: int, dtype: str = "bfloat16",
                       spec: Optional[TPUSpec] = None, calibration=None,
                       vmem_budget_fraction: float = 0.4) -> KernelPlan:
    """Square tile for the tiled matmul: the largest MXU-aligned tile whose
    triple (lhs, rhs, acc) double-buffered footprint fits the budget."""
    import jax.numpy as jnp
    spec, source = _resolve_spec(spec, calibration)
    db = jnp.dtype(dtype).itemsize
    budget = spec.vmem_bytes * vmem_budget_fraction
    tile = 128
    for t in (128, 256, 512, 1024):
        if 2 * (2 * t * t * db + t * t * 4) <= budget:
            tile = t
    tile = min(tile, max(8, m), max(8, n), max(8, k))
    knobs = Knobs(unit_bytes=tile * db, burst_bytes=tile * tile * db,
                  outstanding=2)
    return KernelPlan(
        kernel="matmul", bq=tile, bkv=tile, pipeline_depth=2, dtype=dtype,
        interpret=None, head_dim=tile,
        predicted_gbps=predict_bw(Pattern.SEQUENTIAL, knobs, spec) / 1e9,
        source=source)


def derive_plan(kernel: str, *, shape_sig: Tuple[int, ...], dtype: str,
                spec: Optional[TPUSpec] = None, calibration=None) -> KernelPlan:
    """Dispatch on kernel name; ``shape_sig`` is the kernel's tuning-relevant
    shape tuple (see :func:`repro.tune.cache.plan_for` for the per-kernel
    signatures)."""
    if kernel == "flash_attention":
        sq, skv, head_dim = shape_sig
        return derive_attention_plan(sq=sq, skv=skv, head_dim=head_dim,
                                     dtype=dtype, spec=spec,
                                     calibration=calibration)
    if kernel == "decode_attention":
        seq_len, head_dim = shape_sig
        return derive_decode_plan(seq_len=seq_len, head_dim=head_dim,
                                  dtype=dtype, spec=spec,
                                  calibration=calibration)
    if kernel == "paged_attention":
        # optional trailing element: per-shard kv-head count under serve-side
        # TP — it never changes the page geometry (the 512B rule is per head
        # row) but keys the cache, so a calibration made on an N-way engine
        # re-derives independently of the single-device plan
        max_len, head_dim = shape_sig[:2]
        return derive_paged_plan(max_len=max_len, head_dim=head_dim,
                                 dtype=dtype, spec=spec,
                                 calibration=calibration)
    if kernel == "paged_verify":
        verify_tokens, max_len, head_dim = shape_sig[:3]
        return derive_verify_plan(verify_tokens=verify_tokens,
                                  max_len=max_len, head_dim=head_dim,
                                  dtype=dtype, spec=spec,
                                  calibration=calibration)
    if kernel == "matmul":
        m, n, k = shape_sig
        return derive_matmul_plan(m=m, n=n, k=k, dtype=dtype, spec=spec,
                                  calibration=calibration)
    raise ValueError(f"unknown kernel {kernel!r}; known: {KERNELS}")

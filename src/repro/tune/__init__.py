"""repro.tune — the closed tune->execute loop (paper §5 applied to the code).

``core.autotune`` picks knobs from the analytic/calibrated memory model;
this package turns those knobs into persisted :class:`KernelPlan`s that the
Pallas kernels (:mod:`repro.kernels.ops`) and model attention call sites
(:mod:`repro.models.attention`) consume as their *defaults* — so measured
knob choices actually reach the datapath instead of stopping at a report.

Quick use::

    from repro.tune import plan_for
    plan = plan_for("flash_attention", shape_sig=(4096, 4096, 128))
    plan.bq, plan.bkv, plan.pipeline_depth, plan.resolve_interpret()
"""
from repro.tune.cache import (DEFAULT_PATH, PlanCache,  # noqa: F401
                              default_cache, plan_for, set_default_cache)
from repro.tune.plan import (KERNELS, KernelPlan, auto_interpret,  # noqa: F401
                             derive_attention_plan, derive_decode_plan,
                             derive_matmul_plan, derive_paged_plan,
                             derive_plan, plan_key, spec_fingerprint)

__all__ = [
    "KernelPlan", "KERNELS", "auto_interpret", "plan_key", "spec_fingerprint",
    "derive_plan", "derive_attention_plan", "derive_decode_plan",
    "derive_matmul_plan", "derive_paged_plan",
    "PlanCache", "DEFAULT_PATH", "default_cache", "set_default_cache",
    "plan_for",
]

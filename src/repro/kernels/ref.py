"""Pure-jnp oracles for every kernel (the paper's RTL reference role)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def stream_copy(x: jax.Array, mode: str = "copy") -> jax.Array:
    return x if mode == "copy" else x * 2


def strided_copy(x: jax.Array, *, block_rows: int, stride: int) -> jax.Array:
    rows, cols = x.shape
    br = min(block_rows, rows)
    nblocks = rows // br
    idx = (jnp.arange(nblocks) * stride) % nblocks
    return x.reshape(nblocks, br, cols)[idx].reshape(rows, cols)


def random_gather(x: jax.Array, idx: jax.Array) -> jax.Array:
    return x[idx]


def pointer_chase(table: jax.Array, steps: int) -> jax.Array:
    flat = table[:, 0]

    def body(addr, _):
        nxt = flat[addr]
        return nxt, nxt

    _, trace = jax.lax.scan(body, jnp.int32(0), None, length=steps)
    return trace[:, None]


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32)).astype(x.dtype)


def decode_attention(q, k, v, valid_len, *, softcap=None, scale=None):
    """q: (B,Hq,D); k/v: (B,T,Hkv,D); valid_len (B,) -> (B,Hq,D)."""
    b, hq, d = q.shape
    _, t, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = jnp.arange(t)[None, :] < valid_len[:, None]      # (B, T)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgt,bthd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, d).astype(q.dtype)


def paged_attention(q, k_pages, v_pages, page_table, valid_len, *, scale=None,
                    softcap=None, window=None, k_scale=None, v_scale=None):
    """Gather pages into contiguous caches, then masked-softmax attention.

    ``window`` switches to ring-table semantics (slot ``j`` holds logical
    page ``cur_L - ((cur_L - j) mod N)``); ``k_scale``/``v_scale`` (P, page)
    dequantize int8 pages per token."""
    pool, page, hkv, d = k_pages.shape
    b, n = page_table.shape
    bq, hq, _ = q.shape
    g = hq // hkv
    k = k_pages[page_table].astype(jnp.float32)  # (B, N, page, Hkv, D)
    v = v_pages[page_table].astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale[page_table][..., None, None]
        v = v * v_scale[page_table][..., None, None]
    if window is None:
        base = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None] * page,
                                (b, n))
    else:
        cur = jnp.maximum(valid_len - 1, 0)[:, None] // page      # (B, 1)
        j = jnp.arange(n, dtype=jnp.int32)[None, :]
        base = (cur - (cur - j) % n) * page
    pos = base[:, :, None] + jnp.arange(page, dtype=jnp.int32)[None, None, :]
    mask = (pos < valid_len[:, None, None]) & (pos >= 0)
    if window is not None:
        mask &= pos > valid_len[:, None, None] - 1 - window
    k = k.reshape(b, n * page, hkv, d)
    v = v.reshape(b, n * page, hkv, d)
    mask = mask.reshape(b, n * page)
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, d).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    # zero-mask p so a fully-masked row (valid_len 0 / rotated-out ring
    # slot) contributes exactly 0, matching the kernel — not the uniform
    # garbage softmax produces over an all-NEG_INF row
    p = jnp.where(mask[:, None, None, :], jax.nn.softmax(s, axis=-1), 0.0)
    o = jnp.einsum("bhgt,bthd->bhgd", p, v)
    return o.reshape(b, hq, d).astype(q.dtype)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None,
              scale: Optional[float] = None) -> jax.Array:
    """Naive masked-softmax attention; q (B,Hq,Sq,D), kv (B,Hkv,Skv,D)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(sq)[:, None] + (skv - sq)  # right-aligned (decode-safe)
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)

"""Strided-traversal engine (paper: Figs. 6/8/9, Alg. 6).

Reads row-blocks at ``(i*stride) % num_blocks`` (the paper's
``(ADDR + S) mod G`` work-group walk) and writes them back densely.  Stride 1
degenerates to the sequential engine; larger strides defeat tile contiguity
exactly like AXI bursts are defeated on the FPGA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "stride", "interpret"))
def strided_copy(x: jax.Array, *, block_rows: int = 8, stride: int = 1,
                 interpret: bool = True) -> jax.Array:
    """out[i] = x[(i*stride) % nblocks] block-rows at a time (2D input)."""
    rows, cols = x.shape
    br = min(block_rows, rows)
    assert rows % br == 0
    nblocks = rows // br

    def in_map(i):
        return ((i * stride) % nblocks, 0)

    return pl.pallas_call(
        _kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((br, cols), in_map)],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)

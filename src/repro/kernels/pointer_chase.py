"""Pointer-chase latency engine (paper §3.1, Alg. 5, Table 8).

``addr = mem[addr]`` repeated I times: every load depends on the previous, so
no pipelining is possible and throughput == unit_bytes / T_l — the paper's
pure-latency measurement (0.99 GB/s on the U280).  The kernel keeps the whole
chase table VMEM-resident (the paper's engine equally owns one channel); the
host-level engine in ``core.engines`` runs the HBM-sized variant via XLA.

The visited-index trace is written out (the paper's latency-data write-back
module, Alg. 3) so the computation cannot be optimized away and can be
verified against the ref oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chase_kernel(table_ref, out_ref, steps: int):
    def body(i, addr):
        nxt = table_ref[addr, 0]
        out_ref[i, 0] = nxt
        return nxt

    jax.lax.fori_loop(0, steps, body, jnp.int32(0))


@functools.partial(jax.jit, static_argnames=("steps", "interpret"))
def pointer_chase(table: jax.Array, *, steps: int, interpret: bool = True) -> jax.Array:
    """Follow the chain ``addr = table[addr]`` from 0 for ``steps`` hops.

    ``table``: (n, 1) int32, a permutation cycle (see :func:`make_chain`).
    Returns the (steps, 1) visited trace.
    """
    n, one = table.shape
    assert one == 1
    return pl.pallas_call(
        functools.partial(_chase_kernel, steps=steps),
        in_specs=[pl.BlockSpec((n, 1), lambda: (0, 0))],
        out_specs=pl.BlockSpec((steps, 1), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((steps, 1), jnp.int32),
        interpret=interpret,
    )(table)


def make_chain(n: int, seed: int = 0) -> jax.Array:
    """A single-cycle random permutation chain (Sattolo), host-built like the
    paper's host-initialized random linked list."""
    import numpy as np

    rng = np.random.default_rng(seed)
    perm = np.arange(n)
    # Sattolo's algorithm -> one cycle covering all n entries
    for i in range(n - 1, 0, -1):
        j = rng.integers(0, i)
        perm[i], perm[j] = perm[j], perm[i]
    table = np.empty(n, dtype=np.int32)
    # chain: next[perm[k]] = perm[k+1]
    table[perm[:-1]] = perm[1:]
    table[perm[-1]] = perm[0]
    return jnp.asarray(table)[:, None]

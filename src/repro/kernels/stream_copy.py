"""Sequential-stream engine (paper: sequential read/write, Figs. 7/10, Eq. 5/6).

A grid-pipelined HBM->VMEM->HBM copy.  The BlockSpec block is the paper's
*burst*: one contiguous DMA.  Pallas double-buffers grid inputs, so the
in-flight count (the paper's *outstanding*) is the pipeline depth (>=2).
Knobs swept by benchmarks: block_rows x block_cols (burst bytes) and dtype
(unit size).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _rw_kernel(x_ref, o_ref, scale):
    # read-modify-write variant: touches the same bytes but adds an op so the
    # paper's T_o (Eq. 2) is non-zero.
    o_ref[...] = x_ref[...] * scale


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols", "mode", "interpret"))
def stream_copy(x: jax.Array, *, block_rows: int = 256, block_cols: int = 0,
                mode: str = "copy", interpret: bool = True) -> jax.Array:
    """Copy (or scale) a 2D array block-by-block.

    ``block_rows*block_cols*itemsize`` is the burst size.  ``block_cols=0``
    means full rows (maximally contiguous).
    """
    rows, cols = x.shape
    bc = cols if block_cols in (0, None) else block_cols
    br = min(block_rows, rows)
    assert rows % br == 0 and cols % bc == 0, (x.shape, br, bc)
    grid = (rows // br, cols // bc)
    kern = _copy_kernel if mode == "copy" else functools.partial(_rw_kernel, scale=2)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def burst_bytes(x: jax.Array, block_rows: int, block_cols: int = 0) -> int:
    bc = x.shape[1] if block_cols in (0, None) else block_cols
    return min(block_rows, x.shape[0]) * bc * x.dtype.itemsize

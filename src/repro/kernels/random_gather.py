"""Random-access engine (paper: Tables 7/8, Alg. 4 — LFSR random addresses).

TPU-idiomatic random access: the index vector is *scalar-prefetched* so the
BlockSpec index_map can DMA row ``idx[i]`` for grid step i — the same
indirection mechanism paged-KV attention uses.  Unit size = row bytes; each
touch is an independent transaction (pipelinable but burst-defeating).

Also provides the LFSR generator itself (Galois form), matching the paper's
on-board address generation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# maximal-length Galois LFSR taps
_TAPS = {16: 0xB400, 24: 0xE10000, 32: 0xA3000000}


@functools.partial(jax.jit, static_argnames=("n", "bits"))
def lfsr_indices(n: int, *, bits: int = 24, seed: int = 0xACE1) -> jax.Array:
    """n indices in [0, 2^min(bits,31)) from a Galois LFSR (paper Alg. 4).
    Index space is capped at 2^31 so results stay valid int32 gather indices."""
    taps = jnp.uint32(_TAPS[bits])

    def step(state, _):
        bit = state & 1
        state = state >> 1
        state = jnp.where(bit == 1, state ^ taps, state)
        return state, state

    _, out = jax.lax.scan(step, jnp.uint32(seed | 1), None, length=n)
    return (out & jnp.uint32((1 << min(bits, 31)) - 1)).astype(jnp.int32)


def _gather_kernel(idx_ref, x_ref, o_ref):
    # idx_ref is scalar-prefetched; x_ref already points at row idx[i].
    o_ref[...] = x_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def random_gather(x: jax.Array, idx: jax.Array, *, block_rows: int = 1,
                  interpret: bool = True) -> jax.Array:
    """out[i] = x[idx[i]] (row gather, 2D table).

    ``block_rows`` rows share one transaction only when indices are
    block-aligned; the default 1 models the paper's independent random
    transactions (unit = one row).
    """
    rows, cols = x.shape
    (n,) = idx.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n * block_rows, cols), x.dtype),
        interpret=interpret,
    )(idx, x)

"""Paged-KV decode attention: block-table indirection inside the kernel.

The serving-memory version of the paper's random-access engine: the KV cache
lives in a global page pool (num_pages, page, Hkv, D) and each sequence owns
a per-sequence page table — the kernel's BlockSpec index_map dereferences the
scalar-prefetched table (``table[b, j]``), exactly the mechanism
``random_gather`` benchmarks (r_acc over page-sized units: the advisor's
"unit_bytes: row width >= 512B" guidance is why pages are >= 16 tokens).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(table_ref, vlen_ref, q_ref, kp_ref, vp_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, page: int, n_pages: int,
            hkv: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bh = pl.program_id(0)
    b = bh // hkv
    valid = vlen_ref[b]

    q = q_ref[0].astype(jnp.float32) * scale                 # (g, d)
    k = kp_ref[0].astype(jnp.float32)                        # (page, d)
    v = vp_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, page)
    pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret", "plan"))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, valid_len: jax.Array, *,
                    scale: Optional[float] = None,
                    interpret: Optional[bool] = None,
                    plan=None) -> jax.Array:
    """q: (B, Hq, D); k/v_pages: (P, page, Hkv, D); page_table: (B, N) int32
    (pool page id per logical page; unused entries may be any valid id —
    they are masked by valid_len); valid_len: (B,) -> (B, Hq, D).

    ``plan`` (a :class:`repro.tune.KernelPlan`, hashable => static) carries
    the tuned backend choice; unlike flash/decode it cannot re-block the
    kernel here — ``plan.page_size`` shaped the pool this call receives, so
    the block IS the page and the kernel asserts the two agree.
    ``interpret=None`` resolves plan-first, then the shared auto heuristic."""
    if plan is not None and k_pages.shape[1] != plan.page_size:
        raise ValueError(
            f"pool page size {k_pages.shape[1]} != plan.page_size "
            f"{plan.page_size}: the pool must be laid out from the plan")
    if interpret is None:
        if plan is not None:
            interpret = plan.resolve_interpret()
        else:
            from repro.tune import auto_interpret
            interpret = auto_interpret()
    b, hq, d = q.shape
    pool, page, hkv, _ = k_pages.shape
    _, n_pages = page_table.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    qf = q.reshape(b * hkv, g, d)
    # flatten pages per kv head: (P*Hkv, page, d)
    kf = jnp.swapaxes(k_pages, 1, 2).reshape(pool * hkv, page, d)
    vf = jnp.swapaxes(v_pages, 1, 2).reshape(pool * hkv, page, d)

    def page_map(bh, j, table_ref, vlen_ref, hkv=hkv):
        b_ = bh // hkv
        h_ = bh % hkv
        return (table_ref[b_, j] * hkv + h_, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * hkv, n_pages),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda bh, j, t, vl: (bh, 0, 0)),
            pl.BlockSpec((1, page, d),
                         lambda bh, j, t, vl: page_map(bh, j, t, vl)),
            pl.BlockSpec((1, page, d),
                         lambda bh, j, t, vl: page_map(bh, j, t, vl)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda bh, j, t, vl: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, page=page, n_pages=n_pages,
                          hkv=hkv),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, d), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), valid_len.astype(jnp.int32), qf, kf, vf)
    return out.reshape(b, hq, d)

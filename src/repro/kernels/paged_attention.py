"""Paged-KV decode attention: block-table indirection inside the kernel.

The serving-memory version of the paper's random-access engine: the KV cache
lives in a global page pool (num_pages, page, Hkv, D) and each sequence owns
a per-sequence page table — the kernel's BlockSpec index_map dereferences the
scalar-prefetched table (``table[b, j]``), exactly the mechanism
``random_gather`` benchmarks (r_acc over page-sized units: the advisor's
"unit_bytes: row width >= 512B" guidance is why pages are >= 16 tokens).

Three serving-path extensions share the one kernel body:

- ``softcap`` — gemma2-style logit soft-capping applied to the raw scores
  before masking (mirrors the dense kernels' ``attn_logit_softcap``).
- ``window`` — *ring* tables for sliding-window layers: the table holds
  ``ring_slots = ceil(window/page)+1`` rotating slots and the kernel
  recovers each slot's absolute positions from ``valid_len`` alone
  (slot ``j`` holds logical page ``L_j = cur_L - ((cur_L - j) mod R)``),
  masking both the causal bound and the window's trailing edge — stale
  tokens left from a rotated-out page land on "future" positions and mask
  away for free.
- ``k_scale``/``v_scale`` — int8 KV pages carry a per-token fp32 scale lane
  per page ``(P, page)``; dequantization is fused into the score/value
  loads, so the HBM stream stays at the paper's halved unit size.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(table_ref, vlen_ref, q_ref, kp_ref, vp_ref, *rest,
            scale: float, page: int, n_pages: int, hkv: int,
            softcap: Optional[float], window: Optional[int], quant: bool):
    if quant:
        ks_ref, vs_ref = rest[0], rest[1]
        o_ref, m_ref, l_ref, acc_ref = rest[2:]
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bh = pl.program_id(0)
    b = bh // hkv
    valid = vlen_ref[b]

    q = q_ref[0].astype(jnp.float32) * scale                 # (g, d)
    k = kp_ref[0].astype(jnp.float32)                        # (page, d)
    v = vp_ref[0].astype(jnp.float32)
    if quant:
        k = k * ks_ref[0][:, None]
        v = v * vs_ref[0][:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, page)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    if window is None:
        base = j * page
    else:
        # ring slot j currently holds logical page L_j = the largest
        # L <= cur_L with L % ring_slots == j (negative L => not yet live)
        cur_l = (valid - 1) // page
        delta = jax.lax.rem(cur_l - j, n_pages)
        delta = jnp.where(delta < 0, delta + n_pages, delta)
        base = (cur_l - delta) * page
    pos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    msk = (pos < valid) & (pos >= 0)
    if window is not None:
        msk &= pos > valid - 1 - window
    s = jnp.where(msk, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    # mask p explicitly: a fully-masked page visited while m is still at its
    # NEG_INF init (a rotated-out ring slot) must contribute exactly zero
    p = jnp.where(msk, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "softcap", "window",
                                             "interpret", "plan"))
def paged_attention(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                    page_table: jax.Array, valid_len: jax.Array, *,
                    scale: Optional[float] = None,
                    softcap: Optional[float] = None,
                    window: Optional[int] = None,
                    k_scale: Optional[jax.Array] = None,
                    v_scale: Optional[jax.Array] = None,
                    interpret: Optional[bool] = None,
                    plan=None) -> jax.Array:
    """q: (B, Hq, D); k/v_pages: (P, page, Hkv, D); page_table: (B, N) int32
    (pool page id per logical page; unused entries may be any valid id —
    they are masked by valid_len); valid_len: (B,) -> (B, Hq, D).

    ``window`` switches the table to *ring* semantics (N = ring slots,
    positions derived from valid_len; see module docstring).  ``k_scale``/
    ``v_scale`` (P, page) fp32 dequantize int8 pages in-kernel.

    ``plan`` (a :class:`repro.tune.KernelPlan`, hashable => static) carries
    the tuned backend choice; unlike flash/decode it cannot re-block the
    kernel here — ``plan.page_size`` shaped the pool this call receives, so
    the block IS the page and the kernel asserts the two agree.
    ``interpret=None`` resolves plan-first, then the shared auto heuristic."""
    if plan is not None and k_pages.shape[1] != plan.page_size:
        raise ValueError(
            f"pool page size {k_pages.shape[1]} != plan.page_size "
            f"{plan.page_size}: the pool must be laid out from the plan")
    if (k_scale is None) != (v_scale is None):
        raise ValueError("k_scale and v_scale must be passed together")
    if interpret is None:
        if plan is not None:
            interpret = plan.resolve_interpret()
        else:
            from repro.tune import auto_interpret
            interpret = auto_interpret()
    b, hq, d = q.shape
    pool, page, hkv, _ = k_pages.shape
    _, n_pages = page_table.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    quant = k_scale is not None

    qf = q.reshape(b * hkv, g, d)
    # flatten pages per kv head: (P*Hkv, page, d)
    kf = jnp.swapaxes(k_pages, 1, 2).reshape(pool * hkv, page, d)
    vf = jnp.swapaxes(v_pages, 1, 2).reshape(pool * hkv, page, d)

    def page_map(bh, j, table_ref, vlen_ref, hkv=hkv):
        b_ = bh // hkv
        h_ = bh % hkv
        return (table_ref[b_, j] * hkv + h_, 0, 0)

    def scale_map(bh, j, table_ref, vlen_ref, hkv=hkv):
        return (table_ref[bh // hkv, j], 0)

    in_specs = [
        pl.BlockSpec((1, g, d), lambda bh, j, t, vl: (bh, 0, 0)),
        pl.BlockSpec((1, page, d),
                     lambda bh, j, t, vl: page_map(bh, j, t, vl)),
        pl.BlockSpec((1, page, d),
                     lambda bh, j, t, vl: page_map(bh, j, t, vl)),
    ]
    args = [qf, kf, vf]
    if quant:
        in_specs += [
            pl.BlockSpec((1, page),
                         lambda bh, j, t, vl: scale_map(bh, j, t, vl)),
            pl.BlockSpec((1, page),
                         lambda bh, j, t, vl: scale_map(bh, j, t, vl)),
        ]
        args += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * hkv, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, g, d), lambda bh, j, t, vl: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, page=page, n_pages=n_pages,
                          hkv=hkv, softcap=softcap, window=window,
                          quant=quant),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, d), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), valid_len.astype(jnp.int32), *args)
    return out.reshape(b, hq, d)

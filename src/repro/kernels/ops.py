"""Jit'd dispatching wrappers: Pallas on TPU, interpret-mode Pallas or the jnp
oracle elsewhere.  Models call these; benchmarks call the engines directly."""
from __future__ import annotations

from typing import Optional

from repro.kernels import decode_attention as _da
from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import matmul as _mm
from repro.kernels import pointer_chase as _pc
from repro.kernels import random_gather as _rg
from repro.kernels import ref
from repro.kernels import stream_copy as _sc
from repro.kernels import strided_copy as _st


def on_tpu() -> bool:
    from repro.tune import auto_interpret
    return not auto_interpret()  # the one backend heuristic (repro.tune)


def _interp(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    from repro.tune import auto_interpret
    return auto_interpret()


def stream_copy(x, *, block_rows=256, block_cols=0, mode="copy", interpret=None):
    return _sc.stream_copy(x, block_rows=block_rows, block_cols=block_cols,
                           mode=mode, interpret=_interp(interpret))


def strided_copy(x, *, block_rows=8, stride=1, interpret=None):
    return _st.strided_copy(x, block_rows=block_rows, stride=stride,
                            interpret=_interp(interpret))


def random_gather(x, idx, *, interpret=None):
    return _rg.random_gather(x, idx, interpret=_interp(interpret))


def lfsr_indices(n, *, bits=24, seed=0xACE1):
    return _rg.lfsr_indices(n, bits=bits, seed=seed)


def pointer_chase(table, *, steps, interpret=None):
    return _pc.pointer_chase(table, steps=steps, interpret=_interp(interpret))


def make_chain(n, seed=0):
    return _pc.make_chain(n, seed)


def matmul(x, y, *, bm=None, bn=None, bk=None, interpret=None, plan=None):
    """Tiles default to the cached :class:`repro.tune.KernelPlan`.
    ``interpret`` passes through unresolved so a plan's pinned mode wins."""
    return _mm.matmul(x, y, bm=bm, bn=bn, bk=bk, interpret=interpret,
                      plan=plan)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, bq=None, bkv=None, interpret=None, plan=None):
    """Blocks default to the cached :class:`repro.tune.KernelPlan`.
    ``interpret`` passes through unresolved so a plan's pinned mode wins."""
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        bq=bq, bkv=bkv, interpret=interpret, plan=plan)


def decode_attention(q, k, v, valid_len, *, softcap=None, scale=None,
                     bkv=None, interpret=None, plan=None):
    """Split-KV block defaults to the cached :class:`repro.tune.KernelPlan`.
    ``interpret`` passes through unresolved so a plan's pinned mode wins."""
    return _da.decode_attention(q, k, v, valid_len, softcap=softcap,
                                scale=scale, bkv=bkv, interpret=interpret,
                                plan=plan)


def paged_attention(q, k_pages, v_pages, page_table, valid_len, *,
                    scale=None, softcap=None, window=None, k_scale=None,
                    v_scale=None, interpret=None, plan=None):
    """Page size is pinned by the pool layout (shaped from the plan at
    pool-creation time); ``interpret`` passes through unresolved so a plan's
    pinned mode wins.  ``softcap``/``window``/``k_scale``/``v_scale`` select
    the softcapped, ring-table, and int8-dequant kernel paths."""
    return _pa.paged_attention(q, k_pages, v_pages, page_table, valid_len,
                               scale=scale, softcap=softcap, window=window,
                               k_scale=k_scale, v_scale=v_scale,
                               interpret=interpret, plan=plan)


# re-export oracles for tests/benches
oracle = ref

"""Blockwise online-softmax attention (paper pattern: *nest* — interleaved
multi-cursor sequential traversal, Table 9).

The paper's `nest` row reaches full sequential bandwidth because both cursors
are blocked so the inner stream stays buffered; flash-attention blocking is
exactly that transformation, so this kernel is the paper's technique applied
to the framework's dominant memory consumer.

Grid = (batch*q_heads, q_blocks, kv_blocks); kv is the innermost (sequential)
dimension so the f32 (m, l, acc) scratch carries across kv steps.  Supports
causal masking, sliding windows (gemma2 / recurrentgemma local layers), GQA
head grouping, and attn-logit softcap (gemma2, grok).  Forward only — training
uses the differentiable chunked XLA path in ``repro.models.attention`` (same
math; this kernel is oracle-checked against it).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: Optional[int],
                 softcap: Optional[float], bq: int, bkv: int, n_kv: int):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                   # (bkv, d)
    v = v_ref[0].astype(jnp.float32)                   # (bkv, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_idx = pl.program_id(1)
    q_pos = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_pos = kv_idx * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), dtype=jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                             # (bq, bkv)
    alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(kv_idx == n_kv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bkv", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, scale: Optional[float] = None,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D); Hq % Hkv == 0."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    assert sq % bq == 0 and skv % bkv == 0
    n_kv = skv // bkv

    qf = q.reshape(b * hq, sq, d)
    kf = k.reshape(b * hkv, skv, d)
    vf = v.reshape(b * hkv, skv, d)

    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, scale=scale, causal=causal, window=window,
            softcap=softcap, bq=bq, bkv=bkv, n_kv=n_kv),
        grid=(b * hq, sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j, g=g: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq, d)

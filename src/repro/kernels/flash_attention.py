"""Blockwise online-softmax attention (paper pattern: *nest* — interleaved
multi-cursor sequential traversal, Table 9).

The paper's `nest` row reaches full sequential bandwidth because both cursors
are blocked so the inner stream stays buffered; flash-attention blocking is
exactly that transformation, so this kernel is the paper's technique applied
to the framework's dominant memory consumer.

Block sizes default to the tuned :class:`repro.tune.KernelPlan` for the call
shape (the closed tune->execute loop); ``interpret`` defaults to auto —
compile on a real TPU backend, interpret elsewhere.  Ragged sequence lengths
are padded to the block grid inside the wrapper and masked in-kernel, so odd
prompt lengths never crash the grid arithmetic.

Grid = (batch*q_heads, q_blocks, kv_blocks); kv is the innermost (sequential)
dimension so the f32 (m, l, acc) scratch carries across kv steps.  Supports
causal masking, sliding windows (gemma2 / recurrentgemma local layers), GQA
head grouping, and attn-logit softcap (gemma2, grok).  Forward only — training
uses the differentiable chunked XLA path in ``repro.models.attention`` (same
math; this kernel is oracle-checked against it).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: Optional[int],
                 softcap: Optional[float], bq: int, bkv: int, n_kv: int,
                 kv_len: int):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                   # (bkv, d)
    v = v_ref[0].astype(jnp.float32)                   # (bkv, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bkv)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_idx = pl.program_id(1)
    q_pos = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_pos = kv_idx * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    # ragged pad: kv rows past the true length are grid filler, never attended
    mask = k_pos < kv_len
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                             # (bq, bkv)
    alpha = jnp.exp(m_prev - m_new)                    # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(kv_idx == n_kv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "bq", "bkv", "interpret"))
def _flash_call(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                window: Optional[int], softcap: Optional[float], scale: float,
                bq: int, bkv: int, interpret: bool) -> jax.Array:
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv

    # ragged lengths: pad up to the block grid; the kernel masks k_pos >= skv
    # and the padded q rows are sliced off below
    pad_q = (-sq) % bq
    pad_kv = (-skv) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    sq_p, skv_p = sq + pad_q, skv + pad_kv
    n_kv = skv_p // bkv

    qf = q.reshape(b * hq, sq_p, d)
    kf = k.reshape(b * hkv, skv_p, d)
    vf = v.reshape(b * hkv, skv_p, d)

    out = pl.pallas_call(
        functools.partial(
            _attn_kernel, scale=scale, causal=causal, window=window,
            softcap=softcap, bq=bq, bkv=bkv, n_kv=n_kv, kv_len=skv),
        grid=(b * hq, sq_p // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j, g=g: (h // g, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j, g=g: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq_p, d)[:, :, :sq]


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    bq: Optional[int] = None, bkv: Optional[int] = None,
                    interpret: Optional[bool] = None,
                    plan=None) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Skv, D); Hq % Hkv == 0.

    ``bq``/``bkv``/``interpret`` left as ``None`` resolve from the cached
    :class:`repro.tune.KernelPlan` for ``(Sq, Skv, D, dtype)`` (pass ``plan``
    to supply one explicitly); ``interpret=None`` ultimately auto-detects the
    backend (compile on TPU, interpret elsewhere).
    """
    b, hq, sq, d = q.shape
    skv = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    if bq is None or bkv is None or (plan is not None and interpret is None):
        if plan is None:
            from repro.tune import plan_for
            plan = plan_for("flash_attention", shape_sig=(sq, skv, d),
                            dtype=str(q.dtype))
        bq = bq if bq is not None else plan.bq
        bkv = bkv if bkv is not None else plan.bkv
        if interpret is None:
            interpret = plan.resolve_interpret()
    if interpret is None:
        from repro.tune import auto_interpret
        interpret = auto_interpret()
    bq = max(1, min(bq, sq))
    bkv = max(1, min(bkv, skv))
    return _flash_call(q, k, v, causal=causal, window=window, softcap=softcap,
                       scale=scale, bq=bq, bkv=bkv, interpret=bool(interpret))

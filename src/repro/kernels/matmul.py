"""Tiled MXU matmul (paper pattern: rs_tra — repeated sequential weight
streaming; also the compute-roofline probe).

Classic three-level blocking: grid (M/bm, N/bn, K/bk) with an f32 VMEM
accumulator that persists across the innermost (K) grid dimension.  Block
shapes are the paper's burst knob; MXU wants all of bm/bn/bk to be multiples
of 128 (lane) / 8 (sublane).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = True) -> jax.Array:
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, y.shape, bm, bn, bk)
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)

"""Tiled MXU matmul (paper pattern: rs_tra — repeated sequential weight
streaming; also the compute-roofline probe).

Classic three-level blocking: grid (M/bm, N/bn, K/bk) with an f32 VMEM
accumulator that persists across the innermost (K) grid dimension.  Block
shapes are the paper's burst knob; MXU wants all of bm/bn/bk to be multiples
of 128 (lane) / 8 (sublane).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, y_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _matmul_call(x: jax.Array, y: jax.Array, *, bm: int, bn: int,
                 bk: int, interpret: bool) -> jax.Array:
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, y.shape, bm, bn, bk)
    n_k = k // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)


def matmul(x: jax.Array, y: jax.Array, *, bm: Optional[int] = None,
           bn: Optional[int] = None, bk: Optional[int] = None,
           interpret: Optional[bool] = None, plan=None) -> jax.Array:
    """Tile sizes left as ``None`` resolve from the cached
    :class:`repro.tune.KernelPlan` for ``(M, N, K, dtype)``;
    ``interpret=None`` ultimately auto-detects the backend."""
    m, k = x.shape
    n = y.shape[1]

    def fit(block, dim):
        """plan tiles must divide the actual dim — halve until they do."""
        block = min(block, dim)
        while dim % block:
            block //= 2
        return max(1, block)

    if (bm is None or bn is None or bk is None
            or (plan is not None and interpret is None)):
        if plan is None:
            from repro.tune import plan_for
            plan = plan_for("matmul", shape_sig=(m, n, k), dtype=str(x.dtype))
        bm = bm if bm is not None else fit(plan.bq, m)
        bn = bn if bn is not None else fit(plan.bq, n)
        bk = bk if bk is not None else fit(plan.bq, k)
        if interpret is None:
            interpret = plan.resolve_interpret()
    if interpret is None:
        from repro.tune import auto_interpret
        interpret = auto_interpret()
    return _matmul_call(x, y, bm=min(bm, m), bn=min(bn, n), bk=min(bk, k),
                        interpret=bool(interpret))

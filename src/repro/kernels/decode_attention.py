"""Flash-decode kernel: one-token queries against a long KV cache.

Decode is the paper's pure-bandwidth regime (`rs_tra` over the cache): each
step streams the whole cache once.  The kernel splits the KV stream across
grid steps (split-KV / FlashDecoding style) with an online-softmax scratch
carried across the innermost grid dimension, and masks by a scalar-prefetched
per-batch valid length.  Supports GQA (q heads grouped per kv head).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(vlen_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, softcap: Optional[float], bkv: int, n_kv: int,
            hkv: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bh = pl.program_id(0)
    b = bh // hkv
    valid = vlen_ref[b]

    q = q_ref[0].astype(jnp.float32) * scale          # (g, d)
    k = k_ref[0].astype(jnp.float32)                   # (bkv, d)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (g, bkv)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "scale", "bkv",
                                             "interpret"))
def _decode_call(q: jax.Array, k: jax.Array, v: jax.Array,
                 valid_len: jax.Array, *, softcap: Optional[float],
                 scale: float, bkv: int, interpret: bool) -> jax.Array:
    b, hq, d = q.shape
    _, t, hkv, _ = k.shape
    g = hq // hkv
    # ragged cache lengths: pad to the kv grid; padded rows sit past every
    # per-batch valid_len, so the in-kernel mask already hides them
    pad = (-t) % bkv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        t += pad
    n_kv = t // bkv

    qf = q.reshape(b * hkv, g, d)
    kf = jnp.swapaxes(k, 1, 2).reshape(b * hkv, t, d)
    vf = jnp.swapaxes(v, 1, 2).reshape(b * hkv, t, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hkv, n_kv),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda h, j, vl: (h, 0, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, j, vl: (h, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, j, vl: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda h, j, vl: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, softcap=softcap, bkv=bkv,
                          n_kv=n_kv, hkv=hkv),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, g, d), q.dtype),
        interpret=interpret,
    )(valid_len.astype(jnp.int32), qf, kf, vf)
    return out.reshape(b, hq, d)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     valid_len: jax.Array, *, softcap: Optional[float] = None,
                     scale: Optional[float] = None, bkv: Optional[int] = None,
                     interpret: Optional[bool] = None, plan=None) -> jax.Array:
    """q: (B, Hq, D); k/v: (B, T, Hkv, D); valid_len: (B,) int32 -> (B, Hq, D).

    ``bkv``/``interpret`` left as ``None`` resolve from the cached
    :class:`repro.tune.KernelPlan` for ``(T, D, dtype)`` (split-KV block =
    tuned rs_tra burst / row width); ``interpret=None`` ultimately
    auto-detects the backend.
    """
    d = q.shape[-1]
    t = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    if bkv is None or (plan is not None and interpret is None):
        if plan is None:
            from repro.tune import plan_for
            plan = plan_for("decode_attention", shape_sig=(t, d),
                            dtype=str(k.dtype))
        bkv = bkv if bkv is not None else plan.bkv
        if interpret is None:
            interpret = plan.resolve_interpret()
    if interpret is None:
        from repro.tune import auto_interpret
        interpret = auto_interpret()
    bkv = max(1, min(bkv, t))
    return _decode_call(q, k, v, valid_len, softcap=softcap, scale=scale,
                        bkv=bkv, interpret=bool(interpret))

"""Pallas TPU kernels: the paper's two benchmark engines (stream / strided /
random-gather / pointer-chase) + the perf-critical compute kernels the
framework itself uses (tiled matmul, flash attention = the paper's `nest`
pattern blocked).  Every kernel has a jnp oracle in ref.py and is validated
with interpret=True on CPU."""
from repro.kernels import ops, ref  # noqa: F401

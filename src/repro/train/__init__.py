from repro.train.checkpoint import CheckpointManager  # noqa: F401
from repro.train.fault import (FailureInjector, PreemptionError,  # noqa: F401
                               StragglerMonitor, run_with_recovery)
from repro.train.loop import TrainConfig, Trainer, quick_train  # noqa: F401

"""Sharded checkpointing: per-leaf .npy under an atomically-renamed step dir.

Layout:
  <dir>/step_000042.tmp/...   (written)
  <dir>/step_000042/          (atomic rename on completion)
    MANIFEST.json             {step, keys, shapes, dtypes}
    <flat-key>.npy            one file per pytree leaf (per host in multihost)

Features: async save thread, keep-last-k GC, restore with *resharding*
(device_put against any target sharding tree — this is the elastic-scaling
path: a checkpoint written on one mesh restores onto another).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
                       for p in path)
        flat[key] = leaf
    return flat


def _unflatten_into(tree_like, flat: dict):
    leaves_paths = jax.tree_util.tree_flatten_with_path(tree_like)
    vals = []
    for path, leaf in leaves_paths[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p))))
                       for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        vals.append(flat[key])
    return jax.tree_util.tree_unflatten(leaves_paths[1], vals)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: Optional[bool] = None):
        """Snapshot to host memory synchronously, write to disk (async by
        default), atomic-rename, GC old steps."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()  # one in-flight save at a time

        def write():
            tmp = self._step_dir(step) + ".tmp"
            final = self._step_dir(step)
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            flat = _flatten(host_tree)
            manifest = dict(step=step, keys=sorted(flat))
            for key, arr in flat.items():
                fn = key.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fn), arr)
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        blocking = (not self.async_save) if blocking is None else blocking
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, step: Optional[int], tree_like: Any,
                shardings: Any = None) -> Any:
        """Restore into the structure of ``tree_like``; if ``shardings`` is
        given, device_put each leaf (works across mesh changes = elastic)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        flat = {}
        for key in manifest["keys"]:
            fn = key.replace("/", "__") + ".npy"
            flat[key] = np.load(os.path.join(d, fn))
        tree = _unflatten_into(tree_like, flat)
        if shardings is not None:
            flat_t, treedef = jax.tree.flatten(tree)
            flat_s = treedef.flatten_up_to(shardings)
            tree = jax.tree.unflatten(
                treedef,
                [jax.device_put(t, s) for t, s in zip(flat_t, flat_s)])
        return tree

"""Fault tolerance: retry-with-restore, straggler detection, elastic remesh.

Single-host simulation of the mechanisms a 1000-node run needs; every policy
here is pure control-plane logic over the checkpoint manager and step timer,
so it is mesh-size independent.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

log = logging.getLogger("repro.fault")


class PreemptionError(RuntimeError):
    """Raised by tests / injected hooks to simulate a node loss."""


@dataclass
class StragglerMonitor:
    """Flags steps slower than ``threshold`` x rolling median.

    On real pods the mitigation is to exclude/replace the slow host and
    re-shard (elastic path); here the hook is called so policies are
    testable."""

    window: int = 32
    threshold: float = 3.0
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    times: List[float] = field(default_factory=list)
    flagged: List[int] = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        hist = self.times[-self.window:]
        med = sorted(hist)[len(hist) // 2]
        if len(hist) >= 5 and seconds > self.threshold * med:
            self.flagged.append(step)
            log.warning("straggler step %d: %.3fs vs median %.3fs", step,
                        seconds, med)
            if self.on_straggler:
                self.on_straggler(step, seconds, med)
            return True
        return False


@dataclass
class FailureInjector:
    """Deterministic failure injection for tests: raise at given steps."""

    fail_at: tuple = ()
    seen: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.seen:
            self.seen.add(step)
            raise PreemptionError(f"injected preemption at step {step}")


def run_with_recovery(run_fn: Callable[[Optional[int]], int],
                      max_failures: int = 3) -> int:
    """``run_fn(resume_step)`` runs until completion or raises.  On failure we
    restart from the latest checkpoint (run_fn re-reads it).  Returns the
    final step."""
    failures = 0
    resume: Optional[int] = None
    while True:
        try:
            return run_fn(resume)
        except PreemptionError as e:   # noqa: PERF203
            failures += 1
            log.warning("recovering from failure %d: %s", failures, e)
            if failures > max_failures:
                raise
            resume = -1  # sentinel: restore latest
            time.sleep(0.01)

"""Training loop: sharded step + data pipeline + checkpoint/restart +
straggler monitoring, with exact resume (deterministic data keyed by step).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeCell
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist import sharding as sh
from repro.dist.steps import make_train_step
from repro.models.registry import ModelBundle, build
from repro.optim import adamw
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import FailureInjector, StragglerMonitor

log = logging.getLogger("repro.train")


@dataclass
class TrainConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    seed: int = 0
    data_kind: str = "uniform"     # uniform | markov
    microbatches: int = 1


class Trainer:
    def __init__(self, bundle: ModelBundle, cell: ShapeCell, mesh,
                 policy: sh.ShardingPolicy, opt_cfg: adamw.AdamWConfig,
                 tcfg: TrainConfig,
                 injector: Optional[FailureInjector] = None):
        self.bundle = bundle
        self.cell = cell
        self.mesh = mesh
        self.policy = policy
        self.opt_cfg = opt_cfg
        self.tcfg = tcfg
        self.injector = injector
        (self.step_fn, self.p_shard, self.o_shard,
         self.batch_sharder) = make_train_step(
            bundle, mesh, policy, opt_cfg, microbatches=tcfg.microbatches)
        self.data = SyntheticLM(
            bundle.cfg, cell, DataConfig(seed=tcfg.seed, kind=tcfg.data_kind))
        self.ckpt = (CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
                     if tcfg.ckpt_dir else None)
        self.monitor = StragglerMonitor()
        self.history: list = []

    # ------------------------------------------------------------------
    @staticmethod
    def _put_tree(tree, shardings):
        flat, treedef = jax.tree.flatten(tree)
        flat_s = treedef.flatten_up_to(shardings)
        return jax.tree.unflatten(
            treedef, [jax.device_put(x, s) for x, s in zip(flat, flat_s)])

    def init_state(self, key=None):
        key = jax.random.PRNGKey(self.tcfg.seed) if key is None else key
        with jax.set_mesh(self.mesh):
            params = self._put_tree(self.bundle.init(key), self.p_shard)
            opt_state = self._put_tree(adamw.init(params), self.o_shard)
        return params, opt_state, 0

    def restore_state(self, step: Optional[int] = None):
        abs_params, _ = self.bundle.abstract_params()
        opt_abs = jax.eval_shape(adamw.init, abs_params)
        tree_like = dict(params=abs_params, opt=opt_abs)
        shardings = dict(params=self.p_shard, opt=self.o_shard)
        restored = self.ckpt.restore(step, tree_like, shardings)
        start = int(np.asarray(restored["opt"].step))
        return restored["params"], restored["opt"], start

    # ------------------------------------------------------------------
    def run(self, resume: Optional[int] = None) -> int:
        if resume is not None and self.ckpt and self.ckpt.latest_step() is not None:
            params, opt_state, start = self.restore_state(
                None if resume == -1 else resume)
            log.info("restored at step %d", start)
        else:
            params, opt_state, start = self.init_state()
        it = self.data.iterate(start)
        step = start
        for batch in it:
            if step >= self.tcfg.steps:
                break
            if self.injector:
                self.injector.maybe_fail(step)
            t0 = time.perf_counter()
            batch = self._put(batch)
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.monitor.record(step, dt)
            step += 1
            if step % self.tcfg.log_every == 0 or step == self.tcfg.steps:
                m = {k: float(np.asarray(v)) for k, v in metrics.items()}
                m.update(step=step, sec=dt, tok_s=self.cell.tokens / dt)
                self.history.append(m)
                log.info("step %d loss %.4f (%.3fs)", step, m["loss"], dt)
            if self.ckpt and (step % self.tcfg.ckpt_every == 0
                              or step == self.tcfg.steps):
                self.ckpt.save(step, dict(params=params, opt=opt_state))
        if self.ckpt:
            self.ckpt.wait()
        self._final = (params, opt_state)
        return step

    def _put(self, batch):
        abs_b = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch)
        shard = self.batch_sharder(abs_b)
        flat_b, treedef = jax.tree.flatten(batch)
        flat_s = treedef.flatten_up_to(shard)
        return jax.tree.unflatten(
            treedef, [jax.device_put(b, s) for b, s in zip(flat_b, flat_s)])


def quick_train(cfg: ModelConfig, cell: ShapeCell, mesh, steps: int = 5,
                policy_name: str = "fsdp_tp", flags=None, **tkw):
    """Convenience wrapper used by examples/tests."""
    from repro.models.transformer import RuntimeFlags
    bundle = build(cfg, flags or RuntimeFlags())
    policy = sh.POLICIES[policy_name]
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    tcfg = TrainConfig(steps=steps, **tkw)
    tr = Trainer(bundle, cell, mesh, policy, opt_cfg, tcfg)
    tr.run()
    return tr

"""Knob search driven by the analytic memory model (paper §5 applied).

Given a pattern + hardware spec + VMEM budget, pick the Pallas/BlockSpec
parameters the model predicts best — the machine version of the paper's
"choose the right optimization level that meets throughput while consuming
as few resources as possible".
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Iterable, List, Optional, Tuple

from repro.core.memmodel import TPUSpec, V5E, min_outstanding_for_peak, predict_bw, vmem_ok
from repro.core.patterns import Knobs, Pattern


@dataclass(frozen=True)
class TunedResult:
    knobs: Knobs
    predicted_gbps: float
    vmem_bytes: int
    note: str = ""
    # best predicted bandwidth over the whole feasible set (GB/s) — the
    # chosen knobs are within 2% of this; monotone in the VMEM budget
    best_gbps: float = 0.0
    # measured/predicted ratio for this pattern when tuned under a
    # calibration (repro.bench.calibrate); None in purely analytic mode
    measured_vs_predicted: Optional[float] = None


def tune_pattern(pattern: Pattern, spec: TPUSpec = V5E,
                 vmem_budget_fraction: float = 0.5,
                 unit_candidates: Iterable[int] = (256, 512, 1024, 2048, 4096),
                 burst_candidates: Iterable[int] = tuple(
                     2 ** i for i in range(12, 23)),
                 outstanding_candidates: Iterable[int] = (1, 2, 3, 4, 8, 16, 32),
                 calibration=None,
                 ) -> TunedResult:
    """Smallest-resource knobs within 2% of the best predicted bandwidth
    (the paper's resource-throughput tradeoff, Tables 3-5).

    ``calibration`` (a :class:`repro.bench.calibrate.CalibrationResult`)
    switches to measured mode: the search runs against the *fitted* spec —
    the constants observed on this host — and the result carries the
    pattern's measured/predicted ratio so callers can de-rate analytic
    expectations.
    """
    if calibration is not None:
        spec = calibration.spec
    best: List[Tuple[float, int, Knobs]] = []
    for u in unit_candidates:
        for b in burst_candidates:
            if b < u:
                continue
            for no in outstanding_candidates:
                k = Knobs(unit_bytes=u, burst_bytes=b, outstanding=no)
                if not vmem_ok(k, spec, vmem_budget_fraction):
                    continue
                bw = predict_bw(pattern, k, spec)
                best.append((bw, k.vmem_bytes(), k))
    if not best:
        raise ValueError("no feasible knobs under the VMEM budget")
    top_bw = max(b[0] for b in best)
    feasible = [b for b in best if b[0] >= 0.98 * top_bw]
    bw, vmem, knobs = min(feasible, key=lambda t: t[1])
    ratio = (calibration.measured_vs_predicted(pattern)
             if calibration is not None else None)
    return TunedResult(knobs=knobs, predicted_gbps=bw / 1e9, vmem_bytes=vmem,
                       note=f"NO*={min_outstanding_for_peak(knobs.burst_bytes, spec)}",
                       best_gbps=top_bw / 1e9, measured_vs_predicted=ratio)


def tune_attention_blocks(head_dim: int, kv_heads_per_device: int = 1,
                          dtype_bytes: int = 2, spec: TPUSpec = V5E,
                          vmem_budget_fraction: float = 0.4,
                          candidates=(128, 256, 512, 1024, 2048, 4096),
                          ) -> Tuple[int, int]:
    """(bq, bkv) for the nest/flash tiling: maximize the kv burst under the
    VMEM budget; q tile secondary (it is re-used across the whole kv stream).
    VMEM per program ~= (bq*(d+4) + 2*bkv*d*NO) * bytes, NO=2."""
    budget = spec.vmem_bytes * vmem_budget_fraction
    best = (128, 128)
    best_score = -1.0
    for bq in candidates:
        for bkv in candidates:
            vmem = (bq * (head_dim + 4) * 4          # fp32 q + m/l/acc rows
                    + 2 * bkv * head_dim * dtype_bytes * 2)
            if vmem > budget:
                continue
            k = Knobs(unit_bytes=head_dim * dtype_bytes,
                      burst_bytes=bkv * head_dim * dtype_bytes, outstanding=2)
            score = predict_bw(Pattern.NEST, k, spec) * min(bq, bkv)
            if score > best_score:
                best_score, best = score, (bq, bkv)
    return best


def tune_ssd_chunk(d_inner: int, nheads: int, head_dim: int, dstate: int,
                   candidates=(64, 128, 256, 512)) -> int:
    """Chunk Q balancing intra-chunk (Q*H bytes/token) vs inter-chunk state
    (H*P*N/Q bytes/token): optimum near sqrt(P*N)."""
    target = (head_dim * dstate) ** 0.5
    return min(candidates, key=lambda q: abs(q - target))

"""Access-pattern classification + per-site optimization advice (paper §5/§6).

Two entry points:

- :func:`advise_model` — analytic: walks a ModelConfig x ShapeCell and emits a
  SiteReport per memory-significant structure (embedding gather = r_acc,
  attention = nest, weight streaming = rs_tra, MoE routing = expert-level
  r_acc, recurrent state = VMEM-resident), each with bytes and the paper's
  optimization direction.
- :func:`classify_hlo` — empirical: op-histogram over a lowered/compiled HLO
  text, mapping gathers/scatters/dots/whiles/collectives onto the taxonomy.
  Used to sanity-check that the compiled artifact exhibits the predicted mix.
"""
from __future__ import annotations

import re
from functools import lru_cache
from typing import Dict, List, Optional

from repro.configs.base import ATTN, DECODE, MOE, RGLRU, SSD, ModelConfig, ShapeCell
from repro.core.memmodel import TPUSpec, V5E
from repro.core.patterns import ADVICE, Pattern, SiteReport


@lru_cache(maxsize=None)
def _tuned_gbps(pattern: Pattern, spec: TPUSpec) -> float:
    """Model-predicted tuned bandwidth for a pattern under ``spec`` (GB/s).
    Cached — TPUSpec is frozen/hashable and the knob search is pure."""
    from repro.core.autotune import tune_pattern
    return tune_pattern(pattern, spec).predicted_gbps


def advise_model(cfg: ModelConfig, cell: ShapeCell, engines: int = 1,
                 param_engines: int = None, spec: TPUSpec = V5E,
                 calibration=None) -> List[SiteReport]:
    """``engines`` is the parallel-access-engine count of the active
    sharding policy on its mesh (``ShardingPolicy.engines(mesh)``, paper
    Tables 3-5): traffic is reported *per engine*, i.e. per mesh shard,
    since each shard streams its slice from its own HBM stack.

    Batch-scaled sites (embedding, attention, states, routing) split across
    all ``engines``; the weight stream splits only across ``param_engines``
    (``ShardingPolicy.param_engines(mesh)`` — 1 for pure DP, where params
    replicate and every shard streams the full model).  Defaults to
    ``engines`` when unset.

    ``spec`` grounds each site's ``predicted_gbps`` (tuned-model bandwidth
    for its pattern).  Passing a ``calibration``
    (:class:`repro.bench.calibrate.CalibrationResult`) switches predictions
    to the host-fitted constants and stamps every site with the pattern's
    ``measured_vs_predicted`` ratio — measured mode."""
    reports: List[SiteReport] = []
    dt = 2  # bf16
    tokens = cell.tokens
    d = cfg.d_model
    engines = max(1, engines)
    param_engines = engines if param_engines is None else max(1, param_engines)

    # embedding gather: random row access into the (V, d) table
    reports.append(SiteReport(
        op_name="embedding.lookup", pattern=Pattern.R_ACC,
        bytes_moved=tokens * d * dt, shape=(cfg.vocab_size, d),
        detail=f"row={d*dt}B from a {cfg.vocab_size}-row table; widen row / "
               f"shard vocab so gathers stay local (address-mapping)"))

    total, active = cfg.param_count()
    reports.append(SiteReport(
        op_name="params.stream", pattern=Pattern.RS_TRA,
        bytes_moved=active * dt,
        detail="per-step weight streaming; FSDP all-gather of layer i+1 "
               "overlaps layer i compute (prefetch = outstanding)"))

    for j, lspec in enumerate(cfg.layer_pattern):
        if lspec.mixer == ATTN:
            kv = cell.seq_len if lspec.sliding_window is None else min(
                lspec.sliding_window, cell.seq_len)
            qn = 1 if cell.kind == DECODE else cell.seq_len
            b = cell.global_batch
            bytes_kv = b * kv * cfg.num_kv_heads * cfg.resolved_head_dim * dt * 2
            reports.append(SiteReport(
                op_name=f"attn[p{j}]{'.window' if lspec.sliding_window else ''}",
                pattern=Pattern.NEST, bytes_moved=bytes_kv,
                shape=(qn, kv),
                detail=f"q-cursor {qn} x kv-cursor {kv}; block both cursors "
                       f"(flash tiling) so the kv stream stays VMEM-resident"))
        elif lspec.mixer == SSD:
            h = cfg.ssm_expand * d // cfg.ssm_head_dim
            state = cell.global_batch * h * cfg.ssm_head_dim * cfg.ssm_state * 4
            reports.append(SiteReport(
                op_name=f"ssd[p{j}].state", pattern=Pattern.SEQUENTIAL,
                bytes_moved=state,
                detail=f"constant {state/1e6:.2f}MB state; chunk size trades "
                       f"intra (~Q*H/token) vs inter (~H*P*N/Q/token) traffic"))
        elif lspec.mixer == RGLRU:
            w = cfg.lru_width or d
            reports.append(SiteReport(
                op_name=f"rglru[p{j}].state", pattern=Pattern.SEQUENTIAL,
                bytes_moved=cell.global_batch * w * 4,
                detail="streaming recurrence; associative-scan keeps it "
                       "bandwidth-bound, not latency-bound"))
        if lspec.mlp == MOE:
            reports.append(SiteReport(
                op_name=f"moe[p{j}].route", pattern=Pattern.R_ACC,
                bytes_moved=3 * d * cfg.d_ff * cfg.num_experts_per_tok * dt,
                detail=f"top-{cfg.num_experts_per_tok}/{cfg.num_experts} "
                       f"expert pick; sort-dispatch converts token-level "
                       f"r_acc into per-expert rs_tra (the paper's conversion)"))
    if cell.kind == DECODE:
        reports.append(SiteReport(
            op_name="kv_cache.decode_stream", pattern=Pattern.RS_TRA,
            bytes_moved=sum(r.bytes_moved for r in reports
                            if r.pattern == Pattern.NEST),
            detail="decode re-reads the whole cache per token: pure "
                   "bandwidth; batch tokens to amortize (throughput mode)"))
    if engines > 1 or param_engines > 1:
        for r in reports:
            n = param_engines if r.op_name == "params.stream" else engines
            if n > 1:
                r.bytes_moved = max(1, r.bytes_moved // n)
                r.detail = f"[1/{n} engines] " + r.detail
    eff_spec = calibration.spec if calibration is not None else spec
    for r in reports:
        r.predicted_gbps = _tuned_gbps(r.pattern, eff_spec)
        if calibration is not None:
            r.measured_vs_predicted = calibration.measured_vs_predicted(
                r.pattern)
    return reports


_OPS = {
    "gather(": Pattern.R_ACC,
    "scatter(": Pattern.R_ACC,
    "dynamic-slice(": Pattern.RANDOM,
    "dynamic-update-slice(": Pattern.RANDOM,
}


def classify_hlo(hlo_text: str) -> Dict[str, int]:
    """Histogram of memory-relevant opcodes in an HLO module."""
    counts: Dict[str, int] = {}
    for pat, _ in _OPS.items():
        counts[pat.rstrip("(")] = hlo_text.count(f" {pat}")
    counts["dot"] = len(re.findall(r"\bdot\(", hlo_text))
    counts["while"] = len(re.findall(r"\bwhile\(", hlo_text))
    for c in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute"):
        counts[c] = len(re.findall(rf"\b{c}(?:-start)?\(", hlo_text))
    return counts


def render_report(reports: List[SiteReport]) -> str:
    calibrated = any(r.measured_vs_predicted is not None for r in reports)
    head = "site | pattern | bytes | pred GB/s"
    head += " | meas/pred | direction" if calibrated else " | direction"
    lines = [head]
    for r in reports:
        row = (f"{r.op_name:28s} | {r.pattern.value:10s} | "
               f"{r.bytes_moved/2**20:10.1f}MiB | {r.predicted_gbps:8.1f}")
        if calibrated:
            ratio = ("      n/a" if r.measured_vs_predicted is None
                     else f"{r.measured_vs_predicted:9.3f}")
            row += f" | {ratio}"
        lines.append(row + f" | {r.advice.knob_moves[0]}")
    return "\n".join(lines)

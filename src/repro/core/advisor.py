"""Access-pattern classification + per-site optimization advice (paper §5/§6).

Two entry points:

- :func:`advise_model` — analytic: walks a ModelConfig x ShapeCell and emits a
  SiteReport per memory-significant structure (embedding gather = r_acc,
  attention = nest, weight streaming = rs_tra, MoE routing = expert-level
  r_acc, recurrent state = VMEM-resident), each with bytes and the paper's
  optimization direction.
- :func:`classify_hlo` — empirical: op-histogram over a lowered/compiled HLO
  text, mapping gathers/scatters/dots/whiles/collectives onto the taxonomy.
  Used to sanity-check that the compiled artifact exhibits the predicted mix.
"""
from __future__ import annotations

import re
from typing import Dict, List

from repro.configs.base import ATTN, DECODE, MOE, RGLRU, SSD, ModelConfig, ShapeCell
from repro.core.patterns import ADVICE, Pattern, SiteReport


def advise_model(cfg: ModelConfig, cell: ShapeCell, engines: int = 1,
                 param_engines: int = None) -> List[SiteReport]:
    """``engines`` is the parallel-access-engine count of the active
    sharding policy on its mesh (``ShardingPolicy.engines(mesh)``, paper
    Tables 3-5): traffic is reported *per engine*, i.e. per mesh shard,
    since each shard streams its slice from its own HBM stack.

    Batch-scaled sites (embedding, attention, states, routing) split across
    all ``engines``; the weight stream splits only across ``param_engines``
    (``ShardingPolicy.param_engines(mesh)`` — 1 for pure DP, where params
    replicate and every shard streams the full model).  Defaults to
    ``engines`` when unset."""
    reports: List[SiteReport] = []
    dt = 2  # bf16
    tokens = cell.tokens
    d = cfg.d_model
    engines = max(1, engines)
    param_engines = engines if param_engines is None else max(1, param_engines)

    # embedding gather: random row access into the (V, d) table
    reports.append(SiteReport(
        op_name="embedding.lookup", pattern=Pattern.R_ACC,
        bytes_moved=tokens * d * dt, shape=(cfg.vocab_size, d),
        detail=f"row={d*dt}B from a {cfg.vocab_size}-row table; widen row / "
               f"shard vocab so gathers stay local (address-mapping)"))

    total, active = cfg.param_count()
    reports.append(SiteReport(
        op_name="params.stream", pattern=Pattern.RS_TRA,
        bytes_moved=active * dt,
        detail="per-step weight streaming; FSDP all-gather of layer i+1 "
               "overlaps layer i compute (prefetch = outstanding)"))

    for j, spec in enumerate(cfg.layer_pattern):
        if spec.mixer == ATTN:
            kv = cell.seq_len if spec.sliding_window is None else min(
                spec.sliding_window, cell.seq_len)
            qn = 1 if cell.kind == DECODE else cell.seq_len
            b = cell.global_batch
            bytes_kv = b * kv * cfg.num_kv_heads * cfg.resolved_head_dim * dt * 2
            reports.append(SiteReport(
                op_name=f"attn[p{j}]{'.window' if spec.sliding_window else ''}",
                pattern=Pattern.NEST, bytes_moved=bytes_kv,
                shape=(qn, kv),
                detail=f"q-cursor {qn} x kv-cursor {kv}; block both cursors "
                       f"(flash tiling) so the kv stream stays VMEM-resident"))
        elif spec.mixer == SSD:
            h = cfg.ssm_expand * d // cfg.ssm_head_dim
            state = cell.global_batch * h * cfg.ssm_head_dim * cfg.ssm_state * 4
            reports.append(SiteReport(
                op_name=f"ssd[p{j}].state", pattern=Pattern.SEQUENTIAL,
                bytes_moved=state,
                detail=f"constant {state/1e6:.2f}MB state; chunk size trades "
                       f"intra (~Q*H/token) vs inter (~H*P*N/Q/token) traffic"))
        elif spec.mixer == RGLRU:
            w = cfg.lru_width or d
            reports.append(SiteReport(
                op_name=f"rglru[p{j}].state", pattern=Pattern.SEQUENTIAL,
                bytes_moved=cell.global_batch * w * 4,
                detail="streaming recurrence; associative-scan keeps it "
                       "bandwidth-bound, not latency-bound"))
        if spec.mlp == MOE:
            reports.append(SiteReport(
                op_name=f"moe[p{j}].route", pattern=Pattern.R_ACC,
                bytes_moved=3 * d * cfg.d_ff * cfg.num_experts_per_tok * dt,
                detail=f"top-{cfg.num_experts_per_tok}/{cfg.num_experts} "
                       f"expert pick; sort-dispatch converts token-level "
                       f"r_acc into per-expert rs_tra (the paper's conversion)"))
    if cell.kind == DECODE:
        reports.append(SiteReport(
            op_name="kv_cache.decode_stream", pattern=Pattern.RS_TRA,
            bytes_moved=sum(r.bytes_moved for r in reports
                            if r.pattern == Pattern.NEST),
            detail="decode re-reads the whole cache per token: pure "
                   "bandwidth; batch tokens to amortize (throughput mode)"))
    if engines > 1 or param_engines > 1:
        for r in reports:
            n = param_engines if r.op_name == "params.stream" else engines
            if n > 1:
                r.bytes_moved = max(1, r.bytes_moved // n)
                r.detail = f"[1/{n} engines] " + r.detail
    return reports


_OPS = {
    "gather(": Pattern.R_ACC,
    "scatter(": Pattern.R_ACC,
    "dynamic-slice(": Pattern.RANDOM,
    "dynamic-update-slice(": Pattern.RANDOM,
}


def classify_hlo(hlo_text: str) -> Dict[str, int]:
    """Histogram of memory-relevant opcodes in an HLO module."""
    counts: Dict[str, int] = {}
    for pat, _ in _OPS.items():
        counts[pat.rstrip("(")] = hlo_text.count(f" {pat}")
    counts["dot"] = len(re.findall(r"\bdot\(", hlo_text))
    counts["while"] = len(re.findall(r"\bwhile\(", hlo_text))
    for c in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
              "collective-permute"):
        counts[c] = len(re.findall(rf"\b{c}(?:-start)?\(", hlo_text))
    return counts


def render_report(reports: List[SiteReport]) -> str:
    lines = ["site | pattern | bytes | direction"]
    for r in reports:
        lines.append(
            f"{r.op_name:28s} | {r.pattern.value:10s} | "
            f"{r.bytes_moved/2**20:10.1f}MiB | {r.advice.knob_moves[0]}")
    return "\n".join(lines)

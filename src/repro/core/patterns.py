"""Canonical memory-access patterns and their optimization directions.

This is the paper's §5/§6 taxonomy (rs_tra / rr_tra / r_acc / nest + the
micro-patterns the engines sweep) re-grounded in the TPU memory hierarchy
(HBM -> VMEM -> VREG).  ``core.advisor`` classifies a compiled program's
memory ops into these patterns and emits the per-pattern guidance below;
``core.autotune`` turns the guidance into concrete Pallas/BlockSpec knobs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, Optional, Tuple


class Pattern(str, Enum):
    # micro patterns (engine-level, paper §3/§4)
    SEQUENTIAL = "sequential"      # address-continuous stream (burstable)
    STRIDED = "strided"            # constant stride > contiguous tile
    RANDOM = "random"              # independent random indices (LFSR analogue)
    CHASE = "chase"                # dependent loads (pointer chasing)
    # application patterns (paper §6, database taxonomy)
    RS_TRA = "rs_tra"              # repetitive sequential traversal (weight streaming)
    RR_TRA = "rr_tra"              # repetitive random traversal
    R_ACC = "r_acc"                # random access (embedding / expert gather)
    NEST = "nest"                  # interleaved multi-cursor sequential (attention)


@dataclass(frozen=True)
class Knobs:
    """The paper's optimization parameters, TPU-translated.

    unit_bytes   — transaction width  (dtype bytes x lane-tile width)
    burst_bytes  — contiguous DMA size (BlockSpec block bytes)
    outstanding  — DMAs in flight (pipeline/multiple-buffering depth)
    stride       — inter-tile stride in units of burst_bytes (1 = contiguous)
    engines      — concurrent access engines (grid programs / shards)
    """

    unit_bytes: int = 2 * 128            # bf16 x one 128-lane vector
    burst_bytes: int = 2 * 8 * 128 * 128  # one (8*128)x128 bf16 tile = 256 KiB
    outstanding: int = 2                  # double buffering
    stride: int = 1
    engines: int = 1

    def vmem_bytes(self) -> int:
        """Buffering cost — the paper's BRAM column (Tables 3-5): buffers that
        must be resident = burst x outstanding per engine."""
        return self.burst_bytes * self.outstanding * self.engines


@dataclass(frozen=True)
class Advice:
    """Optimization direction for one pattern (the paper's §5/§6 prose,
    machine-readable)."""

    pattern: Pattern
    summary: str
    knob_moves: Tuple[str, ...]
    expected_bw_fraction: Tuple[float, float]  # (naive, optimized) of HBM peak


ADVICE: Dict[Pattern, Advice] = {
    Pattern.SEQUENTIAL: Advice(
        Pattern.SEQUENTIAL,
        "Stream with maximal contiguous tiles; saturates HBM once "
        "burst*outstanding covers the DMA latency-bandwidth product.",
        ("unit_bytes: widen to >=128 lanes * dtype",
         "burst_bytes: grow until VMEM budget; diminishing past ~1MB",
         "outstanding: 2-3 (double/triple buffer) suffices when bursts are large"),
        (0.6, 0.95),
    ),
    Pattern.STRIDED: Advice(
        Pattern.STRIDED,
        "Throughput collapses ~1/stride once the stride exceeds the tile row; "
        "fold the stride into the tile (transpose/relayout) or widen unit size "
        "to amortize (paper Figs. 6/8/9).",
        ("relayout: make the strided dim minor (stride -> 1)",
         "unit_bytes: widen so each strided touch moves a full tile",
         "outstanding: raise to cover per-touch latency"),
        (0.05, 0.6),
    ),
    Pattern.RANDOM: Advice(
        Pattern.RANDOM,
        "Independent random indices pipeline but defeat bursts: bandwidth = "
        "unit_bytes / latency * outstanding, two orders below sequential "
        "(paper Table 8: 421 -> 5.8 GB/s).",
        ("unit_bytes: the ONLY lever that scales throughput linearly",
         "outstanding: raise until latency-covered (Eq. 4)",
         "sort/bucket indices when semantics allow -> SEQUENTIAL"),
        (0.005, 0.1),
    ),
    Pattern.CHASE: Advice(
        Pattern.CHASE,
        "Dependent loads serialize on full latency; no pipelining possible "
        "(paper Table 8: 0.99 GB/s).  Restructure the data (block the linked "
        "structure) or prefetch speculatively.",
        ("restructure: turn chains into index arrays -> RANDOM",
         "block: store next-pointers with payloads (unit_bytes up)"),
        (0.001, 0.01),
    ),
    Pattern.RS_TRA: Advice(
        Pattern.RS_TRA,
        "Weight streaming: sequential traversal repeated every step; ideal "
        "double-buffered; on multi-chip, FSDP all-gather is the 'burst'.",
        ("burst_bytes: per-layer parameter shard",
         "overlap: prefetch layer i+1 during layer i compute",
         "address-mapping analogue: shard params so gathers are contiguous"),
        (0.5, 0.9),
    ),
    Pattern.RR_TRA: Advice(
        Pattern.RR_TRA,
        "Repeated random traversal (shuffled epochs): randomness amortized by "
        "large unit size (paper: unit-size dominates).",
        ("unit_bytes: page-sized records", "prefetch one epoch ahead"),
        (0.02, 0.3),
    ),
    Pattern.R_ACC: Advice(
        Pattern.R_ACC,
        "Pure random access (embedding rows, MoE expert pick): size the row to "
        "the transaction; one-hot matmul converts gather -> RS_TRA when the "
        "table is small relative to compute.",
        ("unit_bytes: row width >= 512B",
         "outstanding: batch the gathers (vectorized take)",
         "convert: one-hot einsum when table fits the FLOP budget"),
        (0.005, 0.15),
    ),
    Pattern.NEST: Advice(
        Pattern.NEST,
        "Interleaved multi-cursor sequential (attention q-blocks over kv "
        "stream): block both cursors so the inner stream stays VMEM-resident "
        "-- this is exactly flash-attention blocking; the paper's 'nest' row "
        "hits full sequential bandwidth (Table 9: 421 GB/s).",
        ("block: tile q and kv cursors (BlockSpec on both)",
         "burst_bytes: kv tile sized to VMEM minus q/accumulator",
         "outstanding: 2 on the kv stream"),
        (0.3, 0.95),
    ),
}


@dataclass
class SiteReport:
    """One classified load/store site (advisor output)."""

    op_name: str
    pattern: Pattern
    bytes_moved: int
    shape: Tuple[int, ...] = ()
    detail: str = ""
    advice: Optional[Advice] = None
    # model-predicted tuned bandwidth for this pattern (GB/s) under the spec
    # the advisor ran with; 0.0 until the advisor fills it in
    predicted_gbps: float = 0.0
    # measured/predicted ratio for this pattern from a calibration pass
    # (repro.bench.calibrate); None when running purely analytic
    measured_vs_predicted: Optional[float] = None

    def __post_init__(self):
        if self.advice is None:
            self.advice = ADVICE[self.pattern]

"""The paper's two benchmarking engines as host-driven harnesses.

Each engine runs the XLA-compiled reference op (timed — real relative curves
on this host, the paper's qualitative claims) and reports the analytic TPU
projection from ``core.memmodel`` next to it (the absolute numbers a v5e
would see).  The Pallas kernels are the TPU-target implementations of the
same engines; interpret-mode correctness is asserted in tests, and their
BlockSpec parameters are exactly the knobs modeled here.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.memmodel import TPUSpec, V5E, achieved_bw, predict_bw, theoretical_bw
from repro.core.patterns import Knobs, Pattern
from repro.kernels import ops, ref


@dataclass
class Row:
    name: str
    pattern: str
    bytes_moved: float
    wall_s: float
    gbps_measured: float
    gbps_tpu_model: float
    extras: dict = field(default_factory=dict)

    def csv(self) -> str:
        us = self.wall_s * 1e6
        return (f"{self.name},{us:.2f},"
                f"gbps_measured={self.gbps_measured:.3f};"
                f"gbps_tpu_model={self.gbps_tpu_model:.3f};"
                + ";".join(f"{k}={v}" for k, v in self.extras.items()))


def _time(fn, *args, trials: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# Latency engine (paper §3.1)
# ---------------------------------------------------------------------------

def latency_chase(n_entries: int = 1 << 16, steps: int = 1 << 14,
                  seed: int = 0, spec: TPUSpec = V5E) -> Row:
    """Dependent-load chain latency (ns/hop measured; T_l modeled)."""
    table = ops.make_chain(n_entries, seed)
    fn = jax.jit(lambda t: ref.pointer_chase(t, steps))
    wall = _time(fn, table)
    ns_per_hop = wall / steps * 1e9
    unit = 4  # int32 payload
    return Row(
        name=f"chase_n{n_entries}", pattern=Pattern.CHASE.value,
        bytes_moved=steps * unit, wall_s=wall,
        gbps_measured=achieved_bw(steps * unit, wall) / 1e9,
        gbps_tpu_model=predict_bw(Pattern.CHASE, Knobs(unit_bytes=unit)) / 1e9,
        extras=dict(ns_per_hop=f"{ns_per_hop:.1f}",
                    t_l_model_ns=f"{spec.dma_latency_s*1e9:.0f}"))


def latency_by_region(n_regions: int = 8, entries_per_region: int = 1 << 14,
                      steps: int = 1 << 12) -> List[Row]:
    """Per-address-region chase (the paper's per-channel Table 2 analogue)."""
    rows = []
    for r in range(n_regions):
        table = ops.make_chain(entries_per_region, seed=r)
        fn = jax.jit(lambda t: ref.pointer_chase(t, steps))
        wall = _time(fn, table)
        rows.append(Row(
            name=f"region_{r}", pattern=Pattern.CHASE.value,
            bytes_moved=steps * 4, wall_s=wall,
            gbps_measured=achieved_bw(steps * 4, wall) / 1e9,
            gbps_tpu_model=predict_bw(Pattern.CHASE, Knobs(unit_bytes=4)) / 1e9,
            extras=dict(ns_per_hop=f"{wall/steps*1e9:.1f}")))
    return rows


# ---------------------------------------------------------------------------
# Bandwidth engine (paper §3.2/§4.2)
# ---------------------------------------------------------------------------

def bw_sequential(rows: int = 4096, cols: int = 2048, dtype=jnp.float32,
                  mode: str = "copy") -> Row:
    x = jnp.ones((rows, cols), dtype)
    fn = jax.jit(lambda a: ref.stream_copy(a, mode))
    wall = _time(fn, x)
    nbytes = x.size * x.dtype.itemsize * 2  # read + write
    knobs = Knobs(unit_bytes=128 * x.dtype.itemsize,
                  burst_bytes=cols * x.dtype.itemsize * 8)
    return Row(
        name=f"seq_{dtype.__name__ if hasattr(dtype,'__name__') else dtype}_{rows}x{cols}",
        pattern=Pattern.SEQUENTIAL.value, bytes_moved=nbytes, wall_s=wall,
        gbps_measured=achieved_bw(nbytes, wall) / 1e9,
        gbps_tpu_model=predict_bw(Pattern.SEQUENTIAL, knobs) / 1e9,
        extras=dict(theoretical_tpu_gbps=f"{theoretical_bw()/1e9:.0f}"))


def bw_strided(rows: int, cols: int, stride: int, block_rows: int = 8,
               dtype=jnp.float32) -> Row:
    x = jnp.ones((rows, cols), dtype)
    fn = jax.jit(lambda a: ref.strided_copy(a, block_rows=block_rows,
                                            stride=stride))
    wall = _time(fn, x)
    nbytes = x.size * x.dtype.itemsize * 2
    knobs = Knobs(unit_bytes=cols * x.dtype.itemsize * block_rows,
                  stride=stride)
    return Row(
        name=f"stride_{stride}", pattern=Pattern.STRIDED.value,
        bytes_moved=nbytes, wall_s=wall,
        gbps_measured=achieved_bw(nbytes, wall) / 1e9,
        gbps_tpu_model=predict_bw(Pattern.STRIDED, knobs) / 1e9,
        extras=dict(block_rows=block_rows))


def bw_random(n_rows: int = 1 << 15, cols: int = 128, n_idx: int = 1 << 14,
              dtype=jnp.float32, generator: str = "lfsr") -> Row:
    x = jnp.ones((n_rows, cols), dtype)

    def make_idx(seed):
        if generator == "lfsr":
            return ops.lfsr_indices(n_idx, bits=24, seed=0xACE1 + seed) % n_rows
        return jax.random.randint(jax.random.PRNGKey(seed), (n_idx,), 0, n_rows)

    fn = jax.jit(ref.random_gather)
    # fresh indices per trial: re-timing the same gather measures the cached
    # working set, not memory (the paper's page-hit effect on the host)
    jax.block_until_ready(fn(x, make_idx(0)))
    walls = []
    for t in range(1, 4):
        idx = make_idx(t)
        jax.block_until_ready(idx)
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x, idx))
        walls.append(time.perf_counter() - t0)
    wall = min(walls)
    idx = make_idx(1)
    nbytes = n_idx * cols * x.dtype.itemsize * 2
    knobs = Knobs(unit_bytes=cols * x.dtype.itemsize, outstanding=8)
    return Row(
        name=f"random_{generator}_row{cols*x.dtype.itemsize}B",
        pattern=Pattern.RANDOM.value, bytes_moved=nbytes, wall_s=wall,
        gbps_measured=achieved_bw(nbytes, wall) / 1e9,
        gbps_tpu_model=predict_bw(Pattern.RANDOM, knobs) / 1e9)


def bw_unit_size_sweep(units=(4, 16, 64, 256, 1024, 4096)) -> List[Row]:
    """paper Fig. 7: throughput vs transaction width (row bytes)."""
    rows = []
    for u in units:
        cols = max(1, u // 4)
        r = bw_random(n_rows=1 << 13, cols=cols, n_idx=1 << 13,
                      dtype=jnp.float32)
        r.name = f"unit_{u}B"
        r.extras["unit_bytes"] = u
        rows.append(r)
    return rows


def bw_outstanding_sweep(depths=(1, 2, 4, 8, 16, 32, 64)) -> List[Row]:
    """paper Fig. 5: modeled knee at NO* = ceil(T_l * BW / burst); measured
    via chunked async dispatch width on CPU (relative signal only)."""
    out = []
    burst = 64 * 1024
    for no in depths:
        knobs = Knobs(burst_bytes=burst, outstanding=no)
        out.append(Row(
            name=f"outstanding_{no}", pattern=Pattern.SEQUENTIAL.value,
            bytes_moved=0, wall_s=0.0, gbps_measured=float("nan"),
            gbps_tpu_model=predict_bw(Pattern.SEQUENTIAL, knobs) / 1e9,
            extras=dict(vmem_bytes=knobs.vmem_bytes())))
    return out

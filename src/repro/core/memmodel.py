"""Analytic memory-performance model (paper Eqs. 1-6, TPU-translated).

The paper models HBM behaviour under a high-level toolchain with five numbers:
transaction latency ``T_l`` (Eq. 1), loop iteration interval ``tau_II``
(Eqs. 2-4: serialized / pipelined / pipelined-with-NO-outstanding), achieved
bandwidth (Eq. 5) and theoretical bandwidth (Eq. 6).  We keep the same model
and re-ground the constants in TPU v5e hardware; predictions feed the
benchmarks (each bench reports measured + modeled columns) and the autotuner.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core.patterns import Knobs, Pattern


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (tile/page/bucket rounding)."""
    p = 1
    while p < n:
        p *= 2
    return p


@dataclass(frozen=True)
class TPUSpec:
    """Hardware constants (v5e numbers from the assignment brief)."""

    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12       # per chip
    hbm_bw: float = 819e9                 # bytes/s per chip
    ici_bw: float = 50e9                  # bytes/s per link (collective term)
    hbm_bytes: int = 16 * 2**30           # capacity per chip
    vmem_bytes: int = 128 * 2**20         # on-chip buffer budget (BRAM analogue)
    clock_hz: float = 940e6
    # modeled DMA transaction latency (HBM row + controller + DMA setup).
    # The FPGA paper measures 58 cycles idle / ~107 loaded at 300MHz-class
    # clocks; TPU HBM2e+DMA engines land in the same few-hundred-ns regime.
    dma_latency_s: float = 700e-9

    @property
    def dma_latency_cycles(self) -> float:
        return self.dma_latency_s * self.clock_hz


V5E = TPUSpec()

# v5e 2D torus: 4 ICI links/chip; the roofline collective term uses 1 link
# (worst-case single-axis collective) per the assignment formula.
ICI_LINKS_PER_CHIP = 4


# ---------------------------------------------------------------------------
# Paper equations
# ---------------------------------------------------------------------------

def t_l(spec: TPUSpec = V5E) -> float:
    """Eq. 1 — absolute transaction latency (seconds)."""
    return spec.dma_latency_s


def tau_ii_serialized(t_op: float, spec: TPUSpec = V5E) -> float:
    """Eq. 2 — blocked loop: every access waits for the previous access AND
    the dependent op: tau = T_l + T_o."""
    return t_l(spec) + t_op


def tau_ii_pipelined(spec: TPUSpec = V5E) -> float:
    """Eq. 3 — pipelined but dependence on returned data: tau = T_l."""
    return t_l(spec)


def tau_ii_outstanding(outstanding: int, spec: TPUSpec = V5E) -> float:
    """Eq. 4 (corrected steady-state form) — NO requests in flight:
    tau = max(1 cycle, T_l / NO)."""
    return max(1.0 / spec.clock_hz, t_l(spec) / max(1, outstanding))


def achieved_bw(total_bytes: float, wall_s: float) -> float:
    """Eq. 5 — achieved bandwidth from bytes moved and host-timed seconds."""
    return total_bytes / wall_s


def theoretical_bw(spec: TPUSpec = V5E) -> float:
    """Eq. 6 analogue — peak per-chip HBM bandwidth (the N*W*F/8e9 of a TPU
    is its published HBM number; DMA engines, not AXI channels, set N*W)."""
    return spec.hbm_bw


# ---------------------------------------------------------------------------
# Pattern throughput predictions (drives benchmarks + autotuner)
# ---------------------------------------------------------------------------

def predict_bw(pattern: Pattern, knobs: Knobs, spec: TPUSpec = V5E) -> float:
    """Predicted bytes/s for an engine running ``pattern`` with ``knobs``.

    Steady state per tile/touch: t = max(transfer_time, T_l / NO); the chase
    pattern forbids overlap entirely (NO == 1 by construction).
    """
    lat = t_l(spec)
    if pattern in (Pattern.SEQUENTIAL, Pattern.RS_TRA, Pattern.NEST):
        b = knobs.burst_bytes
        t = max(b / spec.hbm_bw, lat / max(1, knobs.outstanding))
        return min(spec.hbm_bw, b / t)
    if pattern == Pattern.STRIDED:
        # each touch moves unit_bytes of useful data but occupies the channel
        # for min(stride, page/unit) * unit worth of row activation; model as
        # useful fraction 1/stride down to the latency floor.
        b = knobs.unit_bytes
        t = max(b * knobs.stride / spec.hbm_bw, lat / max(1, knobs.outstanding))
        return min(spec.hbm_bw / max(1, knobs.stride), b / t)
    if pattern in (Pattern.RANDOM, Pattern.R_ACC, Pattern.RR_TRA):
        b = knobs.unit_bytes
        t = max(b / spec.hbm_bw, lat / max(1, knobs.outstanding))
        return min(spec.hbm_bw, b / t)
    if pattern == Pattern.CHASE:
        return knobs.unit_bytes / lat
    raise ValueError(pattern)


def aggregate_bw(pattern: Pattern, knobs: Knobs, spec: TPUSpec = V5E) -> float:
    """Multi-engine aggregate bytes/s (paper Tables 3-5 scaling).

    The paper scales bandwidth by instantiating parallel access engines over
    banked HBM; the TPU analogue is mesh shards, each streaming from its own
    HBM stack, so the aggregate is linear in the engine count.  The engine
    count should come from the active sharding policy's mesh shape
    (``repro.dist.sharding.ShardingPolicy.engines``), not be hardcoded —
    ``Knobs(engines=policy.engines(mesh))``.
    """
    return predict_bw(pattern, knobs, spec) * max(1, knobs.engines)


def min_outstanding_for_peak(burst_bytes: int, spec: TPUSpec = V5E) -> int:
    """Knee of the paper's Fig. 5: NO* = ceil(T_l * BW / burst)."""
    import math
    return max(1, math.ceil(t_l(spec) * spec.hbm_bw / max(1, burst_bytes)))


def vmem_ok(knobs: Knobs, spec: TPUSpec = V5E, budget_fraction: float = 0.5) -> bool:
    """The paper's BRAM constraint (Tables 3-5): buffering must fit VMEM."""
    return knobs.vmem_bytes() <= spec.vmem_bytes * budget_fraction


# ---------------------------------------------------------------------------
# Roofline terms (assignment formulas)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    chips: int
    model_flops: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bound_s_no_overlap(self) -> float:
        """Conservative serial model: terms sum (no DMA/ICI/MXU overlap)."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """How close the dominant-term time is to the pure-compute ideal for
        the *useful* (MODEL_FLOPS) work: ideal_s / bound (terms overlapped —
        the usual TPU model where DMA, ICI and MXU pipelines run
        concurrently)."""
        if not self.model_flops or not self.bound_s:
            return 0.0
        ideal = self.compute_s * self.useful_flops_ratio  # useful-compute time
        return ideal / self.bound_s

    @property
    def roofline_fraction_no_overlap(self) -> float:
        """Conservative variant: terms serialized (sum)."""
        if not self.model_flops or not self.bound_s_no_overlap:
            return 0.0
        ideal = self.compute_s * self.useful_flops_ratio
        return ideal / self.bound_s_no_overlap


def roofline(hlo_flops: float, hlo_bytes: float, collective_bytes: float,
             chips: int, model_flops: float = 0.0,
             spec: TPUSpec = V5E, per_chip: bool = True) -> RooflineTerms:
    """Assignment formulas.  ``per_chip=True`` means the inputs are already
    per-chip quantities (XLA:CPU cost_analysis reports per-device)."""
    scale = 1.0 if per_chip else 1.0 / chips
    return RooflineTerms(
        compute_s=hlo_flops * scale / spec.peak_flops_bf16,
        memory_s=hlo_bytes * scale / spec.hbm_bw,
        collective_s=collective_bytes * scale / spec.ici_bw,
        hlo_flops=hlo_flops * scale,
        hlo_bytes=hlo_bytes * scale,
        collective_bytes=collective_bytes * scale,
        chips=chips,
        model_flops=model_flops * scale,
    )

"""Roofline-term extraction from compiled XLA artifacts.

Sources (assignment formulas):
- ``compiled.cost_analysis()`` -> HLO_FLOPs, HLO_bytes (per-device on XLA:CPU)
- ``compiled.as_text()``       -> collective_bytes: sum of operand sizes over
  all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Caveat handled here: XLA cost analysis counts a ``while`` (lax.scan) body
ONCE.  Full-depth dry-run compiles use scan (that is the deployable artifact
and the memory_analysis source), so for *cost* we compile the same cell in
roofline mode (layers unrolled at nb in {1,2}, inner scans replaced by
DAG-structured equivalents) and extrapolate affinely: cost(nb) = a + b*nb is
exact for repeated blocks (layer compute, per-layer collectives, and the
optimizer update are all affine in block count).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.core.memmodel import RooflineTerms, TPUSpec, V5E, roofline

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one HLO instruction line: "  %name = <ret-type> opcode(<operands>) ..."
_LINE_RE = re.compile(
    r"=\s*(?P<ret>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")

_SHAPE_RE = re.compile(r"(?P<dt>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")


def _shape_bytes(token: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(token):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUPS_RE = re.compile(
    r"replica_groups=(?:\{\{(?P<first>[0-9, ]+)\}|\[(?P<gc>\d+),(?P<gs>\d+)\])")


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return 2
    if m.group("gs"):
        return int(m.group("gs"))
    return len(m.group("first").split(","))


def collective_stats(hlo_text: str) -> Tuple[float, Dict[str, Dict[str, float]]]:
    """Per-device wire bytes for every collective op.

    Operands are not inline-typed in optimized HLO, so bytes derive from the
    RESULT type + the replica-group size G (ring model):
      all-gather         result*(G-1)/G      (receives all other shards)
      reduce-scatter     result*(G-1)        (operand = result*G)
      all-reduce         2*result*(G-1)/G    (RS + AG phases)
      all-to-all         result*(G-1)/G
      collective-permute result
    ``-done`` halves of async pairs are skipped.
    """
    per: Dict[str, Dict[str, float]] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        op = m.group("op")
        rbytes = _shape_bytes(m.group("ret"))
        g = _group_size(line)
        if op == "all-gather":
            wire = rbytes * (g - 1) / g
        elif op == "reduce-scatter":
            wire = rbytes * (g - 1)
        elif op == "all-reduce":
            wire = 2 * rbytes * (g - 1) / g
        elif op == "all-to-all":
            wire = rbytes * (g - 1) / g
        else:  # collective-permute
            wire = rbytes
        d = per.setdefault(op, dict(count=0, bytes=0.0))
        d["count"] += 1
        d["bytes"] += wire
        total += wire
    return total, per


# ---------------------------------------------------------------------------
# Fusion-aware HBM byte estimate
# ---------------------------------------------------------------------------

# ops whose operands+outputs are genuine HBM traffic on TPU
_COUNTED_OPS = {
    "dot", "convolution", "fusion", "custom-call",
    "gather", "scatter", "dynamic-slice", "dynamic-update-slice",
    "copy", "transpose", "concatenate", "pad", "slice", "reverse",
    "reduce", "reduce-window", "sort", "select-and-scatter", "cholesky",
    "triangular-solve", "rng", "rng-bit-generator",
}
# pointwise/free ops assumed fused into neighbours (TPU fusion model)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<ret>\([^)]*\)|\S+?)\s+(?P<op>[\w\-]+)\((?P<args>[^)]*)\)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(")


def fused_bytes(hlo_text: str) -> float:
    return fused_bytes_detail(hlo_text)[0]


_META_SCOPE_RE = re.compile(r'op_name="([^"]*)"')


def fused_bytes_detail(hlo_text: str, scopes: Tuple[str, ...] = ("flash_inner",)
                       ) -> Tuple[float, Dict[str, float]]:
    """TPU-fusion-model HBM bytes: sum operand+output bytes over data-moving
    ops (dots, fusions, gathers, copies, reduces...), skipping pointwise ops
    (they fuse) and fusion/reducer *bodies* (their traffic is the call's).
    ``while`` bodies count once — same convention as cost_analysis FLOPs.

    Returns (total, {scope: bytes}) where bytes whose op_name metadata
    contains a scope keyword are attributed to it — used to quantify how much
    of the traffic a Pallas kernel would keep VMEM-resident."""
    # split into computations (header: "... (params) -> ret {")
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped:
            mc = _COMP_HDR_RE.match(stripped)
            if mc:
                cur = mc.group("name")
                comps[cur] = []
                continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        comps[cur].append(line)
    # fusion + reducer bodies are internal; while bodies stay top-level
    internal: set = set()
    for lines in comps.values():
        for line in lines:
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", line):
                internal.add(m.group(1))

    total = 0.0
    by_scope: Dict[str, float] = {s: 0.0 for s in scopes}
    for name, lines in comps.items():
        if name in internal:
            continue
        sizes: Dict[str, int] = {}
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            nm, ret, op, args = m.group("name", "ret", "op", "args")
            rbytes = _shape_bytes(ret)
            sizes[nm] = rbytes
            if op in _COUNTED_OPS:
                ob = 0
                for a in args.split(","):
                    a = a.strip().lstrip("%")
                    ob += sizes.get(a, 0)
                total += rbytes + ob
                sm = _META_SCOPE_RE.search(line)
                if sm:
                    for s in scopes:
                        if s in sm.group(1):
                            by_scope[s] += rbytes + ob
                            break
    return total, by_scope


@dataclass(frozen=True)
class CellCost:
    flops: float
    bytes_raw: float      # cost_analysis "bytes accessed" (no-fusion bound)
    bytes_fused: float    # TPU-fusion-model estimate (memory-term source)
    collective: float
    bytes_flash_inner: float = 0.0  # subset of bytes_fused a Pallas flash
    #                                 kernel keeps VMEM-resident

    def __add__(self, other):
        return CellCost(self.flops + other.flops,
                        self.bytes_raw + other.bytes_raw,
                        self.bytes_fused + other.bytes_fused,
                        self.collective + other.collective,
                        self.bytes_flash_inner + other.bytes_flash_inner)

    def scale(self, k: float) -> "CellCost":
        return CellCost(self.flops * k, self.bytes_raw * k,
                        self.bytes_fused * k, self.collective * k,
                        self.bytes_flash_inner * k)


def cost_of(compiled) -> CellCost:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jaxlib returns [dict] per device
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    coll, _ = collective_stats(txt)
    fb, scopes = fused_bytes_detail(txt)
    return CellCost(flops, byts, fb, coll,
                    bytes_flash_inner=scopes.get("flash_inner", 0.0))


def affine_extrapolate(c_a: CellCost, c_b: CellCost, nb_a: int, nb_b: int,
                       nb_target: int) -> CellCost:
    """cost(nb) = base + slope*nb, from two measured points."""
    dn = nb_b - nb_a
    slope = (c_b + c_a.scale(-1)).scale(1.0 / dn)
    base = c_a + slope.scale(-nb_a)
    return base + slope.scale(nb_target)


def terms_from_cost(cost: CellCost, chips: int, model_flops_per_chip: float,
                    spec: TPUSpec = V5E) -> RooflineTerms:
    return roofline(cost.flops, cost.bytes_fused, cost.collective, chips,
                    model_flops=model_flops_per_chip, spec=spec)


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[k] = float(getattr(ma, k, 0.0))
    args = out.get("argument_size_in_bytes", 0.0)
    alias = out.get("alias_size_in_bytes", 0.0)
    out["peak_bytes_per_device"] = (args - alias) + out.get(
        "output_size_in_bytes", 0.0) + out.get("temp_size_in_bytes", 0.0)
    return out

"""The paper's contribution: memory-access-pattern characterization and
optimization for the TPU memory hierarchy (see DESIGN.md §2)."""
from repro.core.memmodel import TPUSpec, V5E, RooflineTerms, roofline  # noqa: F401
from repro.core.patterns import ADVICE, Knobs, Pattern, SiteReport  # noqa: F401
from repro.core import advisor, autotune, engines  # noqa: F401
import repro.core.roofline as roofline_mod  # noqa: F401

"""Serving driver: continuous-batching engine over a small model.

Compares the legacy per-token host loop (window=1, exact-length prefill)
against the PR 3 device-resident fast path (fused decode_many windows +
pow2 prompt bucketing) — the paper's §5 pointer-chase fix applied to our
own scheduler.

    PYTHONPATH=src python examples/serve_lm.py [--requests N] [--batch B]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.models import RuntimeFlags, build
from repro.serve import Request, ServeEngine


def _enqueue(eng, args):
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, eng.bundle.cfg.vocab_size,
                              size=rng.integers(4, 24)).astype(np.int32)
        eng.add_request(Request(rid=i, prompt=prompt,
                                max_new_tokens=args.max_new))


def _drive(bundle, params, args, *, window, bucket, label):
    eng = ServeEngine(bundle, params, batch_size=args.batch, max_len=128,
                      window=window, bucket_prompts=bucket)
    _enqueue(eng, args)
    cold = eng.run_to_completion()     # compiles; reset keeps the traces
    compiles = cold.prefill_retraces
    eng.reset()
    _enqueue(eng, args)
    t0 = time.perf_counter()
    stats = eng.run_to_completion()
    dt = time.perf_counter() - t0
    tpd = stats.decode_steps / max(1, stats.decode_dispatches)
    print(f"  {label:10s} {stats.tokens_out/dt:8.1f} tok/s  "
          f"({stats.tokens_out} tokens in {dt:.2f}s; "
          f"{stats.decode_dispatches} decode dispatches, "
          f"{tpd:.1f} ticks/dispatch, "
          f"{compiles} prefill compiles cold)")
    return stats.tokens_out / dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--window", type=int, default=8,
                    help="fused decode ticks per dispatch (fast path)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV cache (the paper's unit-size lever)")
    args = ap.parse_args()

    cfg = smoke_config(ARCHS[args.arch])
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16,
                         kv_dtype="int8" if args.kv_int8 else "native")
    bundle = build(cfg, flags)
    params = bundle.init(jax.random.PRNGKey(0))

    print(f"=== {args.arch} (batch={args.batch}, "
          f"kv={'int8' if args.kv_int8 else 'native'}) ===")
    base = _drive(bundle, params, args, window=1, bucket=False,
                  label="default")   # one dispatch + host sync per token
    fast = _drive(bundle, params, args, window=args.window,
                  bucket=None,       # auto: on for pure full-attention stacks
                  label="fastpath")
    print(f"  speedup    {fast / base:8.2f}x  "
          f"(tuned decode_many window={args.window} + prompt bucketing)")


if __name__ == "__main__":
    main()

"""Serving driver: continuous-batching engine over a small model.

Compares the legacy per-token host loop (window=1, exact-length prefill)
against the device-resident fast path (fused decode_many windows + pow2
prompt bucketing) — the paper's §5 pointer-chase fix — and, with
``--cache paged`` (the default ``auto`` picks it wherever the stack
supports it), the dense per-slot KV cache against the shared page pool:
chunked prefill, prefix-cached prompt pages, and the ``paged_attention``
kernel dereferencing a device-resident page table (§6 `r_acc`).

Sampling is fused on device (``--temperature/--top-k/--top-p/--seed``;
temperature 0 is exact greedy), and ``--draft self`` (or an arch name)
switches the paged fast path to speculative draft->verify dispatches —
the accept rate prints alongside throughput.

``--priority mixed`` tags alternating requests low/high — the scheduler
admits high first and preempts low under pool pressure — and
``--num-pages N`` undersizes the pool to force it; preemption, host-tier
swap, and resume counters print per engine (the drains stay bitwise
identical to the unpreempted run).

    PYTHONPATH=src python examples/serve_lm.py [--requests N] [--batch B]
                                               [--cache {auto,dense,paged}]
                                               [--temperature T] [--draft self]
                                               [--priority mixed]
                                               [--num-pages N]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.models import RuntimeFlags, build
from repro.serve import Request, SamplingParams, ServeEngine


_PRIORITY_MIX = {"off": lambda i: 0, "low": lambda i: 0,
                 "high": lambda i: 1, "mixed": lambda i: i % 2}


def _enqueue(eng, args):
    rng = np.random.default_rng(0)
    common = rng.integers(0, eng.bundle.cfg.vocab_size,
                          size=16).astype(np.int32)
    for i in range(args.requests):
        tail = rng.integers(0, eng.bundle.cfg.vocab_size,
                            size=rng.integers(4, 24)).astype(np.int32)
        # half the prompts share a prefix: the paged backend's prefix cache
        # serves those tokens from read-only pages
        prompt = np.concatenate([common, tail]) if i % 2 == 0 else tail
        eng.add_request(Request(rid=i, prompt=prompt,
                                max_new_tokens=args.max_new,
                                priority=_PRIORITY_MIX[args.priority](i)))


def _drive(bundle, params, args, *, window, bucket, label, backend=None,
           **kw):
    eng = ServeEngine(bundle, params, batch_size=args.batch, max_len=128,
                      window=window, bucket_prompts=bucket,
                      cache_backend=backend,
                      sampling=SamplingParams(temperature=args.temperature,
                                              top_k=args.top_k,
                                              top_p=args.top_p),
                      seed=args.seed, **kw)
    _enqueue(eng, args)
    cold = eng.run_to_completion()     # compiles; reset keeps the traces
    compiles = cold.prefill_retraces
    eng.reset()
    _enqueue(eng, args)
    t0 = time.perf_counter()
    stats = eng.run_to_completion()
    dt = time.perf_counter() - t0
    tpd = stats.decode_steps / max(1, stats.decode_dispatches)
    extra = ""
    if eng.backend == "paged":
        extra = (f", {stats.prefix_hit_tokens}/{stats.prompt_tokens} "
                 f"prefix-cached prompt tokens")
    if stats.spec_steps:
        extra += (f", {stats.accept_rate:.0%} draft accept rate "
                  f"({stats.draft_accepted}/{stats.draft_tokens} over "
                  f"{stats.spec_steps} verify dispatches)")
    print(f"  {label:10s} {stats.tokens_out/dt:8.1f} tok/s  "
          f"({stats.tokens_out} tokens in {dt:.2f}s; "
          f"{stats.decode_dispatches} decode dispatches, "
          f"{tpd:.1f} ticks/dispatch, "
          f"{compiles} prefill compiles cold{extra})")
    if eng.backend == "paged":
        pages = f" ({eng.stats.pages_peak} pages"
        if eng.stats.ring_pages_peak:
            pages += f" + {eng.stats.ring_pages_peak} ring pages"
        pages += f" of {eng.page} tokens)"
    else:
        pages = " (dense: committed upfront)"
    print(f"  {'':10s} KV HBM: {eng.kv_bytes()/1024:.0f} KiB allocated, "
          f"{eng.live_kv_bytes_peak()/1024:.0f} KiB live-token peak" + pages)
    if args.priority != "off" or stats.preemptions:
        resumes = (f"{stats.swap_ins} swap + {stats.recompute_resumes} "
                   f"recompute resumes")
        if stats.swap_fallbacks:
            resumes += f" ({stats.swap_fallbacks} swap fallbacks)"
        print(f"  {'':10s} scheduler: {stats.preemptions} preemptions "
              f"({stats.preempt_restarts} mid-prefill restarts), "
              f"{stats.swap_outs} swap-outs "
              f"({stats.swap_bytes/1024:.0f} KiB through the host tier), "
              f"{resumes}, {stats.pool_stalls} pool stalls")
    return stats.tokens_out / dt, eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--window", type=int, default=8,
                    help="fused decode ticks per dispatch (fast path)")
    ap.add_argument("--cache", default="auto",
                    choices=("auto", "dense", "paged"),
                    help="KV backend: 'auto' pages pure full-attention "
                         "stacks, dense elsewhere; 'dense'/'paged' pin it")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV cache (the paper's data-width lever; the "
                         "paged backend stores int8 pages + scale lanes and "
                         "derives a proportionally larger page)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax; fused on "
                         "device either way)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest logits (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off)")
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed; per-request streams are "
                         "fold_in(PRNGKey(seed), rid)")
    ap.add_argument("--priority", default="off",
                    choices=sorted(_PRIORITY_MIX),
                    help="scheduler priority classes for the request mix: "
                         "'mixed' alternates low/high (high admits first "
                         "and preempts low under pool pressure), "
                         "'low'/'high' pin one class; preemption/swap "
                         "counters print per engine")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="undersize the paged pool to watch preemption: "
                         "victims' pages are evicted and the request "
                         "resumes via host-tier swap or prefix-cache "
                         "recompute (cost model picks per victim)")
    ap.add_argument("--draft", default=None, metavar="ARCH",
                    help="speculative decoding draft model: 'self' "
                         "(same params — every proposal accepted) or an "
                         "arch name sharing the vocab; requires a pure "
                         "full-attention --cache paged stack")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per verify dispatch")
    args = ap.parse_args()

    cfg = smoke_config(ARCHS[args.arch])
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16,
                         kv_dtype="int8" if args.kv_int8 else "native")
    bundle = build(cfg, flags)
    params = bundle.init(jax.random.PRNGKey(0))
    backend = None if args.cache == "auto" else args.cache

    spec_kw = {}
    if args.draft is not None:
        if args.draft == "self":
            draft_bundle, draft_params = bundle, params
        else:
            draft_bundle = build(smoke_config(ARCHS[args.draft]), flags)
            draft_params = draft_bundle.init(jax.random.PRNGKey(1))
        spec_kw = dict(draft_bundle=draft_bundle, draft_params=draft_params,
                       spec_k=args.spec_k)
        backend = "paged"   # speculative decoding rides the paged fast path

    print(f"=== {args.arch} (batch={args.batch}, cache={args.cache}, "
          f"kv={'int8' if args.kv_int8 else 'native'}, "
          f"T={args.temperature}"
          + (f", draft={args.draft} k={args.spec_k}" if spec_kw else "")
          + ") ===")
    base, _ = _drive(bundle, params, args, window=1, bucket=False,
                     label="default", backend="dense")
    pool_kw = {} if args.num_pages is None else {"num_pages": args.num_pages}
    fast, eng = _drive(bundle, params, args, window=args.window,
                       bucket=None,    # auto: on for full-attention stacks
                       label="fastpath", backend=backend, **spec_kw,
                       **pool_kw)
    print(f"  speedup    {fast / base:8.2f}x  "
          f"(decode_many window={args.window} + prompt bucketing"
          + (" + paged KV pool" if eng.backend == "paged" else "")
          + (" + speculative verify" if spec_kw else "") + ")")


if __name__ == "__main__":
    main()

"""Serving driver: continuous-batching engine over a small model.

    PYTHONPATH=src python examples/serve_lm.py [--requests N] [--batch B]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.models import RuntimeFlags, build
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8 KV cache (the paper's unit-size lever)")
    args = ap.parse_args()

    cfg = smoke_config(ARCHS[args.arch])
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=16, attn_bkv=16,
                         moe_impl="dense", loss_chunk=16,
                         kv_dtype="int8" if args.kv_int8 else "native")
    bundle = build(cfg, flags)
    params = bundle.init(jax.random.PRNGKey(0))
    eng = ServeEngine(bundle, params, batch_size=args.batch, max_len=128)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, 24)).astype(np.int32)
        eng.add_request(Request(rid=i, prompt=prompt,
                                max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    stats = eng.run_to_completion()
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests ({stats.tokens_out} tokens) in "
          f"{dt:.2f}s -> {stats.tokens_out/dt:.1f} tok/s")
    print(f"prefills={stats.prefills} decode_steps={stats.decode_steps} "
          f"(batch={args.batch}, kv={'int8' if args.kv_int8 else 'native'})")


if __name__ == "__main__":
    main()

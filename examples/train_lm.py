"""End-to-end driver: train a ~100M-class LM on learnable synthetic data.

Defaults are sized for a CPU demo (a ~26M 8-layer model, 60 steps, visible
loss decrease vs the log(branching) entropy floor).  ``--full`` trains the
real mamba2-130m (the assigned ~100M arch) — same code path, more compute.

    PYTHONPATH=src python examples/train_lm.py [--steps N] [--full] [--arch X]
"""
import argparse
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import ARCHS, LayerSpec, ModelConfig, ShapeCell, override
from repro.dist import POLICIES
from repro.models import RuntimeFlags, build
from repro.optim import AdamWConfig, schedule
from repro.train import TrainConfig, Trainer

DEMO_100M = ModelConfig(
    name="demo-24m", family="dense", num_layers=8, d_model=512, num_heads=8,
    num_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=1024,
    layer_pattern=(LayerSpec(),), activation="swiglu", tie_embeddings=True,
    param_dtype="float32", compute_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--full", action="store_true",
                    help="train the real mamba2-130m config")
    ap.add_argument("--ckpt", default="/tmp/memroof_train_lm")
    args = ap.parse_args()

    if args.arch:
        cfg = ARCHS[args.arch]
    elif args.full:
        cfg = override(ARCHS["mamba2-130m"], param_dtype="float32",
                       compute_dtype="float32")
    else:
        cfg = DEMO_100M
    total, _ = cfg.param_count()
    print(f"training {cfg.name}: {total/1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")

    cell = ShapeCell("train_demo", "train", args.seq, args.batch)
    flags = RuntimeFlags(attn_impl="chunked", attn_bq=128, attn_bkv=128,
                         loss_chunk=128, moe_impl="dense", remat="none")
    bundle = build(cfg, flags)
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    opt = AdamWConfig(lr=3e-3, weight_decay=0.01,
                      schedule=schedule.warmup_cosine(10, args.steps))
    tcfg = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt,
                       ckpt_every=max(10, args.steps // 3), log_every=5,
                       data_kind="markov")
    tr = Trainer(bundle, cell, mesh, POLICIES["fsdp_tp"], opt, tcfg)
    with jax.set_mesh(mesh):
        tr.run()

    floor = math.log(4)  # markov branching entropy
    first, last = tr.history[0], tr.history[-1]
    print(f"\nloss: {first['loss']:.3f} (step {first['step']}) -> "
          f"{last['loss']:.3f} (step {last['step']}); "
          f"entropy floor ~{floor:.3f}, uniform ~{math.log(cfg.vocab_size):.2f}")
    print(f"throughput: {last['tok_s']:.0f} tok/s on {n_dev} device(s)")
    print(f"checkpoints under {args.ckpt}: resume with the same command")
    assert last["loss"] < first["loss"], "loss did not decrease"


if __name__ == "__main__":
    main()

"""The paper as a feature: classify any (arch x shape) cell's memory access
patterns and print optimization directions + autotuned knobs.

    PYTHONPATH=src python examples/memory_advisor.py --arch grok-1-314b --shape train_4k
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCHS, SHAPES_BY_NAME
from repro.core import advisor
from repro.core.autotune import (tune_attention_blocks, tune_pattern,
                                 tune_ssd_chunk)
from repro.core.memmodel import V5E, predict_bw, theoretical_bw
from repro.core.patterns import ADVICE, Knobs, Pattern


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b", choices=sorted(ARCHS))
    ap.add_argument("--shape", default="prefill_32k",
                    choices=sorted(SHAPES_BY_NAME))
    args = ap.parse_args()
    cfg = ARCHS[args.arch]
    cell = SHAPES_BY_NAME[args.shape]

    print(f"=== memory access pattern report: {cfg.name} x {cell.name} ===")
    reports = advisor.advise_model(cfg, cell)
    print(advisor.render_report(reports))

    print(f"\n=== per-pattern v5e bandwidth model "
          f"(peak {theoretical_bw()/1e9:.0f} GB/s) ===")
    for p in (Pattern.SEQUENTIAL, Pattern.RANDOM, Pattern.CHASE, Pattern.NEST):
        naive, opt = ADVICE[p].expected_bw_fraction
        print(f"  {p.value:12s} naive ~{naive*819:.1f} GB/s -> "
              f"optimized ~{opt*819:.0f} GB/s | {ADVICE[p].summary[:70]}")

    print("\n=== autotuned knobs for this cell ===")
    hd = cfg.resolved_head_dim
    print(f"  attention blocks (hd={hd}):", tune_attention_blocks(hd))
    if cfg.ssm_state:
        print("  ssd chunk:", tune_ssd_chunk(
            cfg.ssm_expand * cfg.d_model,
            cfg.ssm_expand * cfg.d_model // cfg.ssm_head_dim,
            cfg.ssm_head_dim, cfg.ssm_state))
    print("  stream:", tune_pattern(Pattern.SEQUENTIAL))


if __name__ == "__main__":
    main()

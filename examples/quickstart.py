"""Quickstart: the paper's memory engines + advisor, then 5 training steps.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import ARCHS, SHAPES_BY_NAME, ShapeCell, smoke_config
from repro.core import advisor, engines
from repro.core.autotune import tune_attention_blocks, tune_pattern
from repro.core.patterns import Pattern
from repro.dist import POLICIES
from repro.models import RuntimeFlags, build
from repro.optim import AdamWConfig
from repro.train import TrainConfig, Trainer
from repro.tune import default_cache, plan_for


def main():
    print("=== 1. the paper's engines: measured vs modeled (v5e) ===")
    for row in (engines.bw_sequential(rows=1024, cols=512),
                engines.bw_random(n_rows=1 << 12, cols=64, n_idx=1 << 11),
                engines.latency_chase(n_entries=1 << 12, steps=1 << 11)):
        print("  " + row.csv())

    print("\n=== 2. per-pattern optimization directions (paper §5/§6) ===")
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    policy = POLICIES["fsdp_tp"]
    n_eng = policy.engines(mesh)
    print(f"  policy={policy.name}: {n_eng} access engine(s) on this mesh")
    reports = advisor.advise_model(ARCHS["gemma2-27b"],
                                   SHAPES_BY_NAME["prefill_32k"],
                                   engines=n_eng,
                                   param_engines=policy.param_engines(mesh))
    print(advisor.render_report(reports))

    print("\n=== 3. autotuned knobs ===")
    print("  sequential:", tune_pattern(Pattern.SEQUENTIAL))
    print("  attention blocks (hd=128):", tune_attention_blocks(128))

    print("\n=== 3b. the applied KernelPlan for this model (repro.tune) ===")
    big = ARCHS["gemma2-27b"]
    cell = SHAPES_BY_NAME["prefill_32k"]
    plan = plan_for("flash_attention",
                    shape_sig=(cell.seq_len, cell.seq_len,
                               big.resolved_head_dim),
                    dtype=big.compute_dtype)
    print(f"  flash_attention @ {big.name}/{cell.name}: "
          f"bq={plan.bq} bkv={plan.bkv} depth={plan.pipeline_depth} "
          f"dtype={plan.dtype} interpret={plan.resolve_interpret()} "
          f"({plan.predicted_gbps:.0f} GB/s predicted, {plan.source})")
    print(f"  cached in {repr(default_cache().path)} "
          f"— kernels pick this up when called without blocks")

    print("\n=== 4. five training steps of a reduced gemma2 ===")
    cfg = smoke_config(ARCHS["gemma2-27b"])
    bundle = build(cfg, RuntimeFlags(attn_bq=16, attn_bkv=16, moe_impl="dense",
                                     loss_chunk=16))
    tr = Trainer(bundle, ShapeCell("quick", "train", 64, 4), mesh,
                 policy, AdamWConfig(lr=1e-3),
                 TrainConfig(steps=5, log_every=1, data_kind="markov"))
    with jax.set_mesh(mesh):
        tr.run()
    for h in tr.history:
        print(f"  step {h['step']}: loss {h['loss']:.4f} ({h['tok_s']:.0f} tok/s)")


if __name__ == "__main__":
    main()
